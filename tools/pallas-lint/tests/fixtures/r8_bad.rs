//! Known-bad R8 fixture: a numeric config read (`as_int`) whose value never
//! flows through `usize::try_from`/`count()` before use.

pub fn shard_seed(v: &Value) -> Option<i64> {
    let raw = v.as_int()?;
    Some(raw.wrapping_mul(2).wrapping_add(1))
}
