//! Known-good R6 fixture: the hot path writes into preallocated storage;
//! the only allocating fn (`report`) is NOT reachable from `Gp::observe`,
//! which pins the rule's reachability precision.

pub struct Gp {
    buf: Vec<f64>,
    n: usize,
}

impl Gp {
    /// Hot-path root: indexed writes only, no growth.
    pub fn observe(&mut self, x: usize, y: f64) {
        self.buf[x] = y;
        self.n += 1;
        self.refresh(x);
    }

    fn refresh(&mut self, x: usize) {
        self.buf[x] *= 0.5;
    }

    /// Allocates, but is only called from cold reporting code — R6 must
    /// stay silent here.
    pub fn report(&self) -> Vec<f64> {
        self.buf.to_vec()
    }
}
