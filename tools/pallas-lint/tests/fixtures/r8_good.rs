//! Known-good R8 fixture: every `as_int` read is sanctioned — either the
//! value flows through `usize::try_from` in a later statement, or the read
//! happens inside the `count()` validation helper itself.

pub fn shard_count(v: &Value) -> Option<usize> {
    let raw = v.as_int()?;
    usize::try_from(raw).ok()
}

pub fn count(v: &Value, field: &str) -> Option<i64> {
    let raw = v.as_int()?;
    if raw < 0 {
        return None;
    }
    Some(raw)
}
