//! Known-bad R6 fixture: the hot path allocates one call hop away from
//! `Gp::observe`, so the finding must be interprocedural.

pub struct Gp {
    buf: Vec<f64>,
    log: String,
    n: usize,
}

impl Gp {
    /// Hot-path root: statically reachable set starts here.
    pub fn observe(&mut self, x: usize, y: f64) {
        self.n += 1;
        self.record(x, y);
    }

    /// One hop from the root — the `.push()` and `format!` below are the
    /// violations R6 must surface through the call graph.
    fn record(&mut self, x: usize, y: f64) {
        self.buf.push(y);
        let msg = format!("obs arm={x}");
        self.log.push_str(&msg);
    }
}
