//! Known-bad R7 fixture: two-lock cycle. `merge_ab` acquires a → b while
//! `merge_ba` acquires b → a, so the lock-order graph has the cycle
//! pool::a ⇄ pool::b and the linter must flag it.

use std::sync::Mutex;

pub struct Shards {
    a: Mutex<Vec<f64>>,
    b: Mutex<Vec<f64>>,
}

impl Shards {
    pub fn merge_ab(&self) -> f64 {
        let ga = match self.a.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let gb = match self.b.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        ga[0] + gb[0]
    }

    pub fn merge_ba(&self) -> f64 {
        let gb = match self.b.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let ga = match self.a.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        gb[0] - ga[0]
    }
}
