// R1 good: `total_cmp` gives a total order — no NaN panic, identical
// bytes on every platform.
pub fn sort_scores(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

pub fn best(xs: &[f64]) -> Option<f64> {
    xs.iter().copied().max_by(|a, b| a.total_cmp(b))
}
