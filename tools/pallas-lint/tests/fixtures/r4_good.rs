// R4 good: `try_from` rejects negatives instead of wrapping.
pub fn parse_threads(raw: i64) -> Result<usize, String> {
    usize::try_from(raw).map_err(|_| format!("threads must be ≥ 0, got {raw}"))
}

pub fn parse_seeds(raw: i64) -> Result<u64, String> {
    u64::try_from(raw).map_err(|_| format!("seeds must be ≥ 0, got {raw}"))
}
