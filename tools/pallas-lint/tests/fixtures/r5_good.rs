// R5 good: errors propagate; the one deliberate panic site carries a
// justified pragma; test modules may unwrap freely.
pub fn head(v: &[f64]) -> Option<f64> {
    v.first().copied()
}

pub fn head_checked(v: &[f64]) -> Result<f64, String> {
    v.first().copied().ok_or_else(|| "empty input".to_string())
}

pub fn head_invariant(v: &[f64]) -> f64 {
    // pallas-lint: allow(R5) — callers validate non-emptiness upstream (`Problem::validate` asserts it).
    *v.first().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::head(&[1.0]).unwrap(), 1.0);
    }
}
