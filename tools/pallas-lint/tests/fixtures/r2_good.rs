// R2 good: `BTreeMap` iterates in key order — deterministic bytes.
use std::collections::BTreeMap;

pub fn kpi_lines(kpis: &BTreeMap<String, f64>) -> Vec<String> {
    kpis.iter().map(|(k, v)| format!("{k}={v}")).collect()
}
