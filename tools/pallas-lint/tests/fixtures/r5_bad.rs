// R5 bad (library path): panicking accessors and stdout noise in code
// the service depends on.
pub fn head(v: &[f64]) -> f64 {
    println!("inspecting {} values", v.len());
    *v.first().unwrap()
}

pub fn head_or_die(v: &[f64]) -> f64 {
    *v.first().expect("empty input")
}
