//! Known-good R7 fixture: both paths acquire in the same a → b order, one
//! of them through a call hop (`tail` acquires b while the caller holds a),
//! so the lock-order graph has a single edge and stays acyclic.

use std::sync::Mutex;

pub struct Shards {
    a: Mutex<Vec<f64>>,
    b: Mutex<Vec<f64>>,
}

impl Shards {
    pub fn merge(&self) -> f64 {
        let ga = match self.a.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let gb = match self.b.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        ga[0] + gb[0]
    }

    pub fn merge_via_call(&self) -> f64 {
        let ga = match self.a.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        ga[0] + self.tail()
    }

    fn tail(&self) -> f64 {
        let gb = match self.b.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        gb[0]
    }
}
