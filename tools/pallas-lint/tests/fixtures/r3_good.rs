// R3 good: time flows in through the engine's `Clock` abstraction —
// virtual-time runs stay deterministic, wall-time runs plug in `WallClock`.
pub trait Clock {
    fn now(&self) -> f64;
}

pub fn stamp(clock: &dyn Clock) -> f64 {
    clock.now()
}
