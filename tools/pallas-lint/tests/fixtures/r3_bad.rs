// R3 bad (outside `engine/clock.rs`/bench): wall-clock reads leak real
// time into virtual-time code paths.
use std::time::{Instant, SystemTime};

pub fn stamp() -> f64 {
    let t0 = Instant::now();
    std::thread::sleep(std::time::Duration::from_millis(1));
    let _epoch = SystemTime::now();
    t0.elapsed().as_secs_f64()
}
