// R4 bad (under a `config` path): a negative TOML integer wraps through
// `as usize` into an enormous count — the PR-3/PR-5 bug class.
pub fn parse_threads(raw: i64) -> usize {
    raw as usize
}

pub fn parse_seeds(raw: i64) -> u64 {
    raw as u64
}
