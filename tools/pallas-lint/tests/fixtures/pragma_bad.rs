// Pragma bad: an `allow` without a written justification suppresses
// nothing and is itself a finding.
pub fn head(v: &[f64]) -> f64 {
    // pallas-lint: allow(R5)
    *v.first().unwrap()
}
