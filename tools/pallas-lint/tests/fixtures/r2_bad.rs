// R2 bad (under a `report`/`engine`/`sched` path): hash iteration order
// is nondeterministic, so any serialization or scheduling decision that
// walks it breaks byte-identical reports.
use std::collections::HashMap;

pub fn kpi_lines(kpis: &HashMap<String, f64>) -> Vec<String> {
    kpis.iter().map(|(k, v)| format!("{k}={v}")).collect()
}
