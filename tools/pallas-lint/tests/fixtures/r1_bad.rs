// R1 bad: float sort through `partial_cmp` — panics on NaN and leaves
// the order to a platform-dependent escape hatch.
pub fn sort_scores(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn best(xs: &[f64]) -> Option<f64> {
    xs.iter().copied().max_by(|a, b| a.partial_cmp(b).unwrap())
}
