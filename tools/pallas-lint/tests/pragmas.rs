//! Golden test over the tree's `// pallas-lint: allow(…)` inventory.
//!
//! Every pragma is a place the repo opts out of its own invariants, so
//! the *set* of them is a pinned artifact: adding a suppression anywhere
//! in `rust/src`, `rust/benches`, `rust/tests`, or the linter's own
//! sources means updating this table — turning silent lint-debt growth
//! into a reviewable diff line. Line numbers are deliberately not
//! pinned (formatting would churn them); the (file, rules, count)
//! triple is the stable shape.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// `(relative path, "+"-joined rule codes, pragma count)` — keep sorted
/// by path then rules.
const GOLDEN: [(&str, &str, usize); 19] = [
    ("rust/src/engine/clock.rs", "R5", 3),
    ("rust/src/engine/mod.rs", "R3", 2),
    ("rust/src/engine/mod.rs", "R5", 3),
    ("rust/src/gp/mod.rs", "R5", 3),
    ("rust/src/gp/mod.rs", "R6", 5),
    ("rust/src/gp/shard.rs", "R5", 7),
    ("rust/src/gp/shard.rs", "R6", 3),
    ("rust/src/linalg/mod.rs", "R6", 4),
    ("rust/src/metrics/mod.rs", "R5", 1),
    ("rust/src/miu/mod.rs", "R5", 1),
    ("rust/src/pool/mod.rs", "R5", 4),
    ("rust/src/problem/mod.rs", "R5", 1),
    ("rust/src/runtime/mod.rs", "R5", 1),
    ("rust/src/sched/backend.rs", "R6", 1),
    ("rust/src/workload/churn.rs", "R5", 3),
    ("rust/src/workload/fault_plan.rs", "R5", 1),
    ("rust/src/workload/fleet.rs", "R5", 1),
    ("rust/src/workload/synthetic.rs", "R5", 1),
    ("rust/tests/float_order.rs", "R1", 2),
];

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../..").join(rel)
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("reading {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            if p.file_name().map(|n| n == "target").unwrap_or(false) {
                continue;
            }
            rust_files(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

#[test]
fn pragma_inventory_matches_the_golden_table() {
    let roots = ["rust/src", "rust/benches", "rust/tests", "tools/pallas-lint/src"];
    let mut inventory: BTreeMap<(String, String), usize> = BTreeMap::new();
    for root in roots {
        let abs = repo_path(root);
        let mut files = Vec::new();
        rust_files(&abs, &mut files);
        assert!(!files.is_empty(), "no .rs files under {root} — wrong repo layout?");
        for file in files {
            let src = fs::read_to_string(&file)
                .unwrap_or_else(|e| panic!("reading {}: {e}", file.display()));
            let suffix = file.strip_prefix(&abs).expect("walked file under root");
            let rel = format!("{root}/{}", suffix.display()).replace('\\', "/");
            for (_line, rules) in pallas_lint::pragma_inventory(&src) {
                let codes: Vec<&str> = rules.iter().map(|r| r.code()).collect();
                *inventory.entry((rel.clone(), codes.join("+"))).or_insert(0) += 1;
            }
        }
    }
    let got: Vec<(String, String, usize)> =
        inventory.into_iter().map(|((p, r), n)| (p, r, n)).collect();
    let want: Vec<(String, String, usize)> =
        GOLDEN.iter().map(|&(p, r, n)| (p.to_string(), r.to_string(), n)).collect();
    assert_eq!(
        got, want,
        "pragma inventory drifted — if the new suppression is justified, update GOLDEN in {}",
        file!()
    );
}

#[test]
fn golden_table_is_sorted_and_rules_are_known() {
    let mut sorted = GOLDEN;
    sorted.sort();
    assert_eq!(sorted, GOLDEN, "keep GOLDEN sorted by (path, rules)");
    for (_, rules, n) in GOLDEN {
        assert!(n > 0);
        for code in rules.split('+') {
            assert!(pallas_lint::RuleId::parse(code).is_some(), "unknown rule {code} in GOLDEN");
        }
    }
}
