//! Fixture corpus: one known-bad and one known-good snippet per rule,
//! linted under virtual paths that put them in each rule's scope. The
//! bad fixtures are what the CI gate must reject (exit 1); the good
//! fixtures pin the sanctioned replacement idioms as lint-clean.

use pallas_lint::{lint_source, RuleId};
use std::fs;
use std::path::Path;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading fixture {}: {e}", p.display()))
}

/// Lint a fixture under a virtual path (scoping is path-driven).
fn lint_fixture(name: &str, virtual_path: &str) -> Vec<pallas_lint::Diagnostic> {
    lint_source(virtual_path, &fixture(name))
}

#[test]
fn r1_bad_flags_partial_cmp_and_good_is_clean() {
    let bad = lint_fixture("r1_bad.rs", "rust/src/workload/r1_bad.rs");
    assert!(bad.iter().any(|d| d.rule == RuleId::FloatTotalCmp), "{bad:?}");
    assert_eq!(bad.iter().filter(|d| d.rule == RuleId::FloatTotalCmp).count(), 2);
    let good = lint_fixture("r1_good.rs", "rust/src/workload/r1_good.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r2_bad_flags_hashmap_in_report_paths_and_good_is_clean() {
    let bad = lint_fixture("r2_bad.rs", "rust/src/report/r2_bad.rs");
    assert!(bad.iter().any(|d| d.rule == RuleId::HashOrder), "{bad:?}");
    let good = lint_fixture("r2_good.rs", "rust/src/report/r2_good.rs");
    assert!(good.is_empty(), "{good:?}");
    // Outside the byte-stability paths the same code is not R2's business.
    let elsewhere = lint_fixture("r2_bad.rs", "rust/src/workload/r2_bad.rs");
    assert!(!elsewhere.iter().any(|d| d.rule == RuleId::HashOrder), "{elsewhere:?}");
}

#[test]
fn r3_bad_flags_wall_clock_reads_and_good_is_clean() {
    let bad = lint_fixture("r3_bad.rs", "rust/src/sim/r3_bad.rs");
    let r3 = bad.iter().filter(|d| d.rule == RuleId::WallClock).count();
    // `SystemTime` flags on any mention (import + call); `Instant` only on `::now`.
    assert_eq!(r3, 4, "SystemTime import + Instant::now + sleep + SystemTime::now: {bad:?}");
    let good = lint_fixture("r3_good.rs", "rust/src/sim/r3_good.rs");
    assert!(good.is_empty(), "{good:?}");
    // The clock substrate itself is the sanctioned home for these calls.
    let in_clock = lint_fixture("r3_bad.rs", "rust/src/engine/clock.rs");
    assert!(!in_clock.iter().any(|d| d.rule == RuleId::WallClock), "{in_clock:?}");
}

#[test]
fn r4_bad_flags_wrapping_casts_and_good_is_clean() {
    let bad = lint_fixture("r4_bad.rs", "rust/src/config/r4_bad.rs");
    assert_eq!(bad.iter().filter(|d| d.rule == RuleId::WrappingCast).count(), 2, "{bad:?}");
    let good = lint_fixture("r4_good.rs", "rust/src/config/r4_good.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r5_bad_flags_lib_panics_and_good_is_clean() {
    let bad = lint_fixture("r5_bad.rs", "rust/src/gp/r5_bad.rs");
    assert_eq!(bad.iter().filter(|d| d.rule == RuleId::LibPanic).count(), 3, "{bad:?}");
    let good = lint_fixture("r5_good.rs", "rust/src/gp/r5_good.rs");
    assert!(good.is_empty(), "justified pragma + cfg(test) must lint clean: {good:?}");
    // The same panicking code is fine in the CLI layer.
    let in_cli = lint_fixture("r5_bad.rs", "rust/src/cli/r5_bad.rs");
    assert!(in_cli.is_empty(), "{in_cli:?}");
}

#[test]
fn r6_bad_flags_interprocedural_hot_path_allocs_and_good_is_clean() {
    let bad = lint_fixture("r6_bad.rs", "rust/src/gp/r6_bad.rs");
    let r6: Vec<_> = bad.iter().filter(|d| d.rule == RuleId::HotPathAlloc).collect();
    assert_eq!(r6.len(), 3, "push + format! + push_str, one call hop from observe: {bad:?}");
    // The finding is interprocedural: the sites are in `record`, the root
    // is `observe`, and the diagnostic carries the discovery chain.
    assert!(r6.iter().all(|d| d.message.contains("Gp::record ← Gp::observe")), "{r6:?}");
    let good = lint_fixture("r6_good.rs", "rust/src/gp/r6_good.rs");
    assert!(good.is_empty(), "cold `report` alloc must not leak into the hot set: {good:?}");
}

#[test]
fn r7_bad_flags_the_two_lock_cycle_and_good_is_clean() {
    let bad = lint_fixture("r7_bad.rs", "rust/src/pool/r7_bad.rs");
    let r7: Vec<_> = bad.iter().filter(|d| d.rule == RuleId::LockOrder).collect();
    assert_eq!(r7.len(), 2, "both edges of the a ⇄ b cycle: {bad:?}");
    let good = lint_fixture("r7_good.rs", "rust/src/pool/r7_good.rs");
    assert!(good.is_empty(), "consistent a → b order (incl. through `tail`) must pass: {good:?}");
    // The same cycle outside the audited concurrency modules is not R7's
    // business.
    let elsewhere = lint_fixture("r7_bad.rs", "rust/src/gp/r7_bad.rs");
    assert!(!elsewhere.iter().any(|d| d.rule == RuleId::LockOrder), "{elsewhere:?}");
}

#[test]
fn r8_bad_flags_unvalidated_config_reads_and_good_is_clean() {
    let bad = lint_fixture("r8_bad.rs", "rust/src/config/r8_bad.rs");
    assert_eq!(bad.iter().filter(|d| d.rule == RuleId::ConfigValidation).count(), 1, "{bad:?}");
    let good = lint_fixture("r8_good.rs", "rust/src/config/r8_good.rs");
    assert!(good.is_empty(), "later-statement try_from flow and count() itself are sanctioned: {good:?}");
    let elsewhere = lint_fixture("r8_bad.rs", "rust/src/gp/r8_bad.rs");
    assert!(elsewhere.is_empty(), "{elsewhere:?}");
}

#[test]
fn unjustified_pragma_is_reported_and_suppresses_nothing() {
    let diags = lint_fixture("pragma_bad.rs", "rust/src/gp/pragma_bad.rs");
    assert!(diags.iter().any(|d| d.rule == RuleId::Pragma), "{diags:?}");
    assert!(diags.iter().any(|d| d.rule == RuleId::LibPanic), "{diags:?}");
}

#[test]
fn every_bad_fixture_produces_findings_exit_1_contract() {
    // The CLI exits 1 iff findings are non-empty; pin that every bad
    // fixture would fail the gate and every good one would pass it.
    let cases = [
        ("r1_bad.rs", "rust/src/workload/f.rs", true),
        ("r1_good.rs", "rust/src/workload/f.rs", false),
        ("r2_bad.rs", "rust/src/sched/f.rs", true),
        ("r2_good.rs", "rust/src/sched/f.rs", false),
        ("r3_bad.rs", "rust/src/gp/f.rs", true),
        ("r3_good.rs", "rust/src/gp/f.rs", false),
        ("r4_bad.rs", "rust/src/config/f.rs", true),
        ("r4_good.rs", "rust/src/config/f.rs", false),
        ("r5_bad.rs", "rust/src/engine/f.rs", true),
        ("r5_good.rs", "rust/src/engine/f.rs", false),
        ("r6_bad.rs", "rust/src/gp/f.rs", true),
        ("r6_good.rs", "rust/src/gp/f.rs", false),
        ("r7_bad.rs", "rust/src/pool/f.rs", true),
        ("r7_good.rs", "rust/src/pool/f.rs", false),
        ("r8_bad.rs", "rust/src/config/f.rs", true),
        ("r8_good.rs", "rust/src/config/f.rs", false),
        ("pragma_bad.rs", "rust/src/engine/f.rs", true),
    ];
    for (name, path, dirty) in cases {
        let diags = lint_fixture(name, path);
        assert_eq!(!diags.is_empty(), dirty, "{name} under {path}: {diags:?}");
    }
}
