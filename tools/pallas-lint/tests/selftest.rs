//! Self-test: the real tree lints clean. This is the same sweep the
//! blocking CI job runs (`cargo run -p pallas-lint -- rust/src
//! rust/benches rust/tests tools/pallas-lint/src`), expressed as a
//! `cargo test` so the gate also holds in plain `cargo test -q` runs
//! with no extra CI plumbing. All roots are linted in ONE call: the
//! R6–R8 graph rules resolve calls across the whole set, exactly like
//! CI does.

use std::path::{Path, PathBuf};

fn repo_path(rel: &str) -> PathBuf {
    // CARGO_MANIFEST_DIR = <repo>/tools/pallas-lint
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../..").join(rel)
}

#[test]
fn full_tree_lints_clean_as_one_analysis_unit() {
    let roots = [
        repo_path("rust/src"),
        repo_path("rust/benches"),
        repo_path("rust/tests"),
        repo_path("tools/pallas-lint/src"),
    ];
    let diags = pallas_lint::lint_paths(&roots).expect("walk lint roots");
    assert!(
        diags.is_empty(),
        "the tree must lint clean (incl. the R6 hot-path-alloc and R7 lock-order graph rules); \
         fix or add a justified pragma:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn lint_sources_lint_themselves_clean() {
    // Dogfood in isolation too: the linter's own sources must hold the
    // invariants with no help from pragmas elsewhere in the tree.
    let root = repo_path("tools/pallas-lint/src");
    let diags = pallas_lint::lint_paths(&[root]).expect("walk own src");
    assert!(
        diags.is_empty(),
        "pallas-lint must dogfood its own rules:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}
