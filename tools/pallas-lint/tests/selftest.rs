//! Self-test: the real tree lints clean. This is the same sweep the
//! blocking CI job runs (`cargo run -p pallas-lint -- rust/src
//! tools/pallas-lint/src`), expressed as a `cargo test` so the gate also
//! holds in plain `cargo test -q` runs with no extra CI plumbing.

use std::path::{Path, PathBuf};

fn repo_path(rel: &str) -> PathBuf {
    // CARGO_MANIFEST_DIR = <repo>/tools/pallas-lint
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../..").join(rel)
}

#[test]
fn main_crate_sources_lint_clean() {
    let root = repo_path("rust/src");
    let diags = pallas_lint::lint_paths(&[root]).expect("walk rust/src");
    assert!(
        diags.is_empty(),
        "rust/src must lint clean; fix or add a justified pragma:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn lint_sources_lint_themselves_clean() {
    let root = repo_path("tools/pallas-lint/src");
    let diags = pallas_lint::lint_paths(&[root]).expect("walk own src");
    assert!(
        diags.is_empty(),
        "pallas-lint must dogfood its own rules:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    );
}
