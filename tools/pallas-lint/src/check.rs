//! Analysis pipeline. Per file: lex → pragmas → `#[cfg(test)]` mask →
//! token-rule scan (R1–R5). Then, over the *whole file set at once*: parse
//! to AST, build the crate-wide call-resolution index, and run the graph
//! rules (R6–R8) — reachability and lock-order are only meaningful when
//! every file is in the same index. Pragma suppression applies uniformly,
//! keyed by `(path, target line, rule)`.

use crate::diag::{Diagnostic, RuleId};
use crate::lexer::{self, Tok, TokKind};
use crate::{ast, configflow, hotpath, lockorder, parser, pragma, resolve, rules};

/// Lint one file's source. `path` is the file's (possibly virtual) path;
/// it determines rule scoping, so fixture tests can exercise scoped rules
/// by labeling snippets with in-scope paths. Graph rules run over the
/// single-file "crate" this implies.
pub fn lint_source(path: &str, src: &str) -> Vec<Diagnostic> {
    lint_sources(&[(path.to_string(), src.to_string())])
}

/// Lint a set of `(path, source)` files as one crate-wide analysis unit:
/// token rules see each file independently; the R6–R8 call-graph rules
/// see all of them through one symbol index.
pub fn lint_sources(files: &[(String, String)]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut parsed: Vec<ast::ParsedFile> = Vec::new();
    let mut tables: Vec<(String, Vec<pragma::Pragma>)> = Vec::new();
    for (path, src) in files {
        let norm = path.replace('\\', "/");
        let toks = lexer::lex(src);
        let (pragmas, pragma_errors) = pragma::collect(&toks);
        let code: Vec<&Tok> = toks
            .iter()
            .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .collect();
        let mask = test_mask(&code);
        out.extend(
            pragma_errors
                .into_iter()
                .map(|(line, message)| Diagnostic { path: norm.clone(), line, rule: RuleId::Pragma, message }),
        );
        for (rule, line, message) in rules::scan(&norm, &code, &mask) {
            let suppressed = pragmas.iter().any(|p| p.target_line == line && p.rules.contains(&rule));
            if !suppressed {
                out.push(Diagnostic { path: norm.clone(), line, rule, message });
            }
        }
        parsed.push(parser::parse_file(&norm, &code));
        tables.push((norm, pragmas));
    }
    let index = resolve::Index::new(&parsed);
    let mut graph = hotpath::check(&index);
    graph.extend(lockorder::check(&index));
    graph.extend(configflow::check(&index));
    for d in graph {
        let suppressed = tables.iter().any(|(p, pragmas)| {
            *p == d.path && pragmas.iter().any(|pr| pr.target_line == d.line && pr.rules.contains(&d.rule))
        });
        if !suppressed {
            out.push(d);
        }
    }
    out.sort_by(|a, b| (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule)));
    out
}

/// Mark every token belonging to a `#[cfg(test)]` item (attribute through
/// the item's closing brace, or its `;` for block-less items). Only R5
/// consults this mask.
fn test_mask(code: &[&Tok]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        if !is_cfg_test_attr(code, i) {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = attr_end(code, i);
        // Skip further stacked attributes on the same item.
        while j + 1 < code.len() && code[j].text == "#" && code[j + 1].text == "[" {
            j = attr_end(code, j);
        }
        // Find the item body `{` (or a terminating `;`) at bracket depth 0.
        let mut depth = 0i32;
        let mut body = None;
        while j < code.len() {
            match code[j].text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => {
                    body = Some(j);
                    break;
                }
                ";" if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let end = match body {
            Some(open) => brace_close(code, open),
            None => j.min(code.len().saturating_sub(1)),
        };
        for m in &mut mask[start..=end] {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// True when `code[i..]` starts a `#[cfg(… test …)]` attribute.
fn is_cfg_test_attr(code: &[&Tok], i: usize) -> bool {
    let t = |k: usize| code.get(k).map_or("", |tok| tok.text.as_str());
    if !(t(i) == "#" && t(i + 1) == "[" && t(i + 2) == "cfg" && t(i + 3) == "(") {
        return false;
    }
    // Scan the attribute's argument list for a `test` token — covers
    // `cfg(test)` and compounds like `cfg(all(test, feature = "x"))`.
    let mut depth = 1i32;
    let mut k = i + 4;
    while k < code.len() && depth > 0 {
        match t(k) {
            "(" => depth += 1,
            ")" => depth -= 1,
            "test" => return true,
            _ => {}
        }
        k += 1;
    }
    false
}

/// Index just past the `]` closing the attribute starting at `code[i]`
/// (which must be `#`).
fn attr_end(code: &[&Tok], i: usize) -> usize {
    let t = |k: usize| code.get(k).map_or("", |tok| tok.text.as_str());
    let mut depth = 0i32;
    let mut k = i + 1;
    while k < code.len() {
        match t(k) {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
        k += 1;
    }
    code.len()
}

/// Index of the `}` matching the `{` at `open` (or the last token when
/// unbalanced).
fn brace_close(code: &[&Tok], open: usize) -> usize {
    let mut depth = 1i32;
    let mut k = open + 1;
    while k < code.len() {
        match code[k].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
        k += 1;
    }
    code.len().saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_test_module_is_exempt_from_r5_only() {
        let src = "pub fn lib() -> f64 { v.unwrap() }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t(a: f64, b: f64) { v.unwrap(); a.partial_cmp(&b); }\n\
                   }\n";
        let diags = lint_source("rust/src/gp/mod.rs", src);
        // One R5 from the library fn, one R1 from the test body — the
        // test-module unwrap is exempt, the test-module sort is not.
        let r5: Vec<_> = diags.iter().filter(|d| d.rule == RuleId::LibPanic).collect();
        let r1: Vec<_> = diags.iter().filter(|d| d.rule == RuleId::FloatTotalCmp).collect();
        assert_eq!(r5.len(), 1, "{diags:?}");
        assert_eq!(r5[0].line, 1);
        assert_eq!(r1.len(), 1, "{diags:?}");
        assert_eq!(r1[0].line, 4);
    }

    #[test]
    fn justified_pragma_suppresses_only_its_rule_and_line() {
        let src = "pub fn f() {\n\
                   // pallas-lint: allow(R5) — heap non-empty: guarded by the peek above\n\
                   let c = heap.pop().unwrap();\n\
                   let d = heap.pop().unwrap();\n\
                   }\n";
        let diags = lint_source("rust/src/engine/mod.rs", src);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 4);
    }

    #[test]
    fn unjustified_pragma_reports_and_does_not_suppress() {
        let src = "// pallas-lint: allow(R5)\npub fn f() -> f64 { v.unwrap() }\n";
        let diags = lint_source("rust/src/gp/mod.rs", src);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().any(|d| d.rule == RuleId::Pragma));
        assert!(diags.iter().any(|d| d.rule == RuleId::LibPanic));
    }

    #[test]
    fn stacked_attributes_still_mask_the_item() {
        let src = "#[cfg(test)]\n#[allow(dead_code)]\nmod tests { fn f() { v.unwrap(); } }\n";
        assert!(lint_source("rust/src/gp/mod.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_use_item_without_body() {
        let src = "#[cfg(test)]\nuse crate::testutil;\npub fn f() -> f64 { v.unwrap() }\n";
        let diags = lint_source("rust/src/gp/mod.rs", src);
        assert_eq!(diags.len(), 1, "the fn after the cfg(test) use must still be linted: {diags:?}");
        assert_eq!(diags[0].line, 3);
    }

    #[test]
    fn diagnostics_sorted_by_line() {
        let src = "pub fn f(a: f64, b: f64) {\n  x.unwrap();\n  a.partial_cmp(&b);\n}\n";
        let diags = lint_source("rust/src/gp/mod.rs", src);
        let lines: Vec<u32> = diags.iter().map(|d| d.line).collect();
        let mut sorted = lines.clone();
        sorted.sort_unstable();
        assert_eq!(lines, sorted);
    }
}
