//! The R1–R5 rule matchers over a code-token stream.
//!
//! Every rule is a token-sequence pattern plus a *path scope* — the
//! directories where the invariant is enforced or exempted. Scopes match
//! normalized (`/`-separated) path substrings, so the lint behaves the
//! same whether invoked on `rust/src` or an absolute path.

use crate::diag::RuleId;
use crate::lexer::{Tok, TokKind};

/// Integer target types of a narrowing/wrapping `as` cast (R4).
const INT_TYPES: [&str; 12] =
    ["usize", "isize", "u8", "u16", "u32", "u64", "u128", "i8", "i16", "i32", "i64", "i128"];

/// True when `path` has a directory component named `dir`.
fn in_dir(path: &str, dir: &str) -> bool {
    path.starts_with(&format!("{dir}/")) || path.contains(&format!("/{dir}/"))
}

/// R1 applies everywhere — test sorts drive determinism gates too.
fn r1_applies(_path: &str) -> bool {
    true
}

/// R2: hash-order iteration matters where bytes are gated — reports, the
/// scheduling engine, and the policies.
fn r2_applies(path: &str) -> bool {
    in_dir(path, "report") || in_dir(path, "engine") || in_dir(path, "sched")
}

/// R3: wall-clock reads are legal only inside the clock substrate and
/// the bench harness.
fn r3_applies(path: &str) -> bool {
    !(path.ends_with("engine/clock.rs") || in_dir(path, "bench") || in_dir(path, "benches"))
}

/// R4: the wrapping-cast class lives where TOML integers are converted.
fn r4_applies(path: &str) -> bool {
    in_dir(path, "config")
}

/// R5: library code only — binaries, CLI, bench harness, test utilities,
/// and test/ example trees may panic and print freely.
fn r5_applies(path: &str) -> bool {
    let exempt_dirs = ["cli", "bench", "benches", "tests", "examples", "testutil"];
    !(exempt_dirs.iter().any(|d| in_dir(path, d)) || path.ends_with("/main.rs") || path == "main.rs")
}

/// Scan `code` (comment-free token stream) for rule violations.
/// `in_test[i]` marks tokens inside `#[cfg(test)]` items, which only R5
/// exempts — determinism rules (R1–R4) hold in unit tests too.
pub fn scan(path: &str, code: &[&Tok], in_test: &[bool]) -> Vec<(RuleId, u32, String)> {
    let t = |k: usize| code.get(k).map_or("", |tok| tok.text.as_str());
    let kind = |k: usize| code.get(k).map(|tok| tok.kind);
    let (r1, r2, r3, r4, r5) =
        (r1_applies(path), r2_applies(path), r3_applies(path), r4_applies(path), r5_applies(path));
    let mut out = Vec::new();
    for (i, tok) in code.iter().enumerate() {
        if tok.kind != TokKind::Ident {
            continue;
        }
        let line = tok.line;
        match tok.text.as_str() {
            "partial_cmp" if r1 => {
                // `fn partial_cmp` is the `PartialOrd` impl itself, not a call.
                if !(i > 0 && t(i - 1) == "fn") {
                    out.push((
                        RuleId::FloatTotalCmp,
                        line,
                        "float `partial_cmp` panics on NaN and invites platform drift; use \
                         `f64::total_cmp`"
                            .into(),
                    ));
                }
            }
            "HashMap" | "HashSet" if r2 => {
                out.push((
                    RuleId::HashOrder,
                    line,
                    format!(
                        "`{}` in a byte-stability path: hash iteration order is nondeterministic; \
                         use `Vec`, `BTreeMap`, or an index map",
                        tok.text
                    ),
                ));
            }
            "Instant" if r3 => {
                if t(i + 1) == ":" && t(i + 2) == ":" && t(i + 3) == "now" {
                    out.push((
                        RuleId::WallClock,
                        line,
                        "`Instant::now` outside `engine/clock.rs`/bench leaks wall time into \
                         virtual-time code; route through `engine::Clock`"
                            .into(),
                    ));
                }
            }
            "SystemTime" if r3 => {
                out.push((
                    RuleId::WallClock,
                    line,
                    "`SystemTime` outside `engine/clock.rs`/bench; route time through \
                     `engine::Clock`"
                        .into(),
                ));
            }
            "sleep" if r3 => {
                if i >= 3 && t(i - 1) == ":" && t(i - 2) == ":" && t(i - 3) == "thread" {
                    out.push((
                        RuleId::WallClock,
                        line,
                        "`thread::sleep` outside `engine/clock.rs`/bench stalls virtual-time \
                         code on the wall clock"
                            .into(),
                    ));
                }
            }
            "as" if r4 => {
                let target = t(i + 1);
                if INT_TYPES.contains(&target) {
                    out.push((
                        RuleId::WrappingCast,
                        line,
                        format!(
                            "`as {target}` on a config-derived integer silently wraps negatives; \
                             use `{target}::try_from` and reject out-of-range values"
                        ),
                    ));
                }
            }
            "unwrap" if r5 && !in_test[i] => {
                if i > 0 && t(i - 1) == "." && t(i + 1) == "(" && t(i + 2) == ")" {
                    out.push((
                        RuleId::LibPanic,
                        line,
                        "`.unwrap()` in library code; return an error, or justify with \
                         `// pallas-lint: allow(R5) — <why this cannot fail>`"
                            .into(),
                    ));
                }
            }
            "expect" if r5 && !in_test[i] => {
                if i > 0 && t(i - 1) == "." && t(i + 1) == "(" && kind(i + 2) == Some(TokKind::Str) {
                    out.push((
                        RuleId::LibPanic,
                        line,
                        "`.expect(\"…\")` in library code; return an error, or justify with \
                         `// pallas-lint: allow(R5) — <why this cannot fail>`"
                            .into(),
                    ));
                }
            }
            "println" if r5 && !in_test[i] => {
                if t(i + 1) == "!" {
                    out.push((
                        RuleId::LibPanic,
                        line,
                        "`println!` in library code pollutes stdout (reports are piped); use the \
                         CLI layer or `eprintln!` diagnostics"
                            .into(),
                    ));
                }
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn scan_src(path: &str, src: &str) -> Vec<(RuleId, u32, String)> {
        let toks = lex(src);
        let code: Vec<&Tok> =
            toks.iter().filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment)).collect();
        let mask = vec![false; code.len()];
        scan(path, &code, &mask)
    }

    #[test]
    fn partial_cmp_call_flagged_but_impl_exempt() {
        let src = "impl PartialOrd for X {\n    fn partial_cmp(&self, o: &X) -> Option<Ordering> { Some(self.cmp(o)) }\n}\nfn bad(a: f64, b: f64) { a.partial_cmp(&b); }\n";
        let hits = scan_src("rust/src/x.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].1, 4);
    }

    #[test]
    fn hash_collections_only_flagged_in_scoped_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(scan_src("rust/src/sched/mod.rs", src).len(), 1);
        assert_eq!(scan_src("rust/src/workload/mod.rs", src).len(), 0);
    }

    #[test]
    fn wall_clock_exempt_in_clock_and_bench() {
        let src = "let t = Instant::now();\nstd::thread::sleep(d);\n";
        assert_eq!(scan_src("rust/src/sim/mod.rs", src).len(), 2);
        assert_eq!(scan_src("rust/src/engine/clock.rs", src).len(), 0);
        assert_eq!(scan_src("rust/src/bench/mod.rs", src).len(), 0);
    }

    #[test]
    fn instant_import_alone_is_fine() {
        assert_eq!(scan_src("rust/src/engine/mod.rs", "use std::time::{Duration, Instant};\n").len(), 0);
    }

    #[test]
    fn wrapping_casts_flagged_in_config_only() {
        let src = "let n = x as usize;\nlet f = x as f64;\n";
        let hits = scan_src("rust/src/config/mod.rs", src);
        assert_eq!(hits.len(), 1, "float casts are not narrowing: {hits:?}");
        assert_eq!(scan_src("rust/src/gp/mod.rs", src).len(), 0);
    }

    #[test]
    fn expect_with_byte_literal_is_a_parser_method_not_option_expect() {
        let src = "self.expect(b'[')?;\nv.expect(\"boom\");\n";
        let hits = scan_src("rust/src/report/json.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].1, 2);
    }

    #[test]
    fn lib_panics_exempt_in_cli_bench_main() {
        let src = "fn f() { v.unwrap(); println!(\"x\"); }\n";
        assert_eq!(scan_src("rust/src/gp/mod.rs", src).len(), 2);
        assert_eq!(scan_src("rust/src/cli/mod.rs", src).len(), 0);
        assert_eq!(scan_src("rust/src/main.rs", src).len(), 0);
        assert_eq!(scan_src("rust/benches/fig2.rs", src).len(), 0);
    }

    #[test]
    fn unwrap_or_variants_are_not_unwrap() {
        assert_eq!(scan_src("rust/src/gp/mod.rs", "v.unwrap_or(0.0); v.unwrap_or_default();\n").len(), 0);
    }
}
