//! # pallas-lint — determinism & float-safety lint for the mmgpei tree
//!
//! The repo's value proposition — byte-identical `RunReport`s, bit-exact
//! incremental-vs-rebuild oracles, thread-invariant `WorkerPool` merges —
//! rests on invariants that PRs 1–5 repeatedly hand-fixed. This crate
//! turns them into machine-checked policy:
//!
//! * **R1** `float-total-cmp` — no `partial_cmp` float comparisons;
//!   `f64::total_cmp` is total (no NaN panic, no platform drift).
//! * **R2** `hash-order` — no `HashMap`/`HashSet` in `report`/`engine`/
//!   `sched` paths (nondeterministic iteration order).
//! * **R3** `wall-clock` — no `Instant::now`/`SystemTime`/`thread::sleep`
//!   outside `engine/clock.rs` and the bench harness.
//! * **R4** `wrapping-cast` — no `as usize`/`as u64` narrowing on
//!   config-derived integers (negative TOML values silently wrap).
//! * **R5** `lib-panic` — no `unwrap`/`expect`/`println!` in library code
//!   outside `cli`/`bench`/tests.
//!
//! Legitimate sites carry `// pallas-lint: allow(<rule>) — <justification>`
//! pragmas; the justification is mandatory and its absence is itself a
//! finding. Zero dependencies: the lexer is hand-rolled over the Rust
//! token grammar (strings, raw strings, char-vs-lifetime, nested block
//! comments handled correctly), no `syn`, no proc-macros.
//!
//! CLI: `cargo run -p pallas-lint -- rust/src [more paths…]` — exit 0
//! when clean, 1 with `file:line` diagnostics otherwise.

#![warn(missing_docs)]

mod check;
mod lexer;
mod pragma;
mod rules;
mod walk;

pub mod diag;

pub use check::lint_source;
pub use diag::{Diagnostic, RuleId};

use std::fmt;
use std::path::PathBuf;

/// I/O or usage error surfaced to the CLI (exit code 2, distinct from
/// exit 1 = findings).
#[derive(Debug)]
pub struct LintError(pub String);

impl fmt::Display for LintError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for LintError {}

/// Lint every `.rs` file under the given paths (files or directories),
/// returning all findings in deterministic (path, line, rule) order.
pub fn lint_paths(paths: &[PathBuf]) -> Result<Vec<Diagnostic>, LintError> {
    let mut out = Vec::new();
    for root in paths {
        for file in walk::rust_files(root)? {
            let src = std::fs::read_to_string(&file)
                .map_err(|e| LintError(format!("reading {}: {e}", file.display())))?;
            out.extend(check::lint_source(&file.display().to_string(), &src));
        }
    }
    Ok(out)
}
