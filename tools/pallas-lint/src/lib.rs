//! # pallas-lint — determinism, float-safety & call-graph lint for mmgpei
//!
//! The repo's value proposition — byte-identical `RunReport`s, bit-exact
//! incremental-vs-rebuild oracles, thread-invariant `WorkerPool` merges,
//! an allocation-free serving decision path — rests on invariants that
//! PRs 1–8 repeatedly hand-fixed. This crate turns them into
//! machine-checked policy.
//!
//! Token rules (per file, over the raw token stream):
//!
//! * **R1** `float-total-cmp` — no `partial_cmp` float comparisons;
//!   `f64::total_cmp` is total (no NaN panic, no platform drift).
//! * **R2** `hash-order` — no `HashMap`/`HashSet` in `report`/`engine`/
//!   `sched` paths (nondeterministic iteration order).
//! * **R3** `wall-clock` — no `Instant::now`/`SystemTime`/`thread::sleep`
//!   outside `engine/clock.rs` and the bench harness.
//! * **R4** `wrapping-cast` — no `as usize`/`as u64` narrowing on
//!   config-derived integers (negative TOML values silently wrap).
//! * **R5** `lib-panic` — no `unwrap`/`expect`/`println!` in library code
//!   outside `cli`/`bench`/tests.
//!
//! Graph rules (crate-wide, over a hand-rolled AST and a CHA-style call
//! graph built across *all* linted files at once):
//!
//! * **R6** `hot-path-alloc` — no allocating construct in any fn
//!   statically reachable from `Gp::observe`, `EiBackend::eirate`, or
//!   `EiBackend::select_arm`; the static complement of the dynamic
//!   `alloc_counter` test gate.
//! * **R7** `lock-order` — the Mutex acquisition-order graph of `pool`,
//!   `engine/clock.rs`, and `coordinator` must be acyclic; the static
//!   complement of the nightly TSan job.
//! * **R8** `config-validation` — numeric config reads (`as_int`) must
//!   flow through `count()`/`try_from` before use.
//!
//! Legitimate sites carry `// pallas-lint: allow(<rule>) — <justification>`
//! pragmas; the justification is mandatory and its absence is itself a
//! finding. No external dependencies: lexer and recursive-descent parser
//! are hand-rolled over the Rust grammar (strings, raw strings,
//! char-vs-lifetime, nested block comments, generics, nested blocks), no
//! `syn`, no proc-macros — only the main crate's canonical JSON writer
//! for `--json` reports.
//!
//! CLI: `cargo run -p pallas-lint -- [--json <file>] rust/src [more
//! paths…]` — exit 0 when clean, 1 with `file:line` diagnostics otherwise.

#![warn(missing_docs)]

mod ast;
mod callgraph;
mod check;
mod configflow;
mod hotpath;
mod json_out;
mod lexer;
mod lockorder;
mod parser;
mod pragma;
mod resolve;
mod rules;
mod walk;

pub mod diag;

pub use check::{lint_source, lint_sources};
pub use diag::{Diagnostic, RuleId};
pub use json_out::render as render_json;

/// The parsed, well-formed `allow` pragmas of one file as
/// `(target line, rules)` pairs in source order. Malformed pragmas are
/// not included (they are findings, not suppressions). Powers the
/// tree-wide pragma-inventory golden test: the set of places the repo
/// opts out of its own invariants is itself a pinned artifact.
pub fn pragma_inventory(src: &str) -> Vec<(u32, Vec<RuleId>)> {
    let toks = lexer::lex(src);
    let (pragmas, _errors) = pragma::collect(&toks);
    pragmas.into_iter().map(|p| (p.target_line, p.rules)).collect()
}

use std::fmt;
use std::path::PathBuf;

/// I/O or usage error surfaced to the CLI (exit code 2, distinct from
/// exit 1 = findings).
#[derive(Debug)]
pub struct LintError(pub String);

impl fmt::Display for LintError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for LintError {}

/// Lint every `.rs` file under the given paths (files or directories) as
/// one analysis unit — the R6–R8 call-graph rules resolve calls across
/// all of them — returning all findings in deterministic
/// (path, line, rule) order.
pub fn lint_paths(paths: &[PathBuf]) -> Result<Vec<Diagnostic>, LintError> {
    let mut files = Vec::new();
    for root in paths {
        for file in walk::rust_files(root)? {
            let src = std::fs::read_to_string(&file)
                .map_err(|e| LintError(format!("reading {}: {e}", file.display())))?;
            files.push((file.display().to_string(), src));
        }
    }
    Ok(check::lint_sources(&files))
}
