//! CLI entry point: `pallas-lint [--json <file>] <path> [<path>…]`.
//!
//! Exit codes: 0 clean, 1 findings (one `file:line: <rule> …` per line),
//! 2 usage or I/O error. `--json` additionally writes a canonical
//! machine-readable report (written on clean runs too, with `count: 0`,
//! so CI can archive it unconditionally).

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: pallas-lint [--json <file>] <path> [<path>…]\n\n\
Lints .rs files (recursively for directories) against the repo's\n\
determinism, float-safety, and call-graph rules R1–R8; all paths form\n\
one analysis unit for the R6–R8 graph rules. See README.md §Correctness\n\
tooling for the rule list and the `// pallas-lint: allow(<rule>) — <why>`\n\
pragma syntax. `--json <file>` writes a canonical JSON report\n\
(schema `pallas-lint-v1`).";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let mut json_path: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--json" {
            match it.next() {
                Some(p) => json_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("pallas-lint: --json requires a file argument\n\n{USAGE}");
                    return ExitCode::from(2);
                }
            }
        } else {
            paths.push(PathBuf::from(a));
        }
    }
    if paths.is_empty() {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    }
    match pallas_lint::lint_paths(&paths) {
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            ExitCode::from(2)
        }
        Ok(diags) => {
            if let Some(out) = &json_path {
                let doc = pallas_lint::render_json(&diags);
                if let Err(e) = std::fs::write(out, doc) {
                    eprintln!("pallas-lint: writing {}: {e}", out.display());
                    return ExitCode::from(2);
                }
            }
            if diags.is_empty() {
                println!("pallas-lint: clean");
                ExitCode::SUCCESS
            } else {
                for d in &diags {
                    println!("{d}");
                }
                println!("pallas-lint: {} finding(s)", diags.len());
                ExitCode::FAILURE
            }
        }
    }
}
