//! CLI entry point: `pallas-lint <path> [<path>…]`.
//!
//! Exit codes: 0 clean, 1 findings (one `file:line: <rule> …` per line),
//! 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: pallas-lint <path> [<path>…]\n\n\
Lints .rs files (recursively for directories) against the repo's\n\
determinism & float-safety rules R1–R5. See README.md §Correctness\n\
tooling for the rule list and the `// pallas-lint: allow(<rule>) — <why>`\n\
pragma syntax.";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    if args.is_empty() {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    }
    let paths: Vec<PathBuf> = args.iter().map(PathBuf::from).collect();
    match pallas_lint::lint_paths(&paths) {
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            ExitCode::from(2)
        }
        Ok(diags) if diags.is_empty() => {
            println!("pallas-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            println!("pallas-lint: {} finding(s)", diags.len());
            ExitCode::FAILURE
        }
    }
}
