//! Crate-wide call resolution: a CHA-style (class-hierarchy-analysis)
//! index over every parsed file, answering "which `fn` items can this
//! call event reach?".
//!
//! Resolution is deliberately *typed-lite*: `self.method()` resolves via
//! the impl's self type, `self.field.method()` via the struct field's
//! recorded base type, `Type::method()` via path, free fns by module, and
//! — as a last resort — by crate-wide unique name. Two guardrails keep
//! the fallback sound for the graph rules: it never claims allocating
//! method names (an unknown receiver's `.push()` must stay visible to
//! R6), and never claims common std method names (a crate type defining
//! `expect`, like `report::json::Parser`, must not swallow every
//! `Result::expect` in the tree).

use crate::ast::{Event, FnDef, ParsedFile};
use std::collections::{BTreeMap, BTreeSet};

/// Macros that allocate (R6).
pub const ALLOC_MACROS: [&str; 2] = ["format", "vec"];

/// Heap-owning types whose constructors allocate (R6).
pub const ALLOC_TYPES: [&str; 10] =
    ["Vec", "String", "Box", "VecDeque", "BTreeMap", "BTreeSet", "HashMap", "HashSet", "Rc", "Arc"];

/// Constructor names that allocate when called on an [`ALLOC_TYPES`] path.
pub const ALLOC_CTORS: [&str; 3] = ["new", "with_capacity", "from"];

/// Method names that (re)allocate on std containers (R6).
pub const ALLOC_METHODS: [&str; 15] = [
    "push", "push_str", "extend", "insert", "collect", "to_vec", "to_string", "to_owned", "clone",
    "reserve", "resize", "append", "repeat", "join", "split_off",
];

/// Common std method names the unique-name fallback must never claim.
const STD_METHODS: [&str; 31] = [
    "expect", "expect_err", "unwrap", "unwrap_or", "unwrap_or_else", "unwrap_err", "ok", "err",
    "ok_or", "map", "map_err", "and_then", "iter", "into_iter", "iter_mut", "next", "peek", "get",
    "get_mut", "len", "is_empty", "lock", "wait", "take", "last", "first", "min", "max", "abs",
    "sqrt", "drop",
];

/// Resolution context: the enclosing fn's impl/trait/module coordinates.
pub struct Ctx<'a> {
    /// `impl` self type of the enclosing fn.
    pub self_ty: Option<&'a str>,
    /// Trait of the enclosing fn (impl block or trait default).
    pub trait_name: Option<&'a str>,
    /// Module path of the enclosing fn's file.
    pub module: &'a str,
}

impl<'a> Ctx<'a> {
    /// Context of `fn_def`.
    pub fn of(fn_def: &'a FnDef) -> Ctx<'a> {
        Ctx {
            self_ty: fn_def.self_ty.as_deref(),
            trait_name: fn_def.trait_name.as_deref(),
            module: &fn_def.module,
        }
    }
}

/// The crate-wide symbol index. All maps are `BTreeMap`s so iteration —
/// and therefore every diagnostic the graph rules emit — is deterministic.
pub struct Index<'a> {
    /// The parsed files the index was built over.
    pub files: &'a [ParsedFile],
    methods: BTreeMap<(&'a str, &'a str), Vec<&'a FnDef>>,
    trait_defaults: BTreeMap<(&'a str, &'a str), Vec<&'a FnDef>>,
    method_by_name: BTreeMap<&'a str, Vec<&'a FnDef>>,
    free_fns: BTreeMap<&'a str, Vec<&'a FnDef>>,
    fields: BTreeMap<&'a str, BTreeMap<&'a str, &'a str>>,
    types: BTreeSet<&'a str>,
    traits: BTreeSet<&'a str>,
}

impl<'a> Index<'a> {
    /// Build the index over `files`.
    pub fn new(files: &'a [ParsedFile]) -> Index<'a> {
        let mut ix = Index {
            files,
            methods: BTreeMap::new(),
            trait_defaults: BTreeMap::new(),
            method_by_name: BTreeMap::new(),
            free_fns: BTreeMap::new(),
            fields: BTreeMap::new(),
            types: BTreeSet::new(),
            traits: BTreeSet::new(),
        };
        for pf in files {
            ix.types.extend(pf.types.iter().map(String::as_str));
            ix.traits.extend(pf.traits.iter().map(String::as_str));
            for (ty, fs) in &pf.fields {
                let entry = ix.fields.entry(ty).or_default();
                for (f, base) in fs {
                    entry.insert(f, base);
                }
            }
            for f in &pf.fns {
                if let Some(ty) = &f.self_ty {
                    ix.methods.entry((ty.as_str(), f.name.as_str())).or_default().push(f);
                    ix.method_by_name.entry(f.name.as_str()).or_default().push(f);
                } else if let Some(tr) = &f.trait_name {
                    ix.trait_defaults.entry((tr.as_str(), f.name.as_str())).or_default().push(f);
                    ix.method_by_name.entry(f.name.as_str()).or_default().push(f);
                } else {
                    ix.free_fns.entry(f.name.as_str()).or_default().push(f);
                }
            }
        }
        ix
    }

    /// Impl methods of the trait named `tr` whose fn name is `name`, plus
    /// trait defaults — used both for root collection and trait-CHA.
    pub fn trait_methods(&self, tr: &str, name: &str) -> Vec<&'a FnDef> {
        let mut out: Vec<&'a FnDef> = self
            .method_by_name
            .get(name)
            .into_iter()
            .flatten()
            .filter(|f| f.trait_name.as_deref() == Some(tr))
            .copied()
            .collect();
        out.extend(self.trait_defaults.get(&(tr, name)).into_iter().flatten().copied());
        out
    }

    /// Methods on `ty` named `name` (impl blocks anywhere in the tree).
    pub fn methods_on(&self, ty: &str, name: &str) -> Vec<&'a FnDef> {
        self.methods.get(&(ty, name)).cloned().unwrap_or_default()
    }

    /// Resolve a call event to its possible callees (empty when unknown —
    /// std calls, complex receivers, trait objects without an index entry).
    pub fn resolve(&self, ev: &Event, ctx: &Ctx<'_>) -> Vec<&'a FnDef> {
        match ev {
            Event::PathCall { segs, .. } if segs.len() >= 2 => {
                let name = segs[segs.len() - 1].as_str();
                let mut head = segs[segs.len() - 2].as_str();
                if head == "Self" {
                    if let Some(ty) = ctx.self_ty {
                        head = ty;
                    }
                }
                if let Some(got) = self.methods.get(&(head, name)) {
                    return got.clone();
                }
                if let Some(got) = self.trait_defaults.get(&(head, name)) {
                    return got.clone();
                }
                if self.types.contains(head) || self.traits.contains(head) {
                    return Vec::new(); // known type, method defined elsewhere (std)
                }
                // module-qualified free fn: `stats::erf(…)`
                let cands = self.free_fns.get(name).cloned().unwrap_or_default();
                cands
                    .into_iter()
                    .filter(|f| {
                        f.module.ends_with(head) || f.module.split("::").any(|m| m == head)
                    })
                    .collect()
            }
            Event::PathCall { segs, .. } => {
                let name = segs[0].as_str();
                let cands = self.free_fns.get(name).cloned().unwrap_or_default();
                if cands.is_empty() {
                    return Vec::new();
                }
                let same: Vec<&FnDef> =
                    cands.iter().copied().filter(|f| f.module == ctx.module).collect();
                if !same.is_empty() {
                    return same;
                }
                if cands.len() == 1 {
                    return cands;
                }
                Vec::new()
            }
            Event::Method { recv, name, .. } => {
                if recv.first().map(String::as_str) == Some("self") {
                    if let Some(self_ty) = ctx.self_ty {
                        if recv.len() == 1 {
                            let got = self.methods_on(self_ty, name);
                            if !got.is_empty() {
                                return got;
                            }
                            if let Some(tr) = ctx.trait_name {
                                if let Some(got) = self.trait_defaults.get(&(tr, name.as_str())) {
                                    return got.clone();
                                }
                            }
                            return self.unique_method(name);
                        }
                        if recv.len() == 2 {
                            let fty = self
                                .fields
                                .get(self_ty)
                                .and_then(|fs| fs.get(recv[1].as_str()))
                                .copied()
                                .unwrap_or("");
                            if !fty.is_empty() {
                                // typed field: either a crate method or a
                                // std-container method (unresolvable, fine)
                                return self.methods_on(fty, name);
                            }
                            return self.unique_method(name);
                        }
                    } else if let Some(tr) = ctx.trait_name {
                        if recv.len() == 1 {
                            // trait default method body: CHA over every impl
                            let cha = self.trait_methods(tr, name);
                            if !cha.is_empty() {
                                return cha;
                            }
                            return self.unique_method(name);
                        }
                    }
                }
                if !recv.is_empty() {
                    return self.unique_method(name);
                }
                // expression receiver (`f(x).method(…)`): no ident chain to
                // anchor a guess — leave unresolved.
                Vec::new()
            }
            _ => Vec::new(),
        }
    }

    /// Fallback: resolve by name when the method is defined exactly once
    /// crate-wide, excluding alloc/std names (see module docs).
    fn unique_method(&self, name: &str) -> Vec<&'a FnDef> {
        if ALLOC_METHODS.contains(&name) || STD_METHODS.contains(&name) {
            return Vec::new();
        }
        match self.method_by_name.get(name) {
            Some(cands) if cands.len() == 1 => cands.clone(),
            _ => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::{lex, Tok, TokKind};
    use crate::parser::parse_file;

    fn parse(path: &str, src: &str) -> ParsedFile {
        let toks = lex(src);
        let code: Vec<&Tok> = toks
            .iter()
            .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .collect();
        parse_file(path, &code)
    }

    fn first_event_resolution(files: &[ParsedFile]) -> Vec<String> {
        let ix = Index::new(files);
        let caller = &files[0].fns[0];
        let ctx = Ctx::of(caller);
        let mut out = Vec::new();
        crate::ast::for_each_event(&caller.body, &mut |_s, ev| {
            if matches!(ev, Event::Method { .. } | Event::PathCall { .. }) {
                for callee in ix.resolve(ev, &ctx) {
                    out.push(callee.qname());
                }
            }
        });
        out
    }

    #[test]
    fn self_and_field_receivers_resolve_through_types() {
        let src = "struct A { inner: B }\n\
                   impl A { fn top(&self) { self.step(); self.inner.run(); } fn step(&self) {} }\n\
                   struct B;\nimpl B { fn run(&self) {} }\n";
        let files = vec![parse("rust/src/m/mod.rs", src)];
        assert_eq!(first_event_resolution(&files), ["A::step", "B::run"]);
    }

    #[test]
    fn trait_default_self_calls_resolve_via_cha() {
        let src = "trait T { fn go(&self) { self.hook(); } }\n\
                   struct X;\nimpl T for X { fn hook(&self) {} }\n\
                   struct Y;\nimpl T for Y { fn hook(&self) {} }\n";
        let files = vec![parse("rust/src/m/mod.rs", src)];
        assert_eq!(first_event_resolution(&files), ["X::hook", "Y::hook"]);
    }

    #[test]
    fn std_method_names_never_resolve_by_unique_fallback() {
        // `Parser::expect` is the only `expect` in the crate, but a call on
        // an unrelated receiver must NOT resolve to it.
        let src = "struct P;\nimpl P { fn expect(&self) {} }\n\
                   struct Q;\nimpl Q { fn f(&self, v: Option<u8>) { v.expect(\"boom\"); } }\n";
        let pf = parse("rust/src/m/mod.rs", src);
        let files = vec![pf];
        let ix = Index::new(&files);
        let caller = &files[0].fns[1];
        let ctx = Ctx::of(caller);
        let mut resolved = Vec::new();
        crate::ast::for_each_event(&caller.body, &mut |_s, ev| {
            if let Event::Method { .. } = ev {
                resolved.extend(ix.resolve(ev, &ctx).iter().map(|f| f.qname()));
            }
        });
        assert!(resolved.is_empty(), "{resolved:?}");
    }

    #[test]
    fn module_qualified_free_fns_resolve() {
        let a = parse("rust/src/gp/mod.rs", "fn caller() { stats::erf(1.0); }\n");
        let b = parse("rust/src/gp/stats.rs", "pub fn erf(x: f64) -> f64 { x }\n");
        let files = vec![a, b];
        assert_eq!(first_event_resolution(&files), ["gp::stats::erf"]);
    }
}
