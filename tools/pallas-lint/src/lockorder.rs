//! R7 `lock-order`: extract the Mutex/Condvar acquisition orders in the
//! concurrency-bearing modules (`pool`, `engine/clock.rs`, `coordinator`),
//! build the lock-order graph, and fail on any cycle.
//!
//! This is the static complement of the nightly TSan job: TSan only sees
//! executed interleavings; a cyclic lock order is a deadlock waiting for
//! the interleaving CI never ran.
//!
//! Model: a *lock class* is `module::receiver-chain` (`pool::slots` for
//! `self.slots.lock()` in `pool/mod.rs`), so every instance of a field
//! shares a class — the classic conservative approximation. A `lock()`
//! guard bound by `let` is held to the end of its block; a temporary
//! guard dies with its statement (nested blocks of that statement run
//! with it held); `drop(guard)` releases early. Calls made while holding
//! a lock contribute the callee's transitive acquisitions as edges.

use crate::ast::{for_each_event, Event, FnDef, Stmt};
use crate::callgraph::{excluded_from_graph, fn_key, graph_skip, in_dir, FnKey};
use crate::diag::{Diagnostic, RuleId};
use crate::resolve::{Ctx, Index};
use std::collections::{BTreeMap, BTreeSet};

/// Files whose locking behavior R7 audits.
fn r7_scope(path: &str) -> bool {
    in_dir(path, "pool") || path.ends_with("engine/clock.rs") || in_dir(path, "coordinator")
}

/// Lock class of a `lock()` call: `module::receiver-chain`, `self.`
/// stripped so methods and free fns over the same field agree.
fn lock_class(fn_def: &FnDef, recv: &[String]) -> String {
    let name = if recv.is_empty() { "<expr>".to_string() } else { recv.join(".") };
    let name = name.strip_prefix("self.").unwrap_or(&name);
    format!("{}::{name}", fn_def.module)
}

type Edges<'a> = BTreeMap<(String, String), Vec<(&'a str, u32)>>;
type AcqMemo<'a> = BTreeMap<FnKey<'a>, BTreeSet<String>>;

/// Run R7 over the index; returns unsorted diagnostics.
pub fn check<'a>(index: &Index<'a>) -> Vec<Diagnostic> {
    let mut memo: AcqMemo<'a> = BTreeMap::new();
    let mut edges: Edges<'a> = BTreeMap::new();
    for pf in index.files {
        if excluded_from_graph(&pf.path) || !r7_scope(&pf.path) {
            continue;
        }
        for fn_def in &pf.fns {
            if graph_skip(fn_def) {
                continue;
            }
            walk_locks(index, &mut memo, fn_def, &fn_def.body, &[], &mut edges);
        }
    }
    // Cycle detection over lock classes: an edge (a, b) is part of a cycle
    // when b reaches a (or a == b).
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        adj.entry(a).or_default().insert(b);
    }
    let mut out = Vec::new();
    for ((a, b), sites) in &edges {
        if a == b || reaches(&adj, b, a) {
            for (file, line) in sites {
                out.push(Diagnostic {
                    path: file.to_string(),
                    line: *line,
                    rule: RuleId::LockOrder,
                    message: format!(
                        "lock-order cycle: `{a}` is held while `{b}` is acquired here, and the \
                         reverse order exists elsewhere; pick one global acquisition order"
                    ),
                });
            }
        }
    }
    out
}

/// Does `src` reach `dst` in the lock-order graph?
fn reaches(adj: &BTreeMap<&str, BTreeSet<&str>>, src: &str, dst: &str) -> bool {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut stack = vec![src];
    seen.insert(src);
    while let Some(x) = stack.pop() {
        if x == dst {
            return true;
        }
        if let Some(next) = adj.get(x) {
            for y in next {
                if seen.insert(y) {
                    stack.push(y);
                }
            }
        }
    }
    false
}

/// Every lock class `fn_def` (transitively) acquires, for call-under-lock
/// edges. Memoized; recursion cycles contribute nothing (conservative).
fn transitive_acquires<'a>(
    index: &Index<'a>,
    memo: &mut AcqMemo<'a>,
    fn_def: &'a FnDef,
    stack: &mut BTreeSet<FnKey<'a>>,
) -> BTreeSet<String> {
    let key = fn_key(fn_def);
    if let Some(got) = memo.get(&key) {
        return got.clone();
    }
    if stack.contains(&key) {
        return BTreeSet::new();
    }
    stack.insert(key.clone());
    let mut events = Vec::new();
    for_each_event(&fn_def.body, &mut |_s, ev| events.push(ev));
    let mut acq = BTreeSet::new();
    let ctx = Ctx::of(fn_def);
    let in_scope = r7_scope(&fn_def.file) && !graph_skip(fn_def);
    for ev in events {
        if let Event::Method { recv, name, .. } = ev {
            if name == "lock" && in_scope {
                acq.insert(lock_class(fn_def, recv));
            }
        }
        if matches!(ev, Event::Method { .. } | Event::PathCall { .. }) {
            for callee in index.resolve(ev, &ctx) {
                if graph_skip(callee) {
                    continue;
                }
                acq.extend(transitive_acquires(index, memo, callee, stack));
            }
        }
    }
    stack.remove(&key);
    memo.insert(key, acq.clone());
    acq
}

/// Walk a block's statements tracking which lock classes are held, and
/// record held→acquired edges. `held` carries the enclosing blocks' live
/// guards.
fn walk_locks<'a>(
    index: &Index<'a>,
    memo: &mut AcqMemo<'a>,
    fn_def: &'a FnDef,
    stmts: &[Stmt],
    held: &[(String, Option<Vec<String>>)],
    edges: &mut Edges<'a>,
) {
    // Guards `let`-bound in *this* block, live until its end (or `drop`).
    let mut mine: Vec<(String, Option<Vec<String>>)> = Vec::new();
    for s in stmts {
        // Guards acquired in this statement; temporaries die with it.
        let mut stmt_locks: Vec<(String, Option<Vec<String>>)> = Vec::new();
        for ev in &s.events {
            match ev {
                Event::Method { recv, name, line } if name == "lock" => {
                    let cls = lock_class(fn_def, recv);
                    for (h, _) in held.iter().chain(&mine).chain(&stmt_locks) {
                        edges.entry((h.clone(), cls.clone())).or_default().push((fn_def.file.as_str(), *line));
                    }
                    let bindings = if s.is_let { Some(s.bindings.clone()) } else { None };
                    stmt_locks.push((cls, bindings));
                }
                Event::Method { .. } | Event::PathCall { .. } => {
                    if let Event::PathCall { segs, .. } = ev {
                        if segs.last().map(String::as_str) == Some("drop") {
                            continue; // `drop(x)` releases, handled below
                        }
                    }
                    let ctx = Ctx::of(fn_def);
                    for callee in index.resolve(ev, &ctx) {
                        if graph_skip(callee) {
                            continue;
                        }
                        let mut stack = BTreeSet::new();
                        for cls2 in transitive_acquires(index, memo, callee, &mut stack) {
                            for (h, _) in held.iter().chain(&mine).chain(&stmt_locks) {
                                if *h != cls2 {
                                    edges
                                        .entry((h.clone(), cls2.clone()))
                                        .or_default()
                                        .push((fn_def.file.as_str(), ev.line()));
                                }
                            }
                        }
                    }
                }
                _ => {}
            }
        }
        // `drop(guard)` in this statement releases the named guards.
        let mut dropped: BTreeSet<&str> = BTreeSet::new();
        let names_drop = s.events.iter().any(|ev| {
            matches!(ev, Event::PathCall { segs, .. } if segs.last().map(String::as_str) == Some("drop"))
        });
        if names_drop {
            for ev in &s.events {
                if let Event::Word { name, .. } = ev {
                    dropped.insert(name);
                }
            }
            mine.retain(|(_, b)| {
                !b.as_ref().is_some_and(|names| names.iter().any(|n| dropped.contains(n.as_str())))
            });
        }
        // Nested blocks run with this statement's locks held (if-let /
        // match over a `lock()` scrutinee).
        for ch in &s.children {
            let inner: Vec<(String, Option<Vec<String>>)> =
                held.iter().chain(&mine).chain(&stmt_locks).cloned().collect();
            walk_locks(index, memo, fn_def, ch, &inner, edges);
        }
        // `let`-bound guards persist to the end of this block.
        for (cls, b) in stmt_locks {
            if b.is_some() {
                mine.push((cls, b));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::ParsedFile;
    use crate::lexer::{lex, Tok, TokKind};
    use crate::parser::parse_file;

    fn parse(path: &str, src: &str) -> ParsedFile {
        let toks = lex(src);
        let code: Vec<&Tok> = toks
            .iter()
            .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .collect();
        parse_file(path, &code)
    }

    fn run(src: &str) -> Vec<Diagnostic> {
        let files = vec![parse("rust/src/pool/mod.rs", src)];
        let ix = Index::new(&files);
        check(&ix)
    }

    #[test]
    fn two_lock_cycle_is_flagged() {
        let src = "struct S { a: Mutex<u8>, b: Mutex<u8> }\n\
                   impl S {\n\
                       fn ab(&self) { let ga = self.a.lock(); let gb = self.b.lock(); }\n\
                       fn ba(&self) { let gb = self.b.lock(); let ga = self.a.lock(); }\n\
                   }\n";
        let diags = run(src);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags[0].message.contains("pool::a") && diags[0].message.contains("pool::b"));
    }

    #[test]
    fn consistent_order_is_clean_even_through_a_call() {
        let src = "struct S { a: Mutex<u8>, b: Mutex<u8> }\n\
                   impl S {\n\
                       fn ab(&self) { let ga = self.a.lock(); let gb = self.b.lock(); }\n\
                       fn via(&self) { let ga = self.a.lock(); self.tail(); }\n\
                       fn tail(&self) { let gb = self.b.lock(); }\n\
                   }\n";
        assert!(run(src).is_empty());
    }

    #[test]
    fn drop_releases_the_guard() {
        let src = "struct S { a: Mutex<u8>, b: Mutex<u8> }\n\
                   impl S {\n\
                       fn ab(&self) { let ga = self.a.lock(); drop(ga); let gb = self.b.lock(); }\n\
                       fn ba(&self) { let gb = self.b.lock(); let ga = self.a.lock(); }\n\
                   }\n";
        // Without the drop this is the two-lock cycle; with it, `ab` holds
        // nothing when acquiring b, so only the b→a edge exists — acyclic.
        assert!(run(src).is_empty());
    }

    #[test]
    fn out_of_scope_modules_are_ignored() {
        let src = "struct S { a: Mutex<u8>, b: Mutex<u8> }\n\
                   impl S {\n\
                       fn ab(&self) { let ga = self.a.lock(); let gb = self.b.lock(); }\n\
                       fn ba(&self) { let gb = self.b.lock(); let ga = self.a.lock(); }\n\
                   }\n";
        let files = vec![parse("rust/src/gp/mod.rs", src)];
        let ix = Index::new(&files);
        assert!(check(&ix).is_empty());
    }
}
