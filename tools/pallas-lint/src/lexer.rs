//! Hand-rolled lexer over the Rust token grammar — just enough structure
//! for the rule engine: identifiers, punctuation, literals, and comments,
//! with string/char/raw-string/lifetime disambiguation handled correctly
//! so a `"partial_cmp"` inside a string literal can never trip a rule.
//!
//! The lexer is total: any byte sequence produces a token stream (stray
//! characters become [`TokKind::Punct`], unterminated literals run to end
//! of file). Linting must never panic on weird-but-compiling input.

/// Kind of a lexed token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (raw identifiers are stored without `r#`).
    Ident,
    /// Lifetime or loop label (`'a`, `'static`), stored without the quote.
    Lifetime,
    /// String-like literal: `"…"`, `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`.
    Str,
    /// Character or byte literal: `'x'`, `'\n'`, `b'['`.
    Char,
    /// Numeric literal (integer or float, any base, with suffix).
    Num,
    /// A single punctuation character (`.`, `:`, `(`, `#`, …).
    Punct,
    /// `// …` comment; `text` is the body after the slashes (pragmas
    /// live here).
    LineComment,
    /// `/* … */` comment, possibly nested.
    BlockComment,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    /// Token kind.
    pub kind: TokKind,
    /// Token text: identifier name, the punctuation character, or the
    /// line-comment body. Empty for literals and block comments — their
    /// content never participates in a rule.
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

/// Lex `src` into a token stream.
pub fn lex(src: &str) -> Vec<Tok> {
    let mut lx = Lexer { chars: src.chars().collect(), i: 0, line: 1, toks: Vec::new() };
    lx.run();
    lx.toks
}

fn ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    toks: Vec<Tok>,
}

impl Lexer {
    fn peek(&self, k: usize) -> Option<char> {
        self.chars.get(self.i + k).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied();
        if let Some(ch) = c {
            self.i += 1;
            if ch == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, kind: TokKind, text: String, line: u32) {
        self.toks.push(Tok { kind, text, line });
    }

    fn run(&mut self) {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment(line);
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment(line);
            } else if c == '"' {
                self.bump();
                self.string_body(line);
            } else if c == '\'' {
                self.quote(line);
            } else if c == 'r' && self.raw_string_ahead(0) {
                self.bump();
                self.raw_string(line);
            } else if c == 'b' && self.peek(1) == Some('\'') {
                self.bump();
                self.bump();
                self.char_body(line);
            } else if c == 'b' && self.peek(1) == Some('"') {
                self.bump();
                self.bump();
                self.string_body(line);
            } else if c == 'b' && self.peek(1) == Some('r') && self.raw_string_ahead(1) {
                self.bump();
                self.bump();
                self.raw_string(line);
            } else if c == 'r' && self.peek(1) == Some('#') && self.peek(2).is_some_and(ident_start) {
                // Raw identifier `r#type`: strip the sigil, keep the name.
                self.bump();
                self.bump();
                self.ident(line);
            } else if ident_start(c) {
                self.ident(line);
            } else if c.is_ascii_digit() {
                self.number(line);
            } else {
                self.bump();
                self.push(TokKind::Punct, c.to_string(), line);
            }
        }
    }

    fn line_comment(&mut self, line: u32) {
        self.bump();
        self.bump();
        let mut text = String::new();
        while let Some(ch) = self.peek(0) {
            if ch == '\n' {
                break;
            }
            text.push(ch);
            self.bump();
        }
        self.push(TokKind::LineComment, text, line);
    }

    fn block_comment(&mut self, line: u32) {
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while depth > 0 {
            match self.bump() {
                None => break,
                Some('/') if self.peek(0) == Some('*') => {
                    self.bump();
                    depth += 1;
                }
                Some('*') if self.peek(0) == Some('/') => {
                    self.bump();
                    depth -= 1;
                }
                _ => {}
            }
        }
        self.push(TokKind::BlockComment, String::new(), line);
    }

    /// Body of a `"…"` / `b"…"` literal, opening quote already consumed.
    fn string_body(&mut self, line: u32) {
        loop {
            match self.bump() {
                None | Some('"') => break,
                Some('\\') => {
                    self.bump();
                }
                _ => {}
            }
        }
        self.push(TokKind::Str, String::new(), line);
    }

    /// True if an `r"…"` / `r#…#"…"` opener sits at offset `off` (which
    /// must point at the `r`).
    fn raw_string_ahead(&self, off: usize) -> bool {
        let mut k = off + 1;
        while self.peek(k) == Some('#') {
            k += 1;
        }
        self.peek(k) == Some('"')
    }

    /// Raw string body; position is just past the `r` (and `b`).
    fn raw_string(&mut self, line: u32) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        loop {
            match self.bump() {
                None => break,
                Some('"') => {
                    let mut k = 0;
                    while k < hashes && self.peek(k) == Some('#') {
                        k += 1;
                    }
                    if k == hashes {
                        for _ in 0..hashes {
                            self.bump();
                        }
                        break;
                    }
                }
                _ => {}
            }
        }
        self.push(TokKind::Str, String::new(), line);
    }

    /// A bare `'`: char literal (`'x'`, `'\n'`) or lifetime (`'a`).
    fn quote(&mut self, line: u32) {
        self.bump();
        match (self.peek(0), self.peek(1)) {
            (Some('\\'), _) => self.char_escape_body(line),
            (Some(c), Some('\'')) if c != '\'' => {
                self.bump();
                self.bump();
                self.push(TokKind::Char, String::new(), line);
            }
            (Some(c), _) if ident_start(c) => {
                let mut text = String::new();
                while let Some(ch) = self.peek(0) {
                    if !ident_continue(ch) {
                        break;
                    }
                    text.push(ch);
                    self.bump();
                }
                self.push(TokKind::Lifetime, text, line);
            }
            _ => self.push(TokKind::Punct, '\''.to_string(), line),
        }
    }

    /// Body of a char/byte literal, opening quote already consumed.
    fn char_body(&mut self, line: u32) {
        if self.peek(0) == Some('\\') {
            self.char_escape_body(line);
            return;
        }
        self.bump();
        if self.peek(0) == Some('\'') {
            self.bump();
        }
        self.push(TokKind::Char, String::new(), line);
    }

    /// Escaped char literal body (`\n`, `\'`, `\x41`, `\u{1F600}`);
    /// position is at the backslash.
    fn char_escape_body(&mut self, line: u32) {
        self.bump(); // backslash
        if self.peek(0) == Some('u') {
            self.bump();
            if self.peek(0) == Some('{') {
                while let Some(ch) = self.bump() {
                    if ch == '}' {
                        break;
                    }
                }
            }
        } else {
            self.bump(); // the escaped character itself
        }
        // Consume through the closing quote (covers multi-char escapes
        // like \x41); a newline means a malformed literal — stop there
        // rather than swallowing the rest of the file.
        while let Some(ch) = self.peek(0) {
            if ch == '\'' {
                self.bump();
                break;
            }
            if ch == '\n' {
                break;
            }
            self.bump();
        }
        self.push(TokKind::Char, String::new(), line);
    }

    fn ident(&mut self, line: u32) {
        let mut text = String::new();
        while let Some(ch) = self.peek(0) {
            if !ident_continue(ch) {
                break;
            }
            text.push(ch);
            self.bump();
        }
        self.push(TokKind::Ident, text, line);
    }

    fn number(&mut self, line: u32) {
        // `0x…`/`0b…`/`0o…` disable exponent-sign handling so `0x1e-5`
        // lexes as a number minus a number.
        let radix_prefix = self.peek(0) == Some('0') && matches!(self.peek(1), Some('x' | 'X' | 'b' | 'o'));
        let mut prev = ' ';
        while let Some(ch) = self.peek(0) {
            let exp_sign = !radix_prefix && (ch == '+' || ch == '-') && matches!(prev, 'e' | 'E');
            let fraction = ch == '.' && prev != '.' && self.peek(1).is_some_and(|d| d.is_ascii_digit());
            if ch.is_ascii_alphanumeric() || ch == '_' || exp_sign || fraction {
                prev = ch;
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokKind::Num, String::new(), line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src).into_iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text).collect()
    }

    #[test]
    fn identifiers_in_strings_and_comments_are_invisible() {
        let src = r##"
            // partial_cmp in a comment
            /* HashMap /* nested */ still comment */
            let s = "partial_cmp";
            let r = r#"Instant::now"#;
            let b = b"SystemTime";
            let real = total_cmp;
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"total_cmp".to_string()));
        for bad in ["partial_cmp", "HashMap", "Instant", "SystemTime"] {
            assert!(!ids.contains(&bad.to_string()), "{bad} leaked out of a literal/comment");
        }
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let toks = lex("fn f<'a>(x: &'a str) { let c = 'x'; let q = '\\''; let b = b'['; }");
        let lifetimes: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2, "{toks:?}");
        let chars = toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 3, "{toks:?}");
    }

    #[test]
    fn line_numbers_survive_multiline_literals() {
        let src = "let a = \"two\nlines\";\nlet marker = 1;";
        let toks = lex(src);
        let marker = toks.iter().find(|t| t.text == "marker").map(|t| t.line);
        assert_eq!(marker, Some(3));
    }

    #[test]
    fn raw_strings_with_hashes_terminate_correctly() {
        let toks = lex(r###"let x = r#"quote " inside"#; let after = 1;"###);
        assert!(toks.iter().any(|t| t.text == "after"));
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Str).count(), 1);
    }

    #[test]
    fn numbers_do_not_swallow_method_calls() {
        let ids = idents("a.0.total_cmp(&b.0); 1.5e-3; 0x1e; x.max(1.0)");
        assert!(ids.contains(&"total_cmp".to_string()));
        assert!(ids.contains(&"max".to_string()));
    }

    #[test]
    fn raw_identifiers_keep_their_name() {
        let ids = idents("let r#type = 1;");
        assert!(ids.contains(&"type".to_string()));
    }

    #[test]
    fn comment_text_is_captured_for_pragmas() {
        let toks = lex("let x = 1; // pallas-lint: allow(R5) — reason\n");
        let c = toks.iter().find(|t| t.kind == TokKind::LineComment);
        assert!(c.is_some_and(|t| t.text.contains("pallas-lint: allow(R5)")));
    }
}
