//! R8 `config-validation`: every raw numeric field read in the `config`
//! module must flow through the validated accessors — `count()` (the
//! clamping constructor) or an explicit `try_from` conversion — before
//! being used as a count, capacity, or index.
//!
//! The rule is a per-statement dataflow check, deliberately local: an
//! `as_int()` call is sanctioned when (a) it is inside `count()` itself,
//! (b) `try_from`/`count` appears in the same statement, or (c) its
//! `let`-binding is later used in the same block together with
//! `try_from`/`count`. Anything else is a raw read that can smuggle a
//! negative or oversized value into an allocation size.

use crate::ast::{stmt_events_flat, Event, FnDef, Stmt};
use crate::callgraph::in_dir;
use crate::diag::{Diagnostic, RuleId};
use crate::resolve::Index;
use std::collections::BTreeSet;

/// Run R8 over the index; returns unsorted diagnostics.
pub fn check(index: &Index<'_>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut seen: BTreeSet<(String, u32)> = BTreeSet::new();
    for pf in index.files {
        if !in_dir(&pf.path, "config") {
            continue;
        }
        for fn_def in &pf.fns {
            if fn_def.in_test || fn_def.name == "count" {
                continue;
            }
            block(fn_def, &fn_def.body, &mut out, &mut seen);
        }
    }
    out
}

/// Does this event sanction a raw read in its statement?
fn sanctions(ev: &Event) -> bool {
    match ev {
        Event::PathCall { segs, .. } => {
            matches!(segs.last().map(String::as_str), Some("try_from" | "count"))
        }
        Event::Method { name, .. } => name == "count",
        _ => false,
    }
}

fn block(fn_def: &FnDef, stmts: &[Stmt], out: &mut Vec<Diagnostic>, seen: &mut BTreeSet<(String, u32)>) {
    for (i, s) in stmts.iter().enumerate() {
        let flat = stmt_events_flat(s);
        let sites: Vec<u32> = flat
            .iter()
            .filter_map(|ev| match ev {
                Event::Method { name, line, .. } if name == "as_int" => Some(*line),
                _ => None,
            })
            .collect();
        if !sites.is_empty() {
            let mut sanctioned = flat.iter().any(|ev| sanctions(ev));
            if !sanctioned && s.is_let && !s.bindings.is_empty() {
                let binds: BTreeSet<&str> = s.bindings.iter().map(String::as_str).collect();
                for later in &stmts[i + 1..] {
                    let lf = stmt_events_flat(later);
                    let uses = lf.iter().any(
                        |ev| matches!(ev, Event::Word { name, .. } if binds.contains(name.as_str())),
                    );
                    if uses && lf.iter().any(|ev| sanctions(ev)) {
                        sanctioned = true;
                        break;
                    }
                }
            }
            if !sanctioned {
                for line in sites {
                    if seen.insert((fn_def.file.clone(), line)) {
                        out.push(Diagnostic {
                            path: fn_def.file.clone(),
                            line,
                            rule: RuleId::ConfigValidation,
                            message: format!(
                                "raw `as_int` read in `{}` does not flow through `count()`/`try_from`; \
                                 validate the value before use or justify with \
                                 `// pallas-lint: allow(R8) — <why>`",
                                fn_def.qname()
                            ),
                        });
                    }
                }
            }
        }
        for ch in &s.children {
            block(fn_def, ch, out, seen);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::ParsedFile;
    use crate::lexer::{lex, Tok, TokKind};
    use crate::parser::parse_file;

    fn run(path: &str, src: &str) -> Vec<Diagnostic> {
        let toks = lex(src);
        let code: Vec<&Tok> = toks
            .iter()
            .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .collect();
        let files: Vec<ParsedFile> = vec![parse_file(path, &code)];
        let ix = Index::new(&files);
        check(&ix)
    }

    #[test]
    fn unsanctioned_as_int_is_flagged() {
        let src = "impl Cfg {\n\
                       fn workers(&self) -> i64 { let raw = self.v.as_int(); raw.wrapping_mul(2) }\n\
                   }\n";
        let diags = run("rust/src/config/mod.rs", src);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 2);
        assert!(diags[0].message.contains("Cfg::workers"));
    }

    #[test]
    fn same_statement_try_from_sanctions() {
        let src = "impl Cfg {\n\
                       fn workers(&self) -> Option<usize> { usize::try_from(self.v.as_int()).ok() }\n\
                   }\n";
        assert!(run("rust/src/config/mod.rs", src).is_empty());
    }

    #[test]
    fn later_statement_binding_flow_sanctions() {
        let src = "impl Cfg {\n\
                       fn workers(&self) -> Option<usize> {\n\
                           let x = self.v.as_int();\n\
                           usize::try_from(x).ok()\n\
                       }\n\
                   }\n";
        assert!(run("rust/src/config/mod.rs", src).is_empty());
    }

    #[test]
    fn count_fn_and_non_config_files_are_exempt() {
        let src = "impl Cfg {\n\
                       fn count(&self) -> i64 { self.v.as_int() }\n\
                   }\n";
        assert!(run("rust/src/config/mod.rs", src).is_empty());
        let src2 = "impl Gp { fn f(&self) -> i64 { self.v.as_int() } }\n";
        assert!(run("rust/src/gp/mod.rs", src2).is_empty());
    }
}
