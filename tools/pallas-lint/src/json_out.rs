//! Machine-readable lint reports through the main crate's canonical JSON
//! writer (`mmgpei::report::json`), so `pallas-lint --json` artifacts are
//! byte-stable the same way the bench reports are: two runs over the same
//! tree produce identical files, which is what lets CI archive them next
//! to `bench-reports` and diff across commits.

use crate::diag::Diagnostic;
use mmgpei::report::json::Json;

/// Render `diags` (already sorted by the caller) as a canonical JSON
/// document: `{"schema": "pallas-lint-v1", "count": N, "findings": […]}`.
pub fn render(diags: &[Diagnostic]) -> String {
    let findings: Vec<Json> = diags
        .iter()
        .map(|d| {
            Json::Obj(vec![
                ("path".into(), Json::str(d.path.as_str())),
                ("line".into(), Json::num(f64::from(d.line))),
                ("rule".into(), Json::str(d.rule.code())),
                ("name".into(), Json::str(d.rule.name())),
                ("message".into(), Json::str(d.message.as_str())),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::str("pallas-lint-v1")),
        ("count".into(), Json::num(diags.len() as f64)),
        ("findings".into(), Json::Arr(findings)),
    ])
    .to_pretty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diag::RuleId;

    #[test]
    fn report_is_canonical_and_parses_back() {
        let diags = vec![Diagnostic {
            path: "rust/src/gp/mod.rs".to_string(),
            line: 7,
            rule: RuleId::HotPathAlloc,
            message: "`.push()` allocates".to_string(),
        }];
        let text = render(&diags);
        assert_eq!(text, render(&diags), "serialization must be deterministic");
        let doc = mmgpei::report::json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some("pallas-lint-v1"));
        assert_eq!(doc.get("count").unwrap().as_u64(), Some(1));
        let f = &doc.get("findings").unwrap().as_arr().unwrap()[0];
        assert_eq!(f.get("rule").unwrap().as_str(), Some("R6"));
        assert_eq!(f.get("name").unwrap().as_str(), Some("hot-path-alloc"));
        assert_eq!(f.get("line").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn empty_report_has_zero_count() {
        let text = render(&[]);
        let doc = mmgpei::report::json::parse(&text).unwrap();
        assert_eq!(doc.get("count").unwrap().as_u64(), Some(0));
        assert!(doc.get("findings").unwrap().as_arr().unwrap().is_empty());
    }
}
