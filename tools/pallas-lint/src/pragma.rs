//! `// pallas-lint: allow(<rule>, …) — <justification>` pragma parsing.
//!
//! A pragma suppresses the listed rules on its *target line*: the line
//! the comment trails, or — when the comment stands alone on its line —
//! the next line holding any code token. The justification is mandatory:
//! a pragma without one suppresses nothing and is itself reported as a
//! [`RuleId::Pragma`] finding, so every `allow` in the tree documents
//! *why* the invariant holds at that site.

use crate::diag::RuleId;
use crate::lexer::{Tok, TokKind};

/// One parsed, well-formed pragma.
#[derive(Clone, Debug)]
pub struct Pragma {
    /// Rules this pragma suppresses.
    pub rules: Vec<RuleId>,
    /// Line whose findings are suppressed.
    pub target_line: u32,
}

/// Scan a token stream for pragmas. Returns the well-formed pragmas and
/// `(line, message)` errors for malformed ones.
pub fn collect(toks: &[Tok]) -> (Vec<Pragma>, Vec<(u32, String)>) {
    let mut pragmas = Vec::new();
    let mut errors = Vec::new();
    for (i, tok) in toks.iter().enumerate() {
        if tok.kind != TokKind::LineComment {
            continue;
        }
        let body = tok.text.trim_start_matches('/'); // doc comments: `/// pallas-lint: …`
        let Some(rest) = body.trim_start().strip_prefix("pallas-lint:") else {
            continue;
        };
        match parse_allow(rest.trim_start()) {
            Ok(rules) => {
                let target_line = target_line(toks, i, tok.line);
                pragmas.push(Pragma { rules, target_line });
            }
            Err(msg) => errors.push((tok.line, msg)),
        }
    }
    (pragmas, errors)
}

/// Parse `allow(R1, R5) — justification` (separator `—`/`-`/`:` optional,
/// justification not).
fn parse_allow(rest: &str) -> Result<Vec<RuleId>, String> {
    let Some(args) = rest.strip_prefix("allow(") else {
        return Err("unknown pallas-lint directive; expected `allow(<rule>, …) — <justification>`".into());
    };
    let Some(close) = args.find(')') else {
        return Err("unclosed `allow(` in pallas-lint pragma".into());
    };
    let mut rules = Vec::new();
    for part in args[..close].split(',') {
        match RuleId::parse(part) {
            Some(r) => rules.push(r),
            None => return Err(format!("unknown rule `{}` in pallas-lint pragma", part.trim())),
        }
    }
    if rules.is_empty() {
        return Err("empty rule list in pallas-lint pragma".into());
    }
    let just = args[close + 1..]
        .trim()
        .trim_start_matches(|c: char| c == '—' || c == '–' || c == '-' || c == ':')
        .trim();
    if just.is_empty() {
        return Err("pallas-lint allow pragma must carry a written justification after the rule list".into());
    }
    Ok(rules)
}

/// The line a pragma applies to: its own line when code precedes the
/// comment there, else the next line bearing a code token.
fn target_line(toks: &[Tok], comment_idx: usize, comment_line: u32) -> u32 {
    let is_code = |t: &Tok| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment);
    let trailing = toks[..comment_idx].iter().any(|t| t.line == comment_line && is_code(t));
    if trailing {
        return comment_line;
    }
    toks[comment_idx + 1..]
        .iter()
        .find(|t| is_code(t) && t.line > comment_line)
        .map_or(comment_line, |t| t.line)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn standalone_pragma_targets_next_code_line() {
        let src = "fn f() {\n    // pallas-lint: allow(R5) — invariant: guarded above\n\n    g();\n}\n";
        let (pragmas, errors) = collect(&lex(src));
        assert!(errors.is_empty(), "{errors:?}");
        assert_eq!(pragmas.len(), 1);
        assert_eq!(pragmas[0].target_line, 4);
        assert_eq!(pragmas[0].rules, vec![RuleId::LibPanic]);
    }

    #[test]
    fn trailing_pragma_targets_its_own_line() {
        let src = "let x = v.last(); // pallas-lint: allow(R5) — non-empty by construction\n";
        let (pragmas, errors) = collect(&lex(src));
        assert!(errors.is_empty());
        assert_eq!(pragmas[0].target_line, 1);
    }

    #[test]
    fn missing_justification_is_an_error() {
        let (pragmas, errors) = collect(&lex("// pallas-lint: allow(R1)\nlet x = 1;\n"));
        assert!(pragmas.is_empty());
        assert_eq!(errors.len(), 1);
        assert!(errors[0].1.contains("justification"), "{errors:?}");
    }

    #[test]
    fn unknown_rule_is_an_error() {
        let (pragmas, errors) = collect(&lex("// pallas-lint: allow(R9) — because\nlet x = 1;\n"));
        assert!(pragmas.is_empty());
        assert!(errors[0].1.contains("unknown rule"));
    }

    #[test]
    fn multi_rule_pragma_and_name_aliases() {
        let src = "// pallas-lint: allow(R3, lib-panic) — measurement plumbing only\nlet t = now();\n";
        let (pragmas, errors) = collect(&lex(src));
        assert!(errors.is_empty());
        assert_eq!(pragmas[0].rules, vec![RuleId::WallClock, RuleId::LibPanic]);
    }
}
