//! Crate-wide call-graph machinery shared by the graph rules: function
//! identity keys, scope exclusion, and reachability BFS with parent
//! chains for diagnostics.

use crate::ast::{for_each_event, Event, FnDef};
use crate::resolve::{Ctx, Index};
use std::collections::BTreeMap;

/// Stable identity of a `fn` item: (file, line, qualified name) — line
/// alone is not enough, terse one-line impls can put several fns on it.
pub type FnKey<'a> = (&'a str, u32, String);

/// Key of `fn_def`.
pub fn fn_key(fn_def: &FnDef) -> FnKey<'_> {
    (fn_def.file.as_str(), fn_def.line, fn_def.qname())
}

/// True when `path` has a directory component named `dir` (same matching
/// as the token rules' scoping, duplicated here to keep modules acyclic).
pub fn in_dir(path: &str, dir: &str) -> bool {
    path.starts_with(&format!("{dir}/")) || path.contains(&format!("/{dir}/"))
}

/// Files outside the graph rules' world: test/bench/example trees model
/// harness code, not the serving hot path.
pub fn excluded_from_graph(path: &str) -> bool {
    in_dir(path, "tests") || in_dir(path, "benches") || in_dir(path, "examples")
}

/// Fns the graph rules skip entirely: test items, optional-feature items
/// (the dynamic alloc/TSan gates run the default-features build), and
/// anything in an excluded tree.
pub fn graph_skip(fn_def: &FnDef) -> bool {
    fn_def.in_test || fn_def.in_feature || excluded_from_graph(&fn_def.file)
}

/// Reachability map: fn key → (fn, BFS parent) for every fn statically
/// reachable from `roots` through resolvable calls.
pub type Reach<'a> = BTreeMap<FnKey<'a>, (&'a FnDef, Option<FnKey<'a>>)>;

/// BFS the call graph from `roots` (roots excluded by [`graph_skip`] are
/// dropped). Deterministic: worklist order never affects the key set, and
/// parents only affect diagnostic chains, which follow first-discovery.
pub fn reachable<'a>(index: &Index<'a>, roots: &[&'a FnDef]) -> Reach<'a> {
    let mut seen: Reach<'a> = BTreeMap::new();
    let mut work: Vec<&'a FnDef> = Vec::new();
    for &r in roots {
        if graph_skip(r) {
            continue;
        }
        if seen.insert(fn_key(r), (r, None)).is_none() {
            work.push(r);
        }
    }
    while let Some(fn_def) = work.pop() {
        let ctx = Ctx::of(fn_def);
        for_each_event(&fn_def.body, &mut |_s, ev| {
            if !matches!(ev, Event::Method { .. } | Event::PathCall { .. }) {
                return;
            }
            for callee in index.resolve(ev, &ctx) {
                if graph_skip(callee) {
                    continue;
                }
                let k = fn_key(callee);
                if !seen.contains_key(&k) {
                    seen.insert(k, (callee, Some(fn_key(fn_def))));
                    work.push(callee);
                }
            }
        });
    }
    seen
}

/// Human-readable discovery chain for `key`: `callee ← caller ← … ← root`
/// (capped at 6 hops).
pub fn chain(reach: &Reach<'_>, key: FnKey<'_>) -> String {
    let mut parts = Vec::new();
    let mut k = Some(key);
    while let Some(cur) = k.take() {
        if parts.len() >= 6 {
            break;
        }
        match reach.get(&cur) {
            Some((fn_def, parent)) => {
                parts.push(fn_def.qname());
                k.clone_from(parent);
            }
            None => break,
        }
    }
    parts.join(" ← ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::ParsedFile;
    use crate::lexer::{lex, Tok, TokKind};
    use crate::parser::parse_file;

    fn parse(path: &str, src: &str) -> ParsedFile {
        let toks = lex(src);
        let code: Vec<&Tok> = toks
            .iter()
            .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .collect();
        parse_file(path, &code)
    }

    #[test]
    fn bfs_reaches_through_hops_and_skips_cfg_test() {
        let src = "struct A;\n\
                   impl A { fn root(&self) { self.mid(); } fn mid(&self) { self.leaf(); } fn leaf(&self) {} }\n\
                   #[cfg(test)]\nfn t() { x.push(1); }\n";
        let files = vec![parse("rust/src/m/mod.rs", src)];
        let ix = Index::new(&files);
        let roots: Vec<&crate::ast::FnDef> = vec![&files[0].fns[0]];
        let reach = reachable(&ix, &roots);
        // Reach keys sort by (file, line, qname); all three fns share line 2.
        let names: Vec<String> = reach.values().map(|(f, _)| f.qname()).collect();
        assert_eq!(names, ["A::leaf", "A::mid", "A::root"]);
        let leaf_key = fn_key(&files[0].fns[2]);
        assert_eq!(chain(&reach, leaf_key), "A::leaf ← A::mid ← A::root");
    }
}
