//! Lightweight item/statement tree produced by [`crate::parser`].
//!
//! This is deliberately *not* a full Rust AST: the graph rules (R6–R8)
//! only need to know, per function, which calls/macros/identifiers occur
//! in which statement, which statements bind names, and how blocks nest.
//! Expressions stay flat; types are reduced to the last identifier of
//! their leading path (`Mutex<Vec<f64>>` → `Mutex`).

use std::collections::{BTreeMap, BTreeSet};

/// One call/macro/identifier occurrence inside a statement.
#[derive(Clone, Debug)]
pub enum Event {
    /// `recv.name(…)` — `recv` is the receiver's identifier chain
    /// (`self.field` → `["self", "field"]`), empty when the receiver is a
    /// complex expression (`f(x).name(…)`).
    Method {
        /// Receiver identifier chain, outermost first.
        recv: Vec<String>,
        /// Method name.
        name: String,
        /// 1-based source line of the call.
        line: u32,
    },
    /// `A::B::name(…)` or a bare `name(…)` call — `segs` are the path
    /// segments, last one the called name.
    PathCall {
        /// Path segments (`["Vec", "new"]`, or `["helper"]` for a bare call).
        segs: Vec<String>,
        /// 1-based source line of the call.
        line: u32,
    },
    /// `name!(…)` macro invocation.
    Macro {
        /// Macro name without the `!`.
        name: String,
        /// 1-based source line.
        line: u32,
    },
    /// Any other identifier use (dataflow rules match bindings on these).
    Word {
        /// The identifier.
        name: String,
        /// 1-based source line.
        line: u32,
    },
}

impl Event {
    /// Source line of the event.
    pub fn line(&self) -> u32 {
        match self {
            Event::Method { line, .. }
            | Event::PathCall { line, .. }
            | Event::Macro { line, .. }
            | Event::Word { line, .. } => *line,
        }
    }
}

/// One statement: its events, any nested blocks, and — for `let`
/// statements — the names the pattern binds.
#[derive(Clone, Debug, Default)]
pub struct Stmt {
    /// Whether this is a `let` statement.
    pub is_let: bool,
    /// Names bound by the `let` pattern (empty otherwise).
    pub bindings: Vec<String>,
    /// Events in source order (nested-block events live in `children`).
    pub events: Vec<Event>,
    /// Nested blocks (if/match/loop bodies, bare blocks) in source order.
    pub children: Vec<Vec<Stmt>>,
    /// 1-based line the statement starts on.
    pub line: u32,
}

/// One `fn` item with a parsed body.
#[derive(Clone, Debug)]
pub struct FnDef {
    /// Function name.
    pub name: String,
    /// `impl` self type (`impl Gp` → `Gp`), `None` for free fns and trait
    /// declarations.
    pub self_ty: Option<String>,
    /// Trait name when inside `impl Trait for T` or a `trait` block.
    pub trait_name: Option<String>,
    /// File the fn lives in (normalized path, as passed to the linter).
    pub file: String,
    /// Module path derived from the file path (`gp::stats`).
    pub module: String,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Inside a `#[cfg(test)]` item.
    pub in_test: bool,
    /// Inside a `#[cfg(feature = …)]` item — excluded from graph rules,
    /// which model the default-features build the dynamic gates run.
    pub in_feature: bool,
    /// Body statements.
    pub body: Vec<Stmt>,
}

impl FnDef {
    /// Qualified display name: `Gp::observe`, `EiBackend::select_arm`, or
    /// `gp::stats::erf` for free fns.
    pub fn qname(&self) -> String {
        if let Some(ty) = &self.self_ty {
            return format!("{ty}::{}", self.name);
        }
        if let Some(tr) = &self.trait_name {
            return format!("{tr}::{}", self.name);
        }
        if self.module.is_empty() {
            self.name.clone()
        } else {
            format!("{}::{}", self.module, self.name)
        }
    }
}

/// Everything the parser extracts from one file.
#[derive(Clone, Debug)]
pub struct ParsedFile {
    /// Normalized path the file was linted under.
    pub path: String,
    /// Module path derived from the path.
    pub module: String,
    /// All fn items (free, impl, trait-default), outermost to innermost.
    pub fns: Vec<FnDef>,
    /// Struct fields: type → field → base type of the field.
    pub fields: BTreeMap<String, BTreeMap<String, String>>,
    /// Type names defined or impl'd in this file.
    pub types: BTreeSet<String>,
    /// Trait names declared in this file.
    pub traits: BTreeSet<String>,
}

impl ParsedFile {
    /// Empty file record for `path`.
    pub fn new(path: &str) -> ParsedFile {
        ParsedFile {
            path: path.to_string(),
            module: module_of(path),
            fns: Vec::new(),
            fields: BTreeMap::new(),
            types: BTreeSet::new(),
            traits: BTreeSet::new(),
        }
    }
}

/// Module path for a file: the components after the last `src`/`tests`/
/// `benches`/`examples` directory, with `mod.rs`/`lib.rs`/`main.rs`
/// collapsed into their parent (`rust/src/gp/stats.rs` → `gp::stats`,
/// `rust/src/pool/mod.rs` → `pool`).
pub fn module_of(path: &str) -> String {
    let norm = path.replace('\\', "/");
    let parts: Vec<&str> = norm.split('/').collect();
    let mut idx = None;
    for (i, p) in parts.iter().enumerate() {
        if matches!(*p, "src" | "tests" | "benches" | "examples") {
            idx = Some(i);
        }
    }
    let mut comps: Vec<&str> = match idx {
        Some(i) => parts[i + 1..].to_vec(),
        None => parts.last().map(|p| vec![*p]).unwrap_or_default(),
    };
    if let Some(last) = comps.last() {
        if let Some(stem) = last.strip_suffix(".rs") {
            let stem = stem.to_string();
            comps.pop();
            if !matches!(stem.as_str(), "mod" | "lib" | "main") {
                return comps
                    .iter()
                    .map(|c| c.to_string())
                    .chain(std::iter::once(stem))
                    .collect::<Vec<_>>()
                    .join("::");
            }
        }
    }
    comps.join("::")
}

/// Visit every event under `stmts` (depth-first, source order).
pub fn for_each_event<'a>(stmts: &'a [Stmt], f: &mut impl FnMut(&'a Stmt, &'a Event)) {
    for s in stmts {
        for ev in &s.events {
            f(s, ev);
        }
        for ch in &s.children {
            for_each_event(ch, f);
        }
    }
}

/// All events of one statement including its nested blocks, flattened.
pub fn stmt_events_flat(stmt: &Stmt) -> Vec<&Event> {
    let mut out: Vec<&Event> = stmt.events.iter().collect();
    for ch in &stmt.children {
        for s in ch {
            out.extend(stmt_events_flat(s));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths_collapse_mod_lib_main() {
        assert_eq!(module_of("rust/src/gp/stats.rs"), "gp::stats");
        assert_eq!(module_of("rust/src/pool/mod.rs"), "pool");
        assert_eq!(module_of("rust/src/lib.rs"), "");
        assert_eq!(module_of("rust/tests/alloc_counter.rs"), "alloc_counter");
        assert_eq!(module_of("tools/pallas-lint/src/main.rs"), "");
    }

    #[test]
    fn qname_prefers_self_type_then_trait_then_module() {
        let base = FnDef {
            name: "f".into(),
            self_ty: None,
            trait_name: None,
            file: "rust/src/gp/mod.rs".into(),
            module: "gp".into(),
            line: 1,
            in_test: false,
            in_feature: false,
            body: Vec::new(),
        };
        assert_eq!(base.qname(), "gp::f");
        let m = FnDef { self_ty: Some("Gp".into()), ..base.clone() };
        assert_eq!(m.qname(), "Gp::f");
        let t = FnDef { trait_name: Some("EiBackend".into()), ..base };
        assert_eq!(t.qname(), "EiBackend::f");
    }
}
