//! R6 `hot-path-alloc`: no allocating construct in any function statically
//! reachable from the serving hot-path roots — `Gp::observe`,
//! `ShardedGp::observe`, `EiBackend::eirate`, and `EiBackend::select_arm`
//! (impls *and* the trait default).
//!
//! This is the whole-tree static complement of the dynamic
//! `rust/tests/alloc_counter.rs` gate: the counting allocator proves zero
//! allocations on the paths a test run happens to execute; R6 proves it
//! over every path the call graph can reach, in every build of the
//! default feature set. `#[cfg(feature = …)]` items are excluded to match
//! what the dynamic gate runs (the XLA stub path allocates by design).
//!
//! Flagged constructs: `format!`/`vec!`, `Vec::new`-style constructors on
//! heap-owning types, and growth/copy methods (`push`, `extend`,
//! `collect`, `to_vec`, `clone`, …) whose receiver does not resolve to a
//! crate fn. Amortized or cold sites carry
//! `// pallas-lint: allow(R6) — <why>` pragmas.

use crate::ast::{for_each_event, Event, FnDef};
use crate::callgraph::{chain, fn_key, reachable};
use crate::diag::{Diagnostic, RuleId};
use crate::resolve::{Ctx, Index, ALLOC_CTORS, ALLOC_MACROS, ALLOC_METHODS, ALLOC_TYPES};

/// Hot-path roots: (self type or trait, fn name, is-trait).
const ROOTS: [(&str, &str, bool); 4] = [
    ("Gp", "observe", false),
    ("ShardedGp", "observe", false),
    ("EiBackend", "eirate", true),
    ("EiBackend", "select_arm", true),
];

/// Run R6 over the index; returns unsorted diagnostics.
pub fn check(index: &Index<'_>) -> Vec<Diagnostic> {
    let mut roots: Vec<&FnDef> = Vec::new();
    for (owner, name, is_trait) in ROOTS {
        if is_trait {
            roots.extend(index.trait_methods(owner, name));
        } else {
            roots.extend(index.methods_on(owner, name));
        }
    }
    let reach = reachable(index, &roots);
    let mut out = Vec::new();
    for (key, (fn_def, _parent)) in &reach {
        let ctx = Ctx::of(fn_def);
        for_each_event(&fn_def.body, &mut |_s, ev| {
            let what = match ev {
                Event::Macro { name, .. } if ALLOC_MACROS.contains(&name.as_str()) => {
                    Some(format!("`{name}!`"))
                }
                Event::PathCall { segs, .. }
                    if segs.len() >= 2
                        && ALLOC_TYPES.contains(&segs[segs.len() - 2].as_str())
                        && ALLOC_CTORS.contains(&segs[segs.len() - 1].as_str()) =>
                {
                    Some(format!("`{}`", segs.join("::")))
                }
                Event::Method { name, .. }
                    if ALLOC_METHODS.contains(&name.as_str())
                        && index.resolve(ev, &ctx).is_empty() =>
                {
                    Some(format!("`.{name}()`"))
                }
                _ => None,
            };
            if let Some(what) = what {
                out.push(Diagnostic {
                    path: fn_def.file.clone(),
                    line: ev.line(),
                    rule: RuleId::HotPathAlloc,
                    message: format!(
                        "{what} allocates in `{}`, statically reachable from a hot-path root \
                         ({}); hoist the allocation out of the decision path or justify with \
                         `// pallas-lint: allow(R6) — <why amortized or cold>`",
                        fn_def.qname(),
                        chain(&reach, key.clone()),
                    ),
                });
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::ParsedFile;
    use crate::lexer::{lex, Tok, TokKind};
    use crate::parser::parse_file;

    fn parse(path: &str, src: &str) -> ParsedFile {
        let toks = lex(src);
        let code: Vec<&Tok> = toks
            .iter()
            .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .collect();
        parse_file(path, &code)
    }

    #[test]
    fn one_hop_alloc_is_found_and_unreachable_alloc_is_not() {
        let src = "struct Gp { buf: Vec<f64> }\n\
                   impl Gp {\n\
                       pub fn observe(&mut self, y: f64) { self.record(y); }\n\
                       fn record(&mut self, y: f64) { self.buf.push(y); }\n\
                       pub fn cold(&self) -> Vec<f64> { self.buf.to_vec() }\n\
                   }\n";
        let files = vec![parse("rust/src/gp/mod.rs", src)];
        let ix = Index::new(&files);
        let diags = check(&ix);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 4);
        assert!(diags[0].message.contains("Gp::record ← Gp::observe"), "{}", diags[0].message);
    }

    #[test]
    fn trait_default_and_impls_are_roots() {
        let src = "trait EiBackend { fn select_arm(&mut self) -> usize { self.refresh(); 0 } }\n\
                   struct N;\n\
                   impl EiBackend for N { }\n\
                   impl N { fn refresh(&mut self) { let s = format!(\"x\"); } }\n";
        let files = vec![parse("rust/src/sched/backend.rs", src)];
        let ix = Index::new(&files);
        let diags = check(&ix);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 4);
    }

    #[test]
    fn feature_gated_fns_are_outside_the_graph() {
        let src = "struct Gp;\n\
                   #[cfg(feature = \"xla\")]\n\
                   impl Gp { pub fn observe(&mut self) { let v = vec![1.0]; } }\n";
        let files = vec![parse("rust/src/gp/mod.rs", src)];
        let ix = Index::new(&files);
        assert!(check(&ix).is_empty());
    }
}
