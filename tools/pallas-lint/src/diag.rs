//! Diagnostic types: rule identifiers and file:line findings.

use std::fmt;

/// Identifier of a lint rule. `R1`–`R5` are the token-level repo-invariant
/// rules, `R6`–`R8` the call-graph/flow rules; [`RuleId::Pragma`] reports
/// a malformed or unjustified `// pallas-lint: allow(…)` pragma and is
/// itself not suppressible.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// R1 — float comparisons must go through `f64::total_cmp`
    /// (`partial_cmp` panics on NaN and is a platform-drift escape hatch).
    FloatTotalCmp,
    /// R2 — no `HashMap`/`HashSet` in `report`/`engine`/`sched` paths:
    /// hash iteration order is nondeterministic and breaks byte-stable
    /// reports.
    HashOrder,
    /// R3 — no `Instant::now`/`SystemTime`/`thread::sleep` outside
    /// `engine/clock.rs` and the bench harness: wall time must never leak
    /// into virtual-time code.
    WallClock,
    /// R4 — no narrowing `as` casts on config-derived integers (negative
    /// TOML values silently wrap); use `try_from` and reject.
    WrappingCast,
    /// R5 — no `unwrap`/`expect`/`println!` in library code outside
    /// `cli`/`bench`/tests.
    LibPanic,
    /// R6 — no allocating construct in any fn statically reachable from
    /// the serving hot-path roots (`Gp::observe`, `EiBackend::eirate`,
    /// `EiBackend::select_arm`).
    HotPathAlloc,
    /// R7 — the Mutex lock-order graph of `pool`/`engine/clock.rs`/
    /// `coordinator` must be acyclic (static deadlock freedom).
    LockOrder,
    /// R8 — numeric config reads must flow through `count()`/`try_from`
    /// before use.
    ConfigValidation,
    /// Malformed, unknown, or justification-free pragma.
    Pragma,
}

/// All suppressible rules, in report order.
pub const RULES: [RuleId; 8] = [
    RuleId::FloatTotalCmp,
    RuleId::HashOrder,
    RuleId::WallClock,
    RuleId::WrappingCast,
    RuleId::LibPanic,
    RuleId::HotPathAlloc,
    RuleId::LockOrder,
    RuleId::ConfigValidation,
];

impl RuleId {
    /// Short code used in diagnostics and pragmas (`R1` … `R5`).
    pub fn code(self) -> &'static str {
        match self {
            RuleId::FloatTotalCmp => "R1",
            RuleId::HashOrder => "R2",
            RuleId::WallClock => "R3",
            RuleId::WrappingCast => "R4",
            RuleId::LibPanic => "R5",
            RuleId::HotPathAlloc => "R6",
            RuleId::LockOrder => "R7",
            RuleId::ConfigValidation => "R8",
            RuleId::Pragma => "pragma",
        }
    }

    /// Kebab-case rule name, accepted in pragmas as an alias for the code.
    pub fn name(self) -> &'static str {
        match self {
            RuleId::FloatTotalCmp => "float-total-cmp",
            RuleId::HashOrder => "hash-order",
            RuleId::WallClock => "wall-clock",
            RuleId::WrappingCast => "wrapping-cast",
            RuleId::LibPanic => "lib-panic",
            RuleId::HotPathAlloc => "hot-path-alloc",
            RuleId::LockOrder => "lock-order",
            RuleId::ConfigValidation => "config-validation",
            RuleId::Pragma => "pragma",
        }
    }

    /// Parse a pragma rule spec — `R1`/`r1` or the kebab-case name.
    pub fn parse(s: &str) -> Option<RuleId> {
        let t = s.trim();
        RULES.iter().copied().find(|r| t.eq_ignore_ascii_case(r.code()) || t == r.name())
    }
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// File the finding is in, as passed on the command line
    /// (separators normalized to `/`).
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// Violated rule.
    pub rule: RuleId,
    /// Human-readable description carrying the sanctioned fix.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {} [{}] {}",
            self.path,
            self.line,
            self.rule.code(),
            self.rule.name(),
            self.message
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_codes_and_names() {
        assert_eq!(RuleId::parse("R3"), Some(RuleId::WallClock));
        assert_eq!(RuleId::parse("r5"), Some(RuleId::LibPanic));
        assert_eq!(RuleId::parse("float-total-cmp"), Some(RuleId::FloatTotalCmp));
        assert_eq!(RuleId::parse("R6"), Some(RuleId::HotPathAlloc));
        assert_eq!(RuleId::parse("lock-order"), Some(RuleId::LockOrder));
        assert_eq!(RuleId::parse("r8"), Some(RuleId::ConfigValidation));
        assert_eq!(RuleId::parse("R9"), None);
        assert_eq!(RuleId::parse("pragma"), None, "pragma findings are not suppressible");
    }

    #[test]
    fn display_is_file_line_code() {
        let d = Diagnostic {
            path: "rust/src/gp/mod.rs".into(),
            line: 42,
            rule: RuleId::FloatTotalCmp,
            message: "msg".into(),
        };
        assert_eq!(d.to_string(), "rust/src/gp/mod.rs:42: R1 [float-total-cmp] msg");
    }
}
