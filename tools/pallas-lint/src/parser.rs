//! Hand-rolled recursive-descent parser over the [`crate::lexer`] token
//! stream, producing the [`crate::ast`] item/statement tree.
//!
//! Like the lexer, the parser is total: any token sequence produces *some*
//! tree (unknown constructs are skipped token-by-token), so weird but
//! compiling code can never panic the linter — at worst a construct is
//! invisible to the graph rules, which keeps them conservative.
//!
//! What it understands, because the rules need it:
//! * item nesting (`mod`, `impl`, `trait`, `fn`) with `#[cfg(test)]` /
//!   `#[cfg(feature = …)]` attribution;
//! * struct fields and their base types (for `self.field.method()`
//!   receiver typing);
//! * fn bodies as statement lists: `let` bindings, nested blocks, and
//!   method/path/macro call events with receiver chains.

use crate::ast::{Event, FnDef, ParsedFile, Stmt};
use crate::lexer::{Tok, TokKind};

/// Keywords that look like calls when followed by `(` but are not.
const KEYWORD_CALLS: [&str; 20] = [
    "if", "while", "for", "match", "return", "in", "as", "loop", "else", "move", "fn", "let",
    "mut", "ref", "pub", "impl", "where", "unsafe", "break", "continue",
];

/// Statement heads whose trailing `}` ends the statement (no `;` needed).
const BLOCK_HEADS: [&str; 6] = ["if", "for", "while", "loop", "match", "unsafe"];

/// Parse one file's comment-free code tokens into a [`ParsedFile`].
pub fn parse_file(path: &str, code: &[&Tok]) -> ParsedFile {
    let mut p = Parser { code, i: 0, pf: ParsedFile::new(path) };
    p.items(false, false, false);
    p.pf
}

struct Parser<'a> {
    code: &'a [&'a Tok],
    i: usize,
    pf: ParsedFile,
}

impl Parser<'_> {
    fn tok(&self, k: isize) -> Option<&Tok> {
        let j = self.i as isize + k;
        if j < 0 {
            return None;
        }
        self.code.get(j as usize).copied()
    }

    fn t(&self, k: isize) -> &str {
        self.tok(k).map_or("", |t| t.text.as_str())
    }

    fn kind(&self, k: isize) -> Option<TokKind> {
        self.tok(k).map(|t| t.kind)
    }

    fn line(&self) -> u32 {
        self.tok(0).map_or(0, |t| t.line)
    }

    fn eof(&self) -> bool {
        self.i >= self.code.len()
    }

    // --- item level ---

    fn items(&mut self, in_test: bool, in_feature: bool, end_at_brace: bool) {
        let mut pending_test = false;
        let mut pending_feature = false;
        while !self.eof() {
            let t = self.t(0).to_string();
            if end_at_brace && t == "}" {
                self.i += 1;
                return;
            }
            match t.as_str() {
                "#" => {
                    let (is_t, is_f) = self.attr_cfg_flags();
                    pending_test |= is_t;
                    pending_feature |= is_f;
                    continue;
                }
                "pub" => {
                    self.i += 1;
                    if self.t(0) == "(" {
                        self.skip_balanced("(", ")");
                    }
                    continue;
                }
                "unsafe" | "default" | "async" | "extern" => {
                    self.i += 1;
                    if t == "extern" && self.kind(0) == Some(TokKind::Str) {
                        self.i += 1;
                    }
                    continue;
                }
                "struct" => self.parse_struct(),
                "enum" | "union" => {
                    self.i += 2; // keyword + name
                    self.skip_generics();
                    if self.t(0) == "{" {
                        self.skip_balanced("{", "}");
                    } else if self.t(0) == ";" {
                        self.i += 1;
                    }
                }
                "impl" => self.parse_impl(in_test || pending_test, in_feature || pending_feature),
                "trait" => self.parse_trait(in_test || pending_test, in_feature || pending_feature),
                "fn" => {
                    self.parse_fn(None, None, in_test || pending_test, in_feature || pending_feature)
                }
                "mod" => {
                    self.i += 2; // mod name
                    if self.t(0) == "{" {
                        self.i += 1;
                        self.items(in_test || pending_test, in_feature || pending_feature, true);
                    } else if self.t(0) == ";" {
                        self.i += 1;
                    }
                }
                "use" | "static" | "const" | "type" => self.skip_to_semi(),
                "macro_rules" => {
                    self.i += 1;
                    if self.t(0) == "!" {
                        self.i += 1;
                    }
                    self.i += 1; // name
                    if self.t(0) == "{" {
                        self.skip_balanced("{", "}");
                    }
                }
                _ => {
                    self.i += 1;
                    continue;
                }
            }
            pending_test = false;
            pending_feature = false;
        }
    }

    /// At `#`: skip the attribute; report whether a `cfg(…)` argument list
    /// mentions `test` / `feature`.
    fn attr_cfg_flags(&mut self) -> (bool, bool) {
        self.i += 1;
        let mut is_test = false;
        let mut is_feature = false;
        if self.t(0) == "[" {
            let scan_cfg = self.t(1) == "cfg";
            let mut depth = 0i32;
            while !self.eof() {
                match self.t(0) {
                    "[" => depth += 1,
                    "]" => {
                        depth -= 1;
                        if depth == 0 {
                            self.i += 1;
                            break;
                        }
                    }
                    "test" if scan_cfg => is_test = true,
                    "feature" if scan_cfg => is_feature = true,
                    _ => {}
                }
                self.i += 1;
            }
        }
        (is_test, is_feature)
    }

    fn skip_balanced(&mut self, open: &str, close: &str) {
        let mut depth = 0i32;
        while !self.eof() {
            let t = self.t(0);
            if t == open {
                depth += 1;
            } else if t == close {
                depth -= 1;
                if depth == 0 {
                    self.i += 1;
                    return;
                }
            }
            self.i += 1;
        }
    }

    fn skip_generics(&mut self) {
        if self.t(0) != "<" {
            return;
        }
        let mut depth = 0i32;
        while !self.eof() {
            match self.t(0) {
                "<" => depth += 1,
                ">" => {
                    depth -= 1;
                    if depth == 0 {
                        self.i += 1;
                        return;
                    }
                }
                // A brace/paren inside generics means we mis-detected a
                // comparison; bail without consuming it.
                "(" | "{" => return,
                _ => {}
            }
            self.i += 1;
        }
    }

    fn skip_to_semi(&mut self) {
        while !self.eof() {
            match self.t(0) {
                ";" => {
                    self.i += 1;
                    return;
                }
                "{" => {
                    self.skip_balanced("{", "}");
                    return;
                }
                "(" => {
                    self.skip_balanced("(", ")");
                }
                "[" => {
                    self.skip_balanced("[", "]");
                }
                _ => self.i += 1,
            }
        }
    }

    /// Parse a type up to a `stop` token at depth 0; return the last ident
    /// of the leading path (`""` for non-path types).
    fn type_base(&mut self, stop: &[&str]) -> String {
        let mut depth = 0i32;
        let mut base = String::new();
        let mut lead = true;
        while !self.eof() {
            let t = self.t(0);
            if depth == 0 && stop.contains(&t) {
                return base;
            }
            match t {
                "<" | "(" | "[" => {
                    depth += 1;
                    lead = false;
                }
                ">" | ")" | "]" => depth -= 1,
                _ => {
                    if depth == 0
                        && lead
                        && self.kind(0) == Some(TokKind::Ident)
                        && !matches!(t, "dyn" | "impl" | "mut")
                    {
                        base = t.to_string();
                    }
                }
            }
            self.i += 1;
        }
        base
    }

    fn parse_struct(&mut self) {
        self.i += 1; // struct
        let name = self.t(0).to_string();
        self.i += 1;
        self.skip_generics();
        while !self.eof() && !matches!(self.t(0), "{" | "(" | ";") {
            self.i += 1; // where clause
        }
        match self.t(0) {
            ";" => {
                self.i += 1;
                self.pf.types.insert(name);
                return;
            }
            "(" => {
                self.skip_balanced("(", ")");
                if self.t(0) == ";" {
                    self.i += 1;
                }
                self.pf.types.insert(name);
                return;
            }
            _ => {}
        }
        self.i += 1; // {
        let mut fields = Vec::new();
        while !self.eof() && self.t(0) != "}" {
            if self.t(0) == "#" {
                self.attr_cfg_flags();
                continue;
            }
            if self.t(0) == "pub" {
                self.i += 1;
                if self.t(0) == "(" {
                    self.skip_balanced("(", ")");
                }
                continue;
            }
            if self.kind(0) == Some(TokKind::Ident) && self.t(1) == ":" {
                let fname = self.t(0).to_string();
                self.i += 2;
                let base = self.type_base(&[",", "}"]);
                fields.push((fname, base));
                if self.t(0) == "," {
                    self.i += 1;
                }
            } else {
                self.i += 1;
            }
        }
        if self.t(0) == "}" {
            self.i += 1;
        }
        self.pf.types.insert(name.clone());
        self.pf.fields.entry(name).or_default().extend(fields);
    }

    /// Parse an `A::B<..>` type path at the cursor; return the last ident.
    fn path_head(&mut self) -> String {
        let mut base = String::new();
        while !self.eof() {
            if self.kind(0) == Some(TokKind::Ident) {
                base = self.t(0).to_string();
                self.i += 1;
                if self.t(0) == "<" {
                    self.skip_generics();
                }
                if self.t(0) == ":" && self.t(1) == ":" {
                    self.i += 2;
                    continue;
                }
                return base;
            } else if self.t(0) == "<" {
                self.skip_generics();
            } else {
                return base;
            }
        }
        base
    }

    fn parse_impl(&mut self, in_test: bool, in_feature: bool) {
        self.i += 1; // impl
        self.skip_generics();
        let first = self.path_head();
        let mut trait_name = None;
        let mut self_ty = first.clone();
        if self.t(0) == "for" {
            self.i += 1;
            trait_name = Some(first);
            self_ty = self.path_head();
        }
        while !self.eof() && self.t(0) != "{" {
            self.i += 1; // where clause
        }
        self.i += 1; // {
        self.pf.types.insert(self_ty.clone());
        let mut pending_test = false;
        let mut pending_feature = false;
        while !self.eof() && self.t(0) != "}" {
            match self.t(0) {
                "#" => {
                    let (is_t, is_f) = self.attr_cfg_flags();
                    pending_test |= is_t;
                    pending_feature |= is_f;
                }
                "pub" => {
                    self.i += 1;
                    if self.t(0) == "(" {
                        self.skip_balanced("(", ")");
                    }
                }
                "unsafe" | "default" | "async" | "extern" => self.i += 1,
                "fn" => {
                    self.parse_fn(
                        Some(self_ty.clone()),
                        trait_name.clone(),
                        in_test || pending_test,
                        in_feature || pending_feature,
                    );
                    pending_test = false;
                    pending_feature = false;
                }
                "const" | "type" => {
                    self.skip_to_semi();
                    pending_test = false;
                    pending_feature = false;
                }
                _ => self.i += 1,
            }
        }
        if self.t(0) == "}" {
            self.i += 1;
        }
    }

    fn parse_trait(&mut self, in_test: bool, in_feature: bool) {
        self.i += 1; // trait
        let name = self.t(0).to_string();
        self.i += 1;
        self.pf.traits.insert(name.clone());
        while !self.eof() && self.t(0) != "{" {
            self.i += 1;
        }
        self.i += 1;
        let mut pending_test = false;
        let mut pending_feature = false;
        while !self.eof() && self.t(0) != "}" {
            match self.t(0) {
                "#" => {
                    let (is_t, is_f) = self.attr_cfg_flags();
                    pending_test |= is_t;
                    pending_feature |= is_f;
                }
                "fn" => {
                    self.parse_fn(
                        None,
                        Some(name.clone()),
                        in_test || pending_test,
                        in_feature || pending_feature,
                    );
                    pending_test = false;
                    pending_feature = false;
                }
                "const" | "type" => {
                    self.skip_to_semi();
                    pending_test = false;
                    pending_feature = false;
                }
                _ => self.i += 1,
            }
        }
        if self.t(0) == "}" {
            self.i += 1;
        }
    }

    fn parse_fn(
        &mut self,
        self_ty: Option<String>,
        trait_name: Option<String>,
        in_test: bool,
        in_feature: bool,
    ) {
        let ln = self.line();
        self.i += 1; // fn
        let name = self.t(0).to_string();
        self.i += 1;
        self.skip_generics();
        if self.t(0) == "(" {
            self.skip_balanced("(", ")");
        }
        // Return type / where clause: scan to the body `{` or a `;`.
        while !self.eof() && !matches!(self.t(0), "{" | ";") {
            match self.t(0) {
                "<" => self.skip_generics(),
                "(" => self.skip_balanced("(", ")"),
                _ => self.i += 1,
            }
        }
        if self.t(0) == ";" {
            self.i += 1;
            return; // declaration without body
        }
        let body = self.parse_block();
        let fndef = FnDef {
            name,
            self_ty,
            trait_name,
            file: self.pf.path.clone(),
            module: self.pf.module.clone(),
            line: ln,
            in_test,
            in_feature,
            body,
        };
        self.pf.fns.push(fndef);
    }

    // --- statement level ---

    /// At `{`: parse statements until the matching `}`.
    fn parse_block(&mut self) -> Vec<Stmt> {
        self.i += 1; // {
        let mut stmts = Vec::new();
        // (statement under construction, its first token) — flushed on `;`,
        // on a statement-ending block close, and at the block's `}`.
        let mut cur: Option<(Stmt, String)> = None;
        fn flush(stmts: &mut Vec<Stmt>, cur: &mut Option<(Stmt, String)>) {
            if let Some((s, _)) = cur.take() {
                if !s.events.is_empty() || !s.children.is_empty() || s.is_let {
                    stmts.push(s);
                }
            }
        }
        while !self.eof() {
            let t = self.t(0).to_string();
            let k = self.kind(0);
            if t == "}" {
                self.i += 1;
                flush(&mut stmts, &mut cur);
                return stmts;
            }
            if cur.is_none() {
                let mut s = Stmt { line: self.line(), ..Stmt::default() };
                if t == "let" {
                    s.is_let = true;
                    self.i += 1;
                    self.let_pattern(&mut s);
                    cur = Some((s, t));
                    continue;
                }
                cur = Some((s, t.clone()));
            }
            if t == ";" {
                self.i += 1;
                flush(&mut stmts, &mut cur);
                continue;
            }
            if t == "{" {
                let child = self.parse_block();
                if let Some((s, first)) = cur.as_mut() {
                    s.children.push(child);
                    let ends = BLOCK_HEADS.contains(&first.as_str())
                        && !matches!(self.t(0), "else" | "." | "?" | ";" | ")");
                    if ends {
                        flush(&mut stmts, &mut cur);
                    }
                }
                continue;
            }
            if k == Some(TokKind::Ident) {
                let line = self.line();
                let next = self.t(1);
                if next == "!" && !matches!(t.as_str(), "if" | "while" | "match" | "return") {
                    if let Some((s, _)) = cur.as_mut() {
                        s.events.push(Event::Macro { name: t, line });
                    }
                    self.i += 2;
                    continue;
                }
                if next == "(" && !KEYWORD_CALLS.contains(&t.as_str()) {
                    let ev = if self.t(-1) == "." {
                        let recv = self.recv_chain(self.i as isize - 1);
                        Event::Method { recv, name: t, line }
                    } else if self.t(-1) == ":" && self.t(-2) == ":" {
                        Event::PathCall { segs: self.path_segments_back(self.i), line }
                    } else {
                        Event::PathCall { segs: vec![t], line }
                    };
                    if let Some((s, _)) = cur.as_mut() {
                        s.events.push(ev);
                    }
                    self.i += 1;
                    continue;
                }
                if let Some((s, _)) = cur.as_mut() {
                    s.events.push(Event::Word { name: t, line });
                }
                self.i += 1;
                continue;
            }
            self.i += 1;
        }
        flush(&mut stmts, &mut cur);
        stmts
    }

    /// After `let`: collect the pattern's bound names into `s.bindings` and
    /// position the cursor at the `=` / `;` (skipping a `: Type` ascription).
    fn let_pattern(&mut self, s: &mut Stmt) {
        let mut depth = 0i32;
        while !self.eof() {
            let pt = self.t(0);
            if depth == 0 && matches!(pt, "=" | ";" | ":") {
                break;
            }
            match pt {
                "(" | "[" | "<" => depth += 1,
                ")" | "]" | ">" => depth -= 1,
                _ => {
                    if self.kind(0) == Some(TokKind::Ident)
                        && !matches!(pt, "mut" | "ref")
                        && !matches!(self.t(1), "(" | "{")
                        && !(self.t(1) == ":" && self.t(2) == ":")
                    {
                        s.bindings.push(pt.to_string());
                    }
                }
            }
            self.i += 1;
        }
        if self.t(0) == ":" {
            // type ascription: skip to `=` / `;` at depth 0
            let mut depth = 0i32;
            while !self.eof() {
                let pt = self.t(0);
                if depth == 0 && matches!(pt, "=" | ";") {
                    break;
                }
                match pt {
                    "(" | "[" | "<" => depth += 1,
                    ")" | "]" | ">" => depth -= 1,
                    _ => {}
                }
                self.i += 1;
            }
        }
    }

    /// Walk back from the `.` before a method name, collecting the
    /// receiver's identifier chain. Empty for complex receivers
    /// (call results), which the resolver treats as unresolvable.
    fn recv_chain(&self, dot_idx: isize) -> Vec<String> {
        let mut out = Vec::new();
        let mut j = dot_idx;
        while j >= 0 {
            let Some(tok) = self.code.get(j as usize) else { break };
            match tok.text.as_str() {
                "." => {
                    j -= 1;
                    continue;
                }
                "?" => {
                    j -= 1;
                    continue;
                }
                "]" => {
                    // skip an index expression `a[i]`
                    let mut depth = 0i32;
                    while j >= 0 {
                        match self.code[j as usize].text.as_str() {
                            "]" => depth += 1,
                            "[" => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j -= 1;
                    }
                    j -= 1;
                    continue;
                }
                ")" => return Vec::new(), // call-result receiver
                _ => {}
            }
            match tok.kind {
                TokKind::Ident => {
                    out.push(tok.text.clone());
                    j -= 1;
                    if j >= 0 && self.code[j as usize].text == "." {
                        continue;
                    }
                    break;
                }
                TokKind::Num => {
                    // tuple index `a.0.method()`
                    j -= 1;
                    if j >= 0 && self.code[j as usize].text == "." {
                        continue;
                    }
                    break;
                }
                _ => break,
            }
        }
        out.reverse();
        out
    }

    /// Collect the `A::B::name` segments ending at `code[name_idx]`.
    fn path_segments_back(&self, name_idx: usize) -> Vec<String> {
        let mut segs = vec![self.code[name_idx].text.clone()];
        let mut j = name_idx as isize - 1;
        while j >= 1
            && self.code[j as usize].text == ":"
            && self.code[(j - 1) as usize].text == ":"
        {
            j -= 2;
            // turbofish `Vec::<f64>::new`: skip back over `<…>`
            if j >= 0 && self.code[j as usize].text == ">" {
                let mut depth = 0i32;
                while j >= 0 {
                    match self.code[j as usize].text.as_str() {
                        ">" => depth += 1,
                        "<" => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j -= 1;
                }
                j -= 1;
            }
            if j >= 0 && self.code[j as usize].kind == TokKind::Ident {
                segs.push(self.code[j as usize].text.clone());
                j -= 1;
            } else {
                break;
            }
        }
        segs.reverse();
        segs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn parse(path: &str, src: &str) -> ParsedFile {
        let toks = lex(src);
        let code: Vec<&Tok> = toks
            .iter()
            .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .collect();
        parse_file(path, &code)
    }

    #[test]
    fn impl_methods_capture_self_type_and_trait() {
        let src = "struct Gp { buf: Vec<f64> }\n\
                   impl Gp { pub fn observe(&mut self) { self.buf.push(1.0); } }\n\
                   trait EiBackend { fn eirate(&self) -> f64; fn pick(&self) -> usize { self.fallback() } }\n\
                   impl EiBackend for Gp { fn eirate(&self) -> f64 { 0.0 } }\n";
        let pf = parse("rust/src/gp/mod.rs", src);
        assert_eq!(pf.fns.len(), 3, "{:?}", pf.fns.iter().map(|f| f.qname()).collect::<Vec<_>>());
        assert_eq!(pf.fns[0].qname(), "Gp::observe");
        assert_eq!(pf.fns[1].qname(), "EiBackend::pick");
        assert_eq!(pf.fns[2].qname(), "Gp::eirate");
        assert_eq!(pf.fns[2].trait_name.as_deref(), Some("EiBackend"));
        assert_eq!(pf.fields["Gp"]["buf"], "Vec");
    }

    #[test]
    fn method_events_carry_receiver_chains() {
        let src = "impl A { fn f(&self) { self.x.lock(); y.push(1); g(2); B::make(); h(3).push(4); } }\n";
        let pf = parse("x.rs", src);
        let mut shapes = Vec::new();
        for s in &pf.fns[0].body {
            for e in &s.events {
                match e {
                    Event::Method { recv, name, .. } => shapes.push(format!("m:{}:{}", recv.join("."), name)),
                    Event::PathCall { segs, .. } => shapes.push(format!("p:{}", segs.join("::"))),
                    Event::Macro { name, .. } => shapes.push(format!("x:{name}")),
                    Event::Word { .. } => {}
                }
            }
        }
        assert_eq!(
            shapes,
            ["m:self.x:lock", "m:y:push", "p:g", "p:B::make", "p:h", "m::push"],
            "complex receiver must yield an empty chain"
        );
    }

    #[test]
    fn let_bindings_and_nested_blocks() {
        let src = "fn f() { let (a, mut b) = g(); if a { b.push(1); } let c: Vec<f64> = h(); }\n";
        let pf = parse("x.rs", src);
        let body = &pf.fns[0].body;
        assert_eq!(body.len(), 3, "{body:?}");
        assert_eq!(body[0].bindings, ["a", "b"]);
        assert!(body[1].children.len() == 1 && !body[1].is_let);
        assert_eq!(body[2].bindings, ["c"]);
    }

    #[test]
    fn cfg_attrs_mark_test_and_feature_items() {
        let src = "#[cfg(test)]\nmod tests { fn t() { x.push(1); } }\n\
                   #[cfg(feature = \"xla\")]\nfn gated() { y.push(2); }\n\
                   fn plain() {}\n";
        let pf = parse("x.rs", src);
        assert!(pf.fns[0].in_test && !pf.fns[0].in_feature);
        assert!(pf.fns[1].in_feature && !pf.fns[1].in_test);
        assert_eq!(pf.fns.len(), 3);
        assert!(!pf.fns[2].in_test && !pf.fns[2].in_feature);
    }

    #[test]
    fn trait_default_bodies_are_parsed() {
        let src = "trait T { fn a(&self) -> f64; fn b(&self) -> f64 { self.a() + 1.0 } }\n";
        let pf = parse("x.rs", src);
        assert_eq!(pf.fns.len(), 1);
        assert_eq!(pf.fns[0].qname(), "T::b");
        assert_eq!(pf.fns[0].body[0].events.len(), 1);
    }
}
