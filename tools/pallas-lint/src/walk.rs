//! Deterministic `.rs` file discovery: recursive walk, sorted paths, so
//! diagnostics come out in the same order on every machine.

use crate::LintError;
use std::path::{Path, PathBuf};

/// Collect every `.rs` file under `root` (or `root` itself when it is a
/// file), sorted by path. Directories named `target` are skipped.
pub fn rust_files(root: &Path) -> Result<Vec<PathBuf>, LintError> {
    if root.is_file() {
        return Ok(vec![root.to_path_buf()]);
    }
    if !root.is_dir() {
        return Err(LintError(format!("no such file or directory: {}", root.display())));
    }
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let rd = std::fs::read_dir(&dir).map_err(|e| LintError(format!("reading {}: {e}", dir.display())))?;
        for entry in rd {
            let entry = entry.map_err(|e| LintError(format!("reading {}: {e}", dir.display())))?;
            let p = entry.path();
            if p.is_dir() {
                if p.file_name().is_some_and(|n| n == "target") {
                    continue;
                }
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_path_is_an_error() {
        let err = rust_files(Path::new("definitely/not/a/path"));
        assert!(err.is_err());
    }

    #[test]
    fn own_sources_are_found_sorted() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let files = rust_files(&src).expect("walk own src");
        assert!(files.len() >= 7, "{files:?}");
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted);
    }
}
