"""Layer-2: the JAX ``scheduler_step`` graph.

One scheduler decision = one execution of this function. Given the fixed
prior (kernel matrix ``k``, mean ``mu0``), the observation state
(``obs_mask``, ``z``), the dispatch state (``sel_mask``) and the problem
structure (``member``, ``cost``), it produces everything Algorithm 1
needs at a decision point:

  1. masked GP posterior over *all* arms (Supplemental section A formulas
     with a fixed-shape masked Cholesky — unobserved rows/columns are
     replaced by identity so padding and not-yet-observed arms are inert);
  2. per-user incumbents ``best_i = max z over observed arms of user i``
     (floored at 0, matching the rust EMPTY_INCUMBENT — all paper
     workloads have non-negative performances);
  3. the Layer-1 Pallas kernels: the fused posterior contraction and the
     fused EIrate scoring.

The function is shape-polymorphic in nothing: ``aot.py`` lowers one HLO
artifact per (N, L) bucket and the rust runtime pads its state into the
bucket. Python never runs at decision time.
"""

import jax
import jax.numpy as jnp

from . import linalg_jax
from .kernels import eirate as eirate_kernel
from .kernels import posterior as posterior_kernel

# Jitter added to observed diagonal entries. The rust native backend adds
# jitter only when a Cholesky pivot fails (typically never on the paper's
# PD priors), so this is kept tiny to hold native↔XLA parity at ~1e-9
# while still guarding genuinely duplicated arms in f64.
JITTER = 1e-12


def scheduler_step(k, mu0, obs_mask, z, sel_mask, member, cost):
    """One MM-GP-EI decision step.

    Args:
      k:        [L, L] prior covariance over arms.
      mu0:      [L] prior mean.
      obs_mask: [L] 1.0 where the arm's z has been observed.
      z:        [L] observed performances (0 where unobserved).
      sel_mask: [L] 1.0 where the arm is dispatched (observed or running).
      member:   [N, L] 0/1 membership (user i owns arm x).
      cost:     [L] arm costs c(x); padding arms must carry cost 1.

    Returns:
      (eirate, mu_t, sigma_t, best): [L], [L], [L], [N].
    """
    m = obs_mask
    # Masked SPD system: A = M K M + (I - M) + jitter*M.
    a = k * m[:, None] * m[None, :] + jnp.diag(1.0 - m + JITTER * m)
    # jax-native Cholesky/solves: jnp.linalg lowers to LAPACK FFI
    # custom-calls on CPU, which the pinned PJRT runtime cannot execute.
    lchol = linalg_jax.cholesky(a)
    resid = m * (z - mu0)
    # Whitened quantities only — no backward solve needed (§Perf L2):
    #   W = L^{-1} V^T, gamma = L^{-1} resid,
    #   mu = mu0 + W^T gamma,  sigma^2 = K_xx - ||W column||^2.
    v = k * m[None, :]
    w = linalg_jax.solve_lower(lchol, v.T)  # [O=L, L(arm axis)]
    gamma = linalg_jax.solve_lower(lchol, resid[:, None])[:, 0]
    wt = w.T  # [L, O=L]
    kdiag = jnp.diagonal(k)
    # Layer-1 fused contraction.
    mu, var = posterior_kernel.posterior_diag(wt, gamma, kdiag, mu0)
    # Pin observed arms to their exact values (kills jitter residue).
    mu = jnp.where(m > 0.5, z, mu)
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    sigma = jnp.where(m > 0.5, 0.0, sigma)
    # Incumbents (floored at 0 = rust EMPTY_INCUMBENT).
    best = jnp.max(member * (m * z)[None, :], axis=1)
    # Layer-1 fused EIrate.
    scores = eirate_kernel.eirate(mu, sigma, best, member, cost, sel_mask)
    return scores, mu, sigma, best


def scheduler_step_ref(k, mu0, obs_mask, z, sel_mask, member, cost):
    """Pure-jnp reference of :func:`scheduler_step` (no Pallas), used by
    the python test-suite to validate the composed graph."""
    from .kernels import ref

    mu, sigma = ref.gp_posterior_ref(k, mu0, obs_mask, z, jitter=JITTER)
    best = jnp.max(member * (obs_mask * z)[None, :], axis=1)
    scores = ref.eirate_ref(mu, sigma, best, member, cost, sel_mask)
    return scores, mu, sigma, best
