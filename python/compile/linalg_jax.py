"""Parser-safe dense linear algebra for the AOT path.

``jnp.linalg.cholesky`` / ``jax.scipy.linalg.cho_solve`` lower to LAPACK
FFI *custom-calls* on CPU (``lapack_dpotrf_ffi``, ``lapack_dtrsm_ffi``)
which the pinned runtime (xla_extension 0.5.1, behind the published
``xla`` crate) can neither parse nor execute. The AOT ``scheduler_step``
graph therefore uses these jax-native implementations, which lower to
plain HLO (while-loops over fused vector ops) and round-trip through the
0.5.1 HLO text parser.

Numerics: unblocked right-looking Cholesky and row-sweep triangular
solves, identical operation order to the rust ``linalg`` module — the
backend-parity test suite relies on this agreement (~1e-9 on the paper's
problem sizes).

The python test-suite cross-checks every function against
``jnp.linalg``/``jax.scipy`` on random SPD systems.
"""

import jax
import jax.numpy as jnp
from jax import lax

# Panel width for the blocked algorithms (§Perf L2). A block step does
# O(n·B) work as dense matmuls, so the HLO while-loop runs n/B trips of
# MXU/gemm-shaped bodies instead of n trips of vector ops — on the pinned
# CPU PJRT this cut the L=512 scheduler_step from ~152 ms to the
# low-tens-of-ms range (see EXPERIMENTS.md §Perf).
BLOCK = 32


def _cholesky_unblocked(a):
    """Right-looking unblocked Cholesky (used for diagonal blocks and
    shapes not divisible by BLOCK)."""
    n = a.shape[-1]
    idx = jnp.arange(n)

    def body(j, carry):
        a_work, l = carry
        d = jnp.sqrt(a_work[j, j])
        col = a_work[:, j] / d
        col = jnp.where(idx >= j, col, 0.0)  # keep L[j:, j]; col[j] == d
        l = l.at[:, j].set(col)
        below = jnp.where(idx > j, col, 0.0)
        a_work = a_work - jnp.outer(below, below)
        return a_work, l

    _, l = lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l


def _solve_lower_unblocked(l, b):
    """Row-sweep forward substitution (small systems / fallback)."""
    n = b.shape[0]

    def body(i, y):
        yi = (b[i, :] - l[i, :] @ y) / l[i, i]
        return y.at[i, :].set(yi)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _solve_upper_unblocked(u, b):
    """Row-sweep backward substitution for upper-triangular ``u``."""
    n = b.shape[0]

    def body(k, x):
        i = n - 1 - k
        xi = (b[i, :] - u[i, :] @ x) / u[i, i]
        return x.at[i, :].set(xi)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def cholesky(a, block=BLOCK):
    """Lower Cholesky factor of SPD matrix ``a`` ([n, n]), pure-HLO.

    Blocked right-looking variant: per panel, factor the B×B diagonal
    block with the unblocked loop, form the sub-diagonal panel with one
    triangular solve, and apply the rank-B Schur update as a dense
    matmul. Falls back to the unblocked loop when B does not divide n.
    """
    a = jnp.asarray(a)  # numpy closures break fori_loop tracing
    n = a.shape[-1]
    if n <= block or n % block != 0:
        return _cholesky_unblocked(a)
    nb = n // block
    rows = jnp.arange(n)

    def body(jb, carry):
        a_work, l = carry
        start = jb * block
        d = lax.dynamic_slice(a_work, (start, start), (block, block))
        ld = _cholesky_unblocked(d)
        # Full-height column strip; only rows below the block are valid.
        strip = lax.dynamic_slice(a_work, (0, start), (n, block))  # [n, B]
        sol = _solve_lower_unblocked(ld, strip.T).T  # [n, B] = strip·Ld⁻ᵀ
        below = (rows >= start + block)[:, None]
        panel = jnp.where(below, sol, 0.0)
        # Write the diagonal block + sub-diagonal panel into L.
        col = panel + lax.dynamic_update_slice(
            jnp.zeros((n, block), a.dtype), ld, (start, 0)
        )
        l = lax.dynamic_update_slice(l, col, (0, start))
        # Rank-B Schur update of the trailing submatrix (dense matmul;
        # rows/cols already consumed are never read again).
        a_work = a_work - panel @ panel.T
        return a_work, l

    _, l = lax.fori_loop(0, nb, body, (a, jnp.zeros_like(a)))
    return l


def solve_lower(l, b, block=BLOCK):
    """Solve ``L Y = B`` for lower-triangular ``L`` ([n, n]), ``B`` [n, m].

    Blocked forward substitution: each trip solves one B-row panel
    against the diagonal block after a dense-matmul update with all
    previously solved rows.
    """
    l, b = jnp.asarray(l), jnp.asarray(b)
    n = b.shape[0]
    if n <= block or n % block != 0:
        return _solve_lower_unblocked(l, b)
    nb = n // block

    def body(jb, y):
        start = jb * block
        lrows = lax.dynamic_slice(l, (start, 0), (block, n))  # [B, n]
        # Unsolved rows of y are still zero, and L's diagonal block
        # columns hit them, so one full-width matmul charges exactly the
        # solved prefix.
        rhs = lax.dynamic_slice(b, (start, 0), (block, b.shape[1])) - lrows @ y
        ld = lax.dynamic_slice(l, (start, start), (block, block))
        y_blk = _solve_lower_unblocked(ld, rhs)
        return lax.dynamic_update_slice(y, y_blk, (start, 0))

    return lax.fori_loop(0, nb, body, jnp.zeros_like(b))


def solve_lower_t(l, y, block=BLOCK):
    """Solve ``Lᵀ X = Y`` for lower-triangular ``L``, ``Y`` [n, m].

    Blocked backward substitution over Lᵀ's upper-triangular structure.
    """
    l, y = jnp.asarray(l), jnp.asarray(y)
    n = y.shape[0]
    if n <= block or n % block != 0:
        return _solve_upper_unblocked(l.T, y)
    nb = n // block

    def body(k, x):
        start = (nb - 1 - k) * block
        cols = lax.dynamic_slice(l, (0, start), (n, block))  # [n, B] = Lᵀ rows
        rhs = lax.dynamic_slice(y, (start, 0), (block, y.shape[1])) - cols.T @ x
        ld = lax.dynamic_slice(l, (start, start), (block, block))
        x_blk = _solve_upper_unblocked(ld.T, rhs)
        return lax.dynamic_update_slice(x, x_blk, (start, 0))

    return lax.fori_loop(0, nb, body, jnp.zeros_like(y))


def cho_solve(l, b):
    """Solve ``A X = B`` given the lower Cholesky factor of ``A``."""
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    x = solve_lower_t(l, solve_lower(l, b))
    return x[:, 0] if squeeze else x


# ---------------------------------------------------------------------------
# erf without the `erf` HLO opcode (unknown to the 0.5.1 parser):
# W. J. Cody's rational approximations — the exact coefficients of the
# rust implementation (rust/src/gp/stats.rs), so both sides agree to
# ~1e-15 and backend parity is tight.
# ---------------------------------------------------------------------------

_P0 = (
    3.209377589138469472562e3,
    3.774852376853020208137e2,
    1.138641541510501556495e2,
    3.161123743870565596947e0,
    1.857777061846031526730e-1,
)
_Q0 = (
    2.844236833439170622273e3,
    1.282616526077372275645e3,
    2.440246379344441733056e2,
    2.360129095234412093499e1,
)
_P1 = (
    1.23033935479799725272e3,
    2.05107837782607146532e3,
    1.71204761263407058314e3,
    8.81952221241769090411e2,
    2.98635138197400131132e2,
    6.61191906371416294775e1,
    8.88314979438837594118e0,
    5.64188496988670089180e-1,
    2.15311535474403846343e-8,
)
_Q1 = (
    1.23033935480374942043e3,
    3.43936767414372163696e3,
    4.36261909014324715820e3,
    3.29079923573345962678e3,
    1.62138957456669018874e3,
    5.37181101862009857509e2,
    1.17693950891312499305e2,
    1.57449261107098347253e1,
    1.0,
)
_P2 = (
    -6.58749161529837803157e-4,
    -1.60837851487422766278e-2,
    -1.25781726111229246204e-1,
    -3.60344899949804439429e-1,
    -3.05326634961232344035e-1,
    -1.63153871373020978498e-2,
)
_Q2 = (
    2.33520497626869185443e-3,
    6.05183413124413191178e-2,
    5.27905102951428412248e-1,
    1.87295284992346047209e0,
    2.56852019228982242072e0,
    1.0,
)
_INV_SQRT_PI = 0.564189583547756286948


def _erf_small(x):
    """erf on |x| < 0.5 (argument pre-clamped)."""
    z = x * x
    num = (((_P0[4] * z + _P0[3]) * z + _P0[2]) * z + _P0[1]) * z + _P0[0]
    den = (((z + _Q0[3]) * z + _Q0[2]) * z + _Q0[1]) * z + _Q0[0]
    return x * num / den


def _erfc_mid(x):
    """erfc on 0.5 <= x <= 4 (argument pre-clamped)."""
    num = _P1[8] * x
    den = _Q1[8] * x
    for i in range(7, 0, -1):
        num = (num + _P1[i]) * x
        den = (den + _Q1[i]) * x
    return jnp.exp(-x * x) * (num + _P1[0]) / (den + _Q1[0])


def _erfc_far(x):
    """erfc on x > 4 (argument pre-clamped to <= 27 to avoid overflow)."""
    z = 1.0 / (x * x)
    num = _P2[5] * z
    den = _Q2[5] * z
    for i in range(4, 0, -1):
        num = (num + _P2[i]) * z
        den = (den + _Q2[i]) * z
    r = z * (num + _P2[0]) / (den + _Q2[0])
    return (jnp.exp(-x * x) / x) * (_INV_SQRT_PI + r)


def erf(x):
    """Cody erf, branch-free (jnp.where over pre-clamped arguments)."""
    ax = jnp.abs(x)
    sign = jnp.sign(x)
    small = _erf_small(jnp.clip(x, -0.5, 0.5))
    mid = 1.0 - _erfc_mid(jnp.clip(ax, 0.5, 4.0))
    far = 1.0 - _erfc_far(jnp.clip(ax, 4.0, 27.0))
    out = jnp.where(ax < 0.5, small, jnp.where(ax <= 4.0, sign * mid, sign * far))
    return jnp.where(ax > 27.0, sign, out)
