"""AOT compile path: lower ``scheduler_step`` to HLO text artifacts.

Run once at build time (``make artifacts``); the rust runtime
(``rust/src/runtime``) loads the text, compiles it on the PJRT CPU client
and executes it on the request path — python is never invoked again.

Interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts are lowered in float64 (``jax_enable_x64``) so the rust native
backend and the XLA backend agree to ~1e-9 and parity tests can be tight.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .model import scheduler_step  # noqa: E402

# (N, L) shape buckets to emit. Small bucket covers the Azure (9 users x
# 8 models = 72 arms) and DeepLearning (14 x 8 = 112) protocol instances;
# the medium bucket covers synthetic instances up to 24 users x 20 models.
BUCKETS = [(16, 128), (32, 512)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, l: int) -> str:
    """Lower scheduler_step for an (N, L) bucket to HLO text."""
    f64 = jnp.float64
    spec = jax.ShapeDtypeStruct
    args = (
        spec((l, l), f64),  # k
        spec((l,), f64),  # mu0
        spec((l,), f64),  # obs_mask
        spec((l,), f64),  # z
        spec((l,), f64),  # sel_mask
        spec((n, l), f64),  # member
        spec((l,), f64),  # cost
    )
    lowered = jax.jit(scheduler_step).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    parser.add_argument(
        "--buckets",
        default=",".join(f"{n}x{l}" for n, l in BUCKETS),
        help="comma-separated NxL bucket list",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    buckets = []
    for tok in args.buckets.split(","):
        n, l = tok.lower().split("x")
        buckets.append((int(n), int(l)))
    manifest_lines = []
    for n, l in buckets:
        name = f"scheduler_step_n{n}_l{l}"
        text = lower_bucket(n, l)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {n} {l} {name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')} ({len(buckets)} buckets)")


if __name__ == "__main__":
    main()
