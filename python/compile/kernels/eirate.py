"""Layer-1 Pallas kernel: fused EIrate scoring (paper Eqs. 3-5).

One pass over the arm axis computes, for a VMEM-resident tile of arms,
the expected improvement of every (user, arm) pair, the membership-masked
sum over users, the division by cost, and the selected-arm masking —
fused so the [N, L] intermediate never round-trips to HBM.

TPU mapping (DESIGN.md section Hardware-Adaptation): the arm axis is the
lane dimension, tiled at ``BLOCK_L`` (multiple of 128 on real TPUs; any
multiple works under interpret=True); the user axis (N <= 64 in all paper
workloads) stays fully resident, so the kernel is a single HBM->VMEM
stream over ``member``. ``interpret=True`` is mandatory on CPU PJRT —
real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default arm-tile width. 128 = one TPU lane tile; interpret mode accepts
# any positive multiple of the padded L.
BLOCK_L = 128


def _eirate_kernel(mu_ref, sigma_ref, best_ref, member_ref, cost_ref, sel_ref, out_ref):
    """Kernel body for one arm tile."""
    mu = mu_ref[...]  # [BL]
    sigma = sigma_ref[...]  # [BL]
    cost = cost_ref[...]  # [BL]
    sel = sel_ref[...]  # [BL]
    best = best_ref[...]  # [N]
    member = member_ref[...]  # [N, BL]

    sigma_safe = jnp.maximum(sigma, ref.SIGMA_EPS)
    u = (mu[None, :] - best[:, None]) / sigma_safe[None, :]
    ei_analytic = sigma_safe[None, :] * ref.tau(u)
    ei_degenerate = jnp.maximum(mu[None, :] - best[:, None], 0.0)
    ei = jnp.where(sigma[None, :] > ref.SIGMA_EPS, ei_analytic, ei_degenerate)
    total = jnp.sum(member * ei, axis=0)  # [BL]
    score = total / cost
    out_ref[...] = jnp.where(sel > 0.5, ref.NEG_INF_SCORE, score)


def _pad_arms(x, block, value):
    l = x.shape[-1]
    pad = (-l) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_l",))
def eirate(mu, sigma, best, member, cost, sel_mask, *, block_l=BLOCK_L):
    """Fused EIrate scores for all arms.

    Same contract as :func:`ref.eirate_ref`; arms are padded to a multiple
    of ``block_l`` internally (padding arms carry sel_mask = 1 and cost =
    1 so they score -1e30 and are sliced off).
    """
    l = mu.shape[0]
    mu_p = _pad_arms(mu, block_l, 0.0)
    sigma_p = _pad_arms(sigma, block_l, 1.0)
    cost_p = _pad_arms(cost, block_l, 1.0)
    sel_p = _pad_arms(sel_mask, block_l, 1.0)
    member_p = _pad_arms(member, block_l, 0.0)
    lp = mu_p.shape[0]
    n = best.shape[0]
    grid = (lp // block_l,)
    out = pl.pallas_call(
        _eirate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l,), lambda i: (i,)),  # mu
            pl.BlockSpec((block_l,), lambda i: (i,)),  # sigma
            pl.BlockSpec((n,), lambda i: (0,)),  # best (broadcast)
            pl.BlockSpec((n, block_l), lambda i: (0, i)),  # member
            pl.BlockSpec((block_l,), lambda i: (i,)),  # cost
            pl.BlockSpec((block_l,), lambda i: (i,)),  # sel
        ],
        out_specs=pl.BlockSpec((block_l,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lp,), mu.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(mu_p, sigma_p, best, member_p, cost_p, sel_p)
    return out[:l]
