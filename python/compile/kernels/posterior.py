"""Layer-1 Pallas kernel: fused GP posterior contraction.

After the Layer-2 graph has factorized the masked kernel matrix and
forward-solved ``W = L^{-1} V^T`` (whitened cross-covariances) and
``gamma = L^{-1} resid`` (whitened residuals), the per-arm posterior is
two reductions over the observation axis sharing ONE streamed operand:

    mu[l]  = mu0[l]  + sum_o wt[l, o] * gamma[o]     (posterior mean)
    var[l] = kdiag[l] - sum_o wt[l, o]^2             (posterior variance)

(the ``sigma^2 = K_xx - ||L^{-1}v||^2`` identity removes the backward
solve entirely — §Perf L2 iteration 3 — and means the kernel streams only
``wt``, halving HBM traffic versus the earlier (wt, v) formulation.)

TPU mapping: the ``wt @ gamma`` partial product is an MXU-shaped
contraction; the elementwise square-reduction rides along on the VPU
while the tile is resident in VMEM. Accumulation across the
observation-axis grid dimension uses the standard Pallas revisit pattern
(same output block for every ``o`` step, initialized at ``o == 0``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: arms (lanes) x observations (streamed axis).
BLOCK_L = 128
BLOCK_O = 128


def _posterior_kernel(wt_ref, gamma_ref, kdiag_ref, mu0_ref, mu_ref, var_ref):
    """Kernel body for one (arm-tile, obs-tile) grid step."""
    o_step = pl.program_id(1)

    @pl.when(o_step == 0)
    def _init():
        mu_ref[...] = mu0_ref[...]
        var_ref[...] = kdiag_ref[...]

    wt = wt_ref[...]  # [BL, BO] — the single streamed operand
    gamma = gamma_ref[...]  # [BO]
    mu_ref[...] += wt @ gamma
    var_ref[...] -= jnp.sum(wt * wt, axis=1)


def _pad_axis(x, axis, block, value=0.0):
    pad = (-x.shape[axis]) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_l", "block_o"))
def posterior_diag(wt, gamma, kdiag, mu0, *, block_l=BLOCK_L, block_o=BLOCK_O):
    """Fused posterior mean/variance contraction.

    Same contract as :func:`..kernels.ref.posterior_diag_ref`. Both the
    arm and observation axes are padded to tile multiples; padded
    observations carry zero ``wt``/``gamma`` so they contribute nothing.

    Returns ``(mu, var)`` of shape [L].
    """
    l, o = wt.shape
    wt_p = _pad_axis(_pad_axis(wt, 0, block_l), 1, block_o)
    gamma_p = _pad_axis(gamma, 0, block_o)
    kdiag_p = _pad_axis(kdiag, 0, block_l)
    mu0_p = _pad_axis(mu0, 0, block_l)
    lp, op = wt_p.shape
    grid = (lp // block_l, op // block_o)
    mu, var = pl.pallas_call(
        _posterior_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l, block_o), lambda i, j: (i, j)),  # wt
            pl.BlockSpec((block_o,), lambda i, j: (j,)),  # gamma
            pl.BlockSpec((block_l,), lambda i, j: (i,)),  # kdiag
            pl.BlockSpec((block_l,), lambda i, j: (i,)),  # mu0
        ],
        out_specs=[
            pl.BlockSpec((block_l,), lambda i, j: (i,)),  # mu (revisited over j)
            pl.BlockSpec((block_l,), lambda i, j: (i,)),  # var (revisited over j)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lp,), wt.dtype),
            jax.ShapeDtypeStruct((lp,), wt.dtype),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(wt_p, gamma_p, kdiag_p, mu0_p)
    return mu[:l], var[:l]
