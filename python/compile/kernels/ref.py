"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness bar).

Every Pallas kernel in this package has a reference implementation here,
written with nothing but plain ``jax.numpy`` ops so it is obviously
correct. ``python/tests`` asserts allclose between kernel and reference
across hypothesis-generated shapes; the rust test-suite additionally
checks the AOT artifact built *from the kernels* against its own native
GP implementation.

All math follows the paper:

* ``tau(u) = u * Phi(u) + phi(u)``                      (Lemma 1)
* ``EI_{i,t}(x) = sigma_t(x) * tau((mu_t(x) - best_i)/sigma_t(x))`` (Eq. 3)
* ``EIrate_t(x) = sum_i member[i,x] * EI_{i,t}(x) / c(x)``   (Eqs. 4-5)
"""

import jax.numpy as jnp

from ..linalg_jax import erf  # Cody rational erf — lowers to plain HLO
                              # (the `erf` opcode is unknown to the pinned
                              # xla_extension 0.5.1 HLO parser)

# Score assigned to arms that must not be selected (already dispatched).
NEG_INF_SCORE = -1e30

# Below this posterior std an arm is treated as deterministic.
SIGMA_EPS = 1e-12


def norm_cdf(u):
    """Standard normal CDF."""
    return 0.5 * (1.0 + erf(u / jnp.sqrt(2.0).astype(u.dtype)))


def norm_pdf(u):
    """Standard normal PDF."""
    inv_sqrt_2pi = 1.0 / jnp.sqrt(2.0 * jnp.pi).astype(u.dtype)
    return inv_sqrt_2pi * jnp.exp(-0.5 * u * u)


def tau(u):
    """The paper's tau(u) = u*Phi(u) + phi(u)."""
    return u * norm_cdf(u) + norm_pdf(u)


def expected_improvement(mu, sigma, best):
    """EI of N(mu, sigma^2) over incumbent ``best``; rows of ``best``
    broadcast against columns of ``mu``/``sigma``.

    Handles the degenerate sigma -> 0 case as max(mu - best, 0), exactly
    like the rust implementation (gp::stats::expected_improvement).
    """
    mu2 = mu[None, :]
    best2 = best[:, None]
    sigma2 = jnp.maximum(sigma, SIGMA_EPS)[None, :]
    analytic = sigma2 * tau((mu2 - best2) / sigma2)
    degenerate = jnp.maximum(mu2 - best2, 0.0)
    return jnp.where(sigma[None, :] > SIGMA_EPS, analytic, degenerate)


def eirate_ref(mu, sigma, best, member, cost, sel_mask):
    """Reference EIrate scores (Eq. 5) for all arms.

    Args:
      mu:       [L] posterior means.
      sigma:    [L] posterior stds.
      best:     [N] per-user incumbents.
      member:   [N, L] 0/1 membership matrix.
      cost:     [L] arm costs.
      sel_mask: [L] 0/1, 1 = already selected (score forced to -1e30).

    Returns:
      [L] EIrate scores.
    """
    ei = expected_improvement(mu, sigma, best)  # [N, L]
    total = jnp.sum(member * ei, axis=0)
    score = total / cost
    return jnp.where(sel_mask > 0.5, NEG_INF_SCORE, score)


def posterior_diag_ref(wt, gamma, kdiag, mu0):
    """Reference fused posterior contraction (whitened form).

    Given ``wt = (L^{-1} V^T)^T`` (shape [L, O]), whitened residuals
    ``gamma = L^{-1} resid`` ([O]), prior diagonal ``kdiag`` and prior
    mean ``mu0`` (both [L]):

      mu[l]  = mu0[l]  + sum_o wt[l,o] * gamma[o]
      var[l] = kdiag[l] - sum_o wt[l,o]^2

    Returns (mu, var).
    """
    mu = mu0 + wt @ gamma
    var = kdiag - jnp.sum(wt * wt, axis=1)
    return mu, var


def gp_posterior_ref(k, mu0, obs_mask, z, jitter=1e-10):
    """Full-reference masked GP posterior over all arms (textbook formulas,
    paper Supplemental section A), used to validate the Layer-2 graph.

    Returns (mu_t, sigma_t) with observed arms pinned to (z, 0).
    """
    m = obs_mask
    a = k * m[:, None] * m[None, :] + jnp.diag(1.0 - m) + jnp.diag(m) * jitter
    lchol = jnp.linalg.cholesky(a)
    resid = m * (z - mu0)
    # alpha = A^{-1} resid via two triangular solves.
    import jax.scipy.linalg as jsl

    alpha = jsl.cho_solve((lchol, True), resid)
    v = k * m[None, :]
    mu = mu0 + v @ alpha
    x = jsl.cho_solve((lchol, True), v.T)  # A^{-1} V^T, [L, L]
    var = jnp.diag(k) - jnp.sum(v * x.T, axis=1)
    mu = jnp.where(m > 0.5, z, mu)
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    sigma = jnp.where(m > 0.5, 0.0, sigma)
    return mu, sigma
