"""Make the `compile` package importable whether pytest is invoked from
the repo root (`pytest python/tests/`) or from `python/` (`pytest tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
