"""linalg_jax (the parser-safe HLO-native linear algebra) vs jnp/scipy."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from scipy.special import erf as scipy_erf

from compile import linalg_jax

RNG = np.random.default_rng


def _spd(rng, n):
    b = rng.normal(0, 1, (n, n))
    return b @ b.T + n * np.eye(n)


class TestCholesky:
    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(1, 48), seed=st.integers(0, 2**32 - 1))
    def test_matches_jnp(self, n, seed):
        a = _spd(RNG(seed), n)
        got = np.asarray(linalg_jax.cholesky(jnp.asarray(a)))
        want = np.linalg.cholesky(a)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)

    def test_strictly_lower_output(self):
        a = _spd(RNG(0), 7)
        l = np.asarray(linalg_jax.cholesky(jnp.asarray(a)))
        assert np.allclose(np.triu(l, 1), 0.0)

    def test_blocked_path_matches_unblocked(self):
        # n = 64, 128 are multiples of BLOCK=32 -> blocked algorithm.
        for n in (64, 128):
            a = _spd(RNG(n), n)
            blocked = np.asarray(linalg_jax.cholesky(jnp.asarray(a)))
            unblocked = np.asarray(linalg_jax._cholesky_unblocked(jnp.asarray(a)))
            np.testing.assert_allclose(blocked, unblocked, rtol=1e-9, atol=1e-9)
            np.testing.assert_allclose(blocked, np.linalg.cholesky(a), rtol=1e-9, atol=1e-9)
            assert np.allclose(np.triu(blocked, 1), 0.0)

    def test_blocked_solves_match(self):
        rng = RNG(77)
        n, m = 96, 40
        a = _spd(rng, n)
        b = rng.normal(0, 1, (n, m))
        l = np.linalg.cholesky(a)
        y_b = np.asarray(linalg_jax.solve_lower(jnp.asarray(l), jnp.asarray(b)))
        np.testing.assert_allclose(l @ y_b, b, rtol=1e-8, atol=1e-9)
        x_b = np.asarray(linalg_jax.solve_lower_t(jnp.asarray(l), jnp.asarray(y_b)))
        np.testing.assert_allclose(l.T @ x_b, y_b, rtol=1e-8, atol=1e-9)
        x = np.asarray(linalg_jax.cho_solve(jnp.asarray(l), jnp.asarray(b)))
        np.testing.assert_allclose(a @ x, b, rtol=1e-7, atol=1e-8)


class TestSolves:
    @settings(deadline=None, max_examples=20)
    @given(
        n=st.integers(1, 40),
        m=st.integers(1, 8),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_cho_solve_matches_direct(self, n, m, seed):
        rng = RNG(seed)
        a = _spd(rng, n)
        b = rng.normal(0, 1, (n, m))
        l = linalg_jax.cholesky(jnp.asarray(a))
        x = np.asarray(linalg_jax.cho_solve(l, jnp.asarray(b)))
        np.testing.assert_allclose(a @ x, b, rtol=1e-8, atol=1e-8)

    def test_vector_rhs(self):
        rng = RNG(3)
        a = _spd(rng, 9)
        b = rng.normal(0, 1, 9)
        l = linalg_jax.cholesky(jnp.asarray(a))
        x = np.asarray(linalg_jax.cho_solve(l, jnp.asarray(b)))
        assert x.shape == (9,)
        np.testing.assert_allclose(a @ x, b, rtol=1e-8)

    def test_triangular_solves_individually(self):
        rng = RNG(5)
        a = _spd(rng, 11)
        l = np.linalg.cholesky(a)
        b = rng.normal(0, 1, (11, 3))
        y = np.asarray(linalg_jax.solve_lower(jnp.asarray(l), jnp.asarray(b)))
        np.testing.assert_allclose(l @ y, b, rtol=1e-9)
        x = np.asarray(linalg_jax.solve_lower_t(jnp.asarray(l), jnp.asarray(y)))
        np.testing.assert_allclose(l.T @ x, y, rtol=1e-9)


class TestErf:
    @settings(deadline=None, max_examples=60)
    @given(x=st.floats(min_value=-30.0, max_value=30.0, allow_nan=False))
    def test_matches_scipy_pointwise(self, x):
        got = float(linalg_jax.erf(jnp.asarray(x, dtype=jnp.float64)))
        want = float(scipy_erf(x))
        assert abs(got - want) < 1e-13, f"erf({x}): {got} vs {want}"

    def test_branch_boundaries(self):
        for x in (-4.0, -0.5, 0.0, 0.5, 4.0, 26.0, 27.0, 28.0, 1e6):
            got = float(linalg_jax.erf(jnp.asarray(x, dtype=jnp.float64)))
            want = float(scipy_erf(x))
            assert abs(got - want) < 1e-13

    def test_extreme_arguments_no_nan(self):
        xs = jnp.asarray([-1e12, -100.0, 100.0, 1e12], dtype=jnp.float64)
        out = np.asarray(linalg_jax.erf(xs))
        np.testing.assert_allclose(out, [-1.0, -1.0, 1.0, 1.0])
        assert not np.any(np.isnan(out))

    def test_vectorized(self):
        xs = np.linspace(-6, 6, 4001)
        got = np.asarray(linalg_jax.erf(jnp.asarray(xs)))
        want = scipy_erf(xs)
        np.testing.assert_allclose(got, want, atol=1e-13)
