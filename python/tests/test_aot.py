"""AOT artifact regression tests.

The pinned runtime (xla_extension 0.5.1 behind the published `xla` crate)
parses HLO *text* and executes only classic HLO ops. These tests lower
the scheduler_step buckets exactly as `make artifacts` does and assert
the output stays inside that envelope — catching regressions like the
`erf` opcode or LAPACK FFI custom-calls that newer jax lowers to.
"""

import re

import pytest

from compile.aot import lower_bucket, to_hlo_text, BUCKETS


@pytest.fixture(scope="module")
def small_bucket_hlo():
    return lower_bucket(4, 16)


class TestHloEnvelope:
    def test_no_custom_calls(self, small_bucket_hlo):
        assert "custom-call" not in small_bucket_hlo, (
            "custom-calls (e.g. lapack_*_ffi) cannot execute on the pinned "
            "PJRT runtime — keep linalg on the jax-native path"
        )

    def test_no_erf_opcode(self, small_bucket_hlo):
        # The erf HLO opcode postdates xla_extension 0.5.1's parser.
        assert not re.search(r"\berf\(", small_bucket_hlo), (
            "`erf` opcode leaked into the artifact — use linalg_jax.erf"
        )

    def test_entry_signature_shapes(self, small_bucket_hlo):
        # 7 parameters; the root is a tuple carrying the 4 outputs
        # (eirate, mu, sigma, best).
        assert "ENTRY" in small_bucket_hlo, "missing ENTRY computation"
        params = set(re.findall(r"parameter\((\d)\)", small_bucket_hlo))
        assert params == {str(i) for i in range(7)}, f"params {sorted(params)}"
        assert re.search(r"ROOT .* tuple\(", small_bucket_hlo), "root must be a tuple"

    def test_default_buckets_cover_paper_instances(self):
        # Azure protocol: 9 users × 8 models = 72 arms; DeepLearning:
        # 14 × 8 = 112 arms. The smallest shipped bucket must fit both.
        n_max = max(n for n, _ in BUCKETS)
        l_max = max(l for _, l in BUCKETS)
        assert any(n >= 14 and l >= 112 for n, l in BUCKETS), BUCKETS
        assert n_max >= 14 and l_max >= 112

    def test_lowering_is_deterministic(self):
        a = lower_bucket(4, 16)
        b = lower_bucket(4, 16)
        assert a == b, "HLO text must be reproducible for artifact caching"


class TestToHloText:
    def test_simple_function_roundtrips(self):
        import jax
        import jax.numpy as jnp

        spec = jax.ShapeDtypeStruct((2, 2), jnp.float64)
        lowered = jax.jit(lambda x: (x @ x,)).lower(spec)
        text = to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "dot" in text
