"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; assert_allclose against ref.py is
the core correctness signal for the kernels that end up inside the AOT
artifact the rust coordinator executes.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.eirate import eirate
from compile.kernels.posterior import posterior_diag

RNG = np.random.default_rng


def _random_inputs(rng, n, l):
    mu = rng.normal(0.5, 0.3, l)
    sigma = np.abs(rng.normal(0.0, 0.5, l))
    # Sprinkle exact zeros to exercise the degenerate-sigma branch.
    sigma[rng.random(l) < 0.2] = 0.0
    best = rng.uniform(0.0, 1.0, n)
    member = (rng.random((n, l)) < 0.4).astype(np.float64)
    cost = rng.uniform(0.3, 5.0, l)
    sel = (rng.random(l) < 0.3).astype(np.float64)
    return mu, sigma, best, member, cost, sel


class TestEirateKernel:
    @settings(deadline=None, max_examples=25)
    @given(
        n=st.integers(min_value=1, max_value=40),
        l=st.integers(min_value=1, max_value=300),
        seed=st.integers(min_value=0, max_value=2**32 - 1),
    )
    def test_matches_ref_across_shapes(self, n, l, seed):
        rng = RNG(seed)
        mu, sigma, best, member, cost, sel = _random_inputs(rng, n, l)
        got = eirate(mu, sigma, best, member, cost, sel)
        want = ref.eirate_ref(mu, sigma, best, member, cost, sel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-12)

    def test_block_boundary_shapes(self):
        # Exact block multiples and off-by-one sizes around BLOCK_L.
        rng = RNG(7)
        for l in (127, 128, 129, 255, 256, 257):
            mu, sigma, best, member, cost, sel = _random_inputs(rng, 8, l)
            got = eirate(mu, sigma, best, member, cost, sel)
            want = ref.eirate_ref(mu, sigma, best, member, cost, sel)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)

    def test_selected_arms_masked(self):
        rng = RNG(3)
        mu, sigma, best, member, cost, _ = _random_inputs(rng, 4, 64)
        sel = np.zeros(64)
        sel[10] = 1.0
        got = np.asarray(eirate(mu, sigma, best, member, cost, sel))
        assert got[10] == ref.NEG_INF_SCORE
        assert np.all(got[np.arange(64) != 10] > ref.NEG_INF_SCORE)

    def test_shared_arm_sums_users(self):
        # Two users share one arm -> EI doubles relative to one user.
        mu = jnp.array([0.5])
        sigma = jnp.array([0.2])
        best = jnp.array([0.4, 0.4])
        cost = jnp.array([1.0])
        sel = jnp.array([0.0])
        one = eirate(mu, sigma, best, jnp.array([[1.0], [0.0]]), cost, sel)
        both = eirate(mu, sigma, best, jnp.array([[1.0], [1.0]]), cost, sel)
        np.testing.assert_allclose(np.asarray(both), 2 * np.asarray(one), rtol=1e-12)

    def test_cost_divides(self):
        rng = RNG(11)
        mu, sigma, best, member, _, sel = _random_inputs(rng, 6, 32)
        sel[:] = 0.0
        c1 = np.ones(32)
        c3 = np.full(32, 3.0)
        s1 = np.asarray(eirate(mu, sigma, best, member, c1, sel))
        s3 = np.asarray(eirate(mu, sigma, best, member, c3, sel))
        np.testing.assert_allclose(s3, s1 / 3.0, rtol=1e-12)

    def test_float32_dtype(self):
        rng = RNG(5)
        mu, sigma, best, member, cost, sel = (
            a.astype(np.float32) for a in _random_inputs(rng, 5, 70)
        )
        got = eirate(mu, sigma, best, member, cost, sel)
        want = ref.eirate_ref(mu, sigma, best, member, cost, sel)
        assert np.asarray(got).dtype == np.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-6)


class TestPosteriorKernel:
    @settings(deadline=None, max_examples=25)
    @given(
        l=st.integers(min_value=1, max_value=200),
        o=st.integers(min_value=1, max_value=200),
        seed=st.integers(min_value=0, max_value=2**32 - 1),
    )
    def test_matches_ref_across_shapes(self, l, o, seed):
        rng = RNG(seed)
        wt = rng.normal(0, 1, (l, o))
        gamma = rng.normal(0, 1, o)
        kdiag = rng.uniform(0.5, 2.0, l)
        mu0 = rng.normal(0, 1, l)
        mu, var = posterior_diag(wt, gamma, kdiag, mu0)
        mu_w, var_w = ref.posterior_diag_ref(wt, gamma, kdiag, mu0)
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_w), rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(np.asarray(var), np.asarray(var_w), rtol=1e-10, atol=1e-10)

    def test_multi_tile_accumulation(self):
        # Observation axis spanning several tiles exercises the revisit/
        # accumulate pattern.
        rng = RNG(13)
        l, o = 130, 300
        wt = rng.normal(0, 1, (l, o))
        gamma = rng.normal(0, 1, o)
        kdiag = rng.uniform(0.5, 2.0, l)
        mu0 = rng.normal(0, 1, l)
        mu, var = posterior_diag(wt, gamma, kdiag, mu0)
        mu_w, var_w = ref.posterior_diag_ref(wt, gamma, kdiag, mu0)
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_w), rtol=1e-9)
        np.testing.assert_allclose(np.asarray(var), np.asarray(var_w), rtol=1e-9)

    def test_zero_observations_identity(self):
        # gamma = 0, wt = 0 -> posterior equals prior.
        l, o = 17, 8
        wt = np.zeros((l, o))
        gamma = np.zeros(o)
        kdiag = np.full(l, 1.5)
        mu0 = np.linspace(-1, 1, l)
        mu, var = posterior_diag(wt, gamma, kdiag, mu0)
        np.testing.assert_allclose(np.asarray(mu), mu0, atol=1e-15)
        np.testing.assert_allclose(np.asarray(var), kdiag, atol=1e-15)

    def test_whitened_form_matches_textbook_gp(self):
        # wt = (L^{-1} V^T)^T, gamma = L^{-1} r reproduce the textbook
        # posterior mu0 + V A^{-1} r and diag(K - V A^{-1} V^T).
        rng = RNG(99)
        o, l = 12, 20
        b = rng.normal(0, 1, (o, o))
        a = b @ b.T + o * np.eye(o)
        lchol = np.linalg.cholesky(a)
        v = rng.normal(0, 1, (l, o))
        r = rng.normal(0, 1, o)
        kdiag = np.sum(v * (v @ np.linalg.inv(a)), axis=1) + rng.uniform(0.1, 1.0, l)
        mu0 = rng.normal(0, 1, l)
        wt = np.linalg.solve(lchol, v.T).T
        gamma = np.linalg.solve(lchol, r)
        mu, var = posterior_diag(wt, gamma, kdiag, mu0)
        want_mu = mu0 + v @ np.linalg.solve(a, r)
        want_var = kdiag - np.sum(v * np.linalg.solve(a, v.T).T, axis=1)
        np.testing.assert_allclose(np.asarray(mu), want_mu, rtol=1e-9)
        np.testing.assert_allclose(np.asarray(var), want_var, rtol=1e-8, atol=1e-10)


class TestTauMath:
    @settings(deadline=None, max_examples=50)
    @given(u=st.floats(min_value=-8.0, max_value=8.0))
    def test_tau_identity(self, u):
        # tau(u) = u + tau(-u) (used in the paper's Lemma 3 proof).
        t_pos = float(ref.tau(jnp.array(u)))
        t_neg = float(ref.tau(jnp.array(-u)))
        assert t_pos == pytest.approx(u + t_neg, abs=1e-12)

    def test_tau_known_value(self):
        # tau(0) = phi(0) = 1/sqrt(2*pi)
        assert float(ref.tau(jnp.array(0.0))) == pytest.approx(0.3989422804014327, abs=1e-14)

    @settings(deadline=None, max_examples=30)
    @given(
        mu=st.floats(-2, 2),
        sigma=st.floats(0.01, 2.0),
        a=st.floats(-2, 2),
        seed=st.integers(0, 2**31),
    )
    def test_ei_against_monte_carlo(self, mu, sigma, a, seed):
        rng = RNG(seed)
        draws = rng.normal(mu, sigma, 200_000)
        mc = np.maximum(draws - a, 0.0).mean()
        analytic = float(
            ref.expected_improvement(jnp.array([mu]), jnp.array([sigma]), jnp.array([a]))[0, 0]
        )
        assert analytic == pytest.approx(mc, abs=6e-3)
