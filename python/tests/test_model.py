"""Layer-2 correctness: the composed scheduler_step graph.

Validates (a) the Pallas-backed graph against the pure-jnp reference,
(b) the masked-Cholesky posterior against a direct numpy GP computed on
the observed subset only, and (c) the Algorithm-1 semantics (masking,
incumbents, argmax behaviour) the rust coordinator relies on.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import scheduler_step, scheduler_step_ref

RNG = np.random.default_rng


def _random_problem(rng, n, l):
    """Random SPD prior + problem structure with some observations."""
    b = rng.normal(0, 1, (l, l))
    k = b @ b.T / l + 0.3 * np.eye(l)
    mu0 = rng.uniform(0.2, 0.8, l)
    z_true = rng.uniform(0.0, 1.0, l)
    obs = np.zeros(l)
    n_obs = rng.integers(0, l // 2 + 1)
    obs[rng.choice(l, size=n_obs, replace=False)] = 1.0
    z = z_true * obs
    sel = obs.copy()
    extra_running = rng.random(l) < 0.1
    sel = np.clip(sel + extra_running, 0, 1)
    member = np.zeros((n, l))
    for x in range(l):
        owners = rng.choice(n, size=rng.integers(1, min(3, n) + 1), replace=False)
        member[owners, x] = 1.0
    cost = rng.uniform(0.3, 4.0, l)
    return k, mu0, obs, z, sel, member, cost


class TestSchedulerStepGraph:
    @settings(deadline=None, max_examples=15)
    @given(
        n=st.integers(min_value=1, max_value=16),
        l=st.integers(min_value=2, max_value=96),
        seed=st.integers(min_value=0, max_value=2**32 - 1),
    )
    def test_pallas_graph_matches_ref_graph(self, n, l, seed):
        rng = RNG(seed)
        args = _random_problem(rng, n, l)
        got = scheduler_step(*args)
        want = scheduler_step_ref(*args)
        for g, w, name in zip(got, want, ["eirate", "mu", "sigma", "best"]):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=1e-7, atol=1e-9, err_msg=name
            )

    def test_posterior_matches_direct_numpy_gp(self):
        # Masked fixed-shape posterior == textbook posterior on the
        # observed subset (paper Supplemental A).
        rng = RNG(42)
        n, l = 4, 24
        k, mu0, obs, z, sel, member, cost = _random_problem(rng, n, l)
        obs_idx = np.where(obs > 0.5)[0]
        if len(obs_idx) == 0:
            obs[0] = 1.0
            z[0] = 0.7
            obs_idx = np.array([0])
        _, mu, sigma, _ = scheduler_step(k, mu0, obs, z, sel, member, cost)
        mu = np.asarray(mu)
        sigma = np.asarray(sigma)
        kt = k[np.ix_(obs_idx, obs_idx)]
        kt_inv = np.linalg.inv(kt + 1e-9 * np.eye(len(obs_idx)))
        for x in range(l):
            v = k[x, obs_idx]
            want_mu = mu0[x] + v @ kt_inv @ (z[obs_idx] - mu0[obs_idx])
            want_var = k[x, x] - v @ kt_inv @ v
            if obs[x] > 0.5:
                assert mu[x] == z[x]
                assert sigma[x] == 0.0
            else:
                assert abs(mu[x] - want_mu) < 1e-6, f"mu mismatch at {x}"
                assert abs(sigma[x] - np.sqrt(max(want_var, 0))) < 1e-6

    def test_no_observations_prior_pass_through(self):
        rng = RNG(1)
        n, l = 3, 10
        k, mu0, _, _, _, member, cost = _random_problem(rng, n, l)
        zeros = np.zeros(l)
        scores, mu, sigma, best = scheduler_step(k, mu0, zeros, zeros, zeros, member, cost)
        np.testing.assert_allclose(np.asarray(mu), mu0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(sigma), np.sqrt(np.diagonal(k)), rtol=1e-9)
        np.testing.assert_allclose(np.asarray(best), np.zeros(n), atol=1e-15)
        assert np.all(np.asarray(scores) > ref.NEG_INF_SCORE)

    def test_incumbents_per_user_max(self):
        rng = RNG(2)
        n, l = 3, 12
        k, mu0, _, _, _, _, cost = _random_problem(rng, n, l)
        member = np.zeros((n, l))
        member[0, :4] = 1.0
        member[1, 4:8] = 1.0
        member[2, 8:] = 1.0
        obs = np.zeros(l)
        z = np.zeros(l)
        obs[[0, 1, 4]] = 1.0
        z[[0, 1, 4]] = [0.3, 0.6, 0.9]
        _, _, _, best = scheduler_step(k, mu0, obs, z, obs.copy(), member, cost)
        best = np.asarray(best)
        assert best[0] == 0.6  # max of user 0's observed arms
        assert best[1] == 0.9
        assert best[2] == 0.0  # no observation -> EMPTY_INCUMBENT

    def test_padding_arms_are_inert(self):
        # Emulate the rust runtime's padding contract: padded arms have
        # obs=0, sel=1, member=0, cost=1, k row/col = e_x (identity).
        rng = RNG(3)
        n, l, pad = 3, 10, 6
        k, mu0, obs, z, sel, member, cost = _random_problem(rng, n, l)
        lp = l + pad
        kp = np.eye(lp)
        kp[:l, :l] = k
        mu0p = np.concatenate([mu0, np.zeros(pad)])
        obsp = np.concatenate([obs, np.zeros(pad)])
        zp = np.concatenate([z, np.zeros(pad)])
        selp = np.concatenate([sel, np.ones(pad)])
        memberp = np.concatenate([member, np.zeros((n, pad))], axis=1)
        costp = np.concatenate([cost, np.ones(pad)])
        s_pad, mu_pad, sig_pad, best_pad = scheduler_step(
            kp, mu0p, obsp, zp, selp, memberp, costp
        )
        s, mu, sig, best = scheduler_step(k, mu0, obs, z, sel, member, cost)
        np.testing.assert_allclose(np.asarray(s_pad)[:l], np.asarray(s), rtol=1e-9)
        np.testing.assert_allclose(np.asarray(mu_pad)[:l], np.asarray(mu), rtol=1e-9)
        np.testing.assert_allclose(np.asarray(sig_pad)[:l], np.asarray(sig), rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(np.asarray(best_pad), np.asarray(best), rtol=1e-12)
        # Padding arms can never win the argmax.
        assert np.all(np.asarray(s_pad)[l:] == ref.NEG_INF_SCORE)

    def test_argmax_prefers_cheap_equal_ei(self):
        # Two identical unobserved arms, different costs -> argmax picks
        # the cheap one (the EIrate mechanism).
        n, l = 1, 4
        k = np.eye(l)
        mu0 = np.full(l, 0.5)
        obs = np.zeros(l)
        z = np.zeros(l)
        sel = np.zeros(l)
        member = np.ones((n, l))
        cost = np.array([1.0, 5.0, 1.0, 5.0])
        scores, _, _, _ = scheduler_step(k, mu0, obs, z, sel, member, cost)
        scores = np.asarray(scores)
        assert scores.argmax() in (0, 2)
        assert scores[0] > scores[1]
