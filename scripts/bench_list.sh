#!/usr/bin/env bash
# Single source of truth for the figure-bench list, derived from the
# [[bench]] targets declared in rust/Cargo.toml. Both CI's bench-smoke
# job and scripts/refresh_baselines.sh iterate over this output, so a
# new bench target is automatically gated the moment it is declared —
# e.g. fig6_churn (tenant churn) entered the determinism + thread-
# invariance + baseline gates the moment its [[bench]] block landed.
set -euo pipefail
cd "$(dirname "$0")/.."
awk '/^\[\[bench\]\]/ { in_bench = 1; next }
     /^\[/            { in_bench = 0 }
     in_bench && /^name = / { gsub(/"/, "", $3); print $3 }' rust/Cargo.toml
