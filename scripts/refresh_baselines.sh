#!/usr/bin/env bash
# Regenerate the checked-in CI smoke baselines (baselines/BENCH_*.json).
#
# Runs every figure harness twice in --smoke --json mode, verifies the
# two same-seed reports are byte-identical (the determinism contract the
# CI gate relies on), then installs them under baselines/. Commit the
# result. See baselines/README.md for when refreshing is appropriate.
set -euo pipefail
cd "$(dirname "$0")/.."

BENCHES="$(scripts/bench_list.sh)"
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

for b in $BENCHES; do
  echo "== $b =="
  cargo bench --bench "$b" -- --smoke --json "$TMP/BENCH_$b.json"
  cargo bench --bench "$b" -- --smoke --json "$TMP/second/BENCH_$b.json"
  cmp "$TMP/BENCH_$b.json" "$TMP/second/BENCH_$b.json" || {
    echo "error: $b smoke report is not deterministic" >&2
    exit 1
  }
  install -D "$TMP/BENCH_$b.json" "baselines/BENCH_$b.json"
done

git --no-pager diff --stat -- baselines/ || true
echo "baselines refreshed; review and commit baselines/BENCH_*.json"
