//! Maximum Incremental Uncertainty (paper §5.1) and the Theorem-2 regret
//! bound evaluator.
//!
//! For a PSD kernel matrix `K` over the arm set, the s-MIU score is
//!
//! ```text
//! MIU_s(K) = max over S' ⊂ S ⊆ [|𝓛|], |S| = s, |S'| = s−1 of
//!            sqrt(det(K_S) / det(K_S'))
//! ```
//!
//! By the Schur-complement identity (paper Lemma 5) the ratio equals the
//! *conditional variance* of the element added to `S'`, so
//! `MIU_s(K) = max_{|S'|=s−1} max_{x ∉ S'} σ(x | S')` — the largest
//! one-step increase in explained uncertainty. The exact maximization is
//! combinatorial; this module provides
//!
//! * [`miu_exact`] — exhaustive search (feasible for `|𝓛| ≲ 20`, used by
//!   the test suite and the small real-data instances),
//! * [`miu_greedy`] — a witness-based lower bound via local search,
//! * [`miu_diag_bound`] — the paper's own upper bound
//!   `MIU(T,K) ≤ Σ_{top |𝓛(t)|} sqrt(K_ii)` (§5.2),
//! * [`theorem2_bound`] — the `(MIU + M)·N²/M·c̄` regret bound, used by
//!   the `theory` CLI command to check measured regret against theory.

use crate::linalg::{cholesky_jittered, solve_lower, Mat};

/// Conditional variance `σ²(x | S')` of arm `x` given observed set `idx`,
/// computed through the Cholesky of the principal submatrix.
pub fn conditional_variance(k: &Mat, idx: &[usize], x: usize) -> f64 {
    debug_assert!(!idx.contains(&x));
    if idx.is_empty() {
        return k[(x, x)];
    }
    let sub = crate::linalg::principal_submatrix(k, idx);
    // pallas-lint: allow(R5) — callers pass PSD kernel matrices (every principal submatrix of a PSD matrix is PSD, and the jitter absorbs roundoff); a failure means the input was not a kernel matrix.
    let (l, _) = cholesky_jittered(&sub, 1e-12).expect("submatrix not PSD");
    let v: Vec<f64> = idx.iter().map(|&i| k[(x, i)]).collect();
    let w = solve_lower(&l, &v);
    (k[(x, x)] - w.iter().map(|u| u * u).sum::<f64>()).max(0.0)
}

/// Exact `MIU_s(K)` by exhaustive enumeration of `S'` (size s−1) and the
/// added element. Cost `O(C(n, s−1)·n·s³)`; intended for `n ≲ 20`.
pub fn miu_exact(k: &Mat, s: usize) -> f64 {
    let n = k.rows();
    assert!(s >= 1 && s <= n, "need 1 ≤ s ≤ n");
    if s == 1 {
        // S' = ∅, det(K_∅) := 1 → MIU₁ = max_x sqrt(K_xx).
        return (0..n).map(|x| k[(x, x)].max(0.0).sqrt()).fold(0.0, f64::max);
    }
    let mut best: f64 = 0.0;
    let mut subset: Vec<usize> = (0..s - 1).collect();
    loop {
        // Evaluate all completions of this S'.
        for x in 0..n {
            if !subset.contains(&x) {
                best = best.max(conditional_variance(k, &subset, x).sqrt());
            }
        }
        // Next (s−1)-combination in lexicographic order.
        let mut i = s - 1;
        loop {
            if i == 0 {
                return best;
            }
            i -= 1;
            if subset[i] != i + n - (s - 1) {
                break;
            }
        }
        subset[i] += 1;
        for j in i + 1..s - 1 {
            subset[j] = subset[j - 1] + 1;
        }
    }
}

/// Greedy witness search for `MIU_s(K)`: for each candidate added element
/// `x`, build `S'` greedily to *maximize* the remaining conditional
/// variance of `x` (pick the s−1 conditioning elements least informative
/// about `x`). A valid lower bound on the exact score; exact when the
/// conditioning choice is irrelevant (e.g. diagonal K).
pub fn miu_greedy(k: &Mat, s: usize) -> f64 {
    let n = k.rows();
    assert!(s >= 1 && s <= n);
    if s == 1 {
        return (0..n).map(|x| k[(x, x)].max(0.0).sqrt()).fold(0.0, f64::max);
    }
    let mut best: f64 = 0.0;
    for x in 0..n {
        // Greedily pick s−1 conditioners that keep σ²(x | S') maximal.
        let mut chosen: Vec<usize> = Vec::with_capacity(s - 1);
        for _ in 0..s - 1 {
            let mut arg = usize::MAX;
            let mut val = f64::NEG_INFINITY;
            for c in 0..n {
                if c == x || chosen.contains(&c) {
                    continue;
                }
                let mut trial = chosen.clone();
                trial.push(c);
                let v = conditional_variance(k, &trial, x);
                if v > val {
                    val = v;
                    arg = c;
                }
            }
            chosen.push(arg);
        }
        best = best.max(conditional_variance(k, &chosen, x).sqrt());
    }
    best
}

/// `MIU(T, K) = Σ_{s=2}^{m} MIU_s(K)` with `m = |𝓛(T)|` observed arms
/// (paper Theorem 2), using the given per-s scorer.
pub fn miu_total(k: &Mat, n_observed: usize, scorer: impl Fn(&Mat, usize) -> f64) -> f64 {
    (2..=n_observed.min(k.rows())).map(|s| scorer(k, s)).sum()
}

/// The paper's §5.2 upper bound:
/// `MIU(T,K) ≤ Σ over the top |𝓛(t)| diagonal entries of sqrt(K_ii)`.
pub fn miu_diag_bound(k: &Mat, n_observed: usize) -> f64 {
    let mut diags: Vec<f64> = (0..k.rows()).map(|i| k[(i, i)].max(0.0).sqrt()).collect();
    diags.sort_by(|a, b| b.total_cmp(a));
    diags.iter().take(n_observed).sum()
}

/// Theorem 2 regret bound `(MIU(T,K) + M) · N²/M · c̄` (up to the
/// universal constant the paper absorbs into ≲).
pub fn theorem2_bound(miu_total: f64, n_users: usize, n_devices: usize, mean_opt_cost: f64) -> f64 {
    let n = n_users as f64;
    let m = n_devices as f64;
    (miu_total + m) * n * n / m * mean_opt_cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, Matern52};

    #[test]
    fn diagonal_k_miu_is_largest_variances() {
        // Independent arms: σ(x|S') = σ(x); MIU_s = max diag sqrt.
        let k = Mat::from_rows(&[&[4.0, 0.0, 0.0], &[0.0, 1.0, 0.0], &[0.0, 0.0, 0.25]]);
        for s in 1..=3 {
            let exact = miu_exact(&k, s);
            assert!((exact - 2.0).abs() < 1e-9, "s={s}: {exact}");
            assert!((miu_greedy(&k, s) - exact).abs() < 1e-9);
        }
    }

    #[test]
    fn miu_s1_is_max_sqrt_diag() {
        let k = Mat::from_rows(&[&[1.0, 0.5], &[0.5, 9.0]]);
        assert!((miu_exact(&k, 1) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn conditional_variance_shrinks_with_conditioning() {
        let pts: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64 * 0.3]).collect();
        let k = Matern52 { variance: 1.0, lengthscale: 1.0 }.gram(&pts);
        let v0 = conditional_variance(&k, &[], 3);
        let v1 = conditional_variance(&k, &[2], 3);
        let v2 = conditional_variance(&k, &[2, 4], 3);
        assert!(v0 >= v1 && v1 >= v2, "{v0} {v1} {v2}");
        assert!(v2 >= 0.0);
    }

    #[test]
    fn miu_monotone_decreasing_in_s_for_correlated_k() {
        // For a stationary kernel on a grid, conditioning can only help,
        // and the max over larger S' families includes the smaller ones'
        // worst case — MIU_s should be non-increasing in s here.
        let pts: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 * 0.5]).collect();
        let k = Matern52 { variance: 1.0, lengthscale: 1.0 }.gram(&pts);
        let mut prev = f64::INFINITY;
        for s in 1..=5 {
            let v = miu_exact(&k, s);
            assert!(v <= prev + 1e-9, "MIU_{s} = {v} > prev {prev}");
            prev = v;
        }
    }

    #[test]
    fn greedy_lower_bounds_exact() {
        let pts: Vec<Vec<f64>> = (0..7).map(|i| vec![(i * i % 5) as f64 * 0.4, i as f64 * 0.2]).collect();
        let k = Matern52 { variance: 1.3, lengthscale: 0.8 }.gram(&pts);
        for s in 2..=5 {
            let g = miu_greedy(&k, s);
            let e = miu_exact(&k, s);
            assert!(g <= e + 1e-9, "greedy {g} must lower-bound exact {e} (s={s})");
            assert!(g >= 0.5 * e, "greedy should be a decent witness (s={s}: {g} vs {e})");
        }
    }

    #[test]
    fn total_bounded_by_diag_bound() {
        let pts: Vec<Vec<f64>> = (0..9).map(|i| vec![i as f64 * 0.35]).collect();
        let k = Matern52 { variance: 1.0, lengthscale: 1.2 }.gram(&pts);
        let m = 6;
        let total = miu_total(&k, m, miu_exact);
        let bound = miu_diag_bound(&k, m);
        assert!(total <= bound + 1e-9, "total {total} vs diag bound {bound}");
    }

    #[test]
    fn rank_one_matrix_miu_vanishes_beyond_first() {
        // K = vvᵀ (rank 1): after conditioning on any one arm, every other
        // arm is fully determined → conditional variance 0.
        let v = [1.0, 2.0, 0.5];
        let k = Mat::from_fn(3, 3, |i, j| v[i] * v[j]);
        assert!(miu_exact(&k, 2) < 1e-4, "rank-1: MIU_2 ≈ 0");
        // The paper's O(1/T) special case: bounded MIU(T,K).
        let total = miu_total(&k, 3, miu_exact);
        assert!(total < 1e-3);
    }

    #[test]
    fn theorem2_bound_scalings() {
        let b1 = theorem2_bound(10.0, 20, 1, 2.0);
        let b4 = theorem2_bound(10.0, 20, 4, 2.0);
        // near-linear speedup while M ≪ MIU: bound shrinks ≈ M×.
        assert!(b1 / b4 > 3.0 && b1 / b4 <= 4.0);
        // More users → quadratically more regret.
        assert!(theorem2_bound(10.0, 40, 1, 2.0) / b1 > 3.9);
    }
}
