//! Property-testing mini-framework.
//!
//! The offline environment provides no `proptest`/`quickcheck`, so this
//! module supplies the pieces the test suite needs: seeded random-case
//! generation over a configurable number of cases, value generators built
//! on [`crate::prng::Rng`], and failure reports that include the seed of
//! the offending case so it can be replayed deterministically.

use crate::prng::Rng;

/// Number of random cases per property (override with `MMGPEI_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("MMGPEI_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32)
}

/// Run `property` against `cases` seeded RNGs; panics with the failing
/// seed on the first violation (the property itself should panic/assert).
pub fn for_all_seeds(name: &str, cases: usize, mut property: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Convenience wrapper with the default case count.
pub fn check(name: &str, property: impl FnMut(&mut Rng)) {
    for_all_seeds(name, default_cases(), property);
}

/// Generators for common structured inputs.
pub mod gen {
    use crate::kernels::{exchangeable_user_sim, kronecker_arm_cov};
    use crate::linalg::Mat;
    use crate::problem::{Problem, Truth};
    use crate::prng::Rng;

    /// Random SPD matrix `B Bᵀ + εI` of size `n`.
    pub fn spd(rng: &mut Rng, n: usize) -> Mat {
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += b[(i, k)] * b[(j, k)];
                }
                let v = acc + if i == j { 0.5 * n as f64 } else { 0.0 };
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    /// Random correlation-scaled covariance (unit-ish diagonal).
    pub fn covariance(rng: &mut Rng, n: usize) -> Mat {
        let mut a = spd(rng, n);
        let d: Vec<f64> = (0..n).map(|i| a[(i, i)].sqrt()).collect();
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] /= d[i] * d[j];
            }
        }
        a
    }

    /// Random MDMT problem instance + ground truth, with disjoint per-user
    /// arm blocks (the common case) and a Kronecker prior.
    pub fn problem(rng: &mut Rng, n_users: usize, models_per_user: usize) -> (Problem, Truth) {
        let n_arms = n_users * models_per_user;
        let arms: Vec<(usize, usize)> = (0..n_users)
            .flat_map(|u| (0..models_per_user).map(move |m| (u, m)))
            .collect();
        let rho = rng.uniform_in(0.1, 0.9);
        let user_sim = exchangeable_user_sim(n_users, rho);
        let model_cov = {
            let mut c = covariance(rng, models_per_user);
            for i in 0..models_per_user {
                c[(i, i)] += 0.05;
            }
            c
        };
        let prior_cov = kronecker_arm_cov(&arms, &user_sim, &model_cov);
        let prior_mean = vec![0.5; n_arms];
        let user_arms: Vec<Vec<usize>> = (0..n_users)
            .map(|u| (0..models_per_user).map(|m| u * models_per_user + m).collect())
            .collect();
        let arm_users = Problem::compute_arm_users(n_arms, &user_arms);
        let cost: Vec<f64> = (0..n_arms).map(|_| rng.uniform_in(0.5, 4.0)).collect();
        let p = Problem {
            name: format!("prop-{n_users}x{models_per_user}"),
            n_users,
            cost,
            user_arms,
            arm_users,
            prior_mean: prior_mean.clone(),
            prior_cov: prior_cov.clone(),
        };
        p.validate();
        // Draw the truth from the prior itself (well-specified case).
        let (l, _) = crate::linalg::cholesky_jittered(&prior_cov, 1e-8).unwrap();
        let z = rng.mvn(&prior_mean, &l);
        (p, Truth { z })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_seeds_runs_every_case() {
        let mut count = 0;
        for_all_seeds("counting", 17, |_| {
            count += 1;
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property 'failing' failed on case 0")]
    fn failing_property_reports_seed() {
        for_all_seeds("failing", 4, |_| panic!("boom"));
    }

    #[test]
    fn spd_generator_is_pd() {
        check("spd is positive definite", |rng| {
            let a = gen::spd(rng, 6);
            assert!(crate::linalg::cholesky(&a).is_ok());
        });
    }

    #[test]
    fn covariance_unit_diag() {
        check("covariance has unit diagonal", |rng| {
            let c = gen::covariance(rng, 5);
            for i in 0..5 {
                assert!((c[(i, i)] - 1.0).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn problem_generator_validates() {
        check("generated problems validate", |rng| {
            let (p, t) = gen::problem(rng, 4, 3);
            assert_eq!(t.z.len(), p.n_arms());
            p.validate();
        });
    }
}
