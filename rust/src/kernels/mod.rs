//! Gaussian-process covariance functions over the arm space.
//!
//! The paper (§4.2) chooses the GP prior "from historical experiences":
//! the correlation between two arms depends on (a) the similarity of the
//! *models* and (b) the similarity of the *users' datasets*. This module
//! provides:
//!
//! * stationary kernels over feature vectors ([`Matern52`], [`Rbf`]) —
//!   the synthetic Figure-5 experiment uses Matérn ν = 5/2;
//! * [`empirical_model_cov`] — the "historical runs" estimator: a
//!   model×model covariance estimated from a matrix of holdout-user
//!   accuracies (the paper's protocol isolates 8 users for exactly this);
//! * [`kronecker_arm_cov`] — the user⊗model composition that turns a
//!   model-covariance and a user-similarity into a full arm covariance.

use crate::linalg::Mat;

/// A positive-definite kernel over ℝᵈ feature vectors.
pub trait Kernel {
    /// Covariance `k(x, x')`.
    fn eval(&self, x: &[f64], y: &[f64]) -> f64;

    /// Gram matrix over a set of points.
    fn gram(&self, points: &[Vec<f64>]) -> Mat {
        let n = points.len();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.eval(&points[i], &points[j]);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        k
    }
}

#[inline]
fn sq_dist(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// Matérn ν = 5/2 kernel,
/// `k(r) = σ²(1 + √5 r/ℓ + 5r²/(3ℓ²))·exp(−√5 r/ℓ)` — the kernel used for
/// the paper's synthetic experiment (Figure 5).
#[derive(Clone, Debug)]
pub struct Matern52 {
    /// Output variance σ².
    pub variance: f64,
    /// Lengthscale ℓ.
    pub lengthscale: f64,
}

impl Kernel for Matern52 {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        let r = sq_dist(x, y).sqrt();
        let s = 5f64.sqrt() * r / self.lengthscale;
        self.variance * (1.0 + s + s * s / 3.0) * (-s).exp()
    }
}

/// Squared-exponential (RBF) kernel `σ²·exp(−r²/(2ℓ²))`.
#[derive(Clone, Debug)]
pub struct Rbf {
    /// Output variance σ².
    pub variance: f64,
    /// Lengthscale ℓ.
    pub lengthscale: f64,
}

impl Kernel for Rbf {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        self.variance * (-0.5 * sq_dist(x, y) / (self.lengthscale * self.lengthscale)).exp()
    }
}

/// Empirical model×model covariance from a history matrix.
///
/// `history[u][m]` is the observed performance of model `m` on holdout
/// user `u`'s dataset. Returns `(mean, cov)` where `mean[m]` is the
/// per-model empirical mean and `cov` the (ridge-regularized) empirical
/// covariance across holdout users — the paper's "construct the kernel
/// matrix from historical runs" (§4.2).
pub fn empirical_model_cov(history: &[Vec<f64>], ridge: f64) -> (Vec<f64>, Mat) {
    let u = history.len();
    assert!(u >= 2, "need at least two holdout users to estimate covariance");
    let m = history[0].len();
    let mut mean = vec![0.0; m];
    for row in history {
        assert_eq!(row.len(), m, "ragged history matrix");
        for (acc, &v) in mean.iter_mut().zip(row.iter()) {
            *acc += v;
        }
    }
    for v in mean.iter_mut() {
        *v /= u as f64;
    }
    let mut cov = Mat::zeros(m, m);
    for row in history {
        for i in 0..m {
            let di = row[i] - mean[i];
            for j in 0..=i {
                let dj = row[j] - mean[j];
                cov[(i, j)] += di * dj;
            }
        }
    }
    let denom = (u - 1) as f64;
    for i in 0..m {
        for j in 0..=i {
            let v = cov[(i, j)] / denom;
            cov[(i, j)] = v;
            cov[(j, i)] = v;
        }
    }
    // Ridge keeps the estimate PD when #holdout-users < #models.
    for i in 0..m {
        cov[(i, i)] += ridge;
    }
    (mean, cov)
}

/// Exchangeable user-similarity matrix
/// `U = (1 − ρ)·I + ρ·𝟙𝟙ᵀ` for `ρ ∈ [0, 1)`.
///
/// ρ is the assumed correlation between *different* users' responses to
/// the same model; ρ = 0 recovers fully independent users (the paper's
/// "not converge" special case of §5.2), ρ → 1 makes every user share one
/// latent response.
pub fn exchangeable_user_sim(n_users: usize, rho: f64) -> Mat {
    assert!((0.0..1.0).contains(&rho), "rho must be in [0,1)");
    Mat::from_fn(n_users, n_users, |i, j| if i == j { 1.0 } else { rho })
}

/// Kronecker arm covariance: arm `a = (user uₐ, model mₐ)` gets
/// `K[a,b] = U[uₐ, u_b] · C[mₐ, m_b]` — dataset similarity times model
/// similarity, the standard multi-task GP construction the paper alludes
/// to in §4.2.
///
/// `arms[a] = (user, model)`.
pub fn kronecker_arm_cov(arms: &[(usize, usize)], user_sim: &Mat, model_cov: &Mat) -> Mat {
    let n = arms.len();
    let mut k = Mat::zeros(n, n);
    for a in 0..n {
        let (ua, ma) = arms[a];
        for b in 0..=a {
            let (ub, mb) = arms[b];
            let v = user_sim[(ua, ub)] * model_cov[(ma, mb)];
            k[(a, b)] = v;
            k[(b, a)] = v;
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cholesky, cholesky_jittered};

    #[test]
    fn matern_at_zero_is_variance() {
        let k = Matern52 { variance: 2.5, lengthscale: 1.3 };
        assert!((k.eval(&[0.7, -0.2], &[0.7, -0.2]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn matern_decreases_with_distance() {
        let k = Matern52 { variance: 1.0, lengthscale: 1.0 };
        let mut prev = k.eval(&[0.0], &[0.0]);
        for step in 1..30 {
            let d = step as f64 * 0.3;
            let v = k.eval(&[0.0], &[d]);
            assert!(v < prev, "Matérn must decay at distance {d}");
            assert!(v > 0.0);
            prev = v;
        }
    }

    #[test]
    fn matern_known_value() {
        // k(r=1, ℓ=1, σ²=1) = (1+√5+5/3)·exp(−√5)
        let k = Matern52 { variance: 1.0, lengthscale: 1.0 };
        let s = 5f64.sqrt();
        let want = (1.0 + s + 5.0 / 3.0) * (-s).exp();
        assert!((k.eval(&[0.0], &[1.0]) - want).abs() < 1e-12);
    }

    #[test]
    fn rbf_known_value() {
        let k = Rbf { variance: 1.0, lengthscale: 2.0 };
        assert!((k.eval(&[0.0], &[2.0]) - (-0.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn gram_is_symmetric_pd() {
        let pts: Vec<Vec<f64>> = (0..12).map(|i| vec![i as f64 * 0.37, (i * i % 7) as f64]).collect();
        for k in [&Matern52 { variance: 1.0, lengthscale: 1.5 } as &dyn Kernel] {
            let g = k.gram(&pts);
            for i in 0..12 {
                for j in 0..12 {
                    assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-15);
                }
            }
            assert!(cholesky_jittered(&g, 1e-10).is_ok());
        }
    }

    #[test]
    fn empirical_cov_matches_hand_computation() {
        // Two models, three users.
        let hist = vec![vec![0.8, 0.2], vec![0.6, 0.4], vec![0.7, 0.3]];
        let (mean, cov) = empirical_model_cov(&hist, 0.0);
        assert!((mean[0] - 0.7).abs() < 1e-12);
        assert!((mean[1] - 0.3).abs() < 1e-12);
        // var(model0) = ((0.1)²+(0.1)²+0)/2 = 0.01
        assert!((cov[(0, 0)] - 0.01).abs() < 1e-12);
        assert!((cov[(1, 1)] - 0.01).abs() < 1e-12);
        // cov = (0.1·−0.1 + (−0.1)·0.1 + 0)/2 = −0.01 (perfectly anti-correlated)
        assert!((cov[(0, 1)] + 0.01).abs() < 1e-12);
    }

    #[test]
    fn empirical_cov_ridge_makes_pd() {
        // 2 holdout users, 4 models → rank-1 covariance, needs ridge.
        let hist = vec![vec![0.1, 0.2, 0.3, 0.4], vec![0.5, 0.1, 0.0, 0.9]];
        let (_, cov) = empirical_model_cov(&hist, 1e-4);
        assert!(cholesky(&cov).is_ok(), "ridge must make the estimate PD");
    }

    #[test]
    fn exchangeable_user_sim_shape() {
        let u = exchangeable_user_sim(3, 0.4);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(u[(i, j)], if i == j { 1.0 } else { 0.4 });
            }
        }
    }

    #[test]
    fn kronecker_composition() {
        let user_sim = exchangeable_user_sim(2, 0.5);
        let model_cov = Mat::from_rows(&[&[1.0, 0.3], &[0.3, 2.0]]);
        // arms: (u0,m0), (u0,m1), (u1,m0)
        let arms = [(0, 0), (0, 1), (1, 0)];
        let k = kronecker_arm_cov(&arms, &user_sim, &model_cov);
        assert_eq!(k[(0, 0)], 1.0);
        assert_eq!(k[(0, 1)], 0.3); // same user, different model
        assert_eq!(k[(0, 2)], 0.5); // different user, same model
        assert_eq!(k[(1, 2)], 0.5 * 0.3);
        // Symmetric PD (after tiny jitter).
        assert!(cholesky_jittered(&k, 1e-12).is_ok());
    }

    #[test]
    #[should_panic(expected = "at least two holdout users")]
    fn empirical_cov_needs_two_users() {
        let _ = empirical_model_cov(&[vec![0.5, 0.5]], 0.0);
    }
}
