//! Machine-readable experiment reports (`BENCH_*.json`).
//!
//! Every figure harness and the CLI sweep can emit a [`RunReport`]: a
//! schema-stable, provenance-stamped JSON document with the KPIs the
//! paper's figures plot (regret, time-to-cutoff, speedup, parity) plus
//! optional wall-clock timing percentiles. CI diffs a fresh report
//! against a checked-in baseline with [`super::compare`].
//!
//! **Determinism contract:** KPIs are pure functions of `(config, seed)`
//! — the simulator runs in virtual time and the PRNG/`total_cmp` replay
//! guarantees make them bit-stable — so a *smoke* report (the CI mode)
//! serializes byte-identically across same-seed runs. Wall-clock timings
//! are inherently non-reproducible, so [`RunReport::push_timing`] drops
//! them in smoke mode; full runs carry them and `compare` treats them as
//! warn-only.

use super::json::{parse, Json, JsonError};
use crate::bench::BenchStats;

/// Version stamp written into every report; bump on breaking schema
/// changes (the golden test in `tests/report_golden.rs` pins the layout).
pub const SCHEMA_VERSION: u64 = 1;

/// Which direction of change is an improvement for a KPI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Smaller is better (regret, time-to-cutoff, makespan).
    LowerIsBetter,
    /// Larger is better (speedup, parity fractions).
    HigherIsBetter,
}

impl Direction {
    fn as_str(self) -> &'static str {
        match self {
            Direction::LowerIsBetter => "lower",
            Direction::HigherIsBetter => "higher",
        }
    }

    fn from_str(s: &str) -> Result<Direction, String> {
        match s {
            "lower" => Ok(Direction::LowerIsBetter),
            "higher" => Ok(Direction::HigherIsBetter),
            other => Err(format!("unknown KPI direction {other:?}")),
        }
    }
}

/// One named scalar quality metric.
#[derive(Clone, Debug, PartialEq)]
pub struct Kpi {
    /// Hierarchical name, e.g. `azure/mdmt@M1/cumulative_regret`.
    pub name: String,
    /// The measured value (always finite; non-finite pushes are dropped).
    pub value: f64,
    /// Which direction is an improvement.
    pub better: Direction,
}

/// One wall-clock timing entry (nanosecond percentiles).
#[derive(Clone, Debug, PartialEq)]
pub struct TimingEntry {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations behind the percentiles.
    pub iters: u64,
    /// Mean iteration time in ns.
    pub mean_ns: f64,
    /// Median in ns.
    pub p50_ns: f64,
    /// 95th percentile in ns.
    pub p95_ns: f64,
    /// 99th percentile in ns.
    pub p99_ns: f64,
}

impl TimingEntry {
    /// Mean-only entry (percentiles collapsed onto the mean) for sources
    /// that track totals rather than samples, e.g. the simulator's
    /// per-decision wall time.
    pub fn flat(name: impl Into<String>, iters: u64, mean_ns: f64) -> TimingEntry {
        TimingEntry { name: name.into(), iters, mean_ns, p50_ns: mean_ns, p95_ns: mean_ns, p99_ns: mean_ns }
    }
}

impl From<&BenchStats> for TimingEntry {
    fn from(s: &BenchStats) -> TimingEntry {
        TimingEntry {
            name: s.name.clone(),
            iters: s.iters as u64,
            mean_ns: s.mean.as_nanos() as f64,
            p50_ns: s.p50.as_nanos() as f64,
            p95_ns: s.p95.as_nanos() as f64,
            p99_ns: s.p99.as_nanos() as f64,
        }
    }
}

/// Where the numbers came from.
#[derive(Clone, Debug, PartialEq)]
pub struct Provenance {
    /// Git commit (env `MMGPEI_COMMIT`/`GITHUB_SHA`, else `git rev-parse`,
    /// else `"unknown"`).
    pub commit: String,
    /// Base seed of the sweep.
    pub seed: u64,
    /// FNV-1a hash of the canonical config string(s); folded with
    /// [`RunReport::fold_config`].
    pub config_hash: String,
    /// Whether this was a reduced deterministic smoke run.
    pub smoke: bool,
}

/// A full experiment report: provenance + KPIs + timings.
#[derive(Clone, Debug, PartialEq)]
pub struct RunReport {
    /// Report name (the figure/bench it came from, e.g. `fig2`).
    pub name: String,
    /// Provenance stamp.
    pub provenance: Provenance,
    /// Quality metrics — hard-gated by `compare`.
    pub kpis: Vec<Kpi>,
    /// Wall-clock timings — warn-only in `compare`, empty in smoke mode.
    pub timings: Vec<TimingEntry>,
}

/// 64-bit FNV-1a over bytes: tiny, stable, dependency-free — exactly
/// what a config fingerprint needs (not cryptographic).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100000001b3);
    }
    hash
}

/// Discover the current commit without failing: explicit env override,
/// then the CI-provided sha, then asking git, then `"unknown"`.
pub fn detect_commit() -> String {
    for key in ["MMGPEI_COMMIT", "GITHUB_SHA"] {
        if let Ok(v) = std::env::var(key) {
            if !v.is_empty() {
                return v;
            }
        }
    }
    if let Ok(out) = std::process::Command::new("git").args(["rev-parse", "HEAD"]).output() {
        if out.status.success() {
            if let Ok(s) = String::from_utf8(out.stdout) {
                return s.trim().to_string();
            }
        }
    }
    "unknown".to_string()
}

impl RunReport {
    /// New empty report; the commit is auto-detected.
    pub fn new(name: impl Into<String>, seed: u64, smoke: bool) -> RunReport {
        RunReport {
            name: name.into(),
            provenance: Provenance {
                commit: detect_commit(),
                seed,
                config_hash: format!("{:016x}", fnv1a64(b"")),
                smoke,
            },
            kpis: Vec::new(),
            timings: Vec::new(),
        }
    }

    /// Fold a canonical config string into the provenance hash. Benches
    /// that sweep several configs call this once per config, in a fixed
    /// order, so the hash fingerprints the whole run.
    pub fn fold_config(&mut self, canonical: &str) {
        let prior = u64::from_str_radix(&self.provenance.config_hash, 16).unwrap_or(0);
        let mut bytes = prior.to_be_bytes().to_vec();
        bytes.extend_from_slice(canonical.as_bytes());
        self.provenance.config_hash = format!("{:016x}", fnv1a64(&bytes));
    }

    /// Append a KPI. Non-finite values are dropped (a `t ≤ cutoff` that
    /// was never reached is "absent", not "NaN") — `compare` flags KPIs
    /// that disappear relative to the baseline.
    pub fn push_kpi(&mut self, name: impl Into<String>, value: f64, better: Direction) {
        if value.is_finite() {
            self.kpis.push(Kpi { name: name.into(), value, better });
        }
    }

    /// Append a wall-clock timing entry — dropped in smoke mode so
    /// same-seed smoke reports stay byte-identical.
    pub fn push_timing(&mut self, entry: TimingEntry) {
        if !self.provenance.smoke {
            self.timings.push(entry);
        }
    }

    /// Serialize to the canonical JSON document.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema_version".into(), Json::Num(SCHEMA_VERSION as f64)),
            ("name".into(), Json::str(&self.name)),
            (
                "provenance".into(),
                Json::Obj(vec![
                    ("commit".into(), Json::str(&self.provenance.commit)),
                    ("seed".into(), Json::Num(self.provenance.seed as f64)),
                    ("config_hash".into(), Json::str(&self.provenance.config_hash)),
                    ("smoke".into(), Json::Bool(self.provenance.smoke)),
                ]),
            ),
            (
                "kpis".into(),
                Json::Arr(
                    self.kpis
                        .iter()
                        .map(|k| {
                            Json::Obj(vec![
                                ("name".into(), Json::str(&k.name)),
                                ("value".into(), Json::num(k.value)),
                                ("better".into(), Json::str(k.better.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "timings".into(),
                Json::Arr(
                    self.timings
                        .iter()
                        .map(|t| {
                            Json::Obj(vec![
                                ("name".into(), Json::str(&t.name)),
                                ("iters".into(), Json::num(t.iters as f64)),
                                ("mean_ns".into(), Json::num(t.mean_ns)),
                                ("p50_ns".into(), Json::num(t.p50_ns)),
                                ("p95_ns".into(), Json::num(t.p95_ns)),
                                ("p99_ns".into(), Json::num(t.p99_ns)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Canonical serialized form (what `--json` writes).
    pub fn to_json_string(&self) -> String {
        self.to_json().to_pretty()
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        super::write_report(path, &self.to_json_string())
    }

    /// Parse a report back from JSON text (the `compare` entry point).
    pub fn from_json_str(text: &str) -> Result<RunReport, String> {
        let doc = parse(text).map_err(|e: JsonError| e.to_string())?;
        let version = doc
            .get("schema_version")
            .and_then(Json::as_u64)
            .ok_or("missing schema_version")?;
        if version != SCHEMA_VERSION {
            return Err(format!("unsupported schema_version {version} (expected {SCHEMA_VERSION})"));
        }
        let name = doc.get("name").and_then(Json::as_str).ok_or("missing name")?.to_string();
        let prov = doc.get("provenance").ok_or("missing provenance")?;
        let provenance = Provenance {
            commit: prov.get("commit").and_then(Json::as_str).ok_or("missing provenance.commit")?.to_string(),
            seed: prov.get("seed").and_then(Json::as_u64).ok_or("missing provenance.seed")?,
            config_hash: prov
                .get("config_hash")
                .and_then(Json::as_str)
                .ok_or("missing provenance.config_hash")?
                .to_string(),
            smoke: prov.get("smoke").and_then(Json::as_bool).ok_or("missing provenance.smoke")?,
        };
        let mut kpis = Vec::new();
        for k in doc.get("kpis").and_then(Json::as_arr).ok_or("missing kpis")? {
            kpis.push(Kpi {
                name: k.get("name").and_then(Json::as_str).ok_or("kpi missing name")?.to_string(),
                value: k.get("value").and_then(Json::as_f64).ok_or("kpi missing value")?,
                better: Direction::from_str(k.get("better").and_then(Json::as_str).ok_or("kpi missing better")?)?,
            });
        }
        let mut timings = Vec::new();
        for t in doc.get("timings").and_then(Json::as_arr).ok_or("missing timings")? {
            let field = |key: &str| t.get(key).and_then(Json::as_f64).ok_or_else(|| format!("timing missing {key}"));
            timings.push(TimingEntry {
                name: t.get("name").and_then(Json::as_str).ok_or("timing missing name")?.to_string(),
                iters: t.get("iters").and_then(Json::as_u64).ok_or("timing missing iters")?,
                mean_ns: field("mean_ns")?,
                p50_ns: field("p50_ns")?,
                p95_ns: field("p95_ns")?,
                p99_ns: field("p99_ns")?,
            });
        }
        Ok(RunReport { name, provenance, kpis, timings })
    }

    /// Read a report from a file.
    pub fn from_file(path: &str) -> Result<RunReport, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_json_str(&text).map_err(|e| format!("{path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunReport {
        let mut r = RunReport {
            name: "figX".into(),
            provenance: Provenance {
                commit: "deadbeef".into(),
                seed: 0,
                config_hash: "0000000000000000".into(),
                smoke: true,
            },
            kpis: Vec::new(),
            timings: Vec::new(),
        };
        r.fold_config("dataset=azure");
        r.push_kpi("azure/mdmt@M1/cumulative_regret", 12.5, Direction::LowerIsBetter);
        r.push_kpi("azure/speedup_t0.05", 3.25, Direction::HigherIsBetter);
        r
    }

    #[test]
    fn roundtrips_through_json() {
        let r = sample();
        let parsed = RunReport::from_json_str(&r.to_json_string()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn non_finite_kpis_are_dropped() {
        let mut r = sample();
        let n = r.kpis.len();
        r.push_kpi("nan", f64::NAN, Direction::LowerIsBetter);
        r.push_kpi("inf", f64::INFINITY, Direction::LowerIsBetter);
        assert_eq!(r.kpis.len(), n);
    }

    #[test]
    fn smoke_mode_drops_wall_clock_timings() {
        let mut r = sample();
        assert!(r.provenance.smoke);
        r.push_timing(TimingEntry::flat("decision", 10, 1000.0));
        assert!(r.timings.is_empty());
        r.provenance.smoke = false;
        r.push_timing(TimingEntry::flat("decision", 10, 1000.0));
        assert_eq!(r.timings.len(), 1);
        assert_eq!(r.timings[0].p99_ns, 1000.0);
    }

    #[test]
    fn fold_config_is_order_sensitive_and_stable() {
        let mut a = RunReport::new("x", 0, true);
        let mut b = RunReport::new("x", 0, true);
        a.fold_config("one");
        a.fold_config("two");
        b.fold_config("one");
        b.fold_config("two");
        assert_eq!(a.provenance.config_hash, b.provenance.config_hash);
        let mut c = RunReport::new("x", 0, true);
        c.fold_config("two");
        c.fold_config("one");
        assert_ne!(a.provenance.config_hash, c.provenance.config_hash);
    }

    #[test]
    fn schema_version_is_enforced() {
        let text = sample().to_json_string().replace("\"schema_version\": 1", "\"schema_version\": 99");
        let err = RunReport::from_json_str(&text).unwrap_err();
        assert!(err.contains("schema_version"), "{err}");
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
