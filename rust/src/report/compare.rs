//! Report comparison — the CI quality/perf gate.
//!
//! `mmgpei compare baseline.json candidate.json` loads two
//! [`RunReport`]s and checks every KPI for a regression beyond the
//! configured tolerances. KPI regressions (regret up, speedup/parity
//! down) are **hard failures**; wall-clock timing growth is **warn-only**
//! because CI runners are noisy; a KPI that disappears from the candidate
//! is a hard failure (a gated metric must not silently vanish).

use super::run::{Direction, RunReport};
use crate::metrics::rel_change;

/// Per-metric tolerances for [`compare_reports`].
#[derive(Clone, Copy, Debug)]
pub struct Tolerances {
    /// Allowed relative worsening of a KPI (fraction of |baseline|).
    pub rel: f64,
    /// Absolute slack added on top (guards near-zero baselines).
    pub abs: f64,
    /// Allowed relative growth of a timing mean before warning.
    pub timing_rel: f64,
}

impl Default for Tolerances {
    fn default() -> Self {
        Tolerances { rel: 0.05, abs: 1e-9, timing_rel: 0.5 }
    }
}

/// Severity of one comparison finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Gate-breaking regression.
    Fail,
    /// Noted but non-blocking.
    Warn,
}

/// One comparison finding.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Fail or warn.
    pub severity: Severity,
    /// Metric (or provenance field) the finding is about.
    pub metric: String,
    /// Human-readable explanation with both values.
    pub detail: String,
}

/// Outcome of one report comparison.
#[derive(Clone, Debug, Default)]
pub struct CompareOutcome {
    /// All findings, failures first.
    pub findings: Vec<Finding>,
    /// KPIs present in both reports.
    pub n_kpis_compared: usize,
    /// Timing entries present in both reports.
    pub n_timings_compared: usize,
}

impl CompareOutcome {
    /// Whether the gate should fail.
    pub fn failed(&self) -> bool {
        self.findings.iter().any(|f| f.severity == Severity::Fail)
    }

    /// Number of hard failures.
    pub fn n_failures(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Fail).count()
    }

    /// Render for terminal/CI logs.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let tag = match f.severity {
                Severity::Fail => "FAIL",
                Severity::Warn => "warn",
            };
            out.push_str(&format!("[{tag}] {}: {}\n", f.metric, f.detail));
        }
        out.push_str(&format!(
            "compared {} KPIs, {} timings: {} failure(s), {} warning(s)\n",
            self.n_kpis_compared,
            self.n_timings_compared,
            self.n_failures(),
            self.findings.len() - self.n_failures()
        ));
        out
    }

    fn push(&mut self, severity: Severity, metric: &str, detail: String) {
        self.findings.push(Finding { severity, metric: metric.to_string(), detail });
    }
}

/// How much `candidate` worsened over `baseline` for a KPI, as a signed
/// fraction of |baseline| (positive = worse in the KPI's direction).
fn worsening(better: Direction, baseline: f64, candidate: f64) -> f64 {
    match better {
        Direction::LowerIsBetter => rel_change(baseline, candidate),
        Direction::HigherIsBetter => -rel_change(baseline, candidate),
    }
}

/// Compare `candidate` against `baseline`. Pure and deterministic; the
/// CLI wrapper turns `failed()` into a non-zero exit code.
pub fn compare_reports(baseline: &RunReport, candidate: &RunReport, tol: &Tolerances) -> CompareOutcome {
    let mut out = CompareOutcome::default();
    if baseline.name != candidate.name {
        out.push(
            Severity::Fail,
            "report",
            format!("name mismatch: baseline {:?} vs candidate {:?}", baseline.name, candidate.name),
        );
        return out;
    }
    if baseline.provenance.config_hash != candidate.provenance.config_hash {
        out.push(
            Severity::Warn,
            "provenance/config_hash",
            format!(
                "configs differ ({} vs {}): KPIs may not be comparable — refresh the baseline if the \
                 experiment changed intentionally",
                baseline.provenance.config_hash, candidate.provenance.config_hash
            ),
        );
    }
    if baseline.provenance.smoke != candidate.provenance.smoke {
        out.push(
            Severity::Warn,
            "provenance/smoke",
            format!("smoke={} baseline vs smoke={} candidate", baseline.provenance.smoke, candidate.provenance.smoke),
        );
    }

    // KPIs: hard gate.
    for base in &baseline.kpis {
        let Some(cand) = candidate.kpis.iter().find(|k| k.name == base.name) else {
            out.push(Severity::Fail, &base.name, format!("KPI missing from candidate (baseline {})", base.value));
            continue;
        };
        out.n_kpis_compared += 1;
        if cand.better != base.better {
            out.push(
                Severity::Fail,
                &base.name,
                format!("direction changed ({:?} vs {:?})", base.better, cand.better),
            );
            continue;
        }
        let worse = worsening(base.better, base.value, cand.value);
        let slack = tol.rel + tol.abs / base.value.abs().max(f64::MIN_POSITIVE);
        if worse > slack {
            out.push(
                Severity::Fail,
                &base.name,
                format!("regressed {:+.1}% ({} → {}, tol {:.1}%)", 100.0 * worse, base.value, cand.value, 100.0 * tol.rel),
            );
        }
    }
    for cand in &candidate.kpis {
        if !baseline.kpis.iter().any(|k| k.name == cand.name) {
            out.push(Severity::Warn, &cand.name, format!("new KPI not in baseline (value {})", cand.value));
        }
    }

    // Timings: warn-only (runners are noisy).
    for base in &baseline.timings {
        let Some(cand) = candidate.timings.iter().find(|t| t.name == base.name) else {
            out.push(Severity::Warn, &base.name, "timing missing from candidate".to_string());
            continue;
        };
        out.n_timings_compared += 1;
        let growth = rel_change(base.mean_ns, cand.mean_ns);
        if growth > tol.timing_rel {
            out.push(
                Severity::Warn,
                &base.name,
                format!(
                    "mean time grew {:+.0}% ({:.0} ns → {:.0} ns, warn threshold {:.0}%)",
                    100.0 * growth,
                    base.mean_ns,
                    cand.mean_ns,
                    100.0 * tol.timing_rel
                ),
            );
        }
    }

    out.findings.sort_by_key(|f| match f.severity {
        Severity::Fail => 0,
        Severity::Warn => 1,
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::{Provenance, TimingEntry};

    fn report() -> RunReport {
        let mut r = RunReport {
            name: "fig2".into(),
            provenance: Provenance {
                commit: "abc".into(),
                seed: 0,
                config_hash: "1111111111111111".into(),
                smoke: false,
            },
            kpis: Vec::new(),
            timings: Vec::new(),
        };
        r.push_kpi("azure/mdmt@M1/cumulative_regret", 10.0, Direction::LowerIsBetter);
        r.push_kpi("azure/speedup_t0.05", 4.0, Direction::HigherIsBetter);
        r.push_timing(TimingEntry::flat("decision", 100, 1000.0));
        r
    }

    #[test]
    fn identical_reports_pass() {
        let r = report();
        let out = compare_reports(&r, &r, &Tolerances::default());
        assert!(!out.failed(), "{}", out.render());
        assert_eq!(out.n_kpis_compared, 2);
        assert_eq!(out.n_timings_compared, 1);
    }

    #[test]
    fn within_tolerance_passes() {
        let base = report();
        let mut cand = report();
        cand.kpis[0].value = 10.4; // +4% < 5%
        cand.kpis[1].value = 3.9; // -2.5% < 5%
        assert!(!compare_reports(&base, &cand, &Tolerances::default()).failed());
    }

    #[test]
    fn regret_increase_fails() {
        let base = report();
        let mut cand = report();
        cand.kpis[0].value = 12.0; // +20%
        let out = compare_reports(&base, &cand, &Tolerances::default());
        assert!(out.failed());
        assert_eq!(out.n_failures(), 1);
        assert!(out.render().contains("cumulative_regret"));
    }

    #[test]
    fn speedup_drop_fails_but_speedup_gain_passes() {
        let base = report();
        let mut cand = report();
        cand.kpis[1].value = 3.0; // -25% of a higher-is-better KPI
        assert!(compare_reports(&base, &cand, &Tolerances::default()).failed());
        cand.kpis[1].value = 8.0; // improvement: never a regression
        cand.kpis[0].value = 5.0;
        assert!(!compare_reports(&base, &cand, &Tolerances::default()).failed());
    }

    #[test]
    fn missing_kpi_fails_new_kpi_warns() {
        let base = report();
        let mut cand = report();
        cand.kpis.remove(1);
        cand.push_kpi("azure/new_metric", 1.0, Direction::LowerIsBetter);
        let out = compare_reports(&base, &cand, &Tolerances::default());
        assert!(out.failed());
        assert_eq!(out.n_failures(), 1);
        assert!(out.render().contains("new KPI"));
    }

    #[test]
    fn timing_growth_warns_only() {
        let base = report();
        let mut cand = report();
        cand.timings[0].mean_ns = 5000.0; // 5× slower
        let out = compare_reports(&base, &cand, &Tolerances::default());
        assert!(!out.failed());
        assert!(out.render().contains("grew"));
    }

    #[test]
    fn near_zero_baseline_uses_absolute_slack() {
        let mut base = report();
        base.kpis[0].value = 0.0;
        let mut cand = base.clone();
        cand.kpis[0].value = 1e-12; // within abs tolerance of an exact-zero baseline
        assert!(!compare_reports(&base, &cand, &Tolerances::default()).failed());
        cand.kpis[0].value = 0.5; // a real regression from zero
        assert!(compare_reports(&base, &cand, &Tolerances::default()).failed());
    }

    #[test]
    fn name_mismatch_fails_fast() {
        let base = report();
        let mut cand = report();
        cand.name = "fig3".into();
        let out = compare_reports(&base, &cand, &Tolerances::default());
        assert!(out.failed());
        assert_eq!(out.n_kpis_compared, 0);
    }
}
