//! Hand-rolled JSON: a small value model, a deterministic pretty
//! serializer, and a recursive-descent parser.
//!
//! The crate is deliberately dependency-free (no `serde`), so the
//! experiment reports (`BENCH_*.json`) go through this module. The
//! serializer is **canonical**: object keys keep insertion order, floats
//! render via Rust's shortest-roundtrip `Display`, indentation is two
//! spaces, and the output ends with a single newline — the same value
//! always serializes to the same bytes, which is what lets CI diff two
//! same-seed smoke runs with `cmp`.

use std::fmt;

/// A JSON value. Objects preserve insertion order (deterministic output
/// without sorting surprises).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number. Non-finite values serialize as `null` (JSON has no
    /// NaN/∞), so construct through [`Json::num`] when in doubt.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object as an insertion-ordered key/value list.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Number constructor that maps non-finite values to `Null`.
    pub fn num(v: f64) -> Json {
        if v.is_finite() { Json::Num(v) } else { Json::Null }
    }

    /// String constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The value as f64, if it is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The value as u64, if it is a non-negative integral number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.trunc() == *v => Some(*v as u64),
            _ => None,
        }
    }

    /// The value as &str, if it is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The value as bool, if it is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice, if it is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Canonical pretty serialization (2-space indent, trailing newline).
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    out.push('\n');
                    indent(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    out.push('\n');
                    indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < fields.len() {
                        out.push(',');
                    }
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

/// Canonical number rendering: integral values print without a fraction,
/// everything else through Rust's shortest-roundtrip `Display` (which is
/// deterministic and re-parses to the same bits). Non-finite → `null`.
fn write_num(out: &mut String, v: f64) {
    if !v.is_finite() {
        out.push_str("null");
    } else if v.trunc() == v && v.abs() < 9.0e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        out.push_str(&format!("{v}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document (one top-level value, trailing whitespace ok).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let value = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after value"));
    }
    Ok(value)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain bytes (valid UTF-8 input by construction).
            while matches!(self.peek(), Some(b) if b != b'"' && b != b'\\' && b >= 0x20) {
                self.pos += 1;
            }
            if self.pos > start {
                out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|_| self.err("invalid utf-8"))?);
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    out.push(self.escape()?);
                }
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn escape(&mut self) -> Result<char, JsonError> {
        let c = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
        self.pos += 1;
        Ok(match c {
            b'"' => '"',
            b'\\' => '\\',
            b'/' => '/',
            b'b' => '\u{0008}',
            b'f' => '\u{000c}',
            b'n' => '\n',
            b'r' => '\r',
            b't' => '\t',
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xD800..0xDC00).contains(&hi) {
                    // Surrogate pair: require the low half.
                    if self.peek() == Some(b'\\') {
                        self.pos += 1;
                        self.expect(b'u')?;
                        let lo = self.hex4()?;
                        if !(0xDC00..0xE000).contains(&lo) {
                            return Err(self.err("invalid low surrogate"));
                        }
                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                    } else {
                        return Err(self.err("unpaired high surrogate"));
                    }
                } else {
                    hi
                };
                char::from_u32(code).ok_or_else(|| self.err("invalid code point"))?
            }
            _ => return Err(self.err("unknown escape")),
        })
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut code = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("truncated \\u escape"))?;
            let digit = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            code = code * 16 + digit;
            self.pos += 1;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pretty_output_is_stable() {
        let v = Json::Obj(vec![
            ("b".into(), Json::Num(1.0)),
            ("a".into(), Json::Arr(vec![Json::Num(0.5), Json::Null, Json::Bool(true)])),
            ("s".into(), Json::str("hi\n\"there\"")),
            ("empty".into(), Json::Obj(vec![])),
        ]);
        let expected = "{\n  \"b\": 1,\n  \"a\": [\n    0.5,\n    null,\n    true\n  ],\n  \"s\": \"hi\\n\\\"there\\\"\",\n  \"empty\": {}\n}\n";
        assert_eq!(v.to_pretty(), expected);
        // Serialization is a pure function of the value.
        assert_eq!(v.to_pretty(), v.to_pretty());
    }

    #[test]
    fn roundtrip_preserves_values() {
        let v = Json::Obj(vec![
            ("f".into(), Json::Num(0.1 + 0.2)),
            ("i".into(), Json::Num(-42.0)),
            ("big".into(), Json::Num(1.25e300)),
            ("tiny".into(), Json::Num(5e-324)),
            ("nested".into(), Json::Arr(vec![Json::Obj(vec![("k".into(), Json::str("v"))])])),
        ]);
        let text = v.to_pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::num(f64::INFINITY), Json::Null);
        let mut out = String::new();
        write_num(&mut out, f64::NAN);
        assert_eq!(out, "null");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#"{"s": "a\tbé😀", "n": -1.5e-3}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\tbé😀");
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), -1.5e-3);
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"k\" 1}").is_err());
        let err = parse("nope").unwrap_err();
        assert!(err.to_string().contains("byte 0"), "{err}");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "b": false, "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
