//! Report generation: machine-readable experiment reports
//! (`BENCH_*.json` — see [`run`] for the schema and [`compare`] for the
//! CI gate), CSV series, markdown tables, and terminal ASCII plots of
//! regret curves (what the paper's figures show, rendered for a
//! terminal).

pub mod compare;
pub mod json;
pub mod run;

pub use compare::{compare_reports, CompareOutcome, Finding, Severity, Tolerances};
pub use run::{detect_commit, fnv1a64, Direction, Kpi, Provenance, RunReport, TimingEntry, SCHEMA_VERSION};

use crate::metrics::StepCurve;

/// Render aggregated curves `(t, mean, std)` as a CSV string with one
/// block per labelled series.
pub fn curves_to_csv(series: &[(String, Vec<(f64, f64, f64)>)]) -> String {
    let mut out = String::from("series,t,mean,std\n");
    for (label, pts) in series {
        for &(t, mean, std) in pts {
            out.push_str(&format!("{label},{t:.6},{mean:.9},{std:.9}\n"));
        }
    }
    out
}

/// ASCII line plot of several step curves on a shared time axis.
///
/// Each curve is sampled on a uniform grid and drawn with its own glyph;
/// the y-axis is linear from 0 to the **global** max over every curve's
/// breakpoints — not just the initial values — so curves that rise above
/// where they start (e.g. regret under a growing tenant population)
/// render unclipped.
pub fn ascii_plot(
    title: &str,
    curves: &[(String, StepCurve)],
    width: usize,
    height: usize,
) -> String {
    assert!(width >= 16 && height >= 4);
    let glyphs = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let t_end = curves
        .iter()
        .map(|(_, c)| c.end_time())
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let y_max = curves
        .iter()
        .map(|(_, c)| c.points().iter().map(|p| p.1).fold(0.0f64, f64::max))
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        let glyph = glyphs[ci % glyphs.len()];
        for col in 0..width {
            let t = t_end * col as f64 / (width - 1) as f64;
            let v = curve.value(t);
            let row_f = (1.0 - (v / y_max).clamp(0.0, 1.0)) * (height - 1) as f64;
            let row = row_f.round() as usize;
            grid[row.min(height - 1)][col] = glyph;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}  (y: 0..{y_max:.3}, x: 0..{t_end:.1})\n"));
    for (ri, row) in grid.iter().enumerate() {
        let label = if ri == 0 {
            format!("{y_max:8.3} |")
        } else if ri == height - 1 {
            format!("{:8.3} |", 0.0)
        } else {
            "         |".to_string()
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("          +{}\n", "-".repeat(width)));
    for (ci, (label, _)) in curves.iter().enumerate() {
        out.push_str(&format!("          {} = {label}\n", glyphs[ci % glyphs.len()]));
    }
    out
}

/// Write a string to a file, creating parent directories.
pub fn write_report(path: &str, content: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_format() {
        let csv = curves_to_csv(&[(
            "mdmt".into(),
            vec![(0.0, 1.0, 0.1), (1.0, 0.5, 0.05)],
        )]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "series,t,mean,std");
        assert!(lines[1].starts_with("mdmt,0.000000,1.000000000,"));
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn ascii_plot_contains_series_and_axes() {
        let a = StepCurve::from_points(vec![(0.0, 1.0), (5.0, 0.2)]);
        let b = StepCurve::from_points(vec![(0.0, 0.8), (3.0, 0.0)]);
        let plot = ascii_plot("regret", &[("mdmt".into(), a), ("rr".into(), b)], 40, 10);
        assert!(plot.contains("regret"));
        assert!(plot.contains("* = mdmt"));
        assert!(plot.contains("o = rr"));
        assert!(plot.lines().count() > 10);
    }

    #[test]
    fn ascii_plot_scales_to_global_max_not_initial_values() {
        // A curve that rises to 4× its initial value: the y-axis must
        // cover the peak (glyph lands on the top row at the peak, not
        // clipped at the initial value's height).
        let rising = StepCurve::from_points(vec![(0.0, 1.0), (5.0, 4.0), (9.0, 4.0)]);
        let flat = StepCurve::from_points(vec![(0.0, 1.0), (9.0, 1.0)]);
        let plot = ascii_plot("load spike", &[("rising".into(), rising), ("flat".into(), flat)], 40, 10);
        let lines: Vec<&str> = plot.lines().collect();
        // Header advertises the global max...
        assert!(lines[0].contains("0..4.000"), "{}", lines[0]);
        // ...the top row carries the peak of the rising curve...
        assert!(lines[1].contains('*'), "top row must show the rising curve's peak:\n{plot}");
        // ...and the flat curve sits low (at 1/4 height), not on the top row.
        assert!(!lines[1].contains('o'), "flat curve must not touch the top row:\n{plot}");
    }

    #[test]
    fn write_report_creates_dirs() {
        let dir = std::env::temp_dir().join("mmgpei_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("sub/report.csv");
        write_report(path.to_str().unwrap(), "hello").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "hello");
    }
}
