//! `mmgpei` — launcher for the multi-device, multi-tenant GP-EI service.
//!
//! Subcommands:
//!
//! * `simulate` — run a (policy × devices × seeds) sweep in virtual time
//!   and print the figures' tables/curves. Accepts `--config FILE` or
//!   inline flags.
//! * `serve`    — run the live threaded coordinator (wall-clock, device
//!   worker threads, optional PJRT/XLA scoring backend).
//! * `theory`   — evaluate the Theorem-2 bound against measured regret.
//! * `miu`      — print MIU scores of a workload's prior kernel matrix.
//! * `dataset`  — export a generated workload table to CSV.
//!
//! Run `mmgpei help` for details.

use mmgpei::bench::Table;
use mmgpei::cli::{make_policy, run_experiment, Args};
use mmgpei::config::{Backend, ExperimentConfig};
use mmgpei::coordinator::{serve, ServeConfig};
use mmgpei::metrics::StepCurve;
use mmgpei::miu::{miu_diag_bound, miu_exact, miu_greedy, miu_total, theorem2_bound};
use mmgpei::report::{ascii_plot, compare_reports, curves_to_csv, write_report, RunReport, Tolerances};
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::workload::{azure, deeplearning};

const HELP: &str = "\
mmgpei — multi-device, multi-tenant model selection with GP-EI

USAGE: mmgpei <command> [options]

COMMANDS
  simulate   virtual-time sweep
             --config FILE | --dataset azure|deeplearning|synthetic
             --policies mdmt,round-robin,random[,mdmt-device,mdmt-nocost,mdmt-indep,oracle]
             --devices 1,2,4  --seeds 10  --backend native|xla
             --cutoff 0.01  [--csv reports/out.csv]  [--plot]
             [--json reports/BENCH_name.json]  [--smoke]
             [--churn]  tenant-churn scenario: seeded arrival/departure
             timeline through the unified engine (knobs via a [churn]
             config section; per-tenant exit regret + join latency KPIs)
             [--fleet]  elastic heterogeneous fleet: per-device speeds +
             availability churn with deterministic preemption/requeue
             (knobs via a [fleet] config section, see
             configs/fig7_elastic.toml)
             [--cost-model]  per-(arm, device-class) costs on the fleet
             (requires --fleet; knobs via a [cost_model] config section:
             multipliers, mem_limit; classes spread round-robin; the
             mdmt-device policy scores EI/(c(x, class)/speed))
             [--faults]  deterministic fault injection: seeded device
             crash/restart cycles, lost jobs, stragglers, plus per-job
             deadlines with capped-backoff retries (knobs via a [faults]
             config section, see configs/fig8_faults.toml; combine with
             --fleet for an elastic faulty fleet)
  serve      live threaded coordinator (wall clock)
             --dataset azure --policy mdmt --devices 4 --time-scale 0.005
             --backend native|xla --seed 0 [--verbose]
  theory     Theorem-2 bound vs measured regret
             --dataset azure --devices 1,2,4 --seeds 5
  miu        MIU scores of a workload prior
             --dataset azure [--max-s 8] [--seed 0]
  dataset    export generated tables
             --name azure|deeplearning --out data/azure.csv
  compare    diff two BENCH_*.json reports; exit 1 on KPI regression
             compare baseline.json candidate.json
             [--rel-tol 0.05] [--abs-tol 1e-9] [--timing-tol 0.5]
  help       this text
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    // Only `compare` takes positionals (its two report paths); everywhere
    // else a stray positional is almost certainly a forgotten `--flag`
    // (e.g. `simulate azure` instead of `simulate --dataset azure`) and
    // silently ignoring it would run the wrong experiment.
    if args.command.as_deref() != Some("compare") && !args.positionals.is_empty() {
        eprintln!("error: unexpected positional argument {:?}\n\n{HELP}", args.positionals[0]);
        std::process::exit(2);
    }
    let result = match args.command.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("theory") => cmd_theory(&args),
        Some("miu") => cmd_miu(&args),
        Some("dataset") => cmd_dataset(&args),
        Some("compare") => cmd_compare(&args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => Err(format!("unknown command {other:?}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Build an `ExperimentConfig` from `--config` or inline flags.
fn config_from_args(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.to_string();
    }
    if let Some(p) = args.get_list("policies") {
        cfg.policies = p;
    }
    if let Some(d) = args.get_list("devices") {
        cfg.devices = d
            .iter()
            .map(|s| s.parse::<usize>().map_err(|e| format!("--devices {s:?}: {e}")))
            .collect::<Result<_, _>>()?;
    }
    cfg.seeds = args.get_parsed_or("seeds", cfg.seeds)?;
    cfg.warm_start = args.get_parsed_or("warm-start", cfg.warm_start)?;
    cfg.cutoff = args.get_parsed_or("cutoff", cfg.cutoff)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = b.parse()?;
    }
    if let Some(n) = args.get("synthetic-users") {
        cfg.synthetic.n_users = n.parse().map_err(|e| format!("--synthetic-users: {e}"))?;
    }
    if let Some(n) = args.get("synthetic-models") {
        cfg.synthetic.n_models = n.parse().map_err(|e| format!("--synthetic-models: {e}"))?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let mut cfg = config_from_args(args)?;
    let smoke = args.has_flag("smoke");
    if smoke {
        cfg = cfg.smoke();
    }
    if args.has_flag("churn") {
        cfg.churn = true;
        cfg.validate()?;
    }
    if args.has_flag("fleet") {
        cfg.fleet = true;
        cfg.validate()?;
    }
    if args.has_flag("cost-model") {
        cfg.cost_model = true;
        cfg.validate()?;
    }
    if args.has_flag("faults") {
        cfg.faults = true;
        cfg.validate()?;
    }
    if cfg.churn {
        return cmd_simulate_churn(&cfg, args, smoke);
    }
    if cfg.faults {
        return cmd_simulate_faults(&cfg, args, smoke);
    }
    if cfg.fleet {
        return cmd_simulate_fleet(&cfg, args, smoke);
    }
    eprintln!(
        "simulate: dataset={} policies={:?} devices={:?} seeds={} backend={:?}",
        cfg.dataset, cfg.policies, cfg.devices, cfg.seeds, cfg.backend
    );
    let results = run_experiment(&cfg)?;
    let mut table = Table::new(&[
        "policy",
        "devices",
        "cumulative regret (mean±σ)",
        "time to regret ≤ cutoff",
        "makespan",
    ]);
    for cell in &results.cells {
        let ttc = match cell.time_to_cutoff {
            Some((m, s)) => format!("{m:.2} ± {s:.2}"),
            None => "n/a".into(),
        };
        let mk = mmgpei::metrics::mean_std(
            &cell.runs.iter().map(|r| r.makespan).collect::<Vec<_>>(),
        );
        table.row(vec![
            cell.policy.clone(),
            cell.devices.to_string(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            ttc,
            format!("{:.1}", mk.0),
        ]);
    }
    println!("{}", table.to_markdown());
    if args.has_flag("plot") {
        // Single-seed representative curves for the first device count.
        let m = cfg.devices[0];
        let curves: Vec<(String, StepCurve)> = results
            .cells
            .iter()
            .filter(|c| c.devices == m)
            .map(|c| (c.policy.clone(), c.runs[0].inst_regret.clone()))
            .collect();
        println!("{}", ascii_plot(&format!("instantaneous regret, M={m}"), &curves, 72, 16));
    }
    if let Some(path) = args.get("csv") {
        let series: Vec<(String, Vec<(f64, f64, f64)>)> = results
            .cells
            .iter()
            .map(|c| (format!("{}@M{}", c.policy, c.devices), c.curve.clone()))
            .collect();
        write_report(path, &curves_to_csv(&series)).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("json") {
        let mut report = RunReport::new(cfg.name.clone(), 0, smoke);
        let mut cutoffs = vec![0.05, cfg.cutoff];
        cutoffs.sort_by(f64::total_cmp);
        cutoffs.dedup();
        results.push_kpis(&mut report, "", &cutoffs);
        report.write(path).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// The churn branch of `simulate`: sweep (policy × devices × seeds) over
/// the seeded arrival/departure timeline and print per-tenant service
/// KPIs (exit regret, p99 join-to-first-decision latency).
fn cmd_simulate_churn(
    cfg: &mmgpei::config::ExperimentConfig,
    args: &Args,
    smoke: bool,
) -> Result<(), String> {
    let c = &cfg.churn_cfg;
    eprintln!(
        "simulate --churn: {} tenants ({} initial) × {} models, ρ={}, policies={:?} devices={:?} seeds={}",
        c.n_users, c.initial_users, c.n_models, c.user_corr, cfg.policies, cfg.devices, cfg.seeds
    );
    let results = mmgpei::cli::run_churn_experiment(cfg)?;
    let mut table = Table::new(&[
        "policy",
        "devices",
        "cumulative regret (mean±σ)",
        "mean exit regret/tenant",
        "p99 join latency",
        "served",
        "rebuilds",
    ]);
    for cell in &results.cells {
        table.row(vec![
            cell.policy.clone(),
            cell.devices.to_string(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            format!("{:.3}", cell.mean_exit_regret),
            if cell.p99_join_latency.is_finite() {
                format!("{:.2}", cell.p99_join_latency)
            } else {
                "n/a".into()
            },
            format!("{:.0}%", 100.0 * cell.served_fraction),
            cell.n_rebuilds.to_string(),
        ]);
    }
    println!("{}", table.to_markdown());
    if args.has_flag("plot") {
        let m = cfg.devices[0];
        let curves: Vec<(String, StepCurve)> = results
            .cells
            .iter()
            .filter(|c| c.devices == m)
            .map(|c| (c.policy.clone(), c.runs[0].inst_regret.clone()))
            .collect();
        println!("{}", ascii_plot(&format!("avg active-tenant regret, M={m}"), &curves, 72, 16));
    }
    if let Some(path) = args.get("csv") {
        // Mean ± σ active-tenant regret curves, same shape as the static
        // sweep's CSV (so `simulate --churn --csv` works identically).
        let series: Vec<(String, Vec<(f64, f64, f64)>)> = results
            .cells
            .iter()
            .map(|c| {
                let t_end = c.runs.iter().map(|r| r.makespan).fold(0.0f64, f64::max).max(1e-9);
                let curves: Vec<StepCurve> = c.runs.iter().map(|r| r.inst_regret.clone()).collect();
                let grid = mmgpei::metrics::time_grid(t_end, 120);
                (
                    format!("{}@M{}", c.policy, c.devices),
                    mmgpei::metrics::aggregate_curves(&curves, &grid),
                )
            })
            .collect();
        write_report(path, &curves_to_csv(&series)).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("json") {
        let mut report = RunReport::new(cfg.name.clone(), 0, smoke);
        results.push_kpis(&mut report, "churn/");
        report.write(path).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// The fleet branch of `simulate`: sweep (policy × seeds) over the
/// seeded heterogeneous elastic fleet and print per-policy service KPIs
/// (cumulative regret, preemptions, p99 requeue latency, rebuilds).
fn cmd_simulate_fleet(
    cfg: &mmgpei::config::ExperimentConfig,
    args: &Args,
    smoke: bool,
) -> Result<(), String> {
    let f = &cfg.fleet_cfg;
    eprintln!(
        "simulate --fleet: {} devices ({} online at t=0), speeds [{}, {}), policies={:?} seeds={}",
        f.n_devices, f.initial_online, f.speed_range.0, f.speed_range.1, cfg.policies, cfg.seeds
    );
    if cfg.cost_model {
        eprintln!(
            "  cost model: {} device classes, multipliers {:?} (round-robin over the fleet)",
            cfg.cost_model_cfg.n_classes(),
            cfg.cost_model_cfg.multipliers
        );
    }
    let results = mmgpei::cli::run_fleet_experiment(cfg)?;
    let mut table = Table::new(&[
        "policy",
        "cumulative regret (mean±σ)",
        "makespan",
        "preemptions",
        "p99 requeue latency",
        "rebuilds",
    ]);
    for cell in &results.cells {
        let mk = mmgpei::metrics::mean_std(
            &cell.runs.iter().map(|r| r.sim.makespan).collect::<Vec<_>>(),
        );
        table.row(vec![
            cell.policy.clone(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            format!("{:.1}", mk.0),
            cell.n_preemptions.to_string(),
            if cell.p99_requeue_latency.is_finite() {
                format!("{:.2}", cell.p99_requeue_latency)
            } else {
                "n/a".into()
            },
            cell.n_rebuilds.to_string(),
        ]);
    }
    println!("{}", table.to_markdown());
    if args.has_flag("plot") {
        let curves: Vec<(String, StepCurve)> = results
            .cells
            .iter()
            .map(|c| (c.policy.clone(), c.runs[0].sim.inst_regret.clone()))
            .collect();
        println!(
            "{}",
            ascii_plot(&format!("instantaneous regret, elastic F={}", f.n_devices), &curves, 72, 16)
        );
    }
    if let Some(path) = args.get("json") {
        let mut report = RunReport::new(cfg.name.clone(), 0, smoke);
        results.push_kpis(&mut report, "fleet/");
        report.write(path).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// The faults branch of `simulate`: sweep (policy × seeds) under the
/// seeded fault plan (crash/restart cycles, lost jobs, stragglers,
/// deadline kills with capped-backoff retries) and print robustness
/// KPIs next to the regret numbers.
fn cmd_simulate_faults(
    cfg: &mmgpei::config::ExperimentConfig,
    args: &Args,
    smoke: bool,
) -> Result<(), String> {
    let fc = &cfg.faults_cfg;
    eprintln!(
        "simulate --faults: mtbf={} downtime={} job_failure_gap={} straggler_gap={} horizon={}, policies={:?} seeds={}",
        fc.mtbf, fc.mean_downtime, fc.job_failure_gap, fc.straggler_gap, fc.horizon, cfg.policies, cfg.seeds
    );
    if cfg.fleet {
        let f = &cfg.fleet_cfg;
        eprintln!(
            "  elastic fleet: {} devices ({} online at t=0), speeds [{}, {})",
            f.n_devices, f.initial_online, f.speed_range.0, f.speed_range.1
        );
    }
    let results = mmgpei::cli::run_faults_experiment(cfg)?;
    let mut table = Table::new(&[
        "policy",
        "cumulative regret (mean±σ)",
        "served",
        "crashes",
        "job failures",
        "retries",
        "abandoned",
        "p99 recovery",
    ]);
    for cell in &results.cells {
        table.row(vec![
            cell.policy.clone(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            format!("{:.0}%", 100.0 * cell.served_fraction),
            cell.n_crashes.to_string(),
            cell.n_job_failures.to_string(),
            cell.n_retries.to_string(),
            cell.n_abandoned.to_string(),
            if cell.p99_recovery_latency.is_finite() {
                format!("{:.2}", cell.p99_recovery_latency)
            } else {
                "n/a".into()
            },
        ]);
    }
    println!("{}", table.to_markdown());
    if args.has_flag("plot") {
        let curves: Vec<(String, StepCurve)> = results
            .cells
            .iter()
            .map(|c| (c.policy.clone(), c.runs[0].fleet.sim.inst_regret.clone()))
            .collect();
        println!("{}", ascii_plot("instantaneous regret under faults", &curves, 72, 16));
    }
    if let Some(path) = args.get("json") {
        let mut report = RunReport::new(cfg.name.clone(), 0, smoke);
        results.push_kpis(&mut report, "faults/");
        report.write(path).map_err(|e| e.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    let [baseline_path, candidate_path] = args.positionals.as_slice() else {
        return Err("compare needs exactly two positional report paths: compare baseline.json candidate.json".into());
    };
    // This is the CI gate: a typoed `--reltol 0.01` or a valueless
    // `--rel-tol` silently running with default tolerances is worse than
    // refusing, so the vocabulary is checked strictly.
    for key in args.options.keys() {
        if !["rel-tol", "abs-tol", "timing-tol"].contains(&key.as_str()) {
            return Err(format!("unknown option --{key}"));
        }
    }
    if let Some(flag) = args.flags.first() {
        return Err(match flag.as_str() {
            "rel-tol" | "abs-tol" | "timing-tol" => format!("--{flag} requires a value"),
            other => format!("unknown flag --{other}"),
        });
    }
    let tol = Tolerances {
        rel: args.get_parsed_or("rel-tol", Tolerances::default().rel)?,
        abs: args.get_parsed_or("abs-tol", Tolerances::default().abs)?,
        timing_rel: args.get_parsed_or("timing-tol", Tolerances::default().timing_rel)?,
    };
    let baseline = RunReport::from_file(baseline_path)?;
    let candidate = RunReport::from_file(candidate_path)?;
    let outcome = compare_reports(&baseline, &candidate, &tol);
    print!("{}", outcome.render());
    if outcome.failed() {
        return Err(format!(
            "{} KPI regression(s) in {candidate_path} vs {baseline_path} (rel tol {})",
            outcome.n_failures(),
            tol.rel
        ));
    }
    println!("ok: no KPI regressions in {candidate_path} vs {baseline_path}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = config_from_args(args)?;
    let policy_name = args.get_or("policy", "mdmt");
    let devices: usize = args.get_parsed_or("devices", 2usize)?;
    let time_scale: f64 = args.get_parsed_or("time-scale", 0.005f64)?;
    let seed: u64 = args.get_parsed_or("seed", 0u64)?;
    let (problem, truth) = mmgpei::cli::make_instance(&cfg, seed)?;
    // Live serving is a single run: the policy gets the env-resolved pool
    // so MMGPEI_THREADS shards the per-user GP work.
    let pool = mmgpei::pool::WorkerPool::from_env();
    let mut policy = make_policy(&policy_name, &problem, &truth, seed, cfg.backend, &pool, None)?;
    eprintln!(
        "serving {} with {} devices (time scale {}s/unit, backend {:?})",
        problem.name, devices, time_scale, cfg.backend
    );
    let report = serve(
        &problem,
        &truth,
        policy.as_mut(),
        &ServeConfig {
            n_devices: devices,
            time_scale,
            warm_start_per_user: cfg.warm_start,
            verbose: args.has_flag("verbose"),
        },
    );
    println!(
        "policy {}: {} jobs in {:.3}s; final avg regret {:.5}",
        report.policy,
        report.jobs.len(),
        report.makespan.as_secs_f64(),
        report.inst_regret.final_value()
    );
    println!(
        "decision latency: mean {:?}, max {:?} over {} decisions",
        report.mean_decision_latency(),
        report.max_decision_latency(),
        report.decision_latencies.len()
    );
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<(), String> {
    let cfg = config_from_args(args)?;
    let mut table = Table::new(&[
        "devices",
        "measured Regret_T (mean)",
        "MIU(T,K) (greedy)",
        "Theorem-2 bound",
        "bound / measured",
    ]);
    for &m in &cfg.devices {
        let mut measured = Vec::new();
        let mut bound = Vec::new();
        for seed in 0..cfg.seeds {
            let (problem, truth) = mmgpei::cli::make_instance(&cfg, seed)?;
            let pool = mmgpei::pool::WorkerPool::new(1);
            let mut policy =
                make_policy("mdmt", &problem, &truth, seed, Backend::Native, &pool, None)?;
            let r = simulate(
                &problem,
                &truth,
                policy.as_mut(),
                &SimConfig { n_devices: m, warm_start_per_user: cfg.warm_start, horizon: None, ..Default::default() },
            );
            let n_obs = r.observations.len();
            // Greedy MIU witness on the prior kernel (exact is exponential).
            let miu = miu_total(&problem.prior_cov, n_obs.min(24), miu_greedy)
                .min(miu_diag_bound(&problem.prior_cov, n_obs));
            measured.push(r.cumulative_regret);
            bound.push(theorem2_bound(miu, problem.n_users, m, problem.mean_optimal_cost(&truth)));
        }
        let m_mean = mmgpei::metrics::mean_std(&measured).0;
        let b_mean = mmgpei::metrics::mean_std(&bound).0;
        let miu_col = b_mean / (measured.len() as f64).max(1.0); // placeholder ratio display
        let _ = miu_col;
        table.row(vec![
            m.to_string(),
            format!("{m_mean:.2}"),
            "-".into(),
            format!("{b_mean:.2}"),
            format!("{:.1}×", b_mean / m_mean),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("(bound/measured ≥ 1 everywhere validates Theorem 2 on this workload)");
    Ok(())
}

fn cmd_miu(args: &Args) -> Result<(), String> {
    let cfg = config_from_args(args)?;
    let seed: u64 = args.get_parsed_or("seed", 0u64)?;
    let max_s: usize = args.get_parsed_or("max-s", 8usize)?;
    let (problem, _) = mmgpei::cli::make_instance(&cfg, seed)?;
    let k = &problem.prior_cov;
    println!("prior kernel over {} arms ({} users)", k.rows(), problem.n_users);
    let mut table = Table::new(&["s", "MIU_s greedy", "MIU_s exact (≤14 arms)"]);
    for s in 1..=max_s.min(k.rows()) {
        let exact = if k.rows() <= 14 { format!("{:.4}", miu_exact(k, s)) } else { "-".into() };
        table.row(vec![s.to_string(), format!("{:.4}", miu_greedy(k, s)), exact]);
    }
    println!("{}", table.to_markdown());
    println!(
        "diag upper bound Σ√K_ii (top {}): {:.3}",
        max_s,
        miu_diag_bound(k, max_s)
    );
    Ok(())
}

fn cmd_dataset(args: &Args) -> Result<(), String> {
    let name = args.get_or("name", "azure");
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("data/{name}.csv"));
    let data = match name.as_str() {
        "azure" => azure(),
        "deeplearning" => deeplearning(),
        other => return Err(format!("unknown dataset {other:?}")),
    };
    write_report(&out, &data.to_csv()).map_err(|e| e.to_string())?;
    println!(
        "wrote {out}: {} users × {} models (per-user accuracy σ = {:.3})",
        data.n_users(),
        data.n_models(),
        data.mean_per_user_accuracy_std()
    );
    Ok(())
}
