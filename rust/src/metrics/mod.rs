//! Regret metrics (paper §3.2 and §6.1).
//!
//! * **Cumulative global-happiness regret** (Eq. 2):
//!   `Regret_T = Σ_i ∫₀ᵀ (z(x_i*) − z(x_i*(t))) dt` — the integral of a
//!   piecewise-constant gap, computed exactly from the completion events.
//! * **Instantaneous regret**: the average over users of the current gap
//!   — the paper's "global unhappiness at time T".
//! * Cross-seed aggregation (mean ± 1σ bands, as in the paper's shaded
//!   plots) and time-to-cutoff speedup measurement (Figure 5's metric).

/// A right-continuous piecewise-constant curve: `value(t) = vᵢ` for
/// `t ∈ [tᵢ, tᵢ₊₁)`. Breakpoints must be non-decreasing in time.
#[derive(Clone, Debug, PartialEq)]
pub struct StepCurve {
    points: Vec<(f64, f64)>,
}

impl StepCurve {
    /// New curve with an initial value at t = 0.
    pub fn new(initial: f64) -> Self {
        StepCurve { points: vec![(0.0, initial)] }
    }

    /// Build directly from breakpoints (first must be at t = 0).
    ///
    /// Duplicate breakpoint times are collapsed **last-wins** — the same
    /// rule [`StepCurve::push`] applies — so [`StepCurve::value`]'s binary
    /// search can never land on a stale duplicate and violate
    /// right-continuity.
    pub fn from_points(points: Vec<(f64, f64)>) -> Self {
        assert!(!points.is_empty() && points[0].0 == 0.0, "curve must start at t=0");
        for w in points.windows(2) {
            assert!(w[0].0 <= w[1].0, "breakpoints must be sorted");
        }
        let mut dedup: Vec<(f64, f64)> = Vec::with_capacity(points.len());
        for p in points {
            match dedup.last_mut() {
                Some(last) if last.0 == p.0 => last.1 = p.1,
                _ => dedup.push(p),
            }
        }
        StepCurve { points: dedup }
    }

    /// Append a new value from time `t` on.
    pub fn push(&mut self, t: f64, value: f64) {
        match self.points.last_mut() {
            Some(last) if t == last.0 => last.1 = value,
            Some(last) => {
                assert!(t > last.0, "time must be non-decreasing");
                self.points.push((t, value));
            }
            None => self.points.push((t, value)),
        }
    }

    /// The final breakpoint. Every constructor leaves at least one point
    /// (`new` seeds `t = 0`, `from_points` asserts non-emptiness,
    /// `truncated` keeps ≥ 1), so the accessor is total in practice.
    fn last_point(&self) -> (f64, f64) {
        // pallas-lint: allow(R5) — the non-empty invariant is maintained by every constructor; an empty curve is unreachable without unsafe field access.
        *self.points.last().expect("StepCurve is never empty")
    }

    /// Breakpoints view.
    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    /// Value at time `t` (right-continuous). The search uses the total
    /// order on `f64`, so a NaN query returns the final value instead of
    /// panicking inside `partial_cmp` (NaN sorts after every breakpoint).
    pub fn value(&self, t: f64) -> f64 {
        match self.points.binary_search_by(|p| p.0.total_cmp(&t)) {
            Ok(i) => self.points[i].1,
            Err(0) => self.points[0].1,
            Err(i) => self.points[i - 1].1,
        }
    }

    /// Exact integral `∫₀ᵀ curve(t) dt`.
    pub fn integral_to(&self, t_end: f64) -> f64 {
        let mut acc = 0.0;
        for (i, &(t, v)) in self.points.iter().enumerate() {
            if t >= t_end {
                break;
            }
            let next_t = self.points.get(i + 1).map(|p| p.0).unwrap_or(f64::INFINITY);
            acc += v * (next_t.min(t_end) - t);
        }
        acc
    }

    /// First time at which the curve drops to `≤ cutoff` (the Figure-5
    /// convergence-time metric), or `None` if it never does.
    pub fn first_time_leq(&self, cutoff: f64) -> Option<f64> {
        self.points.iter().find(|&&(_, v)| v <= cutoff).map(|&(t, _)| t)
    }

    /// Final value.
    pub fn final_value(&self) -> f64 {
        self.last_point().1
    }

    /// Last breakpoint time.
    pub fn end_time(&self) -> f64 {
        self.last_point().0
    }

    /// Scale all values by `factor` (e.g. sum-gap → average-gap).
    pub fn scaled(&self, factor: f64) -> StepCurve {
        StepCurve { points: self.points.iter().map(|&(t, v)| (t, v * factor)).collect() }
    }

    /// Restrict the curve to `[0, t_end]`: breakpoints after `t_end` are
    /// dropped (the value at `t_end` carries rightward, as for any step
    /// curve). Used when a report horizon cuts a run short, so the
    /// returned curve and the re-integrated cumulative regret agree.
    pub fn truncated(&self, t_end: f64) -> StepCurve {
        assert!(t_end >= 0.0, "truncation horizon must be non-negative");
        let keep = self.points.partition_point(|p| p.0 <= t_end).max(1);
        StepCurve { points: self.points[..keep].to_vec() }
    }
}

/// Mean ± std of several step curves sampled on a common time grid.
/// Returns `(grid_t, mean, std)` triples — exactly what the paper's
/// shaded 1σ plots show.
pub fn aggregate_curves(curves: &[StepCurve], grid: &[f64]) -> Vec<(f64, f64, f64)> {
    assert!(!curves.is_empty());
    grid.iter()
        .map(|&t| {
            let vals: Vec<f64> = curves.iter().map(|c| c.value(t)).collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / vals.len() as f64;
            (t, mean, var.sqrt())
        })
        .collect()
}

/// Uniform grid `[0, t_end]` with `n` points (n ≥ 2).
pub fn time_grid(t_end: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n).map(|i| t_end * i as f64 / (n - 1) as f64).collect()
}

/// Signed relative change from `baseline` to `candidate` as a fraction
/// of `|baseline|` (the report `compare` gate's unit). The denominator is
/// floored at `f64::MIN_POSITIVE` so an exact-zero baseline yields a
/// huge-but-finite ratio instead of NaN/∞ — absolute tolerances then
/// decide (see `report::compare`).
pub fn rel_change(baseline: f64, candidate: f64) -> f64 {
    (candidate - baseline) / baseline.abs().max(f64::MIN_POSITIVE)
}

/// Nearest-rank p99 of a sample set (consumed: sorted in place with the
/// NaN-safe total order). Returns NaN when empty — callers feed the
/// result to `report::RunReport::push_kpi`, which drops non-finite
/// values. One definition shared by every sweep aggregator (churn join
/// latency, fleet requeue latency) so the percentile convention cannot
/// drift between KPIs.
pub fn p99(mut samples: Vec<f64>) -> f64 {
    samples.sort_by(f64::total_cmp);
    if samples.is_empty() {
        f64::NAN
    } else {
        samples[((samples.len() as f64 - 1.0) * 0.99) as usize]
    }
}

/// Mean and sample-std of a slice (speedup tables).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_is_right_continuous() {
        let c = StepCurve::from_points(vec![(0.0, 2.0), (1.0, 1.0), (3.0, 0.0)]);
        assert_eq!(c.value(0.0), 2.0);
        assert_eq!(c.value(0.999), 2.0);
        assert_eq!(c.value(1.0), 1.0);
        assert_eq!(c.value(2.5), 1.0);
        assert_eq!(c.value(3.0), 0.0);
        assert_eq!(c.value(100.0), 0.0);
    }

    #[test]
    fn integral_exact() {
        let c = StepCurve::from_points(vec![(0.0, 2.0), (1.0, 1.0), (3.0, 0.0)]);
        // ∫₀⁴ = 2·1 + 1·2 + 0·1 = 4
        assert!((c.integral_to(4.0) - 4.0).abs() < 1e-12);
        // Partial: ∫₀^{0.5} = 1
        assert!((c.integral_to(0.5) - 1.0).abs() < 1e-12);
        // Mid-segment: ∫₀² = 2 + 1 = 3
        assert!((c.integral_to(2.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn push_replaces_same_time() {
        let mut c = StepCurve::new(5.0);
        c.push(0.0, 4.0);
        assert_eq!(c.points().len(), 1);
        assert_eq!(c.value(0.0), 4.0);
        c.push(2.0, 1.0);
        assert_eq!(c.value(3.0), 1.0);
    }

    #[test]
    fn first_time_leq_finds_crossing() {
        let c = StepCurve::from_points(vec![(0.0, 1.0), (2.0, 0.5), (5.0, 0.01)]);
        assert_eq!(c.first_time_leq(0.6), Some(2.0));
        assert_eq!(c.first_time_leq(0.01), Some(5.0));
        assert_eq!(c.first_time_leq(0.001), None);
        assert_eq!(c.first_time_leq(2.0), Some(0.0));
    }

    #[test]
    fn aggregate_mean_and_band() {
        let a = StepCurve::from_points(vec![(0.0, 1.0), (1.0, 0.0)]);
        let b = StepCurve::from_points(vec![(0.0, 3.0), (2.0, 0.0)]);
        let agg = aggregate_curves(&[a, b], &[0.0, 1.5, 2.5]);
        assert_eq!(agg[0], (0.0, 2.0, 1.0));
        // at 1.5: values 0 and 3 → mean 1.5, std 1.5
        assert!((agg[1].1 - 1.5).abs() < 1e-12);
        assert!((agg[1].2 - 1.5).abs() < 1e-12);
        assert_eq!(agg[2].1, 0.0);
    }

    #[test]
    fn grid_and_mean_std() {
        let g = time_grid(10.0, 6);
        assert_eq!(g, vec![0.0, 2.0, 4.0, 6.0, 8.0, 10.0]);
        let (m, s) = mean_std(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert!((s - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(mean_std(&[5.0]).1, 0.0);
    }

    #[test]
    fn p99_nearest_rank_and_empty() {
        assert!(p99(Vec::new()).is_nan());
        assert_eq!(p99(vec![5.0]), 5.0);
        // 100 samples 0..100: nearest-rank index (99 * 0.99) as usize = 98.
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(p99(xs), 98.0);
        // Unsorted input is sorted internally with the NaN-safe order;
        // nearest-rank index for 3 samples is (2 · 0.99) as usize = 1.
        assert_eq!(p99(vec![3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn rel_change_signed_and_zero_safe() {
        assert!((rel_change(10.0, 12.0) - 0.2).abs() < 1e-12);
        assert!((rel_change(10.0, 9.0) + 0.1).abs() < 1e-12);
        assert!((rel_change(-10.0, -9.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_change(5.0, 5.0), 0.0);
        let z = rel_change(0.0, 1e-12);
        assert!(z.is_finite() && z > 0.0);
    }

    #[test]
    fn scaled_divides() {
        let c = StepCurve::from_points(vec![(0.0, 4.0), (1.0, 2.0)]);
        let s = c.scaled(0.25);
        assert_eq!(s.value(0.0), 1.0);
        assert_eq!(s.value(1.5), 0.5);
    }

    #[test]
    #[should_panic(expected = "start at t=0")]
    fn from_points_requires_origin() {
        let _ = StepCurve::from_points(vec![(1.0, 2.0)]);
    }

    #[test]
    fn from_points_dedupes_duplicate_times_last_wins() {
        // A duplicate breakpoint time must collapse to its final value —
        // the same rule `push` applies. Before the fix, `value(1.0)`
        // could land on the stale (1.0, 5.0) entry via binary search.
        let c = StepCurve::from_points(vec![(0.0, 2.0), (1.0, 5.0), (1.0, 1.0), (3.0, 0.0)]);
        assert_eq!(c.points().len(), 3);
        assert_eq!(c.value(1.0), 1.0, "right-continuity at a deduped breakpoint");
        assert_eq!(c.value(2.0), 1.0);
        // The integral sees the last-wins value over [1, 3): 2·1 + 1·2 = 4.
        assert!((c.integral_to(3.0) - 4.0).abs() < 1e-12);
        // Duplicates at t = 0 collapse too.
        let d = StepCurve::from_points(vec![(0.0, 9.0), (0.0, 4.0)]);
        assert_eq!(d.points(), &[(0.0, 4.0)]);
    }

    #[test]
    fn value_handles_nan_query_without_panicking() {
        let c = StepCurve::from_points(vec![(0.0, 2.0), (1.0, 1.0)]);
        // total_cmp sorts NaN after every breakpoint → final value, no
        // panic (partial_cmp().unwrap() used to abort here).
        assert_eq!(c.value(f64::NAN), 1.0);
    }

    #[test]
    fn truncated_restricts_domain() {
        let c = StepCurve::from_points(vec![(0.0, 3.0), (1.0, 2.0), (2.0, 1.0), (4.0, 0.0)]);
        let t = c.truncated(2.5);
        assert_eq!(t.points(), &[(0.0, 3.0), (1.0, 2.0), (2.0, 1.0)]);
        assert_eq!(t.final_value(), 1.0);
        // A breakpoint exactly at the horizon is kept (right-continuous
        // value at the cut instant).
        assert_eq!(c.truncated(2.0).points().len(), 3);
        // Truncating before the first post-origin breakpoint keeps t=0.
        assert_eq!(c.truncated(0.0).points(), &[(0.0, 3.0)]);
        // Truncating past the end is a no-op.
        assert_eq!(c.truncated(99.0).points(), c.points());
    }
}
