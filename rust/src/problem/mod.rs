//! Core problem types for the time-sensitive hierarchical bandit (TSHB)
//! abstraction of multi-device, multi-tenant AutoML (paper §3.1).
//!
//! An *arm* is one (model, dataset) evaluation the service can schedule:
//! running it occupies one device for `cost` time units and reveals a
//! scalar performance `z`. Users own subsets of arms (possibly
//! overlapping — the paper explicitly allows shared models).

mod cost;
mod faults;
mod fleet;
mod tenancy;

pub use cost::{CostModel, PerClassCost, UniformCost};
pub use faults::{FaultEvent, FaultKind, FaultPlan, RetryPolicy};
pub use fleet::{DeviceFleet, FleetEvent, FleetEventKind};
pub use tenancy::{ChurnEvent, ChurnEventKind, ChurnSchedule, TenantSet};

use crate::linalg::Mat;

/// Index of an arm in the global arm set `𝓛 = 𝓛₁ ∪ … ∪ 𝓛_N`.
pub type ArmId = usize;

/// Index of a user (tenant).
pub type UserId = usize;

/// A multi-device, multi-tenant model-selection problem instance:
/// everything the *scheduler* is allowed to see (costs, memberships, GP
/// prior) — the true performances live in [`Truth`] and are revealed only
/// through simulated execution.
#[derive(Clone, Debug)]
pub struct Problem {
    /// Human-readable instance name (shows up in reports).
    pub name: String,
    /// Number of tenants N.
    pub n_users: usize,
    /// Per-arm execution cost `c(x)` in abstract time units (paper
    /// Remark 1 assumes these known/estimated up front).
    pub cost: Vec<f64>,
    /// `user_arms[i]` = the candidate set `𝓛_i`.
    pub user_arms: Vec<Vec<ArmId>>,
    /// `arm_users[x]` = users whose candidate set contains `x`
    /// (inverse of `user_arms`; the EI sum of Eq. 4 iterates this).
    pub arm_users: Vec<Vec<UserId>>,
    /// GP prior mean `μ(x)` per arm.
    pub prior_mean: Vec<f64>,
    /// GP prior covariance `k(x, x')` over all arms.
    pub prior_cov: Mat,
}

impl Problem {
    /// Number of arms `|𝓛|`.
    pub fn n_arms(&self) -> usize {
        self.cost.len()
    }

    /// Build the inverse membership map from `user_arms`.
    pub fn compute_arm_users(n_arms: usize, user_arms: &[Vec<ArmId>]) -> Vec<Vec<UserId>> {
        let mut arm_users = vec![Vec::new(); n_arms];
        for (u, arms) in user_arms.iter().enumerate() {
            for &a in arms {
                arm_users[a].push(u);
            }
        }
        arm_users
    }

    /// Validate internal consistency; panics with a description on error.
    /// Called by workload constructors and property tests.
    pub fn validate(&self) {
        let l = self.n_arms();
        assert_eq!(self.prior_mean.len(), l, "prior mean length");
        assert_eq!(self.prior_cov.rows(), l, "prior cov rows");
        assert_eq!(self.prior_cov.cols(), l, "prior cov cols");
        assert_eq!(self.user_arms.len(), self.n_users, "user_arms length");
        assert_eq!(self.arm_users.len(), l, "arm_users length");
        for (u, arms) in self.user_arms.iter().enumerate() {
            assert!(!arms.is_empty(), "user {u} has an empty candidate set");
            for &a in arms {
                assert!(a < l, "user {u} references out-of-range arm {a}");
                assert!(self.arm_users[a].contains(&u), "membership maps disagree");
            }
        }
        for (a, users) in self.arm_users.iter().enumerate() {
            for &u in users {
                assert!(self.user_arms[u].contains(&a), "membership maps disagree");
            }
        }
        for (a, &c) in self.cost.iter().enumerate() {
            assert!(c > 0.0 && c.is_finite(), "arm {a} has non-positive cost {c}");
        }
    }

    /// The two cheapest arms of each user — the experiments' warm-start
    /// protocol ("train the two fastest models for each user", §6.1).
    /// Deduplicated across users (a shared arm is only run once).
    pub fn warm_start_arms(&self, per_user: usize) -> Vec<ArmId> {
        let mut picked = vec![false; self.n_arms()];
        let mut out = Vec::new();
        for arms in &self.user_arms {
            let mut sorted: Vec<ArmId> = arms.clone();
            sorted.sort_by(|&a, &b| {
                self.cost[a].total_cmp(&self.cost[b]).then(a.cmp(&b))
            });
            for &a in sorted.iter().take(per_user) {
                if !picked[a] {
                    picked[a] = true;
                    out.push(a);
                }
            }
        }
        out
    }

    /// Average cost of each user's best arm, `c̄` in Theorem 2.
    pub fn mean_optimal_cost(&self, truth: &Truth) -> f64 {
        let total: f64 = (0..self.n_users)
            .map(|u| self.cost[truth.best_arm(self, u)])
            .sum();
        total / self.n_users as f64
    }
}

/// Hidden ground truth: the performance `z(x)` of every arm, revealed to
/// the scheduler only when the simulated execution finishes.
#[derive(Clone, Debug)]
pub struct Truth {
    /// `z[x]` — e.g. final accuracy of model x on its dataset.
    pub z: Vec<f64>,
}

impl Truth {
    /// The best achievable value for user `u`: `z(x_u*)`.
    pub fn best_value(&self, problem: &Problem, u: UserId) -> f64 {
        problem.user_arms[u]
            .iter()
            .map(|&a| self.z[a])
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// The best arm for user `u`: `x_u* = argmax z`.
    pub fn best_arm(&self, problem: &Problem, u: UserId) -> ArmId {
        *problem.user_arms[u]
            .iter()
            .max_by(|&&a, &&b| self.z[a].total_cmp(&self.z[b]))
            // pallas-lint: allow(R5) — `Problem::validate` rejects empty candidate sets, so the argmax always has at least one element.
            .expect("non-empty candidate set")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_problem() -> (Problem, Truth) {
        // 2 users; user0 owns arms {0,1,2}, user1 owns {2,3} (arm 2 shared).
        let user_arms = vec![vec![0, 1, 2], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "tiny".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 3.0, 0.5],
            user_arms,
            arm_users,
            prior_mean: vec![0.0; 4],
            prior_cov: Mat::eye(4),
        };
        let t = Truth { z: vec![0.5, 0.9, 0.7, 0.2] };
        (p, t)
    }

    #[test]
    fn validate_ok_for_consistent_problem() {
        let (p, _) = tiny_problem();
        p.validate();
    }

    #[test]
    fn arm_users_inverse_of_user_arms() {
        let (p, _) = tiny_problem();
        assert_eq!(p.arm_users[0], vec![0]);
        assert_eq!(p.arm_users[2], vec![0, 1]);
        assert_eq!(p.arm_users[3], vec![1]);
    }

    #[test]
    fn best_value_and_arm() {
        let (p, t) = tiny_problem();
        assert_eq!(t.best_value(&p, 0), 0.9);
        assert_eq!(t.best_arm(&p, 0), 1);
        assert_eq!(t.best_value(&p, 1), 0.7);
        assert_eq!(t.best_arm(&p, 1), 2);
    }

    #[test]
    fn warm_start_two_fastest_dedup() {
        let (p, _) = tiny_problem();
        // user0 fastest two: arms 0 (c=1) and 1 (c=2); user1: 3 (0.5), 2 (3).
        let ws = p.warm_start_arms(2);
        assert_eq!(ws, vec![0, 1, 3, 2]);
        // With per_user=1: user0 → 0, user1 → 3.
        assert_eq!(p.warm_start_arms(1), vec![0, 3]);
    }

    #[test]
    fn mean_optimal_cost_matches() {
        let (p, t) = tiny_problem();
        // best arms: user0 → arm1 (c=2), user1 → arm2 (c=3); mean = 2.5
        assert!((p.mean_optimal_cost(&t) - 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-positive cost")]
    fn validate_rejects_zero_cost() {
        let (mut p, _) = tiny_problem();
        p.cost[1] = 0.0;
        p.validate();
    }

    #[test]
    #[should_panic(expected = "empty candidate set")]
    fn validate_rejects_empty_user() {
        let (mut p, _) = tiny_problem();
        p.user_arms[1].clear();
        p.arm_users = Problem::compute_arm_users(4, &p.user_arms);
        p.validate();
    }
}
