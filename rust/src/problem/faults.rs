//! Deterministic fault injection for the serving engine.
//!
//! The paper's model assumes every dispatched job eventually returns its
//! observation. A production service does not get that luxury: devices
//! crash and come back, jobs fail without revealing anything, and
//! stragglers finish late. This module holds the driver-side vocabulary
//! for injecting those failures **deterministically** — a validated,
//! totally ordered [`FaultPlan`] the engine merges into its timed-event
//! stream (beside tenant churn and fleet availability), so a faulty run
//! replays bit-for-bit from its seed:
//!
//! * [`FaultKind::DeviceCrash`] / [`FaultKind::DeviceRestart`] — the
//!   device drops offline (an in-flight job is preempted and its arm
//!   requeued through the fleet machinery; nothing is revealed) and
//!   later returns;
//! * [`FaultKind::JobFailure`] — the in-flight job on the device dies:
//!   its completion is lost, nothing is revealed to the GP, and the arm
//!   enters the bounded retry/backoff path of [`RetryPolicy`];
//! * [`FaultKind::Straggler`] — the in-flight job slows down: its
//!   *remaining* cost is stretched by the given factor (the observation,
//!   when it finally lands, is unchanged — stragglers delay, they do not
//!   corrupt).
//!
//! [`RetryPolicy`] also carries the per-job deadline: a dispatched job
//! is killed after `deadline_factor × c̄(x, class_d)/s_d` clock units
//! (`c̄` is the *scheduler-visible* cost estimate — Remark 1's split),
//! counted as a failure, and retried with capped exponential backoff.
//! After `max_retries` failed attempts the arm is abandoned for the rest
//! of the run — the service degrades gracefully instead of spinning.

/// What a fault event does when its time comes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The device drops offline mid-run; a running job is preempted (arm
    /// requeued, nothing revealed), and the device stops asking for work
    /// until a [`FaultKind::DeviceRestart`].
    DeviceCrash,
    /// The crashed device comes back online and asks for work.
    DeviceRestart,
    /// The in-flight job on the device fails: the completion is lost,
    /// nothing is revealed, and the arm is retried under the plan's
    /// [`RetryPolicy`]. No effect on an idle device.
    JobFailure,
    /// The in-flight job on the device slows down: its remaining cost is
    /// multiplied by the factor (validated ≥ 1). No effect on an idle
    /// device.
    Straggler(f64),
}

impl FaultKind {
    /// Deterministic tie-break rank inside the engine's merged timeline.
    /// All fault ranks sit *after* the fleet/churn ranks 0–3, so a plan
    /// that shares a timestamp with a scheduled fleet or churn event
    /// applies after it — and an empty plan leaves the historical order
    /// untouched. Within faults: capacity shrinks first (crash), then
    /// in-flight jobs are killed/slowed, then capacity returns (restart)
    /// — a restarting device asks for work against the post-fault queue.
    pub(crate) fn rank(self) -> u8 {
        match self {
            FaultKind::DeviceCrash => 4,
            FaultKind::JobFailure => 5,
            FaultKind::Straggler(_) => 6,
            FaultKind::DeviceRestart => 7,
        }
    }
}

/// One injected fault in (virtual or scaled wall-clock) time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Event time (same unit as arm costs).
    pub time: f64,
    /// Affected device index.
    pub device: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// Deadline/retry semantics for failed jobs (shared by the whole plan).
///
/// A dispatched job gets the deadline `deadline_factor × ĉ/s_d` (ĉ the
/// scheduler-visible cost estimate for the arm on the device's class);
/// blowing it counts as a job failure. Each failure of an arm schedules
/// a re-dispatch after `min(backoff_base × 2^attempt, backoff_cap)`
/// clock units (attempt 0 for the first failure); after `max_retries`
/// failures the arm is abandoned — never re-dispatched, its user's
/// regret keeps integrating against whatever incumbent exists.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Deadline multiplier `k > 1` on the estimated job duration.
    pub deadline_factor: f64,
    /// Failed attempts after which the arm is abandoned.
    pub max_retries: usize,
    /// First backoff delay, in clock units (> 0).
    pub backoff_base: f64,
    /// Upper bound on any backoff delay (≥ `backoff_base`).
    pub backoff_cap: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { deadline_factor: 3.0, max_retries: 3, backoff_base: 0.25, backoff_cap: 4.0 }
    }
}

impl RetryPolicy {
    /// Panics with a description on invalid knobs (generator bug, not a
    /// runtime condition — mirroring [`super::DeviceFleet::new`]).
    pub fn validate(&self) {
        assert!(
            self.deadline_factor.is_finite() && self.deadline_factor > 1.0,
            "retry deadline_factor must be finite and > 1, got {}",
            self.deadline_factor
        );
        assert!(
            self.backoff_base.is_finite() && self.backoff_base > 0.0,
            "retry backoff_base must be finite and positive, got {}",
            self.backoff_base
        );
        assert!(
            self.backoff_cap.is_finite() && self.backoff_cap >= self.backoff_base,
            "retry backoff_cap must be finite and >= backoff_base, got {}",
            self.backoff_cap
        );
    }

    /// Backoff delay before re-dispatching after the `attempt`-th failure
    /// (0-based): `min(backoff_base × 2^attempt, backoff_cap)`, computed
    /// by iterative doubling so huge attempt counts saturate at the cap
    /// instead of overflowing.
    pub fn backoff(&self, attempt: usize) -> f64 {
        let mut delay = self.backoff_base;
        for _ in 0..attempt {
            if delay >= self.backoff_cap {
                break;
            }
            delay *= 2.0;
        }
        delay.min(self.backoff_cap)
    }
}

/// A validated, deterministically ordered fault-injection timeline plus
/// the retry semantics jobs run under.
///
/// Invariants enforced by [`FaultPlan::new`]: finite non-negative event
/// times; device indices in range; straggler factors finite and ≥ 1;
/// events totally ordered by `(time, kind rank, device)`; per device,
/// crash/restart events strictly alternate starting with a crash; no two
/// events share `(time, device, kind rank)` (the order would be
/// ambiguous); and a valid [`RetryPolicy`].
///
/// An **empty** plan ([`FaultPlan::empty`]) is the engine's fault-free
/// mode: it contributes no timed events and arms no deadline machinery,
/// so runs are *byte-identical* to runs with no plan at all — the hard
/// gate `fig8_faults` enforces.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    retry: RetryPolicy,
}

impl FaultPlan {
    /// Sort and validate a fault timeline for a fleet of `n_devices`
    /// device slots. Panics with a description on an inconsistent plan.
    pub fn new(n_devices: usize, mut events: Vec<FaultEvent>, retry: RetryPolicy) -> Self {
        retry.validate();
        for e in &events {
            assert!(
                e.time.is_finite() && e.time >= 0.0,
                "fault event time must be finite and non-negative, got {} for device {}",
                e.time,
                e.device
            );
            assert!(
                e.device < n_devices,
                "fault event references out-of-range device {}",
                e.device
            );
            if let FaultKind::Straggler(factor) = e.kind {
                assert!(
                    factor.is_finite() && factor >= 1.0,
                    "straggler factor must be finite and >= 1, got {factor} for device {}",
                    e.device
                );
            }
        }
        events.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then_with(|| a.kind.rank().cmp(&b.kind.rank()))
                .then_with(|| a.device.cmp(&b.device))
        });
        let mut crashed = vec![false; n_devices];
        let mut last: Vec<Option<(f64, u8)>> = vec![None; n_devices];
        for e in &events {
            match e.kind {
                FaultKind::DeviceCrash => {
                    assert!(!crashed[e.device], "device {} crashes while already crashed", e.device);
                    crashed[e.device] = true;
                }
                FaultKind::DeviceRestart => {
                    assert!(crashed[e.device], "device {} restarts without a prior crash", e.device);
                    crashed[e.device] = false;
                }
                FaultKind::JobFailure | FaultKind::Straggler(_) => {}
            }
            let key = (e.time, e.kind.rank());
            assert!(
                last[e.device] != Some(key),
                "device {} has two identical-kind fault events at time {}",
                e.device,
                e.time
            );
            last[e.device] = Some(key);
        }
        FaultPlan { events, retry }
    }

    /// The fault-free plan: no events, default retry knobs, byte-inert.
    pub fn empty() -> Self {
        FaultPlan { events: Vec::new(), retry: RetryPolicy::default() }
    }

    /// Whether the plan injects nothing (the engine's byte-identity
    /// fast path: no deadlines, no extra wake-ups).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The ordered fault timeline.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// The retry/deadline semantics in force.
    pub fn retry(&self) -> &RetryPolicy {
        &self.retry
    }

    /// Last fault-event time (0 when the timeline is empty).
    pub fn end_time(&self) -> f64 {
        self.events.last().map(|e| e.time).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_empty_and_valid() {
        let p = FaultPlan::empty();
        assert!(p.is_empty());
        assert_eq!(p.events().len(), 0);
        assert_eq!(p.end_time(), 0.0);
        p.retry().validate();
    }

    #[test]
    fn events_sort_by_time_then_rank_then_device() {
        let p = FaultPlan::new(
            3,
            vec![
                FaultEvent { time: 5.0, device: 2, kind: FaultKind::DeviceRestart },
                FaultEvent { time: 5.0, device: 1, kind: FaultKind::JobFailure },
                FaultEvent { time: 5.0, device: 0, kind: FaultKind::Straggler(2.0) },
                FaultEvent { time: 2.0, device: 2, kind: FaultKind::DeviceCrash },
            ],
            RetryPolicy::default(),
        );
        let order: Vec<_> = p.events().iter().map(|e| (e.time, e.device, e.kind.rank())).collect();
        assert_eq!(order, vec![(2.0, 2, 4), (5.0, 1, 5), (5.0, 0, 6), (5.0, 2, 7)]);
        assert_eq!(p.end_time(), 5.0);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let r = RetryPolicy { deadline_factor: 2.0, max_retries: 10, backoff_base: 0.5, backoff_cap: 3.0 };
        assert_eq!(r.backoff(0), 0.5);
        assert_eq!(r.backoff(1), 1.0);
        assert_eq!(r.backoff(2), 2.0);
        assert_eq!(r.backoff(3), 3.0);
        assert_eq!(r.backoff(50), 3.0);
        assert_eq!(r.backoff(10_000), 3.0, "huge attempts must saturate, not overflow");
    }

    #[test]
    #[should_panic(expected = "crashes while already crashed")]
    fn rejects_double_crash() {
        let _ = FaultPlan::new(
            1,
            vec![
                FaultEvent { time: 1.0, device: 0, kind: FaultKind::DeviceCrash },
                FaultEvent { time: 2.0, device: 0, kind: FaultKind::DeviceCrash },
            ],
            RetryPolicy::default(),
        );
    }

    #[test]
    #[should_panic(expected = "restarts without a prior crash")]
    fn rejects_restart_without_crash() {
        let _ = FaultPlan::new(
            1,
            vec![FaultEvent { time: 1.0, device: 0, kind: FaultKind::DeviceRestart }],
            RetryPolicy::default(),
        );
    }

    #[test]
    #[should_panic(expected = "out-of-range device")]
    fn rejects_out_of_range_device() {
        let _ = FaultPlan::new(
            2,
            vec![FaultEvent { time: 1.0, device: 5, kind: FaultKind::JobFailure }],
            RetryPolicy::default(),
        );
    }

    #[test]
    #[should_panic(expected = "straggler factor")]
    fn rejects_sub_unit_straggler() {
        let _ = FaultPlan::new(
            1,
            vec![FaultEvent { time: 1.0, device: 0, kind: FaultKind::Straggler(0.5) }],
            RetryPolicy::default(),
        );
    }

    #[test]
    #[should_panic(expected = "identical-kind fault events")]
    fn rejects_duplicate_events() {
        let _ = FaultPlan::new(
            1,
            vec![
                FaultEvent { time: 1.0, device: 0, kind: FaultKind::JobFailure },
                FaultEvent { time: 1.0, device: 0, kind: FaultKind::JobFailure },
            ],
            RetryPolicy::default(),
        );
    }

    #[test]
    #[should_panic(expected = "deadline_factor")]
    fn rejects_sub_unit_deadline_factor() {
        let _ = FaultPlan::new(
            1,
            Vec::new(),
            RetryPolicy { deadline_factor: 1.0, ..RetryPolicy::default() },
        );
    }

    #[test]
    fn crash_restart_alternation_allows_cycles() {
        let p = FaultPlan::new(
            1,
            vec![
                FaultEvent { time: 1.0, device: 0, kind: FaultKind::DeviceCrash },
                FaultEvent { time: 2.0, device: 0, kind: FaultKind::DeviceRestart },
                FaultEvent { time: 3.0, device: 0, kind: FaultKind::DeviceCrash },
            ],
            RetryPolicy::default(),
        );
        assert_eq!(p.events().len(), 3);
        assert!(!p.is_empty());
    }
}
