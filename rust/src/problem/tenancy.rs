//! Dynamic tenancy: which users are *currently* being served.
//!
//! The paper freezes the tenant set at policy-construction time, but a
//! real service (the ROADMAP's north star; ease.ml's resource-sharing
//! regime) sees tenants **arrive and depart mid-run**. This module holds
//! the driver-side vocabulary for that scenario:
//!
//! * [`TenantSet`] — the active-user mask over a [`Problem`], with the
//!   derived per-arm "retired" view (an arm is retired when none of its
//!   owners is active, so it must not be dispatched);
//! * [`ChurnEvent`] / [`ChurnSchedule`] — a validated, deterministically
//!   ordered arrival/departure timeline the event loops replay.
//!
//! Convention: **every user starts inactive** and becomes active only
//! through an [`ChurnEventKind::Arrival`] event (the t = 0 cohort arrives
//! at time 0). Regret accrues only over a user's active windows (Eq. 2
//! with per-user entry/exit integration limits — see `sim::churn`).

use super::{ArmId, Problem, UserId};

/// Active-user mask over a problem's tenants.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantSet {
    active: Vec<bool>,
    n_active: usize,
}

impl TenantSet {
    /// All `n_users` tenants inactive (the churn-loop starting state).
    pub fn none_active(n_users: usize) -> Self {
        TenantSet { active: vec![false; n_users], n_active: 0 }
    }

    /// All `n_users` tenants active (the paper's static setting).
    pub fn all_active(n_users: usize) -> Self {
        TenantSet { active: vec![true; n_users], n_active: n_users }
    }

    /// Total tenants (active or not).
    pub fn n_users(&self) -> usize {
        self.active.len()
    }

    /// Currently active tenant count.
    pub fn n_active(&self) -> usize {
        self.n_active
    }

    /// Whether tenant `u` is active.
    #[inline]
    pub fn is_active(&self, u: UserId) -> bool {
        self.active[u]
    }

    /// Mark tenant `u` active; returns whether the state changed.
    pub fn activate(&mut self, u: UserId) -> bool {
        if self.active[u] {
            return false;
        }
        self.active[u] = true;
        self.n_active += 1;
        true
    }

    /// Mark tenant `u` inactive; returns whether the state changed.
    pub fn deactivate(&mut self, u: UserId) -> bool {
        if !self.active[u] {
            return false;
        }
        self.active[u] = false;
        self.n_active -= 1;
        true
    }

    /// Iterator over the active tenants, in ascending id order.
    pub fn active_users(&self) -> impl Iterator<Item = UserId> + '_ {
        self.active.iter().enumerate().filter(|(_, &a)| a).map(|(u, _)| u)
    }

    /// Whether arm `x` is retired under this tenant set: retired iff
    /// **no** owning user is active (a shared arm stays live while any
    /// owner is). Retired arms must not be dispatched.
    pub fn arm_retired(&self, problem: &Problem, x: ArmId) -> bool {
        !problem.arm_users[x].iter().any(|&u| self.active[u])
    }

    /// Refresh a preallocated per-arm retired mask (see
    /// [`TenantSet::arm_retired`]) after the arms of `user` changed
    /// eligibility — only that user's arms are re-derived.
    pub fn refresh_retired_for_user(&self, problem: &Problem, user: UserId, retired: &mut [bool]) {
        for &x in &problem.user_arms[user] {
            retired[x] = self.arm_retired(problem, x);
        }
    }
}

/// What a churn event does to its tenant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnEventKind {
    /// The tenant enters (or re-enters) the service.
    Arrival,
    /// The tenant exits; its unstarted arms are retired.
    Departure,
}

impl ChurnEventKind {
    /// Deterministic tie-break rank: at equal times departures apply
    /// before arrivals, so a device freed by a departure sees the
    /// arriving tenant's arms in the same decision.
    fn rank(self) -> u8 {
        match self {
            ChurnEventKind::Departure => 0,
            ChurnEventKind::Arrival => 1,
        }
    }
}

/// One tenant lifecycle event in (virtual or scaled wall-clock) time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnEvent {
    /// Event time (same unit as arm costs).
    pub time: f64,
    /// Affected tenant.
    pub user: UserId,
    /// Arrival or departure.
    pub kind: ChurnEventKind,
}

/// A validated arrival/departure timeline.
///
/// Invariants enforced by [`ChurnSchedule::new`]: finite non-negative
/// times; events totally ordered by `(time, kind rank, user)`; each
/// user's events strictly alternate Arrival → Departure → Arrival → …
/// starting with an Arrival (a user may re-enter any number of times —
/// the "leave-then-rejoin" case the churn parity tests pin).
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnSchedule {
    events: Vec<ChurnEvent>,
}

impl ChurnSchedule {
    /// Sort and validate a raw event list. Panics with a description on
    /// an inconsistent timeline (generator bug, not a runtime condition).
    pub fn new(mut events: Vec<ChurnEvent>) -> Self {
        for e in &events {
            assert!(
                e.time.is_finite() && e.time >= 0.0,
                "churn event time must be finite and non-negative, got {} for user {}",
                e.time,
                e.user
            );
        }
        events.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then_with(|| a.kind.rank().cmp(&b.kind.rank()))
                .then_with(|| a.user.cmp(&b.user))
        });
        let n_users = events.iter().map(|e| e.user + 1).max().unwrap_or(0);
        let mut active = vec![false; n_users];
        let mut last_time = vec![f64::NEG_INFINITY; n_users];
        for e in &events {
            match e.kind {
                ChurnEventKind::Arrival => {
                    assert!(!active[e.user], "user {} arrives while already active", e.user)
                }
                ChurnEventKind::Departure => {
                    assert!(active[e.user], "user {} departs while inactive", e.user)
                }
            }
            assert!(
                e.time > last_time[e.user] || last_time[e.user] == f64::NEG_INFINITY,
                "user {} has two events at the same time {}",
                e.user,
                e.time
            );
            active[e.user] = e.kind == ChurnEventKind::Arrival;
            last_time[e.user] = e.time;
        }
        ChurnSchedule { events }
    }

    /// The ordered event list.
    pub fn events(&self) -> &[ChurnEvent] {
        &self.events
    }

    /// Number of events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the timeline is empty (static tenancy).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Last event time (0 when empty).
    pub fn end_time(&self) -> f64 {
        self.events.last().map(|e| e.time).unwrap_or(0.0)
    }

    /// Users that are ever part of the timeline.
    pub fn n_users_seen(&self) -> usize {
        self.events.iter().map(|e| e.user + 1).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn problem() -> Problem {
        // User 0 owns {0,1}, user 1 owns {1,2}: arm 1 is shared.
        let user_arms = vec![vec![0, 1], vec![1, 2]];
        let arm_users = Problem::compute_arm_users(3, &user_arms);
        Problem {
            name: "tenancy".into(),
            n_users: 2,
            cost: vec![1.0; 3],
            user_arms,
            arm_users,
            prior_mean: vec![0.0; 3],
            prior_cov: Mat::eye(3),
        }
    }

    #[test]
    fn activate_deactivate_track_counts() {
        let mut ts = TenantSet::none_active(3);
        assert_eq!(ts.n_active(), 0);
        assert!(ts.activate(1));
        assert!(!ts.activate(1), "re-activation is a no-op");
        assert!(ts.is_active(1));
        assert_eq!(ts.n_active(), 1);
        assert_eq!(ts.active_users().collect::<Vec<_>>(), vec![1]);
        assert!(ts.deactivate(1));
        assert!(!ts.deactivate(1));
        assert_eq!(ts.n_active(), 0);
        assert_eq!(TenantSet::all_active(4).n_active(), 4);
    }

    #[test]
    fn shared_arm_retires_only_when_all_owners_leave() {
        let p = problem();
        let mut ts = TenantSet::all_active(2);
        let mut retired = vec![false; 3];
        ts.deactivate(0);
        ts.refresh_retired_for_user(&p, 0, &mut retired);
        assert!(retired[0], "user 0's private arm retires");
        assert!(!retired[1], "shared arm stays while user 1 is active");
        ts.deactivate(1);
        ts.refresh_retired_for_user(&p, 1, &mut retired);
        assert!(retired[1] && retired[2]);
        ts.activate(1);
        ts.refresh_retired_for_user(&p, 1, &mut retired);
        assert!(!retired[1] && !retired[2], "rejoin un-retires");
        assert!(retired[0], "the absent tenant's private arm stays retired");
    }

    #[test]
    fn schedule_orders_and_validates() {
        let s = ChurnSchedule::new(vec![
            ChurnEvent { time: 5.0, user: 0, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 5.0, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 9.0, user: 1, kind: ChurnEventKind::Departure },
        ]);
        let kinds: Vec<_> = s.events().iter().map(|e| (e.time, e.user, e.kind)).collect();
        // At t = 5 the departure applies before the arrival.
        assert_eq!(
            kinds,
            vec![
                (0.0, 0, ChurnEventKind::Arrival),
                (5.0, 0, ChurnEventKind::Departure),
                (5.0, 1, ChurnEventKind::Arrival),
                (9.0, 1, ChurnEventKind::Departure),
            ]
        );
        assert_eq!(s.len(), 4);
        assert!(!s.is_empty());
        assert_eq!(s.end_time(), 9.0);
        assert_eq!(s.n_users_seen(), 2);
        assert!(ChurnSchedule::new(vec![]).is_empty());
    }

    #[test]
    fn schedule_allows_leave_then_rejoin() {
        let s = ChurnSchedule::new(vec![
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 2.0, user: 0, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 6.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 8.0, user: 0, kind: ChurnEventKind::Departure },
        ]);
        assert_eq!(s.len(), 4);
    }

    #[test]
    #[should_panic(expected = "arrives while already active")]
    fn schedule_rejects_double_arrival() {
        let _ = ChurnSchedule::new(vec![
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 1.0, user: 0, kind: ChurnEventKind::Arrival },
        ]);
    }

    #[test]
    #[should_panic(expected = "departs while inactive")]
    fn schedule_rejects_orphan_departure() {
        let _ = ChurnSchedule::new(vec![ChurnEvent {
            time: 1.0,
            user: 0,
            kind: ChurnEventKind::Departure,
        }]);
    }
}
