//! Per-(arm, device-class) cost models.
//!
//! The paper (Remark 1) keys execution cost by arm only: `c(x)` is one
//! vector, every device is interchangeable. A service provider's fleet
//! is not: GPU generations differ in throughput *per model family*
//! (beyond the scalar speed `s_d` of [`super::DeviceFleet`]) and in
//! memory — a model that does not fit a device class cannot run there at
//! all. [`CostModel`] generalizes `Problem::cost` behind a
//! `(arm, device-class)` lookup:
//!
//! * [`UniformCost`] — the paper's vector, one class. Byte-compatible:
//!   every cost it returns is the exact `Problem::cost` float.
//! * [`PerClassCost`] — per-class multipliers over a base vector plus a
//!   per-class memory limit; an arm whose base cost (the size proxy)
//!   exceeds a class's limit is **infeasible** there (`cost` returns
//!   `None`) and must be treated as a non-candidate for that class's
//!   devices.
//!
//! Remark-1 fidelity: the *scheduler* sees a cost model built from the
//! scheduler-visible problem (the engine's `sched_view` split), while
//! the engine charges devices from a model over the true costs — exactly
//! the estimated-vs-true split the uniform vector already had.

use super::ArmId;

/// Execution-cost lookup keyed by `(arm, device-class)`.
///
/// `None` means the arm is infeasible on that class (memory limit):
/// device-aware policies score it `−∞` for asking devices of the class,
/// and the engine refuses to dispatch it there (the arm waits for a
/// class that fits it).
pub trait CostModel {
    /// Number of device classes the model distinguishes.
    fn n_classes(&self) -> usize;

    /// True execution cost of `arm` on a device of `class`, or `None`
    /// when the arm cannot run on that class at all.
    fn cost(&self, arm: ArmId, class: usize) -> Option<f64>;

    /// Dense per-class cost table for scoring backends:
    /// `table[class][arm]`, with `+∞` marking infeasible entries (the
    /// sentinel scoring maps to a `−∞` score, i.e. non-candidate).
    fn class_table(&self, n_arms: usize) -> Vec<Vec<f64>> {
        (0..self.n_classes())
            .map(|k| (0..n_arms).map(|x| self.cost(x, k).unwrap_or(f64::INFINITY)).collect())
            .collect()
    }
}

/// The paper's uniform cost vector as a [`CostModel`]: one class, every
/// lookup returns the exact `Problem::cost` float (byte-compatible with
/// the pre-cost-model code paths).
#[derive(Clone, Debug)]
pub struct UniformCost {
    cost: Vec<f64>,
}

impl UniformCost {
    /// Wrap a per-arm cost vector. Panics on non-positive or non-finite
    /// entries (generator-bug contract, mirroring `Problem::validate`).
    pub fn new(cost: Vec<f64>) -> Self {
        for (a, &c) in cost.iter().enumerate() {
            assert!(c > 0.0 && c.is_finite(), "arm {a} has non-positive cost {c}");
        }
        UniformCost { cost }
    }

    /// The model every pre-cost-model run implicitly used.
    pub fn from_problem(problem: &super::Problem) -> Self {
        UniformCost::new(problem.cost.clone())
    }
}

impl CostModel for UniformCost {
    fn n_classes(&self) -> usize {
        1
    }

    fn cost(&self, arm: ArmId, class: usize) -> Option<f64> {
        assert!(class < 1, "UniformCost has one class, got {class}");
        Some(self.cost[arm])
    }
}

/// Per-class multipliers over a base cost vector, with per-class memory
/// limits: `cost(x, k) = base[x] · multipliers[k]`, infeasible
/// (`None`) when `base[x] > mem_limit[k]` — the base cost doubles as the
/// model-size proxy (bigger models cost more *and* need more memory),
/// which keeps the scenario deterministic with zero extra inputs.
///
/// With `multipliers = [1.0]` and `mem_limit = [+∞]` this degenerates
/// bitwise to [`UniformCost`] (`x · 1.0` is an IEEE identity), which is
/// what the uniform-fleet byte-parity gates rely on.
#[derive(Clone, Debug)]
pub struct PerClassCost {
    base: Vec<f64>,
    multipliers: Vec<f64>,
    mem_limit: Vec<f64>,
}

impl PerClassCost {
    /// Validate and build. Panics (generator-bug contract) unless: at
    /// least one class; multipliers finite and positive; `mem_limit`
    /// matches the class count with positive (possibly `+∞`) entries;
    /// base costs positive finite; and every arm is feasible on at
    /// least one class (otherwise it could never be served).
    pub fn new(base: Vec<f64>, multipliers: Vec<f64>, mem_limit: Vec<f64>) -> Self {
        assert!(!multipliers.is_empty(), "need at least one device class");
        assert_eq!(mem_limit.len(), multipliers.len(), "mem_limit length must match multipliers");
        for (k, &m) in multipliers.iter().enumerate() {
            assert!(m.is_finite() && m > 0.0, "class {k} has non-positive multiplier {m}");
        }
        for (k, &l) in mem_limit.iter().enumerate() {
            assert!(l > 0.0 && !l.is_nan(), "class {k} has non-positive memory limit {l}");
        }
        for (a, &c) in base.iter().enumerate() {
            assert!(c > 0.0 && c.is_finite(), "arm {a} has non-positive base cost {c}");
            assert!(
                mem_limit.iter().any(|&l| c <= l),
                "arm {a} (base cost {c}) is infeasible on every device class"
            );
        }
        PerClassCost { base, multipliers, mem_limit }
    }

    /// Build over a problem's cost vector.
    pub fn from_problem(problem: &super::Problem, multipliers: Vec<f64>, mem_limit: Vec<f64>) -> Self {
        PerClassCost::new(problem.cost.clone(), multipliers, mem_limit)
    }
}

impl CostModel for PerClassCost {
    fn n_classes(&self) -> usize {
        self.multipliers.len()
    }

    fn cost(&self, arm: ArmId, class: usize) -> Option<f64> {
        if self.base[arm] > self.mem_limit[class] {
            None
        } else {
            Some(self.base[arm] * self.multipliers[class])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cost_is_byte_compatible() {
        let cost = vec![1.0, 2.5, 0.125];
        let m = UniformCost::new(cost.clone());
        assert_eq!(m.n_classes(), 1);
        for (a, &c) in cost.iter().enumerate() {
            assert_eq!(m.cost(a, 0).unwrap().to_bits(), c.to_bits());
        }
        assert_eq!(m.class_table(3), vec![cost]);
    }

    #[test]
    fn per_class_multiplies_and_enforces_memory() {
        let m = PerClassCost::new(vec![1.0, 3.0], vec![1.0, 2.0], vec![f64::INFINITY, 2.0]);
        // Class 0: no limit, multiplier 1 — bitwise the base costs.
        assert_eq!(m.cost(0, 0).unwrap().to_bits(), 1.0f64.to_bits());
        assert_eq!(m.cost(1, 0).unwrap().to_bits(), 3.0f64.to_bits());
        // Class 1: 2× cost, and arm 1 (base 3 > limit 2) is infeasible.
        assert_eq!(m.cost(0, 1), Some(2.0));
        assert_eq!(m.cost(1, 1), None);
        let table = m.class_table(2);
        assert_eq!(table[0], vec![1.0, 3.0]);
        assert_eq!(table[1][0], 2.0);
        assert!(table[1][1].is_infinite());
    }

    #[test]
    fn unit_multiplier_is_an_ieee_identity() {
        // The uniform-fleet byte-parity gates rely on x·1.0 == x bitwise.
        let base = vec![0.1, 1e-300, 7.5, 1e300];
        let m = PerClassCost::new(base.clone(), vec![1.0], vec![f64::INFINITY]);
        for (a, &c) in base.iter().enumerate() {
            assert_eq!(m.cost(a, 0).unwrap().to_bits(), c.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "infeasible on every device class")]
    fn rejects_arm_feasible_nowhere() {
        let _ = PerClassCost::new(vec![5.0], vec![1.0, 2.0], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "non-positive multiplier")]
    fn rejects_bad_multiplier() {
        let _ = PerClassCost::new(vec![1.0], vec![0.0], vec![f64::INFINITY]);
    }

    #[test]
    #[should_panic(expected = "mem_limit length")]
    fn rejects_mismatched_limits() {
        let _ = PerClassCost::new(vec![1.0], vec![1.0, 2.0], vec![f64::INFINITY]);
    }

    #[test]
    #[should_panic(expected = "non-positive cost")]
    fn uniform_rejects_bad_cost() {
        let _ = UniformCost::new(vec![1.0, -2.0]);
    }
}
