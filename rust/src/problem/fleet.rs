//! Elastic heterogeneous device fleets.
//!
//! The paper's model (§3) assumes `M` identical, always-on devices. The
//! service-provider setting it motivates — mixed GPU generations plus
//! spot/preemptible capacity — is a fleet of *heterogeneous, elastic*
//! devices. This module holds the driver-side vocabulary:
//!
//! * a per-device **speed** `s_d > 0`: running arm `x` on device `d`
//!   occupies it for `c(x)/s_d` time units (the *policy* still sees the
//!   estimated costs of Remark 1 — speeds are an execution property of
//!   the device, not of the arm);
//! * a validated, deterministically ordered **availability schedule**
//!   ([`FleetEvent`]): devices join and leave mid-run. A device that
//!   leaves while running **preempts** its job — the in-flight arm's
//!   decision is requeued deterministically (FIFO, ahead of the
//!   warm-start queue) and nothing is revealed (the revealed-on-
//!   completion contract of the simulator is preserved).
//!
//! Free-device tie-breaking is extended to **(speed desc, index asc)**:
//! when several idle devices could take work, the fastest (lowest index
//! on ties) asks first, so schedules stay bit-replayable. With all
//! speeds equal this degenerates to the historical ascending-index
//! order, which is what keeps unit-speed fleets byte-identical to the
//! pre-fleet event loops.

/// What a fleet event does to its device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetEventKind {
    /// The device comes online (or back online) and asks for work.
    Join,
    /// The device goes offline; a running job is preempted and its arm
    /// requeued, an idle device simply stops asking for work.
    Leave,
}

impl FleetEventKind {
    /// Deterministic tie-break rank: at equal times capacity shrinks
    /// before it grows (and, in the engine's merged timeline, device
    /// leaves apply before tenant churn while device joins apply after —
    /// a joining device asks for work against the post-churn arm set).
    pub(crate) fn rank(self) -> u8 {
        match self {
            FleetEventKind::Leave => 0,
            FleetEventKind::Join => 1,
        }
    }
}

/// One device availability event in (virtual or scaled wall-clock) time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetEvent {
    /// Event time (same unit as arm costs).
    pub time: f64,
    /// Affected device index.
    pub device: usize,
    /// Join or leave.
    pub kind: FleetEventKind,
}

/// A heterogeneous, elastic device fleet: per-device speeds, the set of
/// devices online at t = 0, and a validated availability timeline.
///
/// Invariants enforced by [`DeviceFleet::new`]: at least one device;
/// finite positive speeds; finite non-negative event times; events
/// totally ordered by `(time, kind rank, device)`; each device's events
/// strictly alternate with its starting state (an initially-online
/// device's first event must be a [`FleetEventKind::Leave`], an
/// initially-offline device's a [`FleetEventKind::Join`]); and at least
/// one device is ever online (online at start, or joining later).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceFleet {
    speeds: Vec<f64>,
    online_at_start: Vec<bool>,
    events: Vec<FleetEvent>,
    /// Per-device cost-model class (index into a
    /// [`crate::problem::CostModel`]'s class axis). Constructors default
    /// every device to class 0 — the uniform-cost setting — so fleets
    /// built before the cost-model API stay byte-compatible.
    classes: Vec<usize>,
}

impl DeviceFleet {
    /// Sort and validate a fleet description. Panics with a description
    /// on an inconsistent timeline (generator bug, not a runtime
    /// condition — mirroring `ChurnSchedule::new`).
    pub fn new(speeds: Vec<f64>, online_at_start: Vec<bool>, mut events: Vec<FleetEvent>) -> Self {
        let n = speeds.len();
        assert!(n >= 1, "a fleet needs at least one device");
        assert_eq!(online_at_start.len(), n, "online_at_start length must match speeds");
        for (d, &s) in speeds.iter().enumerate() {
            assert!(s.is_finite() && s > 0.0, "device {d} has non-positive speed {s}");
        }
        for e in &events {
            assert!(
                e.time.is_finite() && e.time >= 0.0,
                "fleet event time must be finite and non-negative, got {} for device {}",
                e.time,
                e.device
            );
            assert!(e.device < n, "fleet event references out-of-range device {}", e.device);
        }
        events.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then_with(|| a.kind.rank().cmp(&b.kind.rank()))
                .then_with(|| a.device.cmp(&b.device))
        });
        let mut online = online_at_start.clone();
        let mut last_time = vec![f64::NEG_INFINITY; n];
        for e in &events {
            match e.kind {
                FleetEventKind::Join => {
                    assert!(!online[e.device], "device {} joins while already online", e.device)
                }
                FleetEventKind::Leave => {
                    assert!(online[e.device], "device {} leaves while offline", e.device)
                }
            }
            assert!(
                e.time > last_time[e.device] || last_time[e.device] == f64::NEG_INFINITY,
                "device {} has two events at the same time {}",
                e.device,
                e.time
            );
            online[e.device] = e.kind == FleetEventKind::Join;
            last_time[e.device] = e.time;
        }
        assert!(
            online_at_start.iter().any(|&o| o)
                || events.iter().any(|e| e.kind == FleetEventKind::Join),
            "fleet has no device that is ever online"
        );
        let classes = vec![0; n];
        DeviceFleet { speeds, online_at_start, events, classes }
    }

    /// Assign per-device cost-model classes (builder style). Panics if
    /// the length does not match the device count — same generator-bug
    /// contract as [`DeviceFleet::new`].
    pub fn with_classes(mut self, classes: Vec<usize>) -> Self {
        assert_eq!(
            classes.len(),
            self.speeds.len(),
            "classes length must match the device count"
        );
        self.classes = classes;
        self
    }

    /// The paper's fleet: `n` identical unit-speed devices, online from
    /// t = 0, no availability events. Runs through the engine are
    /// byte-identical to the pre-fleet event loops (the unit-speed
    /// parity the CI determinism gate and `rust/tests/engine_parity.rs`
    /// pin).
    pub fn uniform(n: usize) -> Self {
        DeviceFleet::new(vec![1.0; n], vec![true; n], Vec::new())
    }

    /// Number of devices that ever exist (online or not).
    pub fn n_devices(&self) -> usize {
        self.speeds.len()
    }

    /// Speed `s_d` of device `d`.
    #[inline]
    pub fn speed(&self, d: usize) -> f64 {
        self.speeds[d]
    }

    /// Cost-model class of device `d` (0 unless assigned via
    /// [`DeviceFleet::with_classes`]).
    #[inline]
    pub fn class(&self, d: usize) -> usize {
        self.classes[d]
    }

    /// Whether device `d` is online at t = 0.
    pub fn online_at_start(&self, d: usize) -> bool {
        self.online_at_start[d]
    }

    /// Count of devices online at t = 0.
    pub fn n_online_at_start(&self) -> usize {
        self.online_at_start.iter().filter(|&&o| o).count()
    }

    /// The ordered availability timeline.
    pub fn events(&self) -> &[FleetEvent] {
        &self.events
    }

    /// Whether the fleet is the static paper setting: unit speeds, all
    /// online, no availability events.
    pub fn is_static_unit(&self) -> bool {
        self.events.is_empty()
            && self.online_at_start.iter().all(|&o| o)
            && self.speeds.iter().all(|&s| s == 1.0)
    }

    /// Aggregate capacity `Σ_d s_d` over the whole fleet (ignoring
    /// availability) — the yardstick the `fig7_elastic` bench compares
    /// against: a unit-speed always-on fleet of `round(Σ s_d)` devices.
    pub fn total_speed(&self) -> f64 {
        self.speeds.iter().sum()
    }

    /// Device indices in free-device wake order: speed descending, index
    /// ascending on ties. With all speeds equal this is `0..n` — the
    /// historical ascending-index order.
    pub fn wake_order(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.speeds.len()).collect();
        order.sort_by(|&a, &b| {
            self.speeds[b].total_cmp(&self.speeds[a]).then_with(|| a.cmp(&b))
        });
        order
    }

    /// Last availability-event time (0 when the timeline is empty).
    pub fn end_time(&self) -> f64 {
        self.events.last().map(|e| e.time).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_fleet_is_static_unit() {
        let f = DeviceFleet::uniform(3);
        assert_eq!(f.n_devices(), 3);
        assert!(f.is_static_unit());
        assert_eq!(f.n_online_at_start(), 3);
        assert_eq!(f.total_speed(), 3.0);
        assert_eq!(f.wake_order(), vec![0, 1, 2]);
        assert_eq!(f.end_time(), 0.0);
    }

    #[test]
    fn classes_default_zero_and_assign_via_builder() {
        let f = DeviceFleet::uniform(3);
        assert_eq!((0..3).map(|d| f.class(d)).collect::<Vec<_>>(), vec![0, 0, 0]);
        let g = DeviceFleet::uniform(3).with_classes(vec![0, 1, 0]);
        assert_eq!(g.class(1), 1);
        // Classes participate in fleet equality.
        assert_ne!(f, g);
        assert_eq!(f, DeviceFleet::uniform(3).with_classes(vec![0, 0, 0]));
    }

    #[test]
    #[should_panic(expected = "classes length")]
    fn rejects_wrong_class_count() {
        let _ = DeviceFleet::uniform(2).with_classes(vec![0]);
    }

    #[test]
    fn wake_order_is_speed_desc_index_asc() {
        let f = DeviceFleet::new(vec![1.0, 2.0, 2.0, 0.5], vec![true; 4], Vec::new());
        assert_eq!(f.wake_order(), vec![1, 2, 0, 3]);
        assert!(!f.is_static_unit());
    }

    #[test]
    fn events_sort_leave_before_join_on_ties() {
        let f = DeviceFleet::new(
            vec![1.0, 1.0],
            vec![true, false],
            vec![
                FleetEvent { time: 5.0, device: 1, kind: FleetEventKind::Join },
                FleetEvent { time: 5.0, device: 0, kind: FleetEventKind::Leave },
                FleetEvent { time: 9.0, device: 1, kind: FleetEventKind::Leave },
            ],
        );
        let kinds: Vec<_> = f.events().iter().map(|e| (e.time, e.device, e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                (5.0, 0, FleetEventKind::Leave),
                (5.0, 1, FleetEventKind::Join),
                (9.0, 1, FleetEventKind::Leave),
            ]
        );
        assert_eq!(f.end_time(), 9.0);
    }

    #[test]
    fn alternation_allows_leave_then_rejoin() {
        let f = DeviceFleet::new(
            vec![2.0],
            vec![true],
            vec![
                FleetEvent { time: 1.0, device: 0, kind: FleetEventKind::Leave },
                FleetEvent { time: 3.0, device: 0, kind: FleetEventKind::Join },
                FleetEvent { time: 7.0, device: 0, kind: FleetEventKind::Leave },
            ],
        );
        assert_eq!(f.events().len(), 3);
        assert_eq!(f.speed(0), 2.0);
    }

    #[test]
    #[should_panic(expected = "joins while already online")]
    fn rejects_join_of_online_device() {
        let _ = DeviceFleet::new(
            vec![1.0],
            vec![true],
            vec![FleetEvent { time: 1.0, device: 0, kind: FleetEventKind::Join }],
        );
    }

    #[test]
    #[should_panic(expected = "leaves while offline")]
    fn rejects_leave_of_offline_device() {
        let _ = DeviceFleet::new(
            vec![1.0],
            vec![false],
            vec![FleetEvent { time: 1.0, device: 0, kind: FleetEventKind::Leave }],
        );
    }

    #[test]
    #[should_panic(expected = "non-positive speed")]
    fn rejects_bad_speed() {
        let _ = DeviceFleet::new(vec![0.0], vec![true], Vec::new());
    }

    #[test]
    #[should_panic(expected = "no device that is ever online")]
    fn rejects_forever_offline_fleet() {
        let _ = DeviceFleet::new(vec![1.0, 1.0], vec![false, false], Vec::new());
    }

    #[test]
    #[should_panic(expected = "out-of-range device")]
    fn rejects_out_of_range_event() {
        let _ = DeviceFleet::new(
            vec![1.0],
            vec![true],
            vec![FleetEvent { time: 1.0, device: 7, kind: FleetEventKind::Leave }],
        );
    }
}
