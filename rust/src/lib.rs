//! # mmgpei — Multi-device, Multi-tenant Model Selection with GP-EI
//!
//! Production-grade reproduction of *"AutoML from Service Provider's
//! Perspective: Multi-device, Multi-tenant Model Selection with GP-EI"*
//! (Yu, Karlaš, Zhong, Zhang, Liu; 2018).
//!
//! The paper's contribution — the MM-GP-EI scheduler that allocates `M`
//! devices to `N` AutoML tenants by maximizing the expected-improvement
//! *rate* summed over tenants — lives in [`sched`] and is driven either by
//! the deterministic discrete-event simulator ([`sim`]) or the real-time
//! threaded serving coordinator ([`coordinator`]); both are thin adapters
//! over the unified scheduling [`engine`], which owns the one event loop
//! (completions, tenant churn, elastic device fleets) behind a virtual-
//! vs wall-clock [`engine::Clock`]. The numeric hot spot of
//! every scheduling decision (GP posterior refresh + EIrate scoring) has
//! two interchangeable backends:
//!
//! * [`gp`] — native rust incremental-Cholesky posterior (default), with
//!   a dirty-set change report driving [`sched::NativeBackend`]'s
//!   incremental EIrate cache, and
//! * [`runtime`] — an AOT-compiled JAX/Pallas `scheduler_step` artifact
//!   executed through the PJRT C API (the `xla` crate); python never runs
//!   at decision time. Compiled only with `--features xla`; the default
//!   build substitutes a stub whose constructor errors, so no PJRT/XLA
//!   toolchain is needed to build, test, or serve natively.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for reproduction results.

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod miu;
pub mod pool;
pub mod prng;
pub mod problem;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod testutil;
pub mod workload;
