//! Plain row-major dense matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense `f64` matrix.
///
/// Deliberately minimal: the library needs contiguous row access (for the
/// cache-friendly Cholesky inner loops) and simple constructors; anything
/// fancier would be dead weight in the offline environment.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// `rows × cols` matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row slices (test/construction convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.row_mut(i).copy_from_slice(row);
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Whole backing slice (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Whole backing slice, mutable.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Symmetrize in place: `A ← (A + Aᵀ)/2`. Useful for covariance
    /// estimates accumulated with floating-point asymmetry.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..i {
                let avg = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = avg;
                self[(j, i)] = avg;
            }
        }
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn eye_is_identity() {
        let i = Mat::eye(3);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(i[(r, c)], if r == c { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn from_fn_matches() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(1, 2)], 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 7 + j * 3) as f64);
        let t = m.transpose();
        assert_eq!(t.rows(), 5);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn symmetrize_averages() {
        let mut m = Mat::from_rows(&[&[1.0, 2.0], &[4.0, 1.0]]);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn max_abs_scans_all() {
        let m = Mat::from_rows(&[&[1.0, -9.5], &[4.0, 1.0]]);
        assert_eq!(m.max_abs(), 9.5);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        let _ = Mat::from_rows(&[&[1.0, 2.0], &[3.0]]);
    }
}
