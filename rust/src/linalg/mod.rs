//! Dense symmetric linear-algebra substrate.
//!
//! The Gaussian-process layer (`crate::gp`) and the Maximum Incremental
//! Uncertainty analysis (`crate::miu`) need a small set of dense
//! operations on symmetric positive-(semi)definite matrices: Cholesky
//! factorization, triangular solves, log-determinants, and — critically
//! for the scheduler hot path — an *incremental* Cholesky that appends
//! one observation (one row/column of the kernel matrix) in `O(t²)`
//! instead of refactorizing in `O(t³)`.
//!
//! Everything is written against a plain row-major [`Mat`] type; the
//! offline build environment ships no BLAS/ndarray, and the problem sizes
//! of the paper (≤ a few thousand arms) are comfortably in scope for
//! cache-aware scalar code.

mod mat;

pub use mat::Mat;

use std::fmt;

/// Errors from factorizations.
///
/// Display/Error are hand-implemented — the offline build environment
/// ships no `thiserror`.
#[derive(Clone, Debug, PartialEq)]
pub enum LinalgError {
    /// The matrix is not positive definite (pivot ≤ 0 at the given index).
    NotPositiveDefinite(usize, f64),
    /// Dimension mismatch between operands.
    DimensionMismatch(String),
}

impl fmt::Display for LinalgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LinalgError::NotPositiveDefinite(i, v) => {
                write!(f, "matrix not positive definite at pivot {i} (value {v})")
            }
            LinalgError::DimensionMismatch(m) => write!(f, "dimension mismatch: {m}"),
        }
    }
}

impl std::error::Error for LinalgError {}

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
///
/// Returns an error if `a` is not (numerically) positive definite.
pub fn cholesky(a: &Mat) -> Result<Mat, LinalgError> {
    let n = a.rows();
    if a.cols() != n {
        return Err(LinalgError::DimensionMismatch(format!(
            "cholesky needs square matrix, got {}x{}",
            a.rows(),
            a.cols()
        )));
    }
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot of row i and row j of L up to column j
            let mut sum = a[(i, j)];
            let (ri, rj) = (l.row(i), l.row(j));
            for k in 0..j {
                sum = ri[k].mul_add(-rj[k], sum);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(LinalgError::NotPositiveDefinite(i, sum));
                }
                l[(i, i)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Cholesky with additive jitter escalation: retries with `jitter * 10^k`
/// added to the diagonal until the factorization succeeds (up to 8
/// escalations). Returns the factor and the jitter actually used.
///
/// GP kernel matrices built from empirical covariance estimates are
/// frequently rank-deficient; this mirrors the standard GP-library
/// behaviour (GPy/GPyOpt/scikit-learn all do the same).
pub fn cholesky_jittered(a: &Mat, base_jitter: f64) -> Result<(Mat, f64), LinalgError> {
    if let Ok(l) = cholesky(a) {
        return Ok((l, 0.0));
    }
    let n = a.rows();
    let mut jitter = base_jitter;
    for _ in 0..8 {
        let mut aj = a.clone();
        for i in 0..n {
            aj[(i, i)] += jitter;
        }
        if let Ok(l) = cholesky(&aj) {
            return Ok((l, jitter));
        }
        jitter *= 10.0;
    }
    Err(LinalgError::NotPositiveDefinite(0, jitter))
}

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let mut y = Vec::new();
    solve_lower_into(l, b, &mut y);
    y
}

/// Buffer-reusing form of [`solve_lower`]: writes the solution into `y`,
/// reusing its capacity. Hot paths that solve repeatedly (Nelder–Mead
/// refits, the scheduler decision loop) call this to stay allocation-free
/// after warm-up.
pub fn solve_lower_into(l: &Mat, b: &[f64], y: &mut Vec<f64>) {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    y.clear();
    y.resize(n, 0.0);
    for i in 0..n {
        let row = l.row(i);
        let mut sum = b[i];
        for k in 0..i {
            sum = row[k].mul_add(-y[k], sum);
        }
        y[i] = sum / row[i];
    }
}

/// Solve `Lᵀ x = y` for lower-triangular `L` (backward substitution).
pub fn solve_lower_transpose(l: &Mat, y: &[f64]) -> Vec<f64> {
    let mut x = Vec::new();
    solve_lower_transpose_into(l, y, &mut x);
    x
}

/// Buffer-reusing form of [`solve_lower_transpose`] (see
/// [`solve_lower_into`] for the contract).
pub fn solve_lower_transpose_into(l: &Mat, y: &[f64], x: &mut Vec<f64>) {
    let n = l.rows();
    debug_assert_eq!(y.len(), n);
    x.clear();
    x.resize(n, 0.0);
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum = l[(k, i)].mul_add(-x[k], sum);
        }
        x[i] = sum / l[(i, i)];
    }
}

/// Solve `A x = b` given the Cholesky factor `L` of `A`.
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let mut scratch = Vec::new();
    let mut x = Vec::new();
    cholesky_solve_into(l, b, &mut scratch, &mut x);
    x
}

/// Buffer-reusing form of [`cholesky_solve`]: `scratch` holds the
/// intermediate forward solve, `x` the solution; both reuse capacity.
pub fn cholesky_solve_into(l: &Mat, b: &[f64], scratch: &mut Vec<f64>, x: &mut Vec<f64>) {
    solve_lower_into(l, b, scratch);
    solve_lower_transpose_into(l, scratch, x);
}

/// `log det A` from its Cholesky factor.
pub fn logdet_from_cholesky(l: &Mat) -> f64 {
    (0..l.rows()).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

/// Incrementally maintained Cholesky factor of a growing SPD matrix.
///
/// This is the scheduler's native hot-path data structure: every finished
/// model appends one row/column to the kernel matrix of observed arms, and
/// [`CholeskyFactor::append`] extends the factor in `O(t²)` (one forward
/// solve) instead of the `O(t³)` full refactorization.
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    /// Row-major storage with stride `cap`; only the lower triangle of
    /// the leading `n × n` block is meaningful. Capacity doubles on
    /// growth so appends are amortized `O(t)` memory traffic instead of
    /// the full `O(t²)` copy a naive re-allocation per append costs
    /// (§Perf L3 iteration 1).
    data: Vec<f64>,
    cap: usize,
    n: usize,
}

impl CholeskyFactor {
    /// Empty factor (0×0 matrix).
    pub fn new() -> Self {
        CholeskyFactor { data: Vec::new(), cap: 0, n: 0 }
    }

    /// Empty factor with reserved capacity (avoids re-layouts when the
    /// final size is known, e.g. `n_arms`).
    pub fn with_capacity(cap: usize) -> Self {
        // pallas-lint: allow(R6) — one-time construction reserve: reached from the observe root only through ShardedGp's lazy per-tenant shard setup, which allocates once per tenant and never again in steady state (tests/alloc_counter.rs warms every tenant before measuring).
        CholeskyFactor { data: vec![0.0; cap * cap], cap, n: 0 }
    }

    /// Current dimension.
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Row `i` of the factor (first `i + 1` entries are the lower
    /// triangle; the remainder of the slice is zero padding).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.n);
        &self.data[i * self.cap..i * self.cap + self.n]
    }

    /// Entry `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.n && j < self.n);
        self.data[i * self.cap + j]
    }

    /// Materialize the factor as a dense `Mat` (test/diagnostic helper).
    pub fn factor(&self) -> Mat {
        Mat::from_fn(self.n, self.n, |i, j| self.data[i * self.cap + j])
    }

    /// Ensure room for dimension `need`, re-laying rows out if the
    /// stride grows (amortized by doubling).
    fn ensure_capacity(&mut self, need: usize) {
        if need <= self.cap {
            return;
        }
        let new_cap = need.max(self.cap * 2).max(8);
        // pallas-lint: allow(R6) — capacity-doubling relayout: reached O(log n) times over a run, never in steady state once the factor's stride has grown to its horizon (alloc_counter proves the per-decision path stays at zero).
        let mut data = vec![0.0; new_cap * new_cap];
        for i in 0..self.n {
            data[i * new_cap..i * new_cap + self.n]
                .copy_from_slice(&self.data[i * self.cap..i * self.cap + self.n]);
        }
        self.data = data;
        self.cap = new_cap;
    }

    /// Fused forward substitution for an append: solves `w = L⁻¹ cross`
    /// writing `w` *directly into the new row's storage* (no scratch
    /// vector — the hot path's zero-allocation contract) and returns
    /// `‖w‖²`. The caller has already run `ensure_capacity(n + 1)`, so
    /// `self.data` splits into the prior rows and the new row at
    /// `n · cap`. Inner products use `f64::mul_add` (one rounding per
    /// term) — both append variants share this helper, so their factors
    /// stay bit-identical on healthy pivots.
    fn substitute_new_row(&mut self, cross: &[f64]) -> f64 {
        let (cap, n) = (self.cap, self.n);
        let (prior, new_row) = self.data.split_at_mut(n * cap);
        let mut sumsq = 0.0;
        for i in 0..n {
            let row = &prior[i * cap..i * cap + i + 1];
            let mut sum = cross[i];
            for k in 0..i {
                sum = row[k].mul_add(-new_row[k], sum);
            }
            let wi = sum / row[i];
            new_row[i] = wi;
            sumsq = wi.mul_add(wi, sumsq);
        }
        sumsq
    }

    /// Append one row/column: `cross[k] = A[new, k]` for existing k, and
    /// `diag = A[new, new]`. Returns the conditional standard deviation
    /// `sqrt(diag − ‖w‖²)` of the appended variable given the existing
    /// ones — exactly the `σ̂` quantity from the paper's Theorem-2 proof
    /// (Lemma 5). Errors if the Schur complement is not positive.
    ///
    /// Allocation-free once capacity covers the new dimension (reserve
    /// with [`CholeskyFactor::with_capacity`]): the forward substitution
    /// writes straight into the new row's storage.
    pub fn append(&mut self, cross: &[f64], diag: f64) -> Result<f64, LinalgError> {
        if cross.len() != self.n {
            return Err(LinalgError::DimensionMismatch(format!(
                "append expected {} cross-covariances, got {}",
                self.n,
                cross.len()
            )));
        }
        self.ensure_capacity(self.n + 1);
        let schur = diag - self.substitute_new_row(cross);
        if schur <= 0.0 {
            return Err(LinalgError::NotPositiveDefinite(self.n, schur));
        }
        let sigma = schur.sqrt();
        self.data[self.n * self.cap + self.n] = sigma;
        self.n += 1;
        Ok(sigma)
    }

    /// Append with jitter escalation on the diagonal (for numerically
    /// singular kernel rows, e.g. duplicated arms). Returns `(σ, jitter)`.
    pub fn append_jittered(
        &mut self,
        cross: &[f64],
        diag: f64,
        base_jitter: f64,
    ) -> Result<(f64, f64), LinalgError> {
        match self.append(cross, diag) {
            Ok(s) => return Ok((s, 0.0)),
            Err(LinalgError::DimensionMismatch(m)) => {
                return Err(LinalgError::DimensionMismatch(m))
            }
            Err(_) => {}
        }
        let mut jitter = base_jitter;
        for _ in 0..10 {
            if let Ok(s) = self.append(cross, diag + jitter) {
                return Ok((s, jitter));
            }
            jitter *= 10.0;
        }
        Err(LinalgError::NotPositiveDefinite(self.n, diag))
    }

    /// Append one row/column like [`CholeskyFactor::append_jittered`],
    /// but guarantee the new diagonal pivot is at least `min_pivot`
    /// (escalating the jitter from `base_jitter` by powers of ten until
    /// the Schur complement clears `min_pivot²`). This is the scheduler
    /// hot path's NaN guard: a pivot that merely squeaks past zero (e.g.
    /// 1e-300 from a duplicated arm) would make the posterior update's
    /// `acc / ltt` division overflow into ±∞ and poison every arm's mean
    /// with NaN. Always succeeds on finite inputs; returns `(σ, jitter)`.
    pub fn append_jittered_min_pivot(
        &mut self,
        cross: &[f64],
        diag: f64,
        base_jitter: f64,
        min_pivot: f64,
    ) -> Result<(f64, f64), LinalgError> {
        if cross.len() != self.n {
            // pallas-lint: allow(R6) — cold error path: the format! only runs when the caller hands a mis-sized cross-covariance slice, which aborts the observation instead of entering the hot loop.
            return Err(LinalgError::DimensionMismatch(format!(
                "append expected {} cross-covariances, got {}",
                self.n,
                cross.len()
            )));
        }
        // w = L⁻¹ cross, substituted in place into the new row's storage
        // (the jitter only perturbs the new diagonal entry, so w is
        // independent of it and never needs recomputing).
        self.ensure_capacity(self.n + 1);
        let schur0 = diag - self.substitute_new_row(cross);
        if !schur0.is_finite() {
            return Err(LinalgError::NotPositiveDefinite(self.n, schur0));
        }
        // Adding `jitter` to the diagonal shifts the Schur complement by
        // exactly `jitter`, so the needed jitter is computable directly —
        // rounded up onto the same ×10 escalation ladder `append_jittered`
        // walks, for bit-compatibility with the historical behaviour.
        let floor = min_pivot * min_pivot;
        let jitter = if schur0 >= floor {
            0.0
        } else {
            let needed = floor - schur0;
            // Cap the escalation at 10^10 × base (the historical 10-step
            // ladder's reach): a Schur complement this far below zero is
            // a genuinely non-PSD prior, not numerical noise, and must
            // fail loudly instead of quietly fabricating a posterior.
            let cap = base_jitter.max(f64::MIN_POSITIVE) * 1e10;
            if needed > cap {
                return Err(LinalgError::NotPositiveDefinite(self.n, schur0));
            }
            let mut j = base_jitter.max(f64::MIN_POSITIVE);
            while j < needed {
                j *= 10.0;
            }
            j
        };
        let sigma = (schur0 + jitter).sqrt();
        self.data[self.n * self.cap + self.n] = sigma;
        self.n += 1;
        Ok((sigma, jitter))
    }

    /// Solve `A x = b` with the current factor.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        debug_assert_eq!(b.len(), self.n);
        let y = self.solve_lower(b);
        self.solve_lower_t(&y)
    }

    /// Forward substitution `L y = b`.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let row = &self.data[i * self.cap..i * self.cap + i + 1];
            let mut sum = b[i];
            for k in 0..i {
                sum = row[k].mul_add(-y[k], sum);
            }
            y[i] = sum / row[i];
        }
        y
    }

    /// Backward substitution `Lᵀ x = y`.
    pub fn solve_lower_t(&self, y: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.n];
        for i in (0..self.n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..self.n {
                sum = self.data[k * self.cap + i].mul_add(-x[k], sum);
            }
            x[i] = sum / self.data[i * self.cap + i];
        }
        x
    }

    /// `log det A`.
    pub fn logdet(&self) -> f64 {
        (0..self.n).map(|i| self.data[i * self.cap + i].ln()).sum::<f64>() * 2.0
    }
}

impl Default for CholeskyFactor {
    fn default() -> Self {
        Self::new()
    }
}

/// Matrix–vector product `A x`.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.cols(), x.len());
    let mut out = vec![0.0; a.rows()];
    for i in 0..a.rows() {
        let row = a.row(i);
        let mut acc = 0.0;
        for (r, v) in row.iter().zip(x.iter()) {
            acc += r * v;
        }
        out[i] = acc;
    }
    out
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

/// Extract the principal submatrix of `a` indexed by `idx` (rows & cols).
pub fn principal_submatrix(a: &Mat, idx: &[usize]) -> Mat {
    let mut out = Mat::zeros(idx.len(), idx.len());
    for (i, &ri) in idx.iter().enumerate() {
        for (j, &cj) in idx.iter().enumerate() {
            out[(i, j)] = a[(ri, cj)];
        }
    }
    out
}

/// In-place lower Cholesky factorization of a symmetric positive-definite
/// matrix stored as a flat row-major `n × n` slice: on success `a` holds
/// `L` (with the strict upper triangle zeroed) such that the original
/// matrix equals `L Lᵀ`.
///
/// This is the allocation-free twin of [`cholesky`] for preallocated flat
/// storage — the sharded GP re-factors its `m × m` coupling matrix
/// `M = I + ρT` on every observation, and the scheduler hot path must not
/// allocate (see `rust/tests/alloc_counter.rs`), so the factorization has
/// to happen in the caller's scratch buffer. Inner products use
/// `f64::mul_add` like every other factorization here, so results are
/// bit-identical to [`cholesky`] on the same input.
pub fn cholesky_lower_in_place(a: &mut [f64], n: usize) -> Result<(), LinalgError> {
    if a.len() != n * n {
        // pallas-lint: allow(R6) — cold error path: the format! only runs on a mis-sized scratch buffer, which aborts the factorization instead of entering the hot loop.
        return Err(LinalgError::DimensionMismatch(format!(
            "cholesky_lower_in_place needs n*n = {} storage, got {}",
            n * n,
            a.len()
        )));
    }
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum = a[i * n + k].mul_add(-a[j * n + k], sum);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(LinalgError::NotPositiveDefinite(i, sum));
                }
                a[i * n + i] = sum.sqrt();
            } else {
                a[i * n + j] = sum / a[j * n + j];
            }
        }
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Maximum absolute difference between two matrices (test helper).
pub fn max_abs_diff(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.rows(), b.rows());
    assert_eq!(a.cols(), b.cols());
    let mut m: f64 = 0.0;
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            m = m.max((a[(i, j)] - b[(i, j)]).abs());
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        // A = B Bᵀ + n·I is SPD.
        let mut rng = Rng::new(seed);
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += b[(i, k)] * b[(j, k)];
                }
                a[(i, j)] = acc + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    /// Miri interprets ~100× slower than native: shrink the O(n³) test
    /// dims so the nightly Miri job stays inside its budget. The asserts
    /// are dimension-generic, so the shrunken runs check the same
    /// invariants on smaller instances.
    fn dim(native: usize) -> usize {
        if cfg!(miri) { native.min(6) } else { native }
    }

    #[test]
    fn cholesky_reconstructs() {
        for n in [1, 2, 3, 5, 17, 40] {
            let n = dim(n);
            let a = random_spd(n, 100 + n as u64);
            let l = cholesky(&a).unwrap();
            // L Lᵀ == A
            let mut rec = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += l[(i, k)] * l[(j, k)];
                    }
                    rec[(i, j)] = acc;
                }
            }
            assert!(max_abs_diff(&a, &rec) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(matches!(cholesky(&a), Err(LinalgError::NotPositiveDefinite(_, _))));
    }

    #[test]
    fn cholesky_rejects_non_square() {
        let a = Mat::zeros(2, 3);
        assert!(matches!(cholesky(&a), Err(LinalgError::DimensionMismatch(_))));
    }

    #[test]
    fn jittered_recovers_semidefinite() {
        // Rank-1 PSD matrix: [[1,1],[1,1]] needs jitter.
        let a = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let (l, jitter) = cholesky_jittered(&a, 1e-10).unwrap();
        assert!(jitter > 0.0);
        assert!(l[(0, 0)] > 0.0 && l[(1, 1)] > 0.0);
    }

    #[test]
    fn solve_matches_direct() {
        let n = dim(12);
        let a = random_spd(n, 7);
        let l = cholesky(&a).unwrap();
        let mut rng = Rng::new(8);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x = cholesky_solve(&l, &b);
        let ax = matvec(&a, &x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-8, "residual at {i}");
        }
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let n = dim(9);
        let a = random_spd(n, 21);
        let l = cholesky(&a).unwrap();
        let mut rng = Rng::new(22);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = solve_lower(&l, &b);
        // L y == b
        for i in 0..n {
            let mut acc = 0.0;
            for k in 0..=i {
                acc += l[(i, k)] * y[k];
            }
            assert!((acc - b[i]).abs() < 1e-10);
        }
        let x = solve_lower_transpose(&l, &y);
        // Lᵀ x == y
        for i in 0..n {
            let mut acc = 0.0;
            for k in i..n {
                acc += l[(k, i)] * x[k];
            }
            assert!((acc - y[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn logdet_matches_known() {
        // diag(4, 9) → det = 36, logdet = ln 36
        let a = Mat::from_rows(&[&[4.0, 0.0], &[0.0, 9.0]]);
        let l = cholesky(&a).unwrap();
        assert!((logdet_from_cholesky(&l) - 36f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn incremental_matches_batch() {
        let n = dim(20);
        let a = random_spd(n, 55);
        let batch = cholesky(&a).unwrap();
        let mut inc = CholeskyFactor::new();
        for t in 0..n {
            let cross: Vec<f64> = (0..t).map(|k| a[(t, k)]).collect();
            inc.append(&cross, a[(t, t)]).unwrap();
        }
        assert_eq!(inc.dim(), n);
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (inc.factor()[(i, j)] - batch[(i, j)]).abs() < 1e-9,
                    "mismatch at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn incremental_sigma_is_conditional_std() {
        // σ̂ returned by append must equal sqrt(det(K_S)/det(K_S')) — the
        // Schur complement identity used in the paper's Lemma 5.
        let n = dim(8);
        let a = random_spd(n, 77);
        let mut inc = CholeskyFactor::new();
        for t in 0..n {
            let cross: Vec<f64> = (0..t).map(|k| a[(t, k)]).collect();
            let sigma = inc.append(&cross, a[(t, t)]).unwrap();
            let idx_s: Vec<usize> = (0..=t).collect();
            let det_s = {
                let sub = principal_submatrix(&a, &idx_s);
                logdet_from_cholesky(&cholesky(&sub).unwrap()).exp()
            };
            let det_sp = if t == 0 {
                1.0
            } else {
                let idx_sp: Vec<usize> = (0..t).collect();
                let sub = principal_submatrix(&a, &idx_sp);
                logdet_from_cholesky(&cholesky(&sub).unwrap()).exp()
            };
            let expected = (det_s / det_sp).sqrt();
            assert!((sigma - expected).abs() < 1e-7 * expected.max(1.0), "t={t}");
        }
    }

    #[test]
    fn incremental_solve_matches_batch_solve() {
        let n = dim(15);
        let a = random_spd(n, 91);
        let mut inc = CholeskyFactor::new();
        for t in 0..n {
            let cross: Vec<f64> = (0..t).map(|k| a[(t, k)]).collect();
            inc.append(&cross, a[(t, t)]).unwrap();
        }
        let mut rng = Rng::new(92);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x1 = inc.solve(&b);
        let l = cholesky(&a).unwrap();
        let x2 = cholesky_solve(&l, &b);
        for i in 0..n {
            assert!((x1[i] - x2[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn append_rejects_duplicate_without_jitter() {
        let mut inc = CholeskyFactor::new();
        inc.append(&[], 1.0).unwrap();
        // Perfectly correlated new variable → Schur complement 0.
        let err = inc.append(&[1.0], 1.0);
        assert!(err.is_err());
        // Jittered append succeeds.
        let (sigma, jitter) = inc.append_jittered(&[1.0], 1.0, 1e-9).unwrap();
        assert!(jitter > 0.0);
        assert!(sigma > 0.0 && sigma < 1e-3);
    }

    #[test]
    fn min_pivot_append_matches_plain_append_when_healthy() {
        // Well-conditioned input: the guard must be a no-op (zero jitter,
        // bit-identical factor to the plain append path).
        let n = dim(10);
        let a = random_spd(n, 314);
        let mut plain = CholeskyFactor::new();
        let mut guarded = CholeskyFactor::new();
        for t in 0..n {
            let cross: Vec<f64> = (0..t).map(|k| a[(t, k)]).collect();
            let s1 = plain.append(&cross, a[(t, t)]).unwrap();
            let (s2, jitter) = guarded
                .append_jittered_min_pivot(&cross, a[(t, t)], 1e-10, 1e-8)
                .unwrap();
            assert_eq!(jitter, 0.0, "healthy pivot must not be jittered (t={t})");
            assert_eq!(s1, s2, "t={t}");
        }
        for i in 0..n {
            for j in 0..=i {
                assert_eq!(plain.get(i, j), guarded.get(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn min_pivot_append_floors_degenerate_pivot() {
        // Perfectly correlated second variable: Schur complement 0, which
        // the plain append rejects; the guarded append floors the pivot.
        let mut inc = CholeskyFactor::new();
        inc.append(&[], 1.0).unwrap();
        let (sigma, jitter) = inc.append_jittered_min_pivot(&[1.0], 1.0, 1e-10, 1e-8).unwrap();
        assert!(jitter > 0.0);
        assert!(sigma >= 1e-8, "pivot must clear the floor, got {sigma}");
        assert!(sigma < 1e-3, "jitter escalation should stay minimal, got {sigma}");
        // Solves stay finite through the floored pivot.
        let x = inc.solve(&[1.0, 1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn into_solves_match_allocating_forms_bit_for_bit() {
        // The `_into` variants are the same arithmetic as the allocating
        // forms (which delegate to them) — and they must reuse capacity,
        // not reallocate, when called repeatedly at the same size.
        let n = dim(11);
        let a = random_spd(n, 33);
        let l = cholesky(&a).unwrap();
        let mut rng = Rng::new(34);
        let mut scratch = Vec::new();
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..4 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            solve_lower_into(&l, &b, &mut y);
            assert_eq!(y, solve_lower(&l, &b));
            cholesky_solve_into(&l, &b, &mut scratch, &mut x);
            assert_eq!(x, cholesky_solve(&l, &b));
            let ptr_before = (scratch.as_ptr(), x.as_ptr(), y.as_ptr());
            solve_lower_into(&l, &b, &mut y);
            cholesky_solve_into(&l, &b, &mut scratch, &mut x);
            assert_eq!(ptr_before, (scratch.as_ptr(), x.as_ptr(), y.as_ptr()), "buffers must be reused");
        }
    }

    #[test]
    fn preallocated_append_does_not_relayout() {
        // with_capacity(n) must make every append write in place (the
        // zero-allocation contract the GP hot path relies on).
        let n = dim(12);
        let a = random_spd(n, 66);
        let mut inc = CholeskyFactor::with_capacity(n);
        let batch = cholesky(&a).unwrap();
        for t in 0..n {
            let cross: Vec<f64> = (0..t).map(|k| a[(t, k)]).collect();
            inc.append(&cross, a[(t, t)]).unwrap();
        }
        for i in 0..n {
            for j in 0..=i {
                assert!((inc.get(i, j) - batch[(i, j)]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn prop_incremental_factor_matches_batch_on_random_spd() {
        // Case count comes from MMGPEI_PROP_CASES (the nightly Miri job
        // sets it to 4); each case draws a fresh SPD instance.
        crate::testutil::check("incremental cholesky matches batch", |rng| {
            let n = dim(7);
            let a = crate::testutil::gen::spd(rng, n);
            let batch = cholesky(&a).unwrap();
            let mut inc = CholeskyFactor::new();
            for t in 0..n {
                let cross: Vec<f64> = (0..t).map(|k| a[(t, k)]).collect();
                inc.append(&cross, a[(t, t)]).unwrap();
            }
            for i in 0..n {
                for j in 0..=i {
                    assert!((inc.get(i, j) - batch[(i, j)]).abs() < 1e-8, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn principal_submatrix_picks_entries() {
        let a = Mat::from_rows(&[&[1., 2., 3.], &[2., 5., 6.], &[3., 6., 9.]]);
        let s = principal_submatrix(&a, &[0, 2]);
        assert_eq!(s[(0, 0)], 1.0);
        assert_eq!(s[(0, 1)], 3.0);
        assert_eq!(s[(1, 0)], 3.0);
        assert_eq!(s[(1, 1)], 9.0);
    }

    #[test]
    fn dot_and_matvec() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(matvec(&a, &[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn in_place_cholesky_matches_batch_bitwise() {
        for n in [1, 2, 5, 12] {
            let n = dim(n);
            let a = random_spd(n, 900 + n as u64);
            let l = cholesky(&a).unwrap();
            let mut flat = vec![0.0; n * n];
            for i in 0..n {
                flat[i * n..(i + 1) * n].copy_from_slice(a.row(i));
            }
            cholesky_lower_in_place(&mut flat, n).unwrap();
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(flat[i * n + j].to_bits(), l[(i, j)].to_bits(), "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn in_place_cholesky_rejects_bad_inputs() {
        // Mis-sized storage.
        let mut short = vec![0.0; 3];
        assert!(matches!(cholesky_lower_in_place(&mut short, 2), Err(LinalgError::DimensionMismatch(_))));
        // Indefinite matrix.
        let mut indef = vec![1.0, 2.0, 2.0, 1.0];
        assert!(matches!(cholesky_lower_in_place(&mut indef, 2), Err(LinalgError::NotPositiveDefinite(1, _))));
    }
}
