//! Live serving with **tenant churn**: the leader's event loop gains
//! Arrival/Departure event kinds alongside worker completions.
//!
//! Completions arrive over the worker channel; churn events fire on the
//! wall clock (`schedule time × time_scale` seconds after start) via a
//! `recv_timeout` deadline on the completion channel — the leader wakes
//! for whichever comes first, exactly like the virtual-time loop in
//! `sim::churn` but under real asynchrony. The policy contract is the
//! same: arm retirement is folded into the mask handed to
//! [`Policy::select`]; churn-capable policies apply joins/leaves in
//! place, everything else goes through the from-scratch rebuild
//! (`sim::churn`'s `rebuild_policy`).

use std::collections::VecDeque;
use std::sync::mpsc::{self, RecvTimeoutError};
use std::thread;
use std::time::{Duration, Instant};

use super::{Done, Job, ServeConfig, ServedJob};
use crate::metrics::StepCurve;
use crate::problem::{ArmId, ChurnEventKind, ChurnSchedule, Problem, TenantSet, Truth, UserId};
use crate::sched::{Incumbents, Policy, SchedContext};
use crate::sim::churn::{assert_disjoint_tenancy, enqueue_warm_arms, rebuild_policy};

/// Result of a live churn serving session.
#[derive(Clone, Debug)]
pub struct ChurnServeReport {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub jobs: Vec<ServedJob>,
    /// Average gap over the currently active tenants, in wall seconds.
    pub inst_regret: StepCurve,
    /// Per-tenant regret (`∫ gap` over active windows, wall seconds).
    pub per_user_regret: Vec<f64>,
    /// Wall-clock join-to-first-dispatch latency per tenant (`None` if a
    /// tenant was never served).
    pub join_latency: Vec<Option<Duration>>,
    /// Wall-clock latency of every scheduling decision.
    pub decision_latencies: Vec<Duration>,
    /// Total session duration.
    pub makespan: Duration,
    /// Churn events served through the rebuild fallback (0 for MM-GP-EI).
    pub n_rebuilds: usize,
}

/// Run a live churn serving session (see the module docs). The schedule
/// is interpreted in cost units: event `time` fires `time × time_scale`
/// wall seconds after start.
pub fn serve_churn(
    problem: &Problem,
    truth: &Truth,
    schedule: &ChurnSchedule,
    factory: &dyn Fn(&Problem) -> Box<dyn Policy>,
    config: &ServeConfig,
) -> ChurnServeReport {
    assert!(config.n_devices >= 1);
    assert!(config.time_scale > 0.0);
    let n_arms = problem.n_arms();
    let n_users = problem.n_users;
    assert!(schedule.n_users_seen() <= n_users);
    assert_disjoint_tenancy(problem);

    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut job_txs = Vec::with_capacity(config.n_devices);
    let mut workers = Vec::with_capacity(config.n_devices);
    for device in 0..config.n_devices {
        let (tx, rx) = mpsc::channel::<Job>();
        let done_tx = done_tx.clone();
        job_txs.push(tx);
        workers.push(thread::spawn(move || {
            while let Ok(job) = rx.recv() {
                thread::sleep(job.sleep);
                if done_tx.send(Done { device, arm: job.arm, z: job.z }).is_err() {
                    break;
                }
            }
        }));
    }
    drop(done_tx);

    let t0 = Instant::now();
    let mut policy = factory(problem);
    // Everyone starts inactive (fresh policy + empty history ≡ rebuilt).
    for u in 0..n_users {
        let _ = policy.user_left(problem, u);
    }
    let mut tenants = TenantSet::none_active(n_users);
    let mut retired = vec![true; n_arms];
    let mut selected = vec![false; n_arms];
    let mut blocked = vec![true; n_arms];
    let mut observed = vec![false; n_arms];
    let mut warm: VecDeque<ArmId> = VecDeque::new();
    let mut history: Vec<(ArmId, f64)> = Vec::new();
    let mut n_rebuilds = 0usize;

    let z_star: Vec<f64> = (0..n_users).map(|u| truth.best_value(problem, u)).collect();
    let empty_ref: Vec<f64> = (0..n_users)
        .map(|u| problem.user_arms[u].iter().map(|&a| truth.z[a]).fold(0.0f64, f64::min))
        .collect();
    let mut incumbents = Incumbents::new(n_users);
    let user_gap = |inc: &Incumbents, u: UserId| -> f64 {
        let b = if inc.has_observation(u) { inc.value(u) } else { empty_ref[u] };
        (z_star[u] - b).max(0.0)
    };
    let avg_active_gap = |inc: &Incumbents, tenants: &TenantSet| -> f64 {
        if tenants.n_active() == 0 {
            0.0
        } else {
            tenants.active_users().map(|u| user_gap(inc, u)).sum::<f64>()
                / tenants.n_active() as f64
        }
    };

    let mut per_user_regret = vec![0.0; n_users];
    let mut arrival_wall = vec![Duration::ZERO; n_users];
    let mut waiting_first_dispatch = vec![false; n_users];
    let mut join_latency: Vec<Option<Duration>> = vec![None; n_users];
    let mut inst_regret = StepCurve::new(0.0);
    let mut t_prev = 0.0f64;
    let mut decision_latencies = Vec::new();
    let mut jobs: Vec<ServedJob> = Vec::with_capacity(n_arms);
    let mut idle: Vec<usize> = Vec::new();
    let mut in_flight = 0usize;

    // Dispatch helper — mirrors `serve`'s, plus the blocked mask, idle
    // parking, and join-latency capture.
    let dispatch = |now: Duration,
                        device: usize,
                        selected: &mut [bool],
                        blocked: &mut [bool],
                        observed: &[bool],
                        warm: &mut VecDeque<ArmId>,
                        policy: &mut dyn Policy,
                        idle: &mut Vec<usize>,
                        waiting: &mut [bool],
                        join_latency: &mut [Option<Duration>],
                        arrival_wall: &[Duration],
                        decision_latencies: &mut Vec<Duration>,
                        in_flight: &mut usize| {
        while let Some(&a) = warm.front() {
            if blocked[a] {
                warm.pop_front();
            } else {
                break;
            }
        }
        let arm = if let Some(a) = warm.pop_front() {
            Some(a)
        } else {
            let ctx =
                SchedContext { problem, selected: blocked, observed, now: now.as_secs_f64() };
            let d0 = Instant::now();
            let pick = policy.select(&ctx);
            decision_latencies.push(d0.elapsed());
            pick
        };
        if let Some(a) = arm {
            assert!(!blocked[a], "policy returned a blocked arm {a}");
            selected[a] = true;
            blocked[a] = true;
            for &u in &problem.arm_users[a] {
                if waiting[u] {
                    waiting[u] = false;
                    join_latency[u] = Some(now.saturating_sub(arrival_wall[u]));
                }
            }
            *in_flight += 1;
            job_txs[device]
                .send(Job {
                    arm: a,
                    sleep: Duration::from_secs_f64(problem.cost[a] * config.time_scale),
                    z: truth.z[a],
                })
                .expect("worker hung up");
        } else {
            idle.push(device);
            idle.sort_unstable();
        }
    };

    let events = schedule.events();
    let mut next_evt = 0usize;

    // Apply every churn event whose wall deadline has passed, integrate
    // regret up to now, and wake idle devices after arrivals. A macro —
    // not a closure — because it reassigns `policy` and touches most of
    // the loop state.
    macro_rules! process_due_events {
        () => {{
            let now = t0.elapsed();
            let now_s = now.as_secs_f64();
            let dt = (now_s - t_prev).max(0.0);
            if dt > 0.0 {
                for u in tenants.active_users() {
                    per_user_regret[u] += user_gap(&incumbents, u) * dt;
                }
            }
            t_prev = now_s;
            let mut any_arrival = false;
            while next_evt < events.len() && events[next_evt].time * config.time_scale <= now_s {
                let e = events[next_evt];
                next_evt += 1;
                match e.kind {
                    ChurnEventKind::Arrival => {
                        if !tenants.activate(e.user) {
                            continue;
                        }
                        if !policy.user_joined(problem, e.user) && !history.is_empty() {
                            n_rebuilds += 1;
                            policy = rebuild_policy(factory, problem, &tenants, &history);
                        }
                        tenants.refresh_retired_for_user(problem, e.user, &mut retired);
                        for &x in &problem.user_arms[e.user] {
                            blocked[x] = selected[x] || retired[x];
                        }
                        enqueue_warm_arms(
                            problem,
                            e.user,
                            config.warm_start_per_user,
                            &selected,
                            &mut warm,
                        );
                        if join_latency[e.user].is_none() {
                            arrival_wall[e.user] = now;
                            waiting_first_dispatch[e.user] = true;
                        }
                        any_arrival = true;
                        if config.verbose {
                            eprintln!("[{now_s:8.3}s] tenant {} joined", e.user);
                        }
                    }
                    ChurnEventKind::Departure => {
                        if !tenants.deactivate(e.user) {
                            continue;
                        }
                        if !policy.user_left(problem, e.user) && !history.is_empty() {
                            n_rebuilds += 1;
                            policy = rebuild_policy(factory, problem, &tenants, &history);
                        }
                        tenants.refresh_retired_for_user(problem, e.user, &mut retired);
                        for &x in &problem.user_arms[e.user] {
                            blocked[x] = selected[x] || retired[x];
                        }
                        waiting_first_dispatch[e.user] = false;
                        if config.verbose {
                            eprintln!("[{now_s:8.3}s] tenant {} left", e.user);
                        }
                    }
                }
            }
            inst_regret.push(now_s, avg_active_gap(&incumbents, &tenants));
            if any_arrival {
                let woken = std::mem::take(&mut idle);
                for d in woken {
                    dispatch(
                        t0.elapsed(),
                        d,
                        &mut selected,
                        &mut blocked,
                        &observed,
                        &mut warm,
                        policy.as_mut(),
                        &mut idle,
                        &mut waiting_first_dispatch,
                        &mut join_latency,
                        &arrival_wall,
                        &mut decision_latencies,
                        &mut in_flight,
                    );
                }
            }
        }};
    }

    // t = 0 cohort, then every device asks for work.
    process_due_events!();
    for device in 0..config.n_devices {
        dispatch(
            t0.elapsed(),
            device,
            &mut selected,
            &mut blocked,
            &observed,
            &mut warm,
            policy.as_mut(),
            &mut idle,
            &mut waiting_first_dispatch,
            &mut join_latency,
            &arrival_wall,
            &mut decision_latencies,
            &mut in_flight,
        );
    }

    loop {
        if in_flight == 0 && next_evt >= events.len() {
            break;
        }
        let msg: Option<Done> = if next_evt < events.len() {
            let deadline = Duration::from_secs_f64(events[next_evt].time * config.time_scale);
            let timeout = deadline.saturating_sub(t0.elapsed());
            match done_rx.recv_timeout(timeout) {
                Ok(d) => Some(d),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        } else {
            match done_rx.recv() {
                Ok(d) => Some(d),
                Err(_) => break,
            }
        };
        match msg {
            None => process_due_events!(),
            Some(done) => {
                in_flight -= 1;
                let finish = t0.elapsed();
                let now_s = finish.as_secs_f64();
                let dt = (now_s - t_prev).max(0.0);
                if dt > 0.0 {
                    for u in tenants.active_users() {
                        per_user_regret[u] += user_gap(&incumbents, u) * dt;
                    }
                }
                t_prev = now_s;
                observed[done.arm] = true;
                policy.observe(problem, done.arm, done.z);
                history.push((done.arm, done.z));
                // Driver-side incumbents fold unconditionally — exactly
                // like the virtual-time loop: the service remembers the
                // best model found for a tenant even if the completion
                // lands after its departure, so a rejoined tenant's gap
                // (and the live KPIs) match `sim::simulate_churn`'s for
                // the same schedule. (Only the *policy's* incumbent is
                // dropped on leave.)
                incumbents.update_arm(problem, done.arm, done.z);
                inst_regret.push(now_s, avg_active_gap(&incumbents, &tenants));
                let run = Duration::from_secs_f64(problem.cost[done.arm] * config.time_scale);
                jobs.push(ServedJob {
                    arm: done.arm,
                    start: finish.saturating_sub(run),
                    finish,
                    z: done.z,
                    device: done.device,
                });
                if config.verbose {
                    eprintln!(
                        "[{now_s:8.3}s] device {} finished arm {} (z = {:.4})",
                        done.device, done.arm, done.z
                    );
                }
                dispatch(
                    t0.elapsed(),
                    done.device,
                    &mut selected,
                    &mut blocked,
                    &observed,
                    &mut warm,
                    policy.as_mut(),
                    &mut idle,
                    &mut waiting_first_dispatch,
                    &mut join_latency,
                    &arrival_wall,
                    &mut decision_latencies,
                    &mut in_flight,
                );
            }
        }
    }

    drop(job_txs);
    for w in workers {
        let _ = w.join();
    }

    ChurnServeReport {
        policy: policy.name(),
        jobs,
        inst_regret,
        per_user_regret,
        join_latency,
        decision_latencies,
        makespan: t0.elapsed(),
        n_rebuilds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ChurnEvent;
    use crate::sched::MmGpEi;

    #[test]
    fn live_churn_serves_arrivals_and_respects_departures() {
        // 2 tenants × 2 cheap arms; tenant 1 joins mid-run. Time scale is
        // kept large relative to scheduling jitter so window checks hold.
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "serve-churn".into(),
            n_users: 2,
            cost: vec![1.0, 1.0, 1.0, 1.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: crate::linalg::Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.7, 0.8, 0.9] };
        let s = ChurnSchedule::new(vec![
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 3.0, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 20.0, user: 0, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 20.0, user: 1, kind: ChurnEventKind::Departure },
        ]);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let report = serve_churn(
            &p,
            &t,
            &s,
            &factory,
            &ServeConfig { n_devices: 2, time_scale: 0.01, warm_start_per_user: 1, verbose: false },
        );
        // Every arm runs (both tenants fully served before the exits).
        let mut arms: Vec<_> = report.jobs.iter().map(|j| j.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3]);
        // Tenant 1's arms must not start before its arrival deadline.
        let arrival = Duration::from_secs_f64(3.0 * 0.01);
        for j in &report.jobs {
            if j.arm >= 2 {
                assert!(
                    j.start + Duration::from_millis(5) >= arrival,
                    "arm {} started {:?} before tenant 1 joined",
                    j.arm,
                    j.start
                );
            }
        }
        assert_eq!(report.n_rebuilds, 0, "MM-GP-EI serves churn in place");
        assert!(report.join_latency[0].is_some() && report.join_latency[1].is_some());
        assert!(report.per_user_regret.iter().all(|&r| r >= 0.0));
        assert!(!report.decision_latencies.is_empty());
    }
}
