//! Live serving with **tenant churn**: the wall-clock churn adapter over
//! the unified engine.
//!
//! Completions arrive over the worker channel; churn events fire on the
//! wall clock (`schedule time × time_scale` seconds after start) via a
//! `recv_timeout` deadline on the completion channel — the leader wakes
//! for whichever comes first, exactly like the virtual-time loop in
//! `sim::simulate_churn` but under real asynchrony. The policy contract
//! is identical because it *is* the same engine: arm retirement folded
//! into the mask handed to [`crate::sched::Policy::select`],
//! churn-capable policies applying joins/leaves in place, everything
//! else rebuilt from scratch.
//!
//! [`serve_churn_deterministic`] runs the very same adapter on the
//! engine's [`MockClock`] — wall-clock semantics, virtual delivery — so
//! the cross-loop parity tests can compare the two adapters bit for bit
//! over one trace (`rust/tests/engine_parity.rs`).

use std::time::Duration;

use super::{jobs_from, ServeConfig, ServedJob};
use crate::config::ExperimentConfig;
use crate::engine::{self, Clock, EngineParams, MockClock, PolicyFactory, PolicyHost, Tenancy, WallClock};
use crate::metrics::StepCurve;
use crate::problem::{ChurnSchedule, Problem, Truth};

/// Result of a live churn serving session.
#[derive(Clone, Debug)]
pub struct ChurnServeReport {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub jobs: Vec<ServedJob>,
    /// Average gap over the currently active tenants, in wall seconds.
    pub inst_regret: StepCurve,
    /// Per-tenant regret (`∫ gap` over active windows, wall seconds).
    pub per_user_regret: Vec<f64>,
    /// Wall-clock join-to-first-dispatch latency per tenant (`None` if a
    /// tenant was never served).
    pub join_latency: Vec<Option<Duration>>,
    /// Wall-clock latency of every scheduling decision.
    pub decision_latencies: Vec<Duration>,
    /// Total session duration (last event offset).
    pub makespan: Duration,
    /// Churn events served through the rebuild fallback (0 for MM-GP-EI).
    pub n_rebuilds: usize,
}

/// Run a live churn serving session (see the module docs). The schedule
/// is interpreted in cost units: event `time` fires `time × time_scale`
/// wall seconds after start.
pub fn serve_churn(
    problem: &Problem,
    truth: &Truth,
    schedule: &ChurnSchedule,
    factory: &PolicyFactory,
    config: &ServeConfig,
) -> ChurnServeReport {
    assert!(config.n_devices >= 1);
    let mut clock = WallClock::spawn(config.n_devices);
    serve_churn_on(problem, truth, schedule, factory, config, &mut clock)
}

/// The wall-clock churn adapter on the engine's deterministic
/// [`MockClock`]: identical code path and report shape as
/// [`serve_churn`], but completions are delivered in exact virtual time
/// — so the run is bit-replayable and directly comparable against
/// `sim::simulate_churn` (the cross-loop parity gate uses exactly this).
pub fn serve_churn_deterministic(
    problem: &Problem,
    truth: &Truth,
    schedule: &ChurnSchedule,
    factory: &PolicyFactory,
    config: &ServeConfig,
) -> ChurnServeReport {
    assert!(config.n_devices >= 1);
    let mut clock = MockClock::new(config.n_devices);
    serve_churn_on(problem, truth, schedule, factory, config, &mut clock)
}

/// The shared adapter body: configure the engine in churn-accounting
/// mode (no horizon — live sessions report what actually ran) and
/// reshape the run into a [`ChurnServeReport`].
fn serve_churn_on(
    problem: &Problem,
    truth: &Truth,
    schedule: &ChurnSchedule,
    factory: &PolicyFactory,
    config: &ServeConfig,
    clock: &mut dyn Clock,
) -> ChurnServeReport {
    assert!(config.n_devices >= 1);
    assert!(config.time_scale > 0.0);
    let fleet = ExperimentConfig::device_fleet(config.n_devices);
    let params = EngineParams {
        problem,
        truth,
        sched_view: None,
        cost_model: None,
        fleet: &fleet,
        tenancy: Tenancy::Churn(schedule),
        warm_start_per_user: config.warm_start_per_user,
        horizon: None,
        stop_at_cutoff: None,
        time_scale: config.time_scale,
        collect_decision_latencies: true,
        faults: None,
        verbose: config.verbose,
    };
    let run = engine::run(&params, PolicyHost::from_factory(factory), clock);
    ChurnServeReport {
        policy: run.policy,
        jobs: jobs_from(&run.observations),
        inst_regret: run.curve,
        per_user_regret: run.per_user_regret,
        join_latency: run
            .join_latency
            .iter()
            .map(|l| l.map(|x| Duration::from_secs_f64(x.max(0.0))))
            .collect(),
        decision_latencies: run.decision_latencies,
        makespan: Duration::from_secs_f64(run.makespan.max(0.0)),
        n_rebuilds: run.n_rebuilds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::{ChurnEvent, ChurnEventKind};
    use crate::sched::{MmGpEi, Policy};

    #[test]
    fn live_churn_serves_arrivals_and_respects_departures() {
        // 2 tenants × 2 cheap arms; tenant 1 joins mid-run. Time scale is
        // kept large relative to scheduling jitter so window checks hold.
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "serve-churn".into(),
            n_users: 2,
            cost: vec![1.0, 1.0, 1.0, 1.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: crate::linalg::Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.7, 0.8, 0.9] };
        let s = ChurnSchedule::new(vec![
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 3.0, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 20.0, user: 0, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 20.0, user: 1, kind: ChurnEventKind::Departure },
        ]);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let report = serve_churn(
            &p,
            &t,
            &s,
            &factory,
            &ServeConfig { n_devices: 2, time_scale: 0.01, warm_start_per_user: 1, verbose: false },
        );
        // Every arm runs (both tenants fully served before the exits).
        let mut arms: Vec<_> = report.jobs.iter().map(|j| j.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3]);
        // Tenant 1's arms must not start before its arrival deadline.
        let arrival = Duration::from_secs_f64(3.0 * 0.01);
        for j in &report.jobs {
            if j.arm >= 2 {
                assert!(
                    j.start + Duration::from_millis(5) >= arrival,
                    "arm {} started {:?} before tenant 1 joined",
                    j.arm,
                    j.start
                );
            }
        }
        assert_eq!(report.n_rebuilds, 0, "MM-GP-EI serves churn in place");
        assert!(report.join_latency[0].is_some() && report.join_latency[1].is_some());
        assert!(report.per_user_regret.iter().all(|&r| r >= 0.0));
        assert!(!report.decision_latencies.is_empty());
    }

    #[test]
    fn deterministic_variant_is_bit_replayable() {
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "serve-churn-det".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 1.5, 0.5],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: crate::linalg::Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.7, 0.8, 0.9] };
        let s = ChurnSchedule::new(vec![
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 1.5, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 9.0, user: 0, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 9.0, user: 1, kind: ChurnEventKind::Departure },
        ]);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let cfg = ServeConfig { n_devices: 2, time_scale: 1.0, warm_start_per_user: 1, verbose: false };
        let a = serve_churn_deterministic(&p, &t, &s, &factory, &cfg);
        let b = serve_churn_deterministic(&p, &t, &s, &factory, &cfg);
        let key = |r: &ChurnServeReport| -> Vec<(usize, usize, Duration)> {
            r.jobs.iter().map(|j| (j.arm, j.device, j.finish)).collect()
        };
        assert_eq!(key(&a), key(&b));
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.per_user_regret), bits(&b.per_user_regret));
        assert_eq!(a.inst_regret, b.inst_regret);
        assert_eq!(a.join_latency, b.join_latency);
    }
}
