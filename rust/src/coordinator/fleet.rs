//! Live serving over an **elastic fleet with fault injection**: the
//! wall-clock fleet adapter over the unified engine.
//!
//! This is the adapter the eager-cancellation rewrite of
//! [`crate::engine::WallClock`] unlocks: device leaves, crashes,
//! deadline kills, and straggler re-dispatches all preempt in-flight
//! jobs, and with condvar-based worker waits the cancelled sleep ends
//! *now* — the device accepts its next job immediately instead of
//! snoozing out the cancelled cost (which corrupted any
//! preemption-heavy wall schedule under the old lazy cancel).
//!
//! Faults come from a validated [`FaultPlan`] (see
//! [`crate::workload::fault_plan`]) interpreted in cost units: an event
//! at plan time `t` fires `t × time_scale` wall seconds after start.
//! Pass [`FaultPlan::empty`] (or rather `None`) for fault-free elastic
//! serving.
//!
//! [`serve_fleet_deterministic`] runs the very same adapter on the
//! engine's [`MockClock`] — wall-clock semantics, virtual delivery — so
//! the cross-loop parity tests can compare it bit for bit against
//! `sim::simulate_faults` over one preemption-heavy fault trace
//! (`rust/tests/engine_parity.rs`).

use std::time::Duration;

use super::{jobs_from, ServeConfig, ServedJob};
use crate::engine::{
    self, Clock, EngineParams, FaultStats, MockClock, PolicyFactory, PolicyHost, Tenancy,
    WallClock,
};
use crate::metrics::StepCurve;
use crate::problem::{DeviceFleet, FaultPlan, Problem, Truth};

/// Result of a live fleet serving session (faulty or fault-free).
#[derive(Clone, Debug)]
pub struct FleetServeReport {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub jobs: Vec<ServedJob>,
    /// Instantaneous regret (average gap over users) in wall seconds.
    pub inst_regret: StepCurve,
    /// Wall-clock latency of every scheduling decision.
    pub decision_latencies: Vec<Duration>,
    /// Total session duration (last event offset).
    pub makespan: Duration,
    /// Jobs cancelled because their device left or crashed mid-run.
    pub n_preemptions: usize,
    /// Per re-dispatched preempted arm: preemption → re-dispatch delay.
    pub requeue_latency: Vec<Duration>,
    /// Fleet/fault events served through the rebuild fallback (0 for
    /// MM-GP-EI).
    pub n_rebuilds: usize,
    /// Fault-path counters (all zero when no plan was injected).
    pub fault_stats: FaultStats,
    /// Arms whose observation actually landed, over all arms.
    pub served_fraction: f64,
}

/// Run a live serving session over an elastic `fleet`, optionally under
/// a fault plan (see the module docs). `config.n_devices` is ignored:
/// the fleet defines the device set.
pub fn serve_fleet(
    problem: &Problem,
    truth: &Truth,
    fleet: &DeviceFleet,
    faults: Option<&FaultPlan>,
    factory: &PolicyFactory,
    config: &ServeConfig,
) -> FleetServeReport {
    let mut clock = WallClock::spawn(fleet.n_devices());
    serve_fleet_on(problem, truth, fleet, faults, factory, config, &mut clock)
}

/// The wall-clock fleet adapter on the engine's deterministic
/// [`MockClock`]: identical code path and report shape as
/// [`serve_fleet`], but completions are delivered in exact virtual time
/// — bit-replayable and directly comparable against
/// `sim::simulate_faults` (the cross-loop parity gate uses exactly
/// this).
pub fn serve_fleet_deterministic(
    problem: &Problem,
    truth: &Truth,
    fleet: &DeviceFleet,
    faults: Option<&FaultPlan>,
    factory: &PolicyFactory,
    config: &ServeConfig,
) -> FleetServeReport {
    let mut clock = MockClock::new(fleet.n_devices());
    serve_fleet_on(problem, truth, fleet, faults, factory, config, &mut clock)
}

/// The shared adapter body: configure the engine in static-tenancy
/// fleet mode with the fault layer armed (or not) and reshape the run
/// into a [`FleetServeReport`].
fn serve_fleet_on(
    problem: &Problem,
    truth: &Truth,
    fleet: &DeviceFleet,
    faults: Option<&FaultPlan>,
    factory: &PolicyFactory,
    config: &ServeConfig,
    clock: &mut dyn Clock,
) -> FleetServeReport {
    assert!(config.time_scale > 0.0);
    let params = EngineParams {
        problem,
        truth,
        sched_view: None,
        cost_model: None,
        fleet,
        tenancy: Tenancy::Static,
        warm_start_per_user: config.warm_start_per_user,
        horizon: None,
        stop_at_cutoff: None,
        time_scale: config.time_scale,
        collect_decision_latencies: true,
        faults,
        verbose: config.verbose,
    };
    let run = engine::run(&params, PolicyHost::from_factory(factory), clock);
    let served_fraction = run.observations.len() as f64 / problem.n_arms() as f64;
    FleetServeReport {
        policy: run.policy,
        jobs: jobs_from(&run.observations),
        inst_regret: run.curve.scaled(1.0 / problem.n_users as f64),
        decision_latencies: run.decision_latencies,
        makespan: Duration::from_secs_f64(run.makespan.max(0.0)),
        n_preemptions: run.n_preemptions,
        requeue_latency: run
            .requeue_latency
            .iter()
            .map(|&x| Duration::from_secs_f64(x.max(0.0)))
            .collect(),
        n_rebuilds: run.n_rebuilds,
        fault_stats: run.fault_stats,
        served_fraction,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::problem::{FaultEvent, FaultKind, RetryPolicy};
    use crate::sched::{MmGpEi, Policy};

    fn tiny() -> (Problem, Truth) {
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "serve-fleet".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 1.0, 2.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.9, 0.4, 0.8] };
        (p, t)
    }

    fn factory(p: &Problem) -> Box<dyn Policy> {
        Box::new(MmGpEi::new(p))
    }

    #[test]
    fn live_fleet_survives_a_preemption_heavy_plan() {
        // Crash/restart cycles on both devices plus a job kill, on the
        // real wall clock. With eager cancellation the whole session is
        // bounded by the virtual makespan × scale, not by the sum of
        // cancelled sleeps.
        let (p, t) = tiny();
        let fleet = DeviceFleet::uniform(2);
        let plan = FaultPlan::new(
            2,
            vec![
                FaultEvent { time: 0.5, device: 0, kind: FaultKind::DeviceCrash },
                FaultEvent { time: 0.6, device: 1, kind: FaultKind::JobFailure },
                FaultEvent { time: 1.5, device: 0, kind: FaultKind::DeviceRestart },
                FaultEvent { time: 2.0, device: 1, kind: FaultKind::Straggler(2.0) },
            ],
            RetryPolicy { deadline_factor: 50.0, ..RetryPolicy::default() },
        );
        let cfg = ServeConfig { n_devices: 2, time_scale: 0.01, warm_start_per_user: 1, verbose: false };
        let report = serve_fleet(&p, &t, &fleet, Some(&plan), &factory, &cfg);
        // Everything is eventually served despite the faults.
        let mut arms: Vec<_> = report.jobs.iter().map(|j| j.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3]);
        assert_eq!(report.served_fraction, 1.0);
        assert_eq!(report.inst_regret.final_value(), 0.0);
        assert_eq!(report.fault_stats.n_crashes, 1);
        assert_eq!(report.fault_stats.n_restarts, 1);
        assert_eq!(report.fault_stats.n_job_failures, 1);
        assert!(report.n_preemptions >= 1, "the crash must preempt the in-flight job");
        assert_eq!(report.n_rebuilds, 0, "MM-GP-EI absorbs fleet/fault events in place");
    }

    #[test]
    fn deterministic_variant_is_bit_replayable_under_faults() {
        let (p, t) = tiny();
        let fleet = DeviceFleet::uniform(2);
        let plan = FaultPlan::new(
            2,
            vec![
                FaultEvent { time: 0.5, device: 0, kind: FaultKind::DeviceCrash },
                FaultEvent { time: 0.7, device: 1, kind: FaultKind::JobFailure },
                FaultEvent { time: 1.2, device: 0, kind: FaultKind::DeviceRestart },
            ],
            RetryPolicy::default(),
        );
        let cfg = ServeConfig { n_devices: 2, time_scale: 1.0, warm_start_per_user: 1, verbose: false };
        let a = serve_fleet_deterministic(&p, &t, &fleet, Some(&plan), &factory, &cfg);
        let b = serve_fleet_deterministic(&p, &t, &fleet, Some(&plan), &factory, &cfg);
        let key = |r: &FleetServeReport| -> Vec<(usize, usize, Duration)> {
            r.jobs.iter().map(|j| (j.arm, j.device, j.finish)).collect()
        };
        assert_eq!(key(&a), key(&b));
        assert_eq!(a.inst_regret, b.inst_regret);
        assert_eq!(a.fault_stats, b.fault_stats);
        assert_eq!(a.requeue_latency, b.requeue_latency);
    }

    #[test]
    fn no_plan_matches_empty_plan_deterministically() {
        // The adapter-level face of the byte-identity gate: `None` and
        // an empty plan are the same fault-free mode.
        let (p, t) = tiny();
        let fleet = DeviceFleet::uniform(2);
        let cfg = ServeConfig { n_devices: 2, time_scale: 1.0, warm_start_per_user: 1, verbose: false };
        let none = serve_fleet_deterministic(&p, &t, &fleet, None, &factory, &cfg);
        let empty_plan = FaultPlan::empty();
        let empty = serve_fleet_deterministic(&p, &t, &fleet, Some(&empty_plan), &factory, &cfg);
        let key = |r: &FleetServeReport| -> Vec<(usize, usize, Duration)> {
            r.jobs.iter().map(|j| (j.arm, j.device, j.finish)).collect()
        };
        assert_eq!(key(&none), key(&empty));
        assert_eq!(none.inst_regret, empty.inst_regret);
        assert_eq!(none.fault_stats, FaultStats::default());
        assert_eq!(empty.fault_stats, FaultStats::default());
    }
}
