//! Live serving coordinator: the paper's service deployed as a real
//! multi-threaded leader/worker system (wall-clock time, real
//! asynchrony) — the **wall-clock adapters** over the unified scheduling
//! engine ([`crate::engine`]), as opposed to the deterministic
//! virtual-time adapters in [`crate::sim`].
//!
//! Topology: the **leader** (caller thread) owns the policy — including a
//! PJRT-backed [`crate::runtime::XlaBackend`], which is not thread-safe —
//! and the regret accounting. Each **device** is a worker thread with its
//! own job channel (spawned by [`crate::engine::WallClock`]); running a
//! model is simulated by sleeping `c(x) × time_scale` seconds (the
//! substitution for real training, see DESIGN.md §3: regret depends only
//! on the schedule). Completions flow back over a shared channel; every
//! completion triggers one scheduling decision, exactly like
//! Algorithm 1's "while there is a device available".
//!
//! The report includes per-decision latencies — the number that must stay
//! far below `min c(x) × time_scale` for the scheduler never to become
//! the bottleneck (§Perf L3 target).
//!
//! Three wall-clock adapters share the engine: [`serve`] (static
//! fleet), [`serve_churn`] (tenant arrivals/departures), and
//! [`serve_fleet`] (elastic fleets with optional fault injection —
//! viable live because [`crate::engine::WallClock`] cancellation is
//! eager: a preempted worker wakes from its condvar wait immediately
//! instead of sleeping out the cancelled job).

mod churn;
mod fleet;

pub use churn::{serve_churn, serve_churn_deterministic, ChurnServeReport};
pub use fleet::{serve_fleet, serve_fleet_deterministic, FleetServeReport};

use std::time::Duration;

use crate::config::ExperimentConfig;
use crate::engine::{self, EngineParams, Observation, PolicyHost, Tenancy, WallClock};
use crate::metrics::StepCurve;
use crate::problem::{ArmId, Problem, Truth};
use crate::sched::Policy;

/// Serving parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Number of device worker threads.
    pub n_devices: usize,
    /// Wall-clock seconds per abstract cost unit.
    pub time_scale: f64,
    /// Warm-start arms per user (paper protocol: 2).
    pub warm_start_per_user: usize,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { n_devices: 2, time_scale: 0.005, warm_start_per_user: 2, verbose: false }
    }
}

/// One served job in the report.
#[derive(Clone, Debug)]
pub struct ServedJob {
    /// Arm that ran.
    pub arm: ArmId,
    /// Dispatch offset from serve start.
    pub start: Duration,
    /// Completion offset from serve start.
    pub finish: Duration,
    /// Revealed performance.
    pub z: f64,
    /// Worker that ran it.
    pub device: usize,
}

/// Convert engine observations (wall seconds) into served-job records.
pub(crate) fn jobs_from(observations: &[Observation]) -> Vec<ServedJob> {
    observations
        .iter()
        .map(|o| ServedJob {
            arm: o.arm,
            start: Duration::from_secs_f64(o.start.max(0.0)),
            finish: Duration::from_secs_f64(o.finish.max(0.0)),
            z: o.z,
            device: o.device,
        })
        .collect()
}

/// Result of a serve session.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub jobs: Vec<ServedJob>,
    /// Instantaneous regret over wall-clock seconds.
    pub inst_regret: StepCurve,
    /// Wall-clock latency of every scheduling decision.
    pub decision_latencies: Vec<Duration>,
    /// Total session duration (last completion offset).
    pub makespan: Duration,
}

impl ServeReport {
    /// Max decision latency (the L3 §Perf headline).
    pub fn max_decision_latency(&self) -> Duration {
        self.decision_latencies.iter().max().copied().unwrap_or_default()
    }

    /// Mean decision latency.
    pub fn mean_decision_latency(&self) -> Duration {
        if self.decision_latencies.is_empty() {
            return Duration::ZERO;
        }
        self.decision_latencies.iter().sum::<Duration>() / self.decision_latencies.len() as u32
    }
}

/// Run a live serving session of `policy` over `(problem, truth)`.
pub fn serve(
    problem: &Problem,
    truth: &Truth,
    policy: &mut dyn Policy,
    config: &ServeConfig,
) -> ServeReport {
    assert!(config.n_devices >= 1);
    assert!(config.time_scale > 0.0);
    let fleet = ExperimentConfig::device_fleet(config.n_devices);
    let mut clock = WallClock::spawn(config.n_devices);
    let params = EngineParams {
        problem,
        truth,
        sched_view: None,
        cost_model: None,
        fleet: &fleet,
        tenancy: Tenancy::Static,
        warm_start_per_user: config.warm_start_per_user,
        horizon: None,
        stop_at_cutoff: None,
        time_scale: config.time_scale,
        collect_decision_latencies: true,
        faults: None,
        verbose: config.verbose,
    };
    let run = engine::run(&params, PolicyHost::borrowed(policy), &mut clock);
    drop(clock); // hang up the job channels and join the workers
    ServeReport {
        policy: run.policy,
        jobs: jobs_from(&run.observations),
        inst_regret: run.curve.scaled(1.0 / problem.n_users as f64),
        decision_latencies: run.decision_latencies,
        makespan: Duration::from_secs_f64(run.makespan.max(0.0)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::sched::MmGpEi;

    fn tiny() -> (Problem, Truth) {
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "serve-test".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 1.0, 2.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.9, 0.4, 0.8] };
        (p, t)
    }

    #[test]
    fn serves_all_arms_and_reaches_zero_regret() {
        let (p, t) = tiny();
        let mut pol = MmGpEi::new(&p);
        let report = serve(
            &p,
            &t,
            &mut pol,
            &ServeConfig { n_devices: 2, time_scale: 0.002, warm_start_per_user: 1, verbose: false },
        );
        assert_eq!(report.jobs.len(), 4);
        assert_eq!(report.inst_regret.final_value(), 0.0);
        let mut arms: Vec<_> = report.jobs.iter().map(|j| j.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3]);
    }

    #[test]
    fn decision_latencies_recorded() {
        let (p, t) = tiny();
        let mut pol = MmGpEi::new(&p);
        let report = serve(
            &p,
            &t,
            &mut pol,
            &ServeConfig { n_devices: 1, time_scale: 0.001, warm_start_per_user: 0, verbose: false },
        );
        assert!(!report.decision_latencies.is_empty());
        assert!(report.mean_decision_latency() <= report.max_decision_latency());
    }

    #[test]
    fn wall_clock_respects_costs_roughly() {
        let (p, t) = tiny();
        let mut pol = MmGpEi::new(&p);
        let scale = 0.004;
        let report = serve(
            &p,
            &t,
            &mut pol,
            &ServeConfig { n_devices: 1, time_scale: scale, warm_start_per_user: 0, verbose: false },
        );
        // Sequential: makespan ≳ Σc × scale.
        let total: f64 = p.cost.iter().sum();
        assert!(report.makespan.as_secs_f64() >= total * scale * 0.9);
    }

    #[test]
    fn parallel_devices_shorten_makespan() {
        let (p, t) = tiny();
        let run = |m: usize| {
            let mut pol = MmGpEi::new(&p);
            serve(
                &p,
                &t,
                &mut pol,
                &ServeConfig {
                    n_devices: m,
                    time_scale: 0.01,
                    warm_start_per_user: 0,
                    verbose: false,
                },
            )
            .makespan
        };
        let m1 = run(1);
        let m4 = run(4);
        assert!(
            m4.as_secs_f64() < m1.as_secs_f64() * 0.8,
            "4 devices {:?} should beat 1 device {:?}",
            m4,
            m1
        );
    }
}
