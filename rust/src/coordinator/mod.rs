//! Live serving coordinator: the paper's service deployed as a real
//! multi-threaded leader/worker system (wall-clock time, real
//! asynchrony), as opposed to the deterministic virtual-time simulator
//! in [`crate::sim`].
//!
//! Topology: the **leader** (caller thread) owns the policy — including a
//! PJRT-backed [`crate::runtime::XlaBackend`], which is not thread-safe —
//! and the regret accounting. Each **device** is a worker thread with its
//! own job channel; running a model is simulated by sleeping
//! `c(x) × time_scale` seconds (the substitution for real training, see
//! DESIGN.md §3: regret depends only on the schedule). Completions flow
//! back over a shared channel; every completion triggers one scheduling
//! decision, exactly like Algorithm 1's "while there is a device
//! available".
//!
//! The report includes per-decision latencies — the number that must stay
//! far below `min c(x) × time_scale` for the scheduler never to become
//! the bottleneck (§Perf L3 target).

mod churn;

pub use churn::{serve_churn, ChurnServeReport};

use std::collections::VecDeque;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::metrics::StepCurve;
use crate::problem::{ArmId, Problem, Truth};
use crate::sched::{Incumbents, Policy, SchedContext};

/// Serving parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Number of device worker threads.
    pub n_devices: usize,
    /// Wall-clock seconds per abstract cost unit.
    pub time_scale: f64,
    /// Warm-start arms per user (paper protocol: 2).
    pub warm_start_per_user: usize,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { n_devices: 2, time_scale: 0.005, warm_start_per_user: 2, verbose: false }
    }
}

/// One served job in the report.
#[derive(Clone, Debug)]
pub struct ServedJob {
    /// Arm that ran.
    pub arm: ArmId,
    /// Dispatch offset from serve start.
    pub start: Duration,
    /// Completion offset from serve start.
    pub finish: Duration,
    /// Revealed performance.
    pub z: f64,
    /// Worker that ran it.
    pub device: usize,
}

/// Result of a serve session.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub jobs: Vec<ServedJob>,
    /// Instantaneous regret over wall-clock seconds.
    pub inst_regret: StepCurve,
    /// Wall-clock latency of every scheduling decision.
    pub decision_latencies: Vec<Duration>,
    /// Total session duration.
    pub makespan: Duration,
}

impl ServeReport {
    /// Max decision latency (the L3 §Perf headline).
    pub fn max_decision_latency(&self) -> Duration {
        self.decision_latencies.iter().max().copied().unwrap_or_default()
    }

    /// Mean decision latency.
    pub fn mean_decision_latency(&self) -> Duration {
        if self.decision_latencies.is_empty() {
            return Duration::ZERO;
        }
        self.decision_latencies.iter().sum::<Duration>() / self.decision_latencies.len() as u32
    }
}

/// Job message to a device worker. Shared with the churn loop
/// (`coordinator::churn`).
pub(crate) struct Job {
    pub(crate) arm: ArmId,
    pub(crate) sleep: Duration,
    pub(crate) z: f64,
}

/// Completion message back to the leader.
pub(crate) struct Done {
    pub(crate) device: usize,
    pub(crate) arm: ArmId,
    pub(crate) z: f64,
}

/// Run a live serving session of `policy` over `(problem, truth)`.
pub fn serve(
    problem: &Problem,
    truth: &Truth,
    policy: &mut dyn Policy,
    config: &ServeConfig,
) -> ServeReport {
    assert!(config.n_devices >= 1);
    assert!(config.time_scale > 0.0);
    let n_arms = problem.n_arms();
    let n_users = problem.n_users;

    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut job_txs = Vec::with_capacity(config.n_devices);
    let mut workers = Vec::with_capacity(config.n_devices);
    for device in 0..config.n_devices {
        let (tx, rx) = mpsc::channel::<Job>();
        let done_tx = done_tx.clone();
        job_txs.push(tx);
        workers.push(thread::spawn(move || {
            // Device worker: "train" each model by sleeping its cost,
            // then report the observed performance.
            while let Ok(job) = rx.recv() {
                thread::sleep(job.sleep);
                if done_tx.send(Done { device, arm: job.arm, z: job.z }).is_err() {
                    break; // leader gone
                }
            }
        }));
    }
    drop(done_tx);

    let t0 = Instant::now();
    let mut selected = vec![false; n_arms];
    let mut observed = vec![false; n_arms];
    let mut warm: VecDeque<ArmId> = problem.warm_start_arms(config.warm_start_per_user).into();
    // Option-based incumbents with the per-user empty reference — same
    // accounting as `sim` (fixes silently-vanishing regret for negative-
    // valued optima; byte-identical for the paper's non-negative tables).
    let z_star: Vec<f64> = (0..n_users).map(|u| truth.best_value(problem, u)).collect();
    let empty_ref: Vec<f64> = (0..n_users)
        .map(|u| problem.user_arms[u].iter().map(|&a| truth.z[a]).fold(0.0f64, f64::min))
        .collect();
    let mut incumbents = Incumbents::new(n_users);
    let gap_avg = |inc: &Incumbents| -> f64 {
        z_star
            .iter()
            .zip(&empty_ref)
            .enumerate()
            .map(|(u, (&s, &e))| {
                let b = if inc.has_observation(u) { inc.value(u) } else { e };
                (s - b).max(0.0)
            })
            .sum::<f64>()
            / n_users as f64
    };
    let mut inst_regret = StepCurve::new(gap_avg(&incumbents));
    let mut decision_latencies = Vec::new();
    let mut jobs = Vec::with_capacity(n_arms);
    let mut in_flight = 0usize;

    let dispatch = |device: usize,
                        selected: &mut Vec<bool>,
                        observed: &[bool],
                        warm: &mut VecDeque<ArmId>,
                        policy: &mut dyn Policy,
                        decision_latencies: &mut Vec<Duration>,
                        in_flight: &mut usize| {
        while let Some(&a) = warm.front() {
            if selected[a] {
                warm.pop_front();
            } else {
                break;
            }
        }
        let arm = if let Some(a) = warm.pop_front() {
            Some(a)
        } else {
            let now = t0.elapsed().as_secs_f64();
            let ctx = SchedContext { problem, selected, observed, now };
            let d0 = Instant::now();
            let pick = policy.select(&ctx);
            decision_latencies.push(d0.elapsed());
            pick
        };
        if let Some(a) = arm {
            assert!(!selected[a], "policy returned already-selected arm {a}");
            selected[a] = true;
            *in_flight += 1;
            job_txs[device]
                .send(Job {
                    arm: a,
                    sleep: Duration::from_secs_f64(problem.cost[a] * config.time_scale),
                    z: truth.z[a],
                })
                .expect("worker hung up");
        }
    };

    for device in 0..config.n_devices {
        dispatch(
            device,
            &mut selected,
            &observed,
            &mut warm,
            policy,
            &mut decision_latencies,
            &mut in_flight,
        );
    }

    while in_flight > 0 {
        let done = done_rx.recv().expect("all workers died");
        in_flight -= 1;
        let finish = t0.elapsed();
        observed[done.arm] = true;
        policy.observe(problem, done.arm, done.z);
        incumbents.update_arm(problem, done.arm, done.z);
        inst_regret.push(finish.as_secs_f64(), gap_avg(&incumbents));
        jobs.push(ServedJob {
            arm: done.arm,
            start: Duration::ZERO, // filled below from cost
            finish,
            z: done.z,
            device: done.device,
        });
        if let Some(last) = jobs.last_mut() {
            let run = Duration::from_secs_f64(problem.cost[last.arm] * config.time_scale);
            last.start = finish.saturating_sub(run);
        }
        if config.verbose {
            eprintln!(
                "[{:8.3}s] device {} finished arm {} (z = {:.4}); avg regret {:.4}",
                finish.as_secs_f64(),
                done.device,
                done.arm,
                done.z,
                gap_avg(&incumbents)
            );
        }
        dispatch(
            done.device,
            &mut selected,
            &observed,
            &mut warm,
            policy,
            &mut decision_latencies,
            &mut in_flight,
        );
    }

    // Shut workers down.
    drop(job_txs);
    for w in workers {
        let _ = w.join();
    }

    ServeReport {
        policy: policy.name(),
        jobs,
        inst_regret,
        decision_latencies,
        makespan: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::sched::MmGpEi;

    fn tiny() -> (Problem, Truth) {
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "serve-test".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 1.0, 2.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.9, 0.4, 0.8] };
        (p, t)
    }

    #[test]
    fn serves_all_arms_and_reaches_zero_regret() {
        let (p, t) = tiny();
        let mut pol = MmGpEi::new(&p);
        let report = serve(
            &p,
            &t,
            &mut pol,
            &ServeConfig { n_devices: 2, time_scale: 0.002, warm_start_per_user: 1, verbose: false },
        );
        assert_eq!(report.jobs.len(), 4);
        assert_eq!(report.inst_regret.final_value(), 0.0);
        let mut arms: Vec<_> = report.jobs.iter().map(|j| j.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3]);
    }

    #[test]
    fn decision_latencies_recorded() {
        let (p, t) = tiny();
        let mut pol = MmGpEi::new(&p);
        let report = serve(
            &p,
            &t,
            &mut pol,
            &ServeConfig { n_devices: 1, time_scale: 0.001, warm_start_per_user: 0, verbose: false },
        );
        assert!(!report.decision_latencies.is_empty());
        assert!(report.mean_decision_latency() <= report.max_decision_latency());
    }

    #[test]
    fn wall_clock_respects_costs_roughly() {
        let (p, t) = tiny();
        let mut pol = MmGpEi::new(&p);
        let scale = 0.004;
        let report = serve(
            &p,
            &t,
            &mut pol,
            &ServeConfig { n_devices: 1, time_scale: scale, warm_start_per_user: 0, verbose: false },
        );
        // Sequential: makespan ≳ Σc × scale.
        let total: f64 = p.cost.iter().sum();
        assert!(report.makespan.as_secs_f64() >= total * scale * 0.9);
    }

    #[test]
    fn parallel_devices_shorten_makespan() {
        let (p, t) = tiny();
        let run = |m: usize| {
            let mut pol = MmGpEi::new(&p);
            serve(
                &p,
                &t,
                &mut pol,
                &ServeConfig {
                    n_devices: m,
                    time_scale: 0.01,
                    warm_start_per_user: 0,
                    verbose: false,
                },
            )
            .makespan
        };
        let m1 = run(1);
        let m4 = run(4);
        assert!(
            m4.as_secs_f64() < m1.as_secs_f64() * 0.8,
            "4 devices {:?} should beat 1 device {:?}",
            m4,
            m1
        );
    }
}
