//! Configuration system: a hand-rolled TOML-subset parser and the typed
//! experiment configuration the launcher consumes.
//!
//! The offline environment ships no `serde`/`toml`, so this module
//! implements the subset the project needs: `[section]` headers,
//! `key = value` pairs with string / integer / float / bool / flat-array
//! values, `#` comments, and helpful line-numbered errors. Experiment
//! configs live in `configs/*.toml`.

mod toml;

pub use toml::{ParseError, TomlDoc, TomlValue};

use crate::problem::{DeviceFleet, PerClassCost, Problem};
use crate::workload::{ChurnConfig, FaultsConfig, FleetConfig, SyntheticConfig};

/// Convert a TOML integer into a non-negative count. `usize::try_from`
/// rejects negatives — which `as usize` would wrap into enormous
/// counts — and, on 32-bit hosts, values beyond the address space.
fn count(v: &TomlValue, key: &str) -> Result<usize, String> {
    let x = v.as_int()?;
    usize::try_from(x).map_err(|_| format!("{key} must be a non-negative count, got {x}"))
}

/// Which posterior/EI backend drives MM-GP-EI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Native rust incremental-Cholesky GP.
    Native,
    /// AOT-compiled JAX/Pallas artifact via PJRT.
    Xla,
}

impl std::str::FromStr for Backend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            other => Err(format!("unknown backend {other:?} (native|xla)")),
        }
    }
}

/// Which data structure serves the native backend's GP posterior (the
/// `[gp]` section's `structure` key).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GpStructure {
    /// One dense incremental-Cholesky factor over every arm — the
    /// default, and the oracle all sharded parity gates compare against.
    #[default]
    Dense,
    /// Per-tenant Cholesky shards + low-rank cross-tenant coupling
    /// ([`crate::gp::ShardedGp`]) for the Kronecker-structured
    /// multi-tenant priors the synthetic and churn workloads generate —
    /// the 10⁴–10⁶-tenant scaling mode.
    Sharded,
}

impl std::str::FromStr for GpStructure {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "dense" => Ok(GpStructure::Dense),
            "sharded" => Ok(GpStructure::Sharded),
            other => Err(format!("unknown gp structure {other:?} (dense|sharded)")),
        }
    }
}

/// Per-class cost-model knobs (the `[cost_model]` section): the
/// parameters of a [`crate::problem::PerClassCost`], keyed by device
/// class. Device classes are spread over the fleet round-robin
/// (`d mod n_classes` — see [`crate::workload::round_robin_classes`]).
#[derive(Clone, Debug)]
pub struct CostModelConfig {
    /// Per-class cost multipliers (`c(x, k) = base(x) · multipliers[k]`);
    /// the length defines the number of device classes.
    pub multipliers: Vec<f64>,
    /// Per-class memory limits: an arm whose base cost exceeds its
    /// class's limit is infeasible there (never scheduled on that
    /// class). Empty = unlimited for every class; `inf` entries allowed.
    pub mem_limit: Vec<f64>,
}

impl Default for CostModelConfig {
    fn default() -> Self {
        CostModelConfig { multipliers: vec![1.0], mem_limit: Vec::new() }
    }
}

impl CostModelConfig {
    /// Number of device classes the model distinguishes.
    pub fn n_classes(&self) -> usize {
        self.multipliers.len()
    }

    /// Effective per-class memory limits (+∞ for every class when the
    /// `mem_limit` key was omitted).
    pub fn limits(&self) -> Vec<f64> {
        if self.mem_limit.is_empty() {
            vec![f64::INFINITY; self.multipliers.len()]
        } else {
            self.mem_limit.clone()
        }
    }

    /// Build the [`PerClassCost`] model over `problem`'s base costs.
    pub fn build(&self, problem: &Problem) -> PerClassCost {
        PerClassCost::from_problem(problem, self.multipliers.clone(), self.limits())
    }

    /// Sanity-check the knob ranges (mirrors `FleetConfig::validate`).
    pub fn validate(&self) -> Result<(), String> {
        if self.multipliers.is_empty() {
            return Err("cost_model: multipliers must name at least one device class".into());
        }
        for (k, &m) in self.multipliers.iter().enumerate() {
            if !m.is_finite() || !(m > 0.0) {
                return Err(format!(
                    "cost_model: multiplier for class {k} must be positive finite, got {m}"
                ));
            }
        }
        if !self.mem_limit.is_empty() && self.mem_limit.len() != self.multipliers.len() {
            return Err(format!(
                "cost_model: mem_limit length {} must match multipliers length {}",
                self.mem_limit.len(),
                self.multipliers.len()
            ));
        }
        for (k, &l) in self.mem_limit.iter().enumerate() {
            if !(l > 0.0) {
                return Err(format!(
                    "cost_model: memory limit for class {k} must be positive, got {l}"
                ));
            }
        }
        Ok(())
    }
}

/// A fully specified experiment: dataset × policies × device counts ×
/// seeds, matching the paper's §6.1 protocol knobs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Experiment name (used for report files).
    pub name: String,
    /// Dataset: "azure", "deeplearning" or "synthetic".
    pub dataset: String,
    /// Policy names (see `cli::make_policy` for the vocabulary).
    pub policies: Vec<String>,
    /// Device counts to sweep.
    pub devices: Vec<usize>,
    /// Number of protocol re-samplings (seeds).
    pub seeds: u64,
    /// Warm-start arms per user (paper: 2).
    pub warm_start: usize,
    /// Users held out for prior estimation (paper: 8).
    pub holdout: usize,
    /// Optional report horizon.
    pub horizon: Option<f64>,
    /// Instantaneous-regret cutoff for time-to-cutoff metrics (Fig. 5).
    pub cutoff: f64,
    /// Scoring backend for MM-GP-EI.
    pub backend: Backend,
    /// GP posterior structure for the native backend (a `[gp]` TOML
    /// section with `structure = "sharded"` opts in). Folded into
    /// [`Self::canonical_string`] **only when sharded**, so dense
    /// configs keep the `config_hash` their baselines were stamped with.
    pub gp_structure: GpStructure,
    /// Worker threads for the seed sweep and policy-internal shard pools
    /// (`0` = resolve from `MMGPEI_THREADS`, serial when unset). An
    /// *execution* knob, not an experiment knob: results are byte-
    /// identical at any thread count (see `crate::pool`), so it is
    /// deliberately excluded from [`Self::canonical_string`] and the
    /// config hash.
    pub threads: usize,
    /// Synthetic workload parameters (used when dataset == "synthetic").
    pub synthetic: SyntheticConfig,
    /// Tenant-churn scenario toggle (CLI `--churn` / a `[churn]` TOML
    /// section): the sweep runs the churn workload generator through the
    /// churn event loop instead of the static-tenancy simulator.
    pub churn: bool,
    /// Churn workload knobs (used when `churn` is set). Folded into
    /// [`Self::canonical_string`] **only when enabled**, so churn-free
    /// configs keep their pre-churn `config_hash` and existing baseline
    /// reports stay byte-identical.
    pub churn_cfg: ChurnConfig,
    /// Elastic-fleet scenario toggle (CLI `--fleet` / a `[fleet]` TOML
    /// section): the sweep runs over a seeded heterogeneous device
    /// fleet (per-device speeds + availability churn) through the
    /// unified engine instead of `devices` identical always-on slots.
    pub fleet: bool,
    /// Fleet workload knobs (used when `fleet` is set). Folded into
    /// [`Self::canonical_string`] **only when enabled** — same
    /// hash-stability contract as the churn block.
    pub fleet_cfg: FleetConfig,
    /// Device-aware cost-model toggle (CLI `--cost-model` / a
    /// `[cost_model]` TOML section): devices get round-robin classes and
    /// the engine charges per-(arm, class) costs through
    /// [`crate::problem::PerClassCost`]. Requires the fleet scenario.
    pub cost_model: bool,
    /// Cost-model knobs (used when `cost_model` is set). Folded into
    /// [`Self::canonical_string`] **only when enabled** — same
    /// hash-stability contract as the churn and fleet blocks.
    pub cost_model_cfg: CostModelConfig,
    /// Fault-injection scenario toggle (CLI `--faults` / a `[faults]`
    /// TOML section): the sweep generates a seeded
    /// [`crate::problem::FaultPlan`] (device crashes/restarts, lost
    /// jobs, stragglers) and runs it through the engine's fault layer
    /// with deadline/retry/backoff semantics.
    pub faults: bool,
    /// Fault-plan knobs (used when `faults` is set). Folded into
    /// [`Self::canonical_string`] **only when enabled** — same
    /// hash-stability contract as the churn/fleet/cost-model blocks, so
    /// fault-free configs keep their historical `config_hash`.
    pub faults_cfg: FaultsConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            dataset: "azure".into(),
            policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
            devices: vec![1],
            seeds: 10,
            warm_start: 2,
            holdout: 8,
            horizon: None,
            cutoff: 0.01,
            backend: Backend::Native,
            gp_structure: GpStructure::Dense,
            threads: 0,
            synthetic: SyntheticConfig::default(),
            churn: false,
            churn_cfg: ChurnConfig::default(),
            fleet: false,
            fleet_cfg: FleetConfig::default(),
            cost_model: false,
            cost_model_cfg: CostModelConfig::default(),
            faults: false,
            faults_cfg: FaultsConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file (see `configs/` for examples).
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_toml_str(&text).map_err(|e| format!("{path}: {e}"))
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = ExperimentConfig::default();
        let exp = doc.section("experiment");
        if let Some(v) = exp.get("name") {
            cfg.name = v.as_str()?.to_string();
        }
        if let Some(v) = exp.get("dataset") {
            cfg.dataset = v.as_str()?.to_string();
        }
        if let Some(v) = exp.get("policies") {
            cfg.policies = v.as_str_array()?;
        }
        if let Some(v) = exp.get("devices") {
            cfg.devices = v.as_usize_array()?;
        }
        if let Some(v) = exp.get("seeds") {
            let x = v.as_int()?;
            cfg.seeds =
                u64::try_from(x).map_err(|_| format!("seeds must be ≥ 0, got {x}"))?;
        }
        if let Some(v) = exp.get("warm_start") {
            cfg.warm_start = count(v, "warm_start")?;
        }
        if let Some(v) = exp.get("holdout") {
            cfg.holdout = count(v, "holdout")?;
        }
        if let Some(v) = exp.get("horizon") {
            cfg.horizon = Some(v.as_float()?);
        }
        if let Some(v) = exp.get("cutoff") {
            cfg.cutoff = v.as_float()?;
        }
        if let Some(v) = exp.get("backend") {
            cfg.backend = v.as_str()?.parse()?;
        }
        if let Some(v) = exp.get("threads") {
            let t = v.as_int()?;
            cfg.threads = usize::try_from(t).map_err(|_| {
                format!("threads must be ≥ 0 (0 = resolve from MMGPEI_THREADS), got {t}")
            })?;
        }
        // A `[gp]` section selects the posterior structure behind the
        // native backend; `structure = "sharded"` swaps the dense factor
        // for the per-tenant sharded store.
        if doc.section_names().any(|s| s == "gp") {
            let gp = doc.section("gp");
            if let Some(v) = gp.get("structure") {
                cfg.gp_structure = v.as_str()?.parse()?;
            }
        }
        // A `[churn]` section opts the experiment into the churn
        // scenario; its keys override the `ChurnConfig` defaults.
        if doc.section_names().any(|s| s == "churn") {
            cfg.churn = true;
            let ch = doc.section("churn");
            if let Some(v) = ch.get("n_users") {
                cfg.churn_cfg.n_users = count(v, "churn.n_users")?;
            }
            if let Some(v) = ch.get("n_models") {
                cfg.churn_cfg.n_models = count(v, "churn.n_models")?;
            }
            if let Some(v) = ch.get("initial_users") {
                cfg.churn_cfg.initial_users = count(v, "churn.initial_users")?;
            }
            if let Some(v) = ch.get("arrival_gap") {
                cfg.churn_cfg.arrival_gap = v.as_float()?;
            }
            if let Some(v) = ch.get("sojourn_lo") {
                cfg.churn_cfg.sojourn.0 = v.as_float()?;
            }
            if let Some(v) = ch.get("sojourn_hi") {
                cfg.churn_cfg.sojourn.1 = v.as_float()?;
            }
            if let Some(v) = ch.get("rejoin_prob") {
                cfg.churn_cfg.rejoin_prob = v.as_float()?;
            }
            if let Some(v) = ch.get("rejoin_gap") {
                cfg.churn_cfg.rejoin_gap = v.as_float()?;
            }
            if let Some(v) = ch.get("user_corr") {
                cfg.churn_cfg.user_corr = v.as_float()?;
            }
            if let Some(v) = ch.get("variance") {
                cfg.churn_cfg.variance = v.as_float()?;
            }
            if let Some(v) = ch.get("lengthscale") {
                cfg.churn_cfg.lengthscale = v.as_float()?;
            }
            if let Some(v) = ch.get("cost_lo") {
                cfg.churn_cfg.cost_range.0 = v.as_float()?;
            }
            if let Some(v) = ch.get("cost_hi") {
                cfg.churn_cfg.cost_range.1 = v.as_float()?;
            }
        }
        // A `[fleet]` section opts the experiment into the elastic-fleet
        // scenario; its keys override the `FleetConfig` defaults.
        if doc.section_names().any(|s| s == "fleet") {
            cfg.fleet = true;
            let fl = doc.section("fleet");
            if let Some(v) = fl.get("n_devices") {
                let x = count(v, "fleet.n_devices")?;
                if x < 1 {
                    return Err(format!("fleet.n_devices must be ≥ 1, got {x}"));
                }
                cfg.fleet_cfg.n_devices = x;
            }
            if let Some(v) = fl.get("initial_online") {
                let x = count(v, "fleet.initial_online")?;
                if x < 1 {
                    return Err(format!("fleet.initial_online must be ≥ 1, got {x}"));
                }
                cfg.fleet_cfg.initial_online = x;
            }
            if let Some(v) = fl.get("speed_lo") {
                cfg.fleet_cfg.speed_range.0 = v.as_float()?;
            }
            if let Some(v) = fl.get("speed_hi") {
                cfg.fleet_cfg.speed_range.1 = v.as_float()?;
            }
            if let Some(v) = fl.get("arrival_gap") {
                cfg.fleet_cfg.arrival_gap = v.as_float()?;
            }
            if let Some(v) = fl.get("uptime_lo") {
                cfg.fleet_cfg.uptime.0 = v.as_float()?;
            }
            if let Some(v) = fl.get("uptime_hi") {
                cfg.fleet_cfg.uptime.1 = v.as_float()?;
            }
            if let Some(v) = fl.get("outage_lo") {
                cfg.fleet_cfg.outage.0 = v.as_float()?;
            }
            if let Some(v) = fl.get("outage_hi") {
                cfg.fleet_cfg.outage.1 = v.as_float()?;
            }
            if let Some(v) = fl.get("horizon") {
                cfg.fleet_cfg.horizon = v.as_float()?;
            }
        }
        // A `[cost_model]` section opts the experiment into device-aware
        // per-class costs; its keys override the `CostModelConfig`
        // defaults. Validation requires the fleet scenario (device
        // classes live on the fleet).
        if doc.section_names().any(|s| s == "cost_model") {
            cfg.cost_model = true;
            let cm = doc.section("cost_model");
            if let Some(v) = cm.get("multipliers") {
                cfg.cost_model_cfg.multipliers = v.as_float_array()?;
            }
            if let Some(v) = cm.get("mem_limit") {
                cfg.cost_model_cfg.mem_limit = v.as_float_array()?;
            }
        }
        // A `[faults]` section opts the experiment into fault-injected
        // serving; its keys override the `FaultsConfig` defaults.
        if doc.section_names().any(|s| s == "faults") {
            cfg.faults = true;
            let fa = doc.section("faults");
            if let Some(v) = fa.get("mtbf") {
                cfg.faults_cfg.mtbf = v.as_float()?;
            }
            if let Some(v) = fa.get("mean_downtime") {
                cfg.faults_cfg.mean_downtime = v.as_float()?;
            }
            if let Some(v) = fa.get("job_failure_gap") {
                cfg.faults_cfg.job_failure_gap = v.as_float()?;
            }
            if let Some(v) = fa.get("straggler_gap") {
                cfg.faults_cfg.straggler_gap = v.as_float()?;
            }
            if let Some(v) = fa.get("slowdown_lo") {
                cfg.faults_cfg.slowdown.0 = v.as_float()?;
            }
            if let Some(v) = fa.get("slowdown_hi") {
                cfg.faults_cfg.slowdown.1 = v.as_float()?;
            }
            if let Some(v) = fa.get("horizon") {
                cfg.faults_cfg.horizon = v.as_float()?;
            }
            if let Some(v) = fa.get("deadline_factor") {
                cfg.faults_cfg.retry.deadline_factor = v.as_float()?;
            }
            if let Some(v) = fa.get("max_retries") {
                cfg.faults_cfg.retry.max_retries = count(v, "faults.max_retries")?;
            }
            if let Some(v) = fa.get("backoff_base") {
                cfg.faults_cfg.retry.backoff_base = v.as_float()?;
            }
            if let Some(v) = fa.get("backoff_cap") {
                cfg.faults_cfg.retry.backoff_cap = v.as_float()?;
            }
        }
        let syn = doc.section("synthetic");
        if let Some(v) = syn.get("n_users") {
            cfg.synthetic.n_users = count(v, "synthetic.n_users")?;
        }
        if let Some(v) = syn.get("n_models") {
            cfg.synthetic.n_models = count(v, "synthetic.n_models")?;
        }
        if let Some(v) = syn.get("variance") {
            cfg.synthetic.variance = v.as_float()?;
        }
        if let Some(v) = syn.get("lengthscale") {
            cfg.synthetic.lengthscale = v.as_float()?;
        }
        if let Some(v) = syn.get("cost_lo") {
            cfg.synthetic.cost_range.0 = v.as_float()?;
        }
        if let Some(v) = syn.get("cost_hi") {
            cfg.synthetic.cost_range.1 = v.as_float()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Canonical one-line-per-field rendering of every knob that affects
    /// results — the input to [`Self::config_hash`]. Field order is fixed;
    /// floats render through Rust's shortest-roundtrip `Display`, so the
    /// same config always produces the same string. The churn and fleet
    /// blocks are appended **only when the scenario is enabled** —
    /// churn-free/fleet-free configs keep their historical hash, so
    /// existing baseline reports still match.
    pub fn canonical_string(&self) -> String {
        let mut s = format!(
            "name={}\ndataset={}\npolicies={}\ndevices={:?}\nseeds={}\nwarm_start={}\nholdout={}\n\
             horizon={:?}\ncutoff={}\nbackend={:?}\nsynthetic.n_users={}\nsynthetic.n_models={}\n\
             synthetic.variance={}\nsynthetic.lengthscale={}\nsynthetic.cost_range=({},{})\n",
            self.name,
            self.dataset,
            self.policies.join(","),
            self.devices,
            self.seeds,
            self.warm_start,
            self.holdout,
            self.horizon,
            self.cutoff,
            self.backend,
            self.synthetic.n_users,
            self.synthetic.n_models,
            self.synthetic.variance,
            self.synthetic.lengthscale,
            self.synthetic.cost_range.0,
            self.synthetic.cost_range.1,
        );
        if self.gp_structure == GpStructure::Sharded {
            // Results-affecting only away from the dense default (ρ > 0
            // posteriors agree to tolerance, not bitwise), so — like the
            // scenario blocks — the key is appended only when it departs
            // from the default and historical hashes stay put.
            s.push_str("gp.structure=sharded\n");
        }
        if self.churn {
            let c = &self.churn_cfg;
            s.push_str(&format!(
                "churn.n_users={}\nchurn.n_models={}\nchurn.initial_users={}\nchurn.arrival_gap={}\n\
                 churn.sojourn=({},{})\nchurn.rejoin_prob={}\nchurn.rejoin_gap={}\nchurn.user_corr={}\n\
                 churn.variance={}\nchurn.lengthscale={}\nchurn.cost_range=({},{})\n",
                c.n_users,
                c.n_models,
                c.initial_users,
                c.arrival_gap,
                c.sojourn.0,
                c.sojourn.1,
                c.rejoin_prob,
                c.rejoin_gap,
                c.user_corr,
                c.variance,
                c.lengthscale,
                c.cost_range.0,
                c.cost_range.1,
            ));
        }
        if self.fleet {
            let f = &self.fleet_cfg;
            s.push_str(&format!(
                "fleet.n_devices={}\nfleet.initial_online={}\nfleet.speed_range=({},{})\n\
                 fleet.arrival_gap={}\nfleet.uptime=({},{})\nfleet.outage=({},{})\nfleet.horizon={}\n",
                f.n_devices,
                f.initial_online,
                f.speed_range.0,
                f.speed_range.1,
                f.arrival_gap,
                f.uptime.0,
                f.uptime.1,
                f.outage.0,
                f.outage.1,
                f.horizon,
            ));
        }
        if self.cost_model {
            let m = &self.cost_model_cfg;
            s.push_str(&format!(
                "cost_model.multipliers={:?}\ncost_model.mem_limit={:?}\n",
                m.multipliers, m.mem_limit
            ));
        }
        if self.faults {
            let f = &self.faults_cfg;
            s.push_str(&format!(
                "faults.mtbf={}\nfaults.mean_downtime={}\nfaults.job_failure_gap={}\n\
                 faults.straggler_gap={}\nfaults.slowdown=({},{})\nfaults.horizon={}\n\
                 faults.deadline_factor={}\nfaults.max_retries={}\nfaults.backoff_base={}\n\
                 faults.backoff_cap={}\n",
                f.mtbf,
                f.mean_downtime,
                f.job_failure_gap,
                f.straggler_gap,
                f.slowdown.0,
                f.slowdown.1,
                f.horizon,
                f.retry.deadline_factor,
                f.retry.max_retries,
                f.retry.backoff_base,
                f.retry.backoff_cap,
            ));
        }
        s
    }

    /// FNV-1a fingerprint of [`Self::canonical_string`] as 16 hex chars —
    /// stamped into report provenance so `compare` can tell whether two
    /// reports measured the same experiment.
    pub fn config_hash(&self) -> String {
        format!("{:016x}", crate::report::fnv1a64(self.canonical_string().as_bytes()))
    }

    /// Effective worker-pool width for the seed sweep: an explicit
    /// `threads` wins; `0` defers to `MMGPEI_THREADS` (serial when
    /// unset). Never affects results — only wall-clock time.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            crate::pool::env_threads().unwrap_or(1)
        }
    }

    /// Reduced deterministic preset for CI smoke runs (`--smoke`): few
    /// seeds and a small synthetic instance, everything else untouched.
    /// Azure/DeepLearning workloads are already small; the seed count is
    /// what dominates sweep cost.
    pub fn smoke(mut self) -> Self {
        self.seeds = self.seeds.min(2);
        self.synthetic.n_users = self.synthetic.n_users.min(12);
        self.synthetic.n_models = self.synthetic.n_models.min(10);
        self.churn_cfg.n_users = self.churn_cfg.n_users.min(10);
        self.churn_cfg.n_models = self.churn_cfg.n_models.min(6);
        self.churn_cfg.initial_users = self.churn_cfg.initial_users.min(self.churn_cfg.n_users);
        self.fleet_cfg.n_devices = self.fleet_cfg.n_devices.min(4);
        self.fleet_cfg.initial_online = self.fleet_cfg.initial_online.min(self.fleet_cfg.n_devices);
        self.fleet_cfg.horizon = self.fleet_cfg.horizon.min(120.0);
        self.faults_cfg.horizon = self.faults_cfg.horizon.min(120.0);
        self
    }

    /// Sanity-check field combinations.
    pub fn validate(&self) -> Result<(), String> {
        if !["azure", "deeplearning", "synthetic"].contains(&self.dataset.as_str()) {
            return Err(format!("unknown dataset {:?}", self.dataset));
        }
        if self.policies.is_empty() {
            return Err("no policies listed".into());
        }
        if self.devices.is_empty() || self.devices.contains(&0) {
            return Err("devices must be non-empty positive".into());
        }
        if self.seeds == 0 {
            return Err("seeds must be >= 1".into());
        }
        if !(self.cutoff > 0.0) {
            return Err("cutoff must be positive".into());
        }
        if self.gp_structure == GpStructure::Sharded {
            if self.backend != Backend::Native {
                return Err("[gp] structure = \"sharded\" requires backend = \"native\" (the AOT \
                            XLA artifact has no sharded store)"
                    .into());
            }
            if !self.churn && self.dataset != "synthetic" {
                return Err(format!(
                    "[gp] structure = \"sharded\" requires a Kronecker-structured prior, which \
                     only the synthetic and churn workloads generate (dataset {:?} has an \
                     empirical dense prior)",
                    self.dataset
                ));
            }
            if self.fleet || self.faults || self.cost_model {
                return Err("[gp] structure = \"sharded\" cannot be combined with \
                            [fleet]/[faults]/[cost_model] yet (sharded-prior construction for \
                            those drivers is a ROADMAP open item)"
                    .into());
            }
            for p in &self.policies {
                if !["mdmt", "round-robin", "random", "oracle"].contains(&p.as_str()) {
                    return Err(format!(
                        "[gp] structure = \"sharded\" currently serves the \"mdmt\" policy (plus \
                         the GP-free baselines round-robin/random/oracle); policy {p:?} would \
                         silently fall back to the dense store — drop it or use structure = \
                         \"dense\""
                    ));
                }
            }
        }
        if self.churn {
            self.churn_cfg.validate()?;
        }
        if self.fleet {
            self.fleet_cfg.validate()?;
            if self.churn {
                return Err(
                    "fleet + churn cannot be combined yet (the engine supports both event \
                     streams; the driver surface is a ROADMAP open item)"
                        .into(),
                );
            }
        }
        if self.cost_model {
            self.cost_model_cfg.validate()?;
            if !self.fleet {
                return Err(
                    "[cost_model] requires the [fleet] scenario (device classes live on the \
                     fleet; add a [fleet] section or drop [cost_model])"
                        .into(),
                );
            }
        }
        if self.faults {
            self.faults_cfg.validate()?;
            if self.churn {
                return Err(
                    "faults + churn cannot be combined yet (the engine merges all three event \
                     streams; the driver surface is a ROADMAP open item)"
                        .into(),
                );
            }
            if self.cost_model {
                return Err(
                    "faults + cost_model cannot be combined yet (the fault sweep charges the \
                     problem's base costs; per-class charging under faults is a ROADMAP open \
                     item)"
                        .into(),
                );
            }
        }
        Ok(())
    }

    /// The uniform always-on unit-speed single-class fleet every
    /// non-`[fleet]` scenario schedules over — the one constructor
    /// behind `sim::simulate`, `sim::simulate_churn`,
    /// `coordinator::serve`, and `coordinator::serve_churn`, so the
    /// "`n` identical devices" convention is written down exactly once.
    pub fn device_fleet(n_devices: usize) -> DeviceFleet {
        DeviceFleet::uniform(n_devices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Figure-2 style experiment
[experiment]
name = "fig2-azure"
dataset = "azure"
policies = ["mdmt", "round-robin", "random"]
devices = [1]
seeds = 10
warm_start = 2
backend = "native"
cutoff = 0.01

[synthetic]
n_users = 50
n_models = 50
"#;

    #[test]
    fn parses_sample_config() {
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.name, "fig2-azure");
        assert_eq!(cfg.dataset, "azure");
        assert_eq!(cfg.policies, vec!["mdmt", "round-robin", "random"]);
        assert_eq!(cfg.devices, vec![1]);
        assert_eq!(cfg.seeds, 10);
        assert_eq!(cfg.backend, Backend::Native);
        assert_eq!(cfg.synthetic.n_users, 50);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let cfg = ExperimentConfig::from_toml_str("[experiment]\ndataset = \"deeplearning\"\n")
            .unwrap();
        assert_eq!(cfg.dataset, "deeplearning");
        assert_eq!(cfg.warm_start, 2);
        assert_eq!(cfg.holdout, 8);
    }

    #[test]
    fn rejects_bad_dataset() {
        let err =
            ExperimentConfig::from_toml_str("[experiment]\ndataset = \"imagenet\"\n").unwrap_err();
        assert!(err.contains("unknown dataset"), "{err}");
    }

    #[test]
    fn rejects_zero_devices() {
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndevices = [0]\n",
        )
        .unwrap_err();
        assert!(err.contains("devices"), "{err}");
    }

    #[test]
    fn config_hash_separates_configs_and_is_stable() {
        let a = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        let b = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(a.config_hash(), b.config_hash());
        assert_eq!(a.config_hash().len(), 16);
        let mut c = a.clone();
        c.seeds += 1;
        assert_ne!(a.config_hash(), c.config_hash());
        let mut d = a.clone();
        d.synthetic.lengthscale *= 2.0;
        assert_ne!(a.config_hash(), d.config_hash());
    }

    #[test]
    fn threads_is_an_execution_knob_outside_the_config_hash() {
        // Thread count cannot change results (pool determinism contract),
        // so two configs differing only in `threads` must fingerprint —
        // and therefore compare — as the same experiment.
        let a = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        let mut b = a.clone();
        b.threads = 4;
        assert_eq!(a.config_hash(), b.config_hash());
        assert_eq!(b.effective_threads(), 4);
        let parsed =
            ExperimentConfig::from_toml_str("[experiment]\ndataset = \"azure\"\nthreads = 3\n").unwrap();
        assert_eq!(parsed.threads, 3);
        // A negative count must error, not wrap through `as usize`.
        let err = ExperimentConfig::from_toml_str("[experiment]\nthreads = -1\n").unwrap_err();
        assert!(err.contains("threads"), "{err}");
    }

    #[test]
    fn negative_counts_error_instead_of_wrapping() {
        // The pallas-lint R4 class: every config-derived integer must go
        // through `try_from`, so a negative TOML value produces a named
        // error instead of wrapping into an enormous count (or, for
        // `seeds`, a garbage RNG stream).
        let cases = [
            ("[experiment]\nseeds = -1\n", "seeds"),
            ("[experiment]\nwarm_start = -2\n", "warm_start"),
            ("[experiment]\nholdout = -3\n", "holdout"),
            ("[churn]\nn_users = -4\n", "churn.n_users"),
            ("[churn]\nn_models = -5\n", "churn.n_models"),
            ("[churn]\ninitial_users = -6\n", "churn.initial_users"),
            ("[fleet]\nn_devices = -7\n", "fleet.n_devices"),
            ("[fleet]\ninitial_online = -8\n", "fleet.initial_online"),
            ("[synthetic]\nn_users = -9\n", "synthetic.n_users"),
            ("[synthetic]\nn_models = -10\n", "synthetic.n_models"),
        ];
        for (toml, key) in cases {
            let err = ExperimentConfig::from_toml_str(toml).unwrap_err();
            assert!(err.contains(key), "{toml:?} should name {key}: {err}");
        }
    }

    #[test]
    fn smoke_preset_shrinks_but_stays_valid() {
        let mut cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        cfg.synthetic.n_users = 50;
        let s = cfg.clone().smoke();
        assert_eq!(s.seeds, 2);
        assert_eq!(s.synthetic.n_users, 12);
        assert_eq!(s.devices, cfg.devices);
        s.validate().unwrap();
        // Already-small configs are untouched.
        let mut tiny = cfg.clone();
        tiny.seeds = 1;
        assert_eq!(tiny.clone().smoke().seeds, 1);
    }

    #[test]
    fn churn_section_opts_in_and_hashes_conditionally() {
        // No [churn] section → churn off, and — critically — the
        // canonical string is unchanged, so churn-free configs keep the
        // config_hash their checked-in baselines were stamped with.
        let plain = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert!(!plain.churn);
        assert!(!plain.canonical_string().contains("churn."));
        let churned = ExperimentConfig::from_toml_str(&format!(
            "{SAMPLE}\n[churn]\nn_users = 12\nn_models = 5\ninitial_users = 4\nrejoin_prob = 0.5\n"
        ))
        .unwrap();
        assert!(churned.churn);
        assert_eq!(churned.churn_cfg.n_users, 12);
        assert_eq!(churned.churn_cfg.n_models, 5);
        assert_eq!(churned.churn_cfg.initial_users, 4);
        assert_eq!(churned.churn_cfg.rejoin_prob, 0.5);
        assert!(churned.canonical_string().contains("churn.n_users=12"));
        assert_ne!(plain.config_hash(), churned.config_hash());
        // Churn knobs are experiment knobs: changing one moves the hash.
        let mut c2 = churned.clone();
        c2.churn_cfg.user_corr = 0.7;
        assert_ne!(churned.config_hash(), c2.config_hash());
    }

    #[test]
    fn churn_knobs_are_validated() {
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[churn]\ninitial_users = 0\n",
        )
        .unwrap_err();
        assert!(err.contains("initial_users"), "{err}");
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[churn]\nuser_corr = 1.5\n",
        )
        .unwrap_err();
        assert!(err.contains("user_corr"), "{err}");
    }

    #[test]
    fn smoke_shrinks_churn_but_keeps_it_valid() {
        let mut cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        cfg.churn = true;
        let s = cfg.smoke();
        assert!(s.churn_cfg.n_users <= 10 && s.churn_cfg.n_models <= 6);
        assert!(s.churn_cfg.initial_users <= s.churn_cfg.n_users);
        s.validate().unwrap();
    }

    #[test]
    fn fleet_section_opts_in_and_hashes_conditionally() {
        // No [fleet] section → fleet off and — critically — the
        // canonical string is unchanged, so fleet-free configs keep the
        // config_hash their checked-in baselines were stamped with.
        let plain = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert!(!plain.fleet);
        assert!(!plain.canonical_string().contains("fleet."));
        let fleeted = ExperimentConfig::from_toml_str(&format!(
            "{SAMPLE}\n[fleet]\nn_devices = 5\ninitial_online = 3\nspeed_lo = 0.25\nspeed_hi = 4.0\nhorizon = 60.0\n"
        ))
        .unwrap();
        assert!(fleeted.fleet);
        assert_eq!(fleeted.fleet_cfg.n_devices, 5);
        assert_eq!(fleeted.fleet_cfg.initial_online, 3);
        assert_eq!(fleeted.fleet_cfg.speed_range, (0.25, 4.0));
        assert_eq!(fleeted.fleet_cfg.horizon, 60.0);
        assert!(fleeted.canonical_string().contains("fleet.n_devices=5"));
        assert_ne!(plain.config_hash(), fleeted.config_hash());
        // Fleet knobs are experiment knobs: changing one moves the hash.
        let mut f2 = fleeted.clone();
        f2.fleet_cfg.arrival_gap = 99.0;
        assert_ne!(fleeted.config_hash(), f2.config_hash());
    }

    #[test]
    fn fleet_knobs_are_validated_and_exclusive_with_churn() {
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[fleet]\ninitial_online = 0\n",
        )
        .unwrap_err();
        assert!(err.contains("initial_online"), "{err}");
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[fleet]\nspeed_lo = 0.0\n",
        )
        .unwrap_err();
        assert!(err.contains("speed"), "{err}");
        // A negative count must error, not wrap through `as usize`.
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[fleet]\nn_devices = -1\n",
        )
        .unwrap_err();
        assert!(err.contains("n_devices"), "{err}");
        // fleet + churn in one config is rejected (ROADMAP open item).
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[churn]\nn_users = 8\n[fleet]\nn_devices = 4\n",
        )
        .unwrap_err();
        assert!(err.contains("fleet + churn"), "{err}");
    }

    #[test]
    fn smoke_shrinks_fleet_but_keeps_it_valid() {
        let mut cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        cfg.fleet = true;
        cfg.fleet_cfg.n_devices = 16;
        cfg.fleet_cfg.initial_online = 12;
        cfg.fleet_cfg.horizon = 500.0;
        let s = cfg.smoke();
        assert!(s.fleet_cfg.n_devices <= 4);
        assert!(s.fleet_cfg.initial_online <= s.fleet_cfg.n_devices);
        assert!(s.fleet_cfg.horizon <= 120.0);
        s.validate().unwrap();
    }

    #[test]
    fn cost_model_section_opts_in_and_hashes_conditionally() {
        // No [cost_model] section → off and — critically — the canonical
        // string is unchanged, so cost-blind configs keep the
        // config_hash their checked-in baselines were stamped with.
        let plain = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert!(!plain.cost_model);
        assert!(!plain.canonical_string().contains("cost_model."));
        let modeled = ExperimentConfig::from_toml_str(&format!(
            "{SAMPLE}\n[fleet]\nn_devices = 4\n\
             [cost_model]\nmultipliers = [1.0, 2.5]\nmem_limit = [inf, 5.0]\n"
        ))
        .unwrap();
        assert!(modeled.cost_model);
        assert_eq!(modeled.cost_model_cfg.multipliers, vec![1.0, 2.5]);
        assert_eq!(modeled.cost_model_cfg.mem_limit, vec![f64::INFINITY, 5.0]);
        assert_eq!(modeled.cost_model_cfg.n_classes(), 2);
        assert!(modeled.canonical_string().contains("cost_model.multipliers=[1.0, 2.5]"));
        assert_ne!(plain.config_hash(), modeled.config_hash());
        // Cost-model knobs are experiment knobs: changing one moves the hash.
        let mut m2 = modeled.clone();
        m2.cost_model_cfg.multipliers[1] = 3.0;
        assert_ne!(modeled.config_hash(), m2.config_hash());
        // Omitted mem_limit means unlimited everywhere.
        assert_eq!(CostModelConfig::default().limits(), vec![f64::INFINITY]);
    }

    #[test]
    fn cost_model_knobs_are_validated_and_require_fleet() {
        // [cost_model] without [fleet] is rejected: classes live on the fleet.
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[cost_model]\nmultipliers = [1.0, 2.0]\n",
        )
        .unwrap_err();
        assert!(err.contains("requires the [fleet]"), "{err}");
        let with_fleet = |body: &str| {
            ExperimentConfig::from_toml_str(&format!(
                "[experiment]\ndataset = \"azure\"\n[fleet]\nn_devices = 4\n[cost_model]\n{body}"
            ))
        };
        let err = with_fleet("multipliers = []\n").unwrap_err();
        assert!(err.contains("at least one device class"), "{err}");
        let err = with_fleet("multipliers = [1.0, -2.0]\n").unwrap_err();
        assert!(err.contains("positive finite"), "{err}");
        let err = with_fleet("multipliers = [1.0, 2.0]\nmem_limit = [5.0]\n").unwrap_err();
        assert!(err.contains("mem_limit length"), "{err}");
        let err = with_fleet("multipliers = [1.0]\nmem_limit = [0.0]\n").unwrap_err();
        assert!(err.contains("memory limit"), "{err}");
        assert!(with_fleet("multipliers = [1.0, 2.0]\n").is_ok());
    }

    #[test]
    fn faults_section_opts_in_and_hashes_conditionally() {
        // No [faults] section → faults off and — critically — the
        // canonical string is unchanged, so fault-free configs keep the
        // config_hash their checked-in baselines were stamped with.
        let plain = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert!(!plain.faults);
        assert!(!plain.canonical_string().contains("faults."));
        let faulty = ExperimentConfig::from_toml_str(&format!(
            "{SAMPLE}\n[faults]\nmtbf = 30.0\nmean_downtime = 5.0\nmax_retries = 2\n\
             deadline_factor = 4.0\nslowdown_lo = 2.0\nslowdown_hi = 6.0\n"
        ))
        .unwrap();
        assert!(faulty.faults);
        assert_eq!(faulty.faults_cfg.mtbf, 30.0);
        assert_eq!(faulty.faults_cfg.mean_downtime, 5.0);
        assert_eq!(faulty.faults_cfg.retry.max_retries, 2);
        assert_eq!(faulty.faults_cfg.retry.deadline_factor, 4.0);
        assert_eq!(faulty.faults_cfg.slowdown, (2.0, 6.0));
        assert!(faulty.canonical_string().contains("faults.mtbf=30"));
        assert_ne!(plain.config_hash(), faulty.config_hash());
        // Fault knobs are experiment knobs: changing one moves the hash.
        let mut f2 = faulty.clone();
        f2.faults_cfg.retry.backoff_cap = 9.0;
        assert_ne!(faulty.config_hash(), f2.config_hash());
    }

    #[test]
    fn faults_knobs_are_validated_and_pairings_rejected() {
        let with_faults = |body: &str| {
            ExperimentConfig::from_toml_str(&format!(
                "[experiment]\ndataset = \"azure\"\n[faults]\n{body}"
            ))
        };
        let err = with_faults("mtbf = -1.0\n").unwrap_err();
        assert!(err.contains("mtbf"), "{err}");
        let err = with_faults("mean_downtime = 0.0\n").unwrap_err();
        assert!(err.contains("mean_downtime"), "{err}");
        let err = with_faults("slowdown_lo = 0.5\n").unwrap_err();
        assert!(err.contains("slowdown"), "{err}");
        let err = with_faults("deadline_factor = 1.0\n").unwrap_err();
        assert!(err.contains("deadline_factor"), "{err}");
        let err = with_faults("backoff_cap = 0.01\n").unwrap_err();
        assert!(err.contains("backoff_cap"), "{err}");
        let err = with_faults("horizon = 0.0\n").unwrap_err();
        assert!(err.contains("horizon"), "{err}");
        // A negative count must error through `count()`, not wrap.
        let err = with_faults("max_retries = -1\n").unwrap_err();
        assert!(err.contains("faults.max_retries"), "{err}");
        // Undesigned pairings are rejected up front.
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[churn]\nn_users = 8\n[faults]\nmtbf = 30.0\n",
        )
        .unwrap_err();
        assert!(err.contains("faults + churn"), "{err}");
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[fleet]\nn_devices = 4\n\
             [cost_model]\nmultipliers = [1.0, 2.0]\n[faults]\nmtbf = 30.0\n",
        )
        .unwrap_err();
        assert!(err.contains("faults + cost_model"), "{err}");
        // faults + fleet is a designed pairing.
        assert!(ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[fleet]\nn_devices = 4\n[faults]\nmtbf = 30.0\n",
        )
        .is_ok());
        assert!(with_faults("mtbf = 30.0\n").is_ok());
    }

    #[test]
    fn smoke_shrinks_faults_but_keeps_them_valid() {
        let mut cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        cfg.faults = true;
        cfg.faults_cfg.horizon = 500.0;
        let s = cfg.smoke();
        assert!(s.faults_cfg.horizon <= 120.0);
        s.validate().unwrap();
    }

    #[test]
    fn shipped_faults_config_parses() {
        let cfg = ExperimentConfig::from_toml_str(include_str!("../../../configs/fig8_faults.toml"))
            .unwrap();
        assert!(cfg.faults && cfg.fleet);
        assert!(!cfg.churn && !cfg.cost_model);
        assert!(cfg.faults_cfg.any_channel_active());
    }

    #[test]
    fn shipped_device_aware_config_parses() {
        let cfg = ExperimentConfig::from_toml_str(include_str!(
            "../../../configs/fig7_device_aware.toml"
        ))
        .unwrap();
        assert!(cfg.fleet && cfg.cost_model);
        assert_eq!(cfg.cost_model_cfg.n_classes(), 2);
        assert!(cfg.cost_model_cfg.limits().iter().all(|l| l.is_infinite()));
        assert!(cfg.policies.contains(&"mdmt-device".to_string()));
    }

    #[test]
    fn gp_section_opts_in_and_hashes_conditionally() {
        // No [gp] section → dense structure and — critically — the
        // canonical string is unchanged, so dense configs keep the
        // config_hash their checked-in baselines were stamped with.
        let plain = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(plain.gp_structure, GpStructure::Dense);
        assert!(!plain.canonical_string().contains("gp.structure"));
        // An explicit dense selection is also hash-neutral.
        let dense = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\n[gp]\nstructure = \"dense\"\n",
        )
        .unwrap();
        assert_eq!(dense.gp_structure, GpStructure::Dense);
        assert!(!dense.canonical_string().contains("gp.structure"));
        let sharded = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"synthetic\"\npolicies = [\"mdmt\"]\n\
             [gp]\nstructure = \"sharded\"\n",
        )
        .unwrap();
        assert_eq!(sharded.gp_structure, GpStructure::Sharded);
        assert!(sharded.canonical_string().contains("gp.structure=sharded"));
        // The structure is an experiment knob away from the default:
        // ρ > 0 posteriors agree to tolerance, not bitwise.
        let mut as_dense = sharded.clone();
        as_dense.gp_structure = GpStructure::Dense;
        assert_ne!(sharded.config_hash(), as_dense.config_hash());
        // Churn + sharded is the headline pairing and must validate.
        let churned = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\npolicies = [\"mdmt\"]\n\
             [gp]\nstructure = \"sharded\"\n[churn]\nn_users = 8\n",
        )
        .unwrap();
        assert!(churned.churn);
        assert_eq!(churned.gp_structure, GpStructure::Sharded);
    }

    #[test]
    fn gp_structure_pairings_are_validated() {
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"synthetic\"\n[gp]\nstructure = \"blocked\"\n",
        )
        .unwrap_err();
        assert!(err.contains("unknown gp structure"), "{err}");
        // Sharded needs the native backend…
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"synthetic\"\npolicies = [\"mdmt\"]\nbackend = \"xla\"\n\
             [gp]\nstructure = \"sharded\"\n",
        )
        .unwrap_err();
        assert!(err.contains("backend"), "{err}");
        // …a Kronecker-structured workload…
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"azure\"\npolicies = [\"mdmt\"]\n[gp]\nstructure = \"sharded\"\n",
        )
        .unwrap_err();
        assert!(err.contains("Kronecker"), "{err}");
        // …no fleet/faults/cost_model pairing…
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"synthetic\"\npolicies = [\"mdmt\"]\n\
             [gp]\nstructure = \"sharded\"\n[fleet]\nn_devices = 4\n",
        )
        .unwrap_err();
        assert!(err.contains("fleet"), "{err}");
        // …and no GP policies that would silently fall back to dense.
        let err = ExperimentConfig::from_toml_str(
            "[experiment]\ndataset = \"synthetic\"\npolicies = [\"mdmt\", \"mdmt-nocost\"]\n\
             [gp]\nstructure = \"sharded\"\n",
        )
        .unwrap_err();
        assert!(err.contains("mdmt-nocost"), "{err}");
        assert_eq!("dense".parse::<GpStructure>().unwrap(), GpStructure::Dense);
        assert_eq!("sharded".parse::<GpStructure>().unwrap(), GpStructure::Sharded);
        assert!("kronecker".parse::<GpStructure>().is_err());
    }

    #[test]
    fn device_fleet_constructor_is_uniform() {
        let f = ExperimentConfig::device_fleet(3);
        assert_eq!(f.n_devices(), 3);
        for d in 0..3 {
            assert_eq!(f.speed(d), 1.0);
            assert_eq!(f.class(d), 0);
            assert!(f.online_at_start(d));
        }
        assert!(f.events().is_empty());
    }

    #[test]
    fn backend_parse() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Xla);
        assert!("tpu".parse::<Backend>().is_err());
    }
}
