//! Minimal TOML-subset parser (sections, scalar values, flat arrays).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Flat array of values.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// String view (errors on other kinds).
    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(format!("expected string, got {other:?}")),
        }
    }

    /// Integer view.
    pub fn as_int(&self) -> Result<i64, String> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => Err(format!("expected integer, got {other:?}")),
        }
    }

    /// Float view (accepts integers).
    pub fn as_float(&self) -> Result<f64, String> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(format!("expected number, got {other:?}")),
        }
    }

    /// Bool view.
    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {other:?}")),
        }
    }

    /// Array-of-strings view.
    pub fn as_str_array(&self) -> Result<Vec<String>, String> {
        match self {
            TomlValue::Array(xs) => {
                xs.iter().map(|v| v.as_str().map(str::to_string)).collect()
            }
            other => Err(format!("expected array, got {other:?}")),
        }
    }

    /// Array-of-floats view (integer entries coerce, like
    /// [`TomlValue::as_float`]).
    pub fn as_float_array(&self) -> Result<Vec<f64>, String> {
        match self {
            TomlValue::Array(xs) => xs.iter().map(|v| v.as_float()).collect(),
            other => Err(format!("expected array, got {other:?}")),
        }
    }

    /// Array-of-usize view.
    pub fn as_usize_array(&self) -> Result<Vec<usize>, String> {
        match self {
            TomlValue::Array(xs) => xs
                .iter()
                .map(|v| {
                    let i = v.as_int()?;
                    usize::try_from(i).map_err(|_| format!("negative array entry {i}"))
                })
                .collect(),
            other => Err(format!("expected array, got {other:?}")),
        }
    }
}

/// Parse error with a line number.
#[derive(Debug)]
pub struct ParseError {
    /// 1-based line.
    pub line: usize,
    /// Description.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: section name → key → value. Keys outside any
/// section land in the "" section.
#[derive(Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse the subset grammar.
    pub fn parse(text: &str) -> Result<TomlDoc, ParseError> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    message: "unterminated section header".into(),
                })?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: lineno,
                message: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ParseError { line: lineno, message: "empty key".into() });
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            doc.sections.entry(current.clone()).or_default().insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Section view (empty map if absent).
    pub fn section(&self, name: &str) -> SectionView<'_> {
        SectionView { map: self.sections.get(name) }
    }

    /// Section names.
    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

/// Borrowed view over one section.
pub struct SectionView<'a> {
    map: Option<&'a BTreeMap<String, TomlValue>>,
}

impl<'a> SectionView<'a> {
    /// Value for a key, if present.
    pub fn get(&self, key: &str) -> Option<&'a TomlValue> {
        self.map.and_then(|m| m.get(key))
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str, lineno: usize) -> Result<TomlValue, ParseError> {
    let err = |m: String| ParseError { line: lineno, message: m };
    if tok.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(rest) = tok.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| err("unterminated string".into()))?;
        if inner.contains('"') {
            return Err(err("embedded quote in string (escapes unsupported)".into()));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = tok.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| err("unterminated array".into()))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            items.push(parse_value(part.trim(), lineno)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match tok {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = tok.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = tok.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(format!("cannot parse value {tok:?}")))
}

/// Split a flat array body at commas not inside quotes.
fn split_array_items(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = TomlDoc::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = -3\n",
        )
        .unwrap();
        let s = doc.section("");
        assert_eq!(s.get("a").unwrap().as_int().unwrap(), 1);
        assert_eq!(s.get("b").unwrap().as_float().unwrap(), 2.5);
        assert_eq!(s.get("c").unwrap().as_str().unwrap(), "hi");
        assert!(s.get("d").unwrap().as_bool().unwrap());
        assert_eq!(s.get("e").unwrap().as_int().unwrap(), -3);
    }

    #[test]
    fn parses_sections_and_arrays() {
        let doc = TomlDoc::parse(
            "[x]\nnums = [1, 2, 3]\nnames = [\"a\", \"b\"]\nempty = []\n[y]\nk = 7\n",
        )
        .unwrap();
        assert_eq!(doc.section("x").get("nums").unwrap().as_usize_array().unwrap(), vec![1, 2, 3]);
        assert_eq!(
            doc.section("x").get("names").unwrap().as_str_array().unwrap(),
            vec!["a", "b"]
        );
        assert_eq!(
            doc.section("x").get("empty").unwrap(),
            &TomlValue::Array(vec![])
        );
        assert_eq!(doc.section("y").get("k").unwrap().as_int().unwrap(), 7);
        assert!(doc.section("z").get("k").is_none());
    }

    #[test]
    fn comments_stripped_even_after_values() {
        let doc = TomlDoc::parse("a = 5 # five\nb = \"x # y\" # real comment\n").unwrap();
        assert_eq!(doc.section("").get("a").unwrap().as_int().unwrap(), 5);
        assert_eq!(doc.section("").get("b").unwrap().as_str().unwrap(), "x # y");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("good = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = TomlDoc::parse("x = \"unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = TomlDoc::parse("[unclosed\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn float_and_int_coercion() {
        let doc = TomlDoc::parse("f = 3\n").unwrap();
        assert_eq!(doc.section("").get("f").unwrap().as_float().unwrap(), 3.0);
        let doc = TomlDoc::parse("f = 3.5\n").unwrap();
        assert!(doc.section("").get("f").unwrap().as_int().is_err());
    }

    #[test]
    fn negative_usize_array_rejected() {
        let doc = TomlDoc::parse("a = [1, -2]\n").unwrap();
        assert!(doc.section("").get("a").unwrap().as_usize_array().is_err());
    }

    #[test]
    fn float_array_coerces_ints_and_rejects_strings() {
        let doc = TomlDoc::parse("a = [1, 2.5, inf]\nb = [\"x\"]\n").unwrap();
        assert_eq!(
            doc.section("").get("a").unwrap().as_float_array().unwrap(),
            vec![1.0, 2.5, f64::INFINITY]
        );
        assert!(doc.section("").get("b").unwrap().as_float_array().is_err());
    }
}
