//! Deterministic scoped worker pool — the multi-tenant throughput layer.
//!
//! The paper's setting is N tenants sharing M devices, and the service's
//! own bookkeeping is embarrassingly parallel across tenants: the
//! independent-GP policies update N private posteriors per completion and
//! rescore EI across per-user arm blocks, and the figure harnesses sweep
//! independent seeds. This module shards that work across OS threads with
//! a **hand-rolled, zero-dependency** pool built on [`std::thread::scope`]
//! (the offline environment ships no rayon), under one hard contract:
//!
//! > **Determinism.** Results are *byte-identical* to the single-threaded
//! > run at any thread count. Work is split into fixed shards, each shard
//! > computes exactly the floats the serial loop would, and shard results
//! > merge in fixed (index) order. Callers must only submit work whose
//! > merge is shard-boundary-invariant — per-item state updates, indexed
//! > result slots, or lowest-index argmax folds; *never* order-sensitive
//! > float reductions across items.
//!
//! CI enforces the contract end-to-end: the `bench-smoke` job runs the
//! whole figure suite at `MMGPEI_THREADS=1` and `=4` and `cmp`s the
//! emitted reports byte for byte.
//!
//! **Sizing.** `MMGPEI_THREADS` picks the thread count everywhere; when
//! unset, policies stay serial (threads = 1), and bench binaries default
//! to 1 in `--smoke` (the CI preset) or the machine's parallelism
//! (capped) for full runs — see [`resolve_threads`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Cap on the auto-detected thread count: the sharded workloads here are
/// memory-bandwidth-bound GP sweeps, which stop scaling well before the
/// core counts of large CI machines.
pub const MAX_AUTO_THREADS: usize = 8;

/// Minimum item count before the *fine-grained* shard methods
/// ([`WorkerPool::map_chunks`], [`WorkerPool::for_each_chunk_mut`])
/// engage threads. These are called once per scheduler event, and a
/// scope spawn/join cycle costs tens of microseconds — comparable to
/// dozens of small per-user GP updates — so small tenant counts (the
/// real datasets have 9–14 served users) always run inline and only
/// paper-scale instances (50+ tenants, where late-run per-user updates
/// are tens of microseconds each) shard. Never affects results — only
/// which code path computes the identical floats.
/// [`WorkerPool::map_indexed`] is exempt: its items are whole
/// simulations, coarse enough to amortize any spawn.
pub const FINE_SHARD_MIN_ITEMS: usize = 32;

/// Thread count requested via `MMGPEI_THREADS` (≥ 1), if set and valid.
pub fn env_threads() -> Option<usize> {
    std::env::var("MMGPEI_THREADS").ok().and_then(|v| v.parse::<usize>().ok()).filter(|&t| t >= 1)
}

/// Resolve the effective thread count for a bench/CLI entry point:
/// `MMGPEI_THREADS` wins; otherwise smoke runs pin 1 (the deterministic
/// CI preset must not pay scope-spawn overhead for tiny instances) and
/// full runs take the machine's parallelism capped at
/// [`MAX_AUTO_THREADS`]. Thread count never affects results — only
/// wall-clock time.
pub fn resolve_threads(smoke: bool) -> usize {
    if let Some(t) = env_threads() {
        return t;
    }
    if smoke {
        return 1;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_AUTO_THREADS)
}

/// A fixed-width scoped worker pool. Cheap to construct and to clone —
/// it owns no threads; each parallel call spawns scoped workers that are
/// joined before the call returns, so borrowed data needs no `'static`
/// bound and panics propagate to the caller.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// Pool with an explicit width (floored at 1 = serial inline
    /// execution, no spawned threads at all).
    pub fn new(threads: usize) -> Self {
        WorkerPool { threads: threads.max(1) }
    }

    /// Pool sized by `MMGPEI_THREADS`, serial when unset — the
    /// constructor policies use, so sharding is strictly opt-in for
    /// library consumers.
    pub fn from_env() -> Self {
        Self::new(env_threads().unwrap_or(1))
    }

    /// Configured width.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether a fine-grained shard call over `n_items` would actually
    /// engage worker threads (width > 1 and at least
    /// [`FINE_SHARD_MIN_ITEMS`] items). Callers with an allocation-free
    /// serial fallback branch on this to keep their inline path
    /// zero-alloc instead of paying [`WorkerPool::map_chunks`]'s
    /// single-chunk `Vec`.
    pub fn engages(&self, n_items: usize) -> bool {
        self.threads > 1 && n_items >= FINE_SHARD_MIN_ITEMS
    }

    /// Run `f(i)` for every `i in 0..n` and return the results **in index
    /// order**. Items are claimed from an atomic counter (load-balanced —
    /// seeds/simulations have heterogeneous cost) and written into
    /// per-index slots, so scheduling order cannot leak into the output.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(n);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let value = f(i);
                    // pallas-lint: allow(R5) — a poisoned slot means a sibling worker panicked; propagating that panic is the contract.
                    *slots[i].lock().expect("result slot poisoned") = Some(value);
                });
            }
        });
        slots
            .into_iter()
            // pallas-lint: allow(R5) — the scope join guarantees every index was written; a poisoned slot re-raises a worker panic.
            .map(|m| m.into_inner().expect("result slot poisoned").expect("worker filled every slot"))
            .collect()
    }

    /// Split `0..n_items` into at most `threads` contiguous ranges, run
    /// `f` on each, and return the per-range results **in range order**.
    ///
    /// The merge the caller performs over the returned values must be
    /// invariant to where the range boundaries fall (the boundaries move
    /// with the thread count *and* the chunk count can collapse to 1 for
    /// small inputs — see [`FINE_SHARD_MIN_ITEMS`]): lowest-index argmax
    /// folds and per-range counts qualify; float sums across items do
    /// not.
    pub fn map_chunks<R, F>(&self, n_items: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(std::ops::Range<usize>) -> R + Sync,
    {
        if !self.engages(n_items) {
            return vec![f(0..n_items)];
        }
        let k = self.threads.min(n_items);
        let bounds = chunk_bounds(n_items, k);
        let slots: Vec<Mutex<Option<R>>> = (0..k).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for (c, range) in bounds.into_iter().enumerate() {
                let slot = &slots[c];
                let f = &f;
                s.spawn(move || {
                    // pallas-lint: allow(R5) — each chunk slot is touched by exactly one worker; poison re-raises that worker's panic.
                    *slot.lock().expect("chunk slot poisoned") = Some(f(range));
                });
            }
        });
        slots
            .into_iter()
            // pallas-lint: allow(R5) — scope join guarantees every chunk ran; poison re-raises the worker panic.
            .map(|m| m.into_inner().expect("chunk slot poisoned").expect("chunk computed"))
            .collect()
    }

    /// Run `f` on near-equal contiguous chunks of `items`, one scoped
    /// worker per chunk. Each item is touched by exactly one worker, so
    /// per-item state updates are trivially deterministic — this is the
    /// shard path for the per-user GP updates of the independent-GP
    /// policies.
    pub fn for_each_chunk_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut [T]) + Sync,
    {
        let n = items.len();
        if !self.engages(n) {
            f(items);
            return;
        }
        let k = self.threads.min(n);
        let sizes: Vec<usize> = chunk_bounds(n, k).into_iter().map(|r| r.len()).collect();
        std::thread::scope(|s| {
            let mut rest = items;
            for size in sizes {
                // `mem::take` moves the remainder out so the split's
                // halves don't keep `rest` itself borrowed across the
                // reassignment (the standard loop-splitting idiom).
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(size);
                rest = tail;
                let f = &f;
                s.spawn(move || f(head));
            }
        });
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::from_env()
    }
}

/// Split `0..n` into `k` contiguous near-equal ranges (first `n % k`
/// ranges take the extra item). `k` must be ≥ 1 and ≤ `max(n, 1)`.
fn chunk_bounds(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    debug_assert!(k >= 1);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for c in 0..k {
        let len = base + usize::from(c < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_returns_index_order_at_any_width() {
        for threads in [1, 2, 3, 7] {
            let pool = WorkerPool::new(threads);
            let got = pool.map_indexed(23, |i| i * i);
            let want: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_indexed_handles_empty_and_single() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_indexed(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn chunk_bounds_cover_exactly_once() {
        for n in [0usize, 1, 5, 16, 17] {
            for k in 1..=n.max(1) {
                let ranges = chunk_bounds(n, k);
                assert_eq!(ranges.len(), k);
                let mut covered = 0;
                let mut expect_start = 0;
                for r in &ranges {
                    assert_eq!(r.start, expect_start, "contiguous (n={n}, k={k})");
                    expect_start = r.end;
                    covered += r.len();
                }
                assert_eq!(covered, n, "n={n} k={k}");
                // Balanced: sizes differ by at most one, larger first.
                let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                assert!(sizes.windows(2).all(|w| w[0] >= w[1] && w[0] - w[1] <= 1), "{sizes:?}");
            }
        }
    }

    #[test]
    fn map_chunks_merge_is_width_invariant_for_argmax() {
        // The intended use: per-chunk lowest-index argmax merged in chunk
        // order equals the global serial argmax at every width.
        let scores: Vec<f64> = (0..57).map(|i| (i * 31 % 13) as f64).collect();
        let serial = {
            let mut best = f64::NEG_INFINITY;
            let mut arg = None;
            for (i, &s) in scores.iter().enumerate() {
                if s > best {
                    best = s;
                    arg = Some(i);
                }
            }
            arg
        };
        for threads in [1, 2, 3, 5, 8] {
            let pool = WorkerPool::new(threads);
            let shards = pool.map_chunks(scores.len(), |range| {
                let mut best = f64::NEG_INFINITY;
                let mut arg = None;
                for i in range {
                    if scores[i] > best {
                        best = scores[i];
                        arg = Some(i);
                    }
                }
                (best, arg)
            });
            let mut best = f64::NEG_INFINITY;
            let mut arg = None;
            for (s, a) in shards {
                if a.is_some() && s > best {
                    best = s;
                    arg = a;
                }
            }
            assert_eq!(arg, serial, "threads={threads}");
        }
    }

    #[test]
    fn for_each_chunk_mut_touches_every_item_once() {
        // 65 items clears FINE_SHARD_MIN_ITEMS so widths > 1 really
        // exercise the threaded split.
        for threads in [1, 2, 4, 9] {
            let pool = WorkerPool::new(threads);
            let mut items = vec![0u32; 65];
            pool.for_each_chunk_mut(&mut items, |chunk| {
                for v in chunk {
                    *v += 1;
                }
            });
            assert!(items.iter().all(|&v| v == 1), "threads={threads}");
        }
    }

    #[test]
    fn tiny_inputs_stay_inline() {
        // Below the fine-grained threshold the call must not shard (the
        // spawn/join cycle would cost more than the work); at the
        // threshold it must.
        let pool = WorkerPool::new(4);
        let chunks = pool.map_chunks(FINE_SHARD_MIN_ITEMS - 1, |r| r.len());
        assert_eq!(chunks, vec![FINE_SHARD_MIN_ITEMS - 1]);
        let chunks = pool.map_chunks(FINE_SHARD_MIN_ITEMS, |r| r.len());
        assert!(chunks.len() > 1, "at the threshold the input shards");
        assert_eq!(chunks.iter().sum::<usize>(), FINE_SHARD_MIN_ITEMS);
    }

    #[test]
    fn for_each_chunk_mut_empty_slice_is_fine() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<u32> = Vec::new();
        pool.for_each_chunk_mut(&mut items, |_| {});
    }

    #[test]
    fn width_floors_at_one() {
        assert_eq!(WorkerPool::new(0).threads(), 1);
        assert_eq!(WorkerPool::new(5).threads(), 5);
    }

    #[test]
    fn resolve_threads_smoke_pins_one_without_env() {
        // Can't mutate the process environment safely under parallel
        // tests; assert the env-free behavior only when the knob is
        // genuinely unset in this run.
        if env_threads().is_none() {
            assert_eq!(resolve_threads(true), 1, "smoke default must be serial");
            assert!(resolve_threads(false) >= 1);
        }
    }
}
