//! Virtual-time **tenant churn** adapter: [`simulate_churn`] replays an
//! arrival/departure timeline through the unified engine
//! ([`crate::engine`]) with [`Tenancy::Churn`] accounting — regret is
//! integrated per user over each tenant's *active windows* only (Eq. 2
//! with entry/exit integration limits), and the service keeps running
//! as the cohort turns over.
//!
//! **Policy churn contract.** The engine owns arm retirement: a departed
//! tenant's unstarted arms are folded into the `selected` mask handed to
//! [`crate::sched::Policy::select`], so every policy is churn-*correct*
//! without changes. Policies that also implement the
//! `user_joined`/`user_left` hooks (MM-GP-EI) apply the tenant change
//! *in place*; for the rest the engine falls back to the from-scratch
//! rebuild — reconstruct via the factory, replay the observation
//! history, replay the current tenant set — which is also the oracle the
//! incremental path is gated against (`rust/tests/churn.rs`,
//! `benches/fig6_churn.rs`).
//!
//! Determinism: virtual time, total event order (churn events before
//! completions at equal times; see `problem::tenancy` for the intra-tick
//! order), device-index tie-breaks — identical seeds replay identical
//! schedules, so churn reports are byte-stable.

use std::time::Duration;

use super::{Observation, SimConfig};
use crate::config::ExperimentConfig;
use crate::engine::{self, EngineParams, PolicyFactory, PolicyHost, Tenancy, VirtualClock};
use crate::metrics::StepCurve;
use crate::problem::{ChurnSchedule, Problem, Truth};

/// Result of one simulated churn run.
#[derive(Clone, Debug)]
pub struct ChurnResult {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub observations: Vec<Observation>,
    /// Average gap over the *currently active* tenants (0 when none).
    pub inst_regret: StepCurve,
    /// `Σ_u` of [`ChurnResult::per_user_regret`] — Eq. 2 summed over
    /// tenants, each integrated over its own active windows.
    pub cumulative_regret: f64,
    /// Per-tenant regret at exit: `∫ gap_u(t) dt` over user `u`'s active
    /// windows (clipped at the report horizon).
    pub per_user_regret: Vec<f64>,
    /// Virtual time from a tenant's (most recent unserved) arrival to the
    /// first dispatch of one of its arms; `None` if it was never served.
    pub join_latency: Vec<Option<f64>>,
    /// Report horizon actually used.
    pub horizon: f64,
    /// Last event time.
    pub makespan: f64,
    /// Wall-clock time spent inside the policy (`select` + `observe`).
    pub decision_wall_time: Duration,
    /// Number of `select` calls answered.
    pub n_decisions: usize,
    /// Churn events the policy could not apply in place (each one cost a
    /// from-scratch rebuild + history replay). 0 for MM-GP-EI.
    pub n_rebuilds: usize,
}

/// Run one churn simulation of the factory's policy on
/// `(problem, truth, schedule)`.
///
/// The problem spans the full tenant universe; `schedule` decides who is
/// active when (every tenant starts inactive — see `problem::tenancy`).
/// A tenant's arrival enqueues its `config.warm_start_per_user` cheapest
/// not-yet-run arms (the paper's warm-start protocol applied per
/// arrival) and wakes idle devices. `config.horizon` clips (or extends)
/// the regret integrals; `config.stop_at_cutoff` is ignored — an empty
/// service floor has zero gap, so the cutoff is meaningless under churn.
pub fn simulate_churn(
    problem: &Problem,
    truth: &Truth,
    schedule: &ChurnSchedule,
    factory: &PolicyFactory,
    config: &SimConfig,
) -> ChurnResult {
    assert!(config.n_devices >= 1, "need at least one device");
    let fleet = ExperimentConfig::device_fleet(config.n_devices);
    let mut clock = VirtualClock::new(config.n_devices);
    let params = EngineParams {
        problem,
        truth,
        sched_view: None,
        cost_model: None,
        fleet: &fleet,
        tenancy: Tenancy::Churn(schedule),
        warm_start_per_user: config.warm_start_per_user,
        horizon: config.horizon,
        stop_at_cutoff: None,
        time_scale: 1.0,
        collect_decision_latencies: false,
        faults: None,
        verbose: false,
    };
    let run = engine::run(&params, PolicyHost::from_factory(factory), &mut clock);
    ChurnResult {
        policy: run.policy,
        observations: run.observations,
        inst_regret: run.curve,
        cumulative_regret: run.cumulative_regret,
        per_user_regret: run.per_user_regret,
        join_latency: run.join_latency,
        horizon: run.horizon,
        makespan: run.makespan,
        decision_wall_time: run.decision_wall_time,
        n_decisions: run.n_decisions,
        n_rebuilds: run.n_rebuilds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::{ChurnEvent, ChurnEventKind};
    use crate::sched::{ForceRebuild, GpEiRoundRobin, MmGpEi, Policy};
    use crate::workload::{churn_workload, ChurnConfig};

    fn small_cfg() -> ChurnConfig {
        ChurnConfig {
            n_users: 6,
            n_models: 4,
            initial_users: 2,
            arrival_gap: 2.0,
            sojourn: (6.0, 14.0),
            rejoin_prob: 0.5,
            rejoin_gap: 3.0,
            ..Default::default()
        }
    }

    fn sim_cfg(devices: usize) -> SimConfig {
        SimConfig { n_devices: devices, warm_start_per_user: 2, horizon: None, stop_at_cutoff: None }
    }

    #[test]
    fn serves_only_active_tenants() {
        let (p, t, s) = churn_workload(&small_cfg(), 3);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        // Every dispatched arm's owner was active at dispatch time.
        let windows: Vec<Vec<(f64, f64)>> = {
            let mut w: Vec<Vec<(f64, f64)>> = vec![Vec::new(); p.n_users];
            let mut open = vec![f64::NAN; p.n_users];
            for e in s.events() {
                match e.kind {
                    ChurnEventKind::Arrival => open[e.user] = e.time,
                    ChurnEventKind::Departure => w[e.user].push((open[e.user], e.time)),
                }
            }
            w
        };
        assert!(!r.observations.is_empty());
        for o in &r.observations {
            let u = p.arm_users[o.arm][0];
            let inside = windows[u].iter().any(|&(a, d)| a <= o.start && o.start < d);
            assert!(inside, "arm {} of user {u} dispatched at {} outside every window", o.arm, o.start);
        }
        assert_eq!(r.n_rebuilds, 0, "MM-GP-EI applies churn in place");
    }

    #[test]
    fn per_user_regret_sums_to_cumulative_and_is_nonnegative() {
        let (p, t, s) = churn_workload(&small_cfg(), 7);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        assert!((r.per_user_regret.iter().sum::<f64>() - r.cumulative_regret).abs() < 1e-9);
        assert!(r.per_user_regret.iter().all(|&x| x >= 0.0));
        // A tenant that was served has a measured join latency ≥ 0.
        for (u, lat) in r.join_latency.iter().enumerate() {
            if let Some(l) = lat {
                assert!(*l >= 0.0, "user {u} latency {l}");
            }
        }
        // Someone was served.
        assert!(r.join_latency.iter().any(|l| l.is_some()));
    }

    #[test]
    fn baselines_run_under_churn_via_rebuild() {
        let (p, t, s) = churn_workload(&small_cfg(), 5);
        let factory =
            |p: &Problem| -> Box<dyn Policy> { Box::new(GpEiRoundRobin::with_pool(p, crate::pool::WorkerPool::new(1))) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        assert!(r.n_rebuilds > 0, "default hooks must route through the rebuild path");
        assert!(!r.observations.is_empty());
        assert!(r.cumulative_regret >= 0.0);
    }

    #[test]
    fn incremental_equals_rebuild_oracle_end_to_end() {
        // The acceptance gate in miniature: the incremental MM-GP-EI and
        // the forced-rebuild oracle must replay bit-identical schedules
        // and regret — including leave-then-rejoin (rejoin_prob > 0).
        let (p, t, s) = churn_workload(&small_cfg(), 11);
        let inc = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let oracle = |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
        let a = simulate_churn(&p, &t, &s, &inc, &sim_cfg(3));
        let b = simulate_churn(&p, &t, &s, &oracle, &sim_cfg(3));
        assert!(b.n_rebuilds > 0 && a.n_rebuilds == 0);
        let key = |r: &ChurnResult| -> Vec<(usize, usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.device, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&a), key(&b), "incremental and rebuild schedules must be bit-identical");
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.per_user_regret), bits(&b.per_user_regret));
        assert_eq!(a.inst_regret, b.inst_regret);
        assert_eq!(a.join_latency, b.join_latency);
    }

    #[test]
    fn deterministic_replay() {
        let (p, t, s) = churn_workload(&small_cfg(), 13);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let a = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        let b = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        let key = |r: &ChurnResult| -> Vec<(usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&a), key(&b));
        assert_eq!(a.cumulative_regret.to_bits(), b.cumulative_regret.to_bits());
    }

    #[test]
    fn horizon_clips_churn_regret_windows() {
        let (p, t, s) = churn_workload(&small_cfg(), 17);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let full = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        let clipped = simulate_churn(
            &p,
            &t,
            &s,
            &factory,
            &SimConfig {
                n_devices: 2,
                warm_start_per_user: 2,
                horizon: Some(full.makespan / 2.0),
                stop_at_cutoff: None,
            },
        );
        assert!(clipped.cumulative_regret <= full.cumulative_regret + 1e-9);
        assert!(clipped.inst_regret.end_time() <= full.makespan / 2.0 + 1e-12);
        for (c, f) in clipped.per_user_regret.iter().zip(&full.per_user_regret) {
            assert!(c <= &(f + 1e-9), "clipping cannot increase a tenant's regret");
        }
    }

    #[test]
    fn handcrafted_leave_then_rejoin_is_served_again() {
        // 2 users × 2 arms, user 1 leaves before its arms run and rejoins
        // later: its arms must be blocked in between and served after.
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "rejoin".into(),
            n_users: 2,
            cost: vec![1.0; 4],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: crate::linalg::Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.7, 0.8, 0.9] };
        let s = ChurnSchedule::new(vec![
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 0.0, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 0.5, user: 1, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 10.0, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 20.0, user: 1, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 20.0, user: 0, kind: ChurnEventKind::Departure },
        ]);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(1));
        // User 1's arms (2, 3) must only start at/after the rejoin…
        for o in &r.observations {
            if o.arm >= 2 {
                assert!(o.start >= 10.0, "arm {} started at {} during the absence", o.arm, o.start);
            }
        }
        // …and they do get served after it.
        assert!(r.observations.iter().any(|o| o.arm >= 2), "rejoined tenant must be served");
        // User 1 accrues regret only over [0, 0.5) ∪ [10, …): its regret
        // is strictly less than a full-window tenant's worst case.
        assert!(r.per_user_regret[1] > 0.0);
    }
}
