//! Discrete-event simulation with **tenant churn**: the event loop gains
//! [`ChurnEventKind::Arrival`] / [`ChurnEventKind::Departure`] event
//! kinds alongside completions, regret is integrated per user over each
//! tenant's *active windows* only (Eq. 2 with entry/exit integration
//! limits), and the service keeps running as the cohort turns over.
//!
//! **Policy churn contract.** The driver owns arm retirement: a departed
//! tenant's unstarted arms are folded into the `selected` mask handed to
//! [`Policy::select`], so every policy is churn-*correct* without
//! changes. Policies that also implement [`Policy::user_joined`] /
//! [`Policy::user_left`] (MM-GP-EI) apply the tenant change *in place*;
//! for the rest the driver falls back to the from-scratch rebuild —
//! reconstruct via the factory, replay the observation history, replay
//! the current tenant set — which is also the oracle the incremental
//! path is gated against (`rust/tests/churn.rs`, `benches/fig6_churn.rs`).
//!
//! Determinism: virtual time, total event order (churn events before
//! completions at equal times; see `problem::tenancy` for the intra-tick
//! order), device-index tie-breaks — identical seeds replay identical
//! schedules, so churn reports are byte-stable.

use std::collections::{BinaryHeap, VecDeque};
use std::time::{Duration, Instant};

use super::{Completion, Observation, SimConfig};
use crate::metrics::StepCurve;
use crate::problem::{ArmId, ChurnEventKind, ChurnSchedule, Problem, TenantSet, Truth, UserId};
use crate::sched::{Incumbents, Policy, SchedContext};

/// Result of one simulated churn run.
#[derive(Clone, Debug)]
pub struct ChurnResult {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub observations: Vec<Observation>,
    /// Average gap over the *currently active* tenants (0 when none).
    pub inst_regret: StepCurve,
    /// `Σ_u` of [`ChurnResult::per_user_regret`] — Eq. 2 summed over
    /// tenants, each integrated over its own active windows.
    pub cumulative_regret: f64,
    /// Per-tenant regret at exit: `∫ gap_u(t) dt` over user `u`'s active
    /// windows (clipped at the report horizon).
    pub per_user_regret: Vec<f64>,
    /// Virtual time from a tenant's (most recent unserved) arrival to the
    /// first dispatch of one of its arms; `None` if it was never served.
    pub join_latency: Vec<Option<f64>>,
    /// Report horizon actually used.
    pub horizon: f64,
    /// Last event time.
    pub makespan: f64,
    /// Wall-clock time spent inside the policy (`select` + `observe`).
    pub decision_wall_time: Duration,
    /// Number of `select` calls answered.
    pub n_decisions: usize,
    /// Churn events the policy could not apply in place (each one cost a
    /// from-scratch rebuild + history replay). 0 for MM-GP-EI.
    pub n_rebuilds: usize,
}

/// From-scratch rebuild: reconstruct the policy, replay the observation
/// history in completion order, then replay the current tenant set (so
/// churn-capable policies freeze the absent tenants' state). This is the
/// fallback for policies whose churn hooks return `false` — and the
/// oracle the incremental hooks are validated against.
pub(crate) fn rebuild_policy(
    factory: &dyn Fn(&Problem) -> Box<dyn Policy>,
    problem: &Problem,
    tenants: &TenantSet,
    history: &[(ArmId, f64)],
) -> Box<dyn Policy> {
    let mut policy = factory(problem);
    for &(a, z) in history {
        policy.observe(problem, a, z);
    }
    for u in 0..problem.n_users {
        if !tenants.is_active(u) {
            let _ = policy.user_left(problem, u);
        }
    }
    policy
}

/// Run one churn simulation of the factory's policy on
/// `(problem, truth, schedule)`.
///
/// The problem spans the full tenant universe; `schedule` decides who is
/// active when (every tenant starts inactive — see `problem::tenancy`).
/// A tenant's arrival enqueues its `config.warm_start_per_user` cheapest
/// not-yet-run arms (the paper's warm-start protocol applied per
/// arrival) and wakes idle devices. `config.horizon` clips (or extends)
/// the regret integrals; `config.stop_at_cutoff` is ignored — an empty
/// service floor has zero gap, so the cutoff is meaningless under churn.
pub fn simulate_churn(
    problem: &Problem,
    truth: &Truth,
    schedule: &ChurnSchedule,
    factory: &dyn Fn(&Problem) -> Box<dyn Policy>,
    config: &SimConfig,
) -> ChurnResult {
    assert!(config.n_devices >= 1, "need at least one device");
    let n_arms = problem.n_arms();
    let n_users = problem.n_users;
    assert_eq!(truth.z.len(), n_arms);
    assert!(
        schedule.n_users_seen() <= n_users,
        "schedule references user {} but the problem has {} users",
        schedule.n_users_seen().saturating_sub(1),
        n_users
    );
    assert_disjoint_tenancy(problem);

    let mut policy = factory(problem);
    // Everyone starts inactive. A fresh policy with an empty history is
    // already "rebuilt", so unsupported hooks are simply ignored here.
    for u in 0..n_users {
        let _ = policy.user_left(problem, u);
    }
    let mut tenants = TenantSet::none_active(n_users);
    let mut retired = vec![true; n_arms];
    let mut selected = vec![false; n_arms];
    // The mask policies see: selected ∪ retired.
    let mut blocked = vec![true; n_arms];
    let mut observed = vec![false; n_arms];
    let mut warm: VecDeque<ArmId> = VecDeque::new();
    let mut history: Vec<(ArmId, f64)> = Vec::new();
    let mut n_rebuilds = 0usize;

    // Regret accounting (same empty-incumbent reference as `simulate`).
    let z_star: Vec<f64> = (0..n_users).map(|u| truth.best_value(problem, u)).collect();
    let empty_ref: Vec<f64> = (0..n_users)
        .map(|u| problem.user_arms[u].iter().map(|&a| truth.z[a]).fold(0.0f64, f64::min))
        .collect();
    let mut incumbents = Incumbents::new(n_users);
    let user_gap = |inc: &Incumbents, u: UserId| -> f64 {
        let b = if inc.has_observation(u) { inc.value(u) } else { empty_ref[u] };
        (z_star[u] - b).max(0.0)
    };
    let avg_active_gap = |inc: &Incumbents, tenants: &TenantSet| -> f64 {
        if tenants.n_active() == 0 {
            0.0
        } else {
            tenants.active_users().map(|u| user_gap(inc, u)).sum::<f64>()
                / tenants.n_active() as f64
        }
    };

    let mut per_user_regret = vec![0.0; n_users];
    let mut arrival_time = vec![0.0f64; n_users];
    let mut waiting_first_dispatch = vec![false; n_users];
    let mut join_latency: Vec<Option<f64>> = vec![None; n_users];

    let mut completions: BinaryHeap<Completion> = BinaryHeap::new();
    let mut idle: Vec<usize> = Vec::new();
    let mut observations = Vec::with_capacity(n_arms);
    let mut decision_wall = Duration::ZERO;
    let mut n_decisions = 0usize;
    let mut inst_curve = StepCurve::new(0.0);
    let mut t_prev = 0.0f64;

    // Dispatch helper: next arm for a free device at time `now`; the
    // device parks in `idle` when no candidate is dispatchable.
    let dispatch = |now: f64,
                        device: usize,
                        selected: &mut [bool],
                        blocked: &mut [bool],
                        observed: &[bool],
                        warm: &mut VecDeque<ArmId>,
                        policy: &mut dyn Policy,
                        completions: &mut BinaryHeap<Completion>,
                        idle: &mut Vec<usize>,
                        waiting: &mut [bool],
                        join_latency: &mut [Option<f64>],
                        arrival_time: &[f64],
                        decision_wall: &mut Duration,
                        n_decisions: &mut usize| {
        while let Some(&a) = warm.front() {
            if blocked[a] {
                warm.pop_front();
            } else {
                break;
            }
        }
        let arm = if let Some(a) = warm.pop_front() {
            Some(a)
        } else {
            let ctx = SchedContext { problem, selected: blocked, observed, now };
            let t0 = Instant::now();
            let pick = policy.select(&ctx);
            *decision_wall += t0.elapsed();
            *n_decisions += 1;
            pick
        };
        if let Some(a) = arm {
            assert!(!blocked[a], "policy returned a blocked (selected/retired) arm {a}");
            selected[a] = true;
            blocked[a] = true;
            for &u in &problem.arm_users[a] {
                if waiting[u] {
                    waiting[u] = false;
                    join_latency[u] = Some(now - arrival_time[u]);
                }
            }
            completions.push(Completion { finish: now + problem.cost[a], device, arm: a, start: now });
        } else {
            idle.push(device);
            idle.sort_unstable();
        }
    };

    let churn_events = schedule.events();
    let mut next_evt = 0usize;

    // Apply the t = 0 events (the initial cohort arrives) before the
    // devices first ask for work.
    while next_evt < churn_events.len() && churn_events[next_evt].time == 0.0 {
        let e = churn_events[next_evt];
        next_evt += 1;
        debug_assert_eq!(e.kind, ChurnEventKind::Arrival, "schedule starts everyone inactive");
        if tenants.activate(e.user) {
            if !policy.user_joined(problem, e.user) {
                // Fresh policy + empty history: already equivalent to a
                // rebuild — no work to replay.
                debug_assert!(history.is_empty());
            }
            tenants.refresh_retired_for_user(problem, e.user, &mut retired);
            for &x in &problem.user_arms[e.user] {
                blocked[x] = selected[x] || retired[x];
            }
            enqueue_warm_arms(problem, e.user, config.warm_start_per_user, &selected, &mut warm);
            arrival_time[e.user] = 0.0;
            waiting_first_dispatch[e.user] = true;
        }
    }
    inst_curve.push(0.0, avg_active_gap(&incumbents, &tenants));
    for d in 0..config.n_devices {
        dispatch(
            0.0,
            d,
            &mut selected,
            &mut blocked,
            &observed,
            &mut warm,
            policy.as_mut(),
            &mut completions,
            &mut idle,
            &mut waiting_first_dispatch,
            &mut join_latency,
            &arrival_time,
            &mut decision_wall,
            &mut n_decisions,
        );
    }

    // Unified event loop: next event is the earlier of the next churn
    // event and the next completion; churn applies first on ties.
    loop {
        let next_completion = completions.peek().map(|c| c.finish);
        let next_churn = churn_events.get(next_evt).map(|e| e.time);
        let (now, churn_first) = match (next_completion, next_churn) {
            (None, None) => break,
            (Some(c), None) => (c, false),
            (None, Some(e)) => (e, true),
            (Some(c), Some(e)) => {
                if e <= c {
                    (e, true)
                } else {
                    (c, false)
                }
            }
        };

        // Integrate per-user regret over [t_prev, now), clipped at the
        // horizon (exact Eq. 2 truncation per active window).
        let (lo, hi) = match config.horizon {
            Some(h) => (t_prev.min(h), now.min(h)),
            None => (t_prev, now),
        };
        let dt = (hi - lo).max(0.0);
        if dt > 0.0 {
            for u in tenants.active_users() {
                per_user_regret[u] += user_gap(&incumbents, u) * dt;
            }
        }
        t_prev = now;

        if churn_first {
            // Drain every churn event scheduled at this instant
            // (departures first — the schedule is pre-ordered).
            while next_evt < churn_events.len() && churn_events[next_evt].time == now {
                let e = churn_events[next_evt];
                next_evt += 1;
                match e.kind {
                    ChurnEventKind::Arrival => {
                        if !tenants.activate(e.user) {
                            continue;
                        }
                        // With an empty history a fresh policy is already
                        // the rebuilt policy — skip the reconstruction
                        // (same rule as `coordinator::serve_churn`, so
                        // the `rebuilds` KPI is comparable across loops).
                        if !policy.user_joined(problem, e.user) && !history.is_empty() {
                            n_rebuilds += 1;
                            policy = rebuild_policy(factory, problem, &tenants, &history);
                        }
                        tenants.refresh_retired_for_user(problem, e.user, &mut retired);
                        for &x in &problem.user_arms[e.user] {
                            blocked[x] = selected[x] || retired[x];
                        }
                        enqueue_warm_arms(
                            problem,
                            e.user,
                            config.warm_start_per_user,
                            &selected,
                            &mut warm,
                        );
                        if join_latency[e.user].is_none() {
                            arrival_time[e.user] = now;
                            waiting_first_dispatch[e.user] = true;
                        }
                    }
                    ChurnEventKind::Departure => {
                        if !tenants.deactivate(e.user) {
                            continue;
                        }
                        if !policy.user_left(problem, e.user) && !history.is_empty() {
                            n_rebuilds += 1;
                            policy = rebuild_policy(factory, problem, &tenants, &history);
                        }
                        tenants.refresh_retired_for_user(problem, e.user, &mut retired);
                        for &x in &problem.user_arms[e.user] {
                            blocked[x] = selected[x] || retired[x];
                        }
                        waiting_first_dispatch[e.user] = false;
                    }
                }
            }
            inst_curve.push(now, avg_active_gap(&incumbents, &tenants));
            // Arrivals may have made arms dispatchable: wake every idle
            // device, in ascending index order (determinism).
            let woken = std::mem::take(&mut idle);
            for d in woken {
                dispatch(
                    now,
                    d,
                    &mut selected,
                    &mut blocked,
                    &observed,
                    &mut warm,
                    policy.as_mut(),
                    &mut completions,
                    &mut idle,
                    &mut waiting_first_dispatch,
                    &mut join_latency,
                    &arrival_time,
                    &mut decision_wall,
                    &mut n_decisions,
                );
            }
        } else {
            let c = completions.pop().expect("completion peeked above");
            let z = truth.z[c.arm];
            observed[c.arm] = true;
            let t0 = Instant::now();
            policy.observe(problem, c.arm, z);
            decision_wall += t0.elapsed();
            history.push((c.arm, z));
            observations.push(Observation {
                arm: c.arm,
                start: c.start,
                finish: now,
                z,
                device: c.device,
            });
            incumbents.update_arm(problem, c.arm, z);
            inst_curve.push(now, avg_active_gap(&incumbents, &tenants));
            dispatch(
                now,
                c.device,
                &mut selected,
                &mut blocked,
                &observed,
                &mut warm,
                policy.as_mut(),
                &mut completions,
                &mut idle,
                &mut waiting_first_dispatch,
                &mut join_latency,
                &arrival_time,
                &mut decision_wall,
                &mut n_decisions,
            );
        }
    }

    let makespan = t_prev;
    let horizon = config.horizon.unwrap_or(makespan);
    if horizon > makespan {
        // Extend each still-active tenant's window with its final gap.
        for u in tenants.active_users() {
            per_user_regret[u] += user_gap(&incumbents, u) * (horizon - makespan);
        }
    } else if horizon < makespan {
        inst_curve = inst_curve.truncated(horizon);
    }
    let cumulative_regret = per_user_regret.iter().sum();

    ChurnResult {
        policy: policy.name(),
        observations,
        inst_regret: inst_curve,
        cumulative_regret,
        per_user_regret,
        join_latency,
        horizon,
        makespan,
        decision_wall_time: decision_wall,
        n_decisions,
        n_rebuilds,
    }
}

/// Churn requires **disjoint per-tenant arm blocks**: an arm shared by
/// tenants that churn independently has no well-defined incremental
/// semantics (the departed owner's dropped incumbent would still price
/// the arm for the remaining owner, diverging from the rebuild oracle).
/// Both churn drivers fail loudly instead of silently diverging.
pub(crate) fn assert_disjoint_tenancy(problem: &Problem) {
    for (x, owners) in problem.arm_users.iter().enumerate() {
        assert!(
            owners.len() == 1,
            "churn requires disjoint per-tenant arm blocks; arm {x} is shared by users {owners:?}"
        );
    }
}

/// Enqueue `per_user` cheapest not-yet-run arms of `user` (ties broken
/// by arm id — the same order [`Problem::warm_start_arms`] uses), the
/// paper's warm-start protocol applied at each arrival. Shared with the
/// live loop (`coordinator::serve_churn`).
pub(crate) fn enqueue_warm_arms(
    problem: &Problem,
    user: UserId,
    per_user: usize,
    selected: &[bool],
    warm: &mut VecDeque<ArmId>,
) {
    if per_user == 0 {
        return;
    }
    let mut arms: Vec<ArmId> =
        problem.user_arms[user].iter().copied().filter(|&a| !selected[a]).collect();
    arms.sort_by(|&a, &b| problem.cost[a].partial_cmp(&problem.cost[b]).unwrap().then(a.cmp(&b)));
    for &a in arms.iter().take(per_user) {
        warm.push_back(a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ChurnEvent;
    use crate::sched::{ForceRebuild, GpEiRoundRobin, MmGpEi};
    use crate::workload::{churn_workload, ChurnConfig};

    fn small_cfg() -> ChurnConfig {
        ChurnConfig {
            n_users: 6,
            n_models: 4,
            initial_users: 2,
            arrival_gap: 2.0,
            sojourn: (6.0, 14.0),
            rejoin_prob: 0.5,
            rejoin_gap: 3.0,
            ..Default::default()
        }
    }

    fn sim_cfg(devices: usize) -> SimConfig {
        SimConfig { n_devices: devices, warm_start_per_user: 2, horizon: None, stop_at_cutoff: None }
    }

    #[test]
    fn serves_only_active_tenants() {
        let (p, t, s) = churn_workload(&small_cfg(), 3);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        // Every dispatched arm's owner was active at dispatch time.
        let windows: Vec<Vec<(f64, f64)>> = {
            let mut w: Vec<Vec<(f64, f64)>> = vec![Vec::new(); p.n_users];
            let mut open = vec![f64::NAN; p.n_users];
            for e in s.events() {
                match e.kind {
                    ChurnEventKind::Arrival => open[e.user] = e.time,
                    ChurnEventKind::Departure => w[e.user].push((open[e.user], e.time)),
                }
            }
            w
        };
        assert!(!r.observations.is_empty());
        for o in &r.observations {
            let u = p.arm_users[o.arm][0];
            let inside = windows[u].iter().any(|&(a, d)| a <= o.start && o.start < d);
            assert!(inside, "arm {} of user {u} dispatched at {} outside every window", o.arm, o.start);
        }
        assert_eq!(r.n_rebuilds, 0, "MM-GP-EI applies churn in place");
    }

    #[test]
    fn per_user_regret_sums_to_cumulative_and_is_nonnegative() {
        let (p, t, s) = churn_workload(&small_cfg(), 7);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        assert!((r.per_user_regret.iter().sum::<f64>() - r.cumulative_regret).abs() < 1e-9);
        assert!(r.per_user_regret.iter().all(|&x| x >= 0.0));
        // A tenant that was served has a measured join latency ≥ 0.
        for (u, lat) in r.join_latency.iter().enumerate() {
            if let Some(l) = lat {
                assert!(*l >= 0.0, "user {u} latency {l}");
            }
        }
        // Someone was served.
        assert!(r.join_latency.iter().any(|l| l.is_some()));
    }

    #[test]
    fn baselines_run_under_churn_via_rebuild() {
        let (p, t, s) = churn_workload(&small_cfg(), 5);
        let factory =
            |p: &Problem| -> Box<dyn Policy> { Box::new(GpEiRoundRobin::with_pool(p, crate::pool::WorkerPool::new(1))) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        assert!(r.n_rebuilds > 0, "default hooks must route through the rebuild path");
        assert!(!r.observations.is_empty());
        assert!(r.cumulative_regret >= 0.0);
    }

    #[test]
    fn incremental_equals_rebuild_oracle_end_to_end() {
        // The acceptance gate in miniature: the incremental MM-GP-EI and
        // the forced-rebuild oracle must replay bit-identical schedules
        // and regret — including leave-then-rejoin (rejoin_prob > 0).
        let (p, t, s) = churn_workload(&small_cfg(), 11);
        let inc = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let oracle = |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
        let a = simulate_churn(&p, &t, &s, &inc, &sim_cfg(3));
        let b = simulate_churn(&p, &t, &s, &oracle, &sim_cfg(3));
        assert!(b.n_rebuilds > 0 && a.n_rebuilds == 0);
        let key = |r: &ChurnResult| -> Vec<(usize, usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.device, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&a), key(&b), "incremental and rebuild schedules must be bit-identical");
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.per_user_regret), bits(&b.per_user_regret));
        assert_eq!(a.inst_regret, b.inst_regret);
        assert_eq!(a.join_latency, b.join_latency);
    }

    #[test]
    fn deterministic_replay() {
        let (p, t, s) = churn_workload(&small_cfg(), 13);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let a = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        let b = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        let key = |r: &ChurnResult| -> Vec<(usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&a), key(&b));
        assert_eq!(a.cumulative_regret.to_bits(), b.cumulative_regret.to_bits());
    }

    #[test]
    fn horizon_clips_churn_regret_windows() {
        let (p, t, s) = churn_workload(&small_cfg(), 17);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let full = simulate_churn(&p, &t, &s, &factory, &sim_cfg(2));
        let clipped = simulate_churn(
            &p,
            &t,
            &s,
            &factory,
            &SimConfig {
                n_devices: 2,
                warm_start_per_user: 2,
                horizon: Some(full.makespan / 2.0),
                stop_at_cutoff: None,
            },
        );
        assert!(clipped.cumulative_regret <= full.cumulative_regret + 1e-9);
        assert!(clipped.inst_regret.end_time() <= full.makespan / 2.0 + 1e-12);
        for (c, f) in clipped.per_user_regret.iter().zip(&full.per_user_regret) {
            assert!(c <= &(f + 1e-9), "clipping cannot increase a tenant's regret");
        }
    }

    #[test]
    fn handcrafted_leave_then_rejoin_is_served_again() {
        // 2 users × 2 arms, user 1 leaves before its arms run and rejoins
        // later: its arms must be blocked in between and served after.
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let p = Problem {
            name: "rejoin".into(),
            n_users: 2,
            cost: vec![1.0; 4],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: crate::linalg::Mat::eye(4),
        };
        let t = Truth { z: vec![0.6, 0.7, 0.8, 0.9] };
        let s = ChurnSchedule::new(vec![
            ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 0.0, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 0.5, user: 1, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 10.0, user: 1, kind: ChurnEventKind::Arrival },
            ChurnEvent { time: 20.0, user: 1, kind: ChurnEventKind::Departure },
            ChurnEvent { time: 20.0, user: 0, kind: ChurnEventKind::Departure },
        ]);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let r = simulate_churn(&p, &t, &s, &factory, &sim_cfg(1));
        // User 1's arms (2, 3) must only start at/after the rejoin…
        for o in &r.observations {
            if o.arm >= 2 {
                assert!(o.start >= 10.0, "arm {} started at {} during the absence", o.arm, o.start);
            }
        }
        // …and they do get served after it.
        assert!(r.observations.iter().any(|o| o.arm >= 2), "rejoined tenant must be served");
        // User 1 accrues regret only over [0, 0.5) ∪ [10, …): its regret
        // is strictly less than a full-window tenant's worst case.
        assert!(r.per_user_regret[1] > 0.0);
    }
}
