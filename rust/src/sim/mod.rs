//! Discrete-event simulation of the multi-device AutoML service.
//!
//! The paper's testbed runs real training jobs on real machines; for a
//! reproducible reproduction we simulate in **virtual time** (DESIGN.md
//! §3): devices are slots in an event queue, running arm `x` occupies a
//! device for exactly `c(x)` time units, and the completion reveals the
//! hidden `z(x)`. Regret is a function of the schedule only, so virtual
//! time preserves every quantity the paper plots while making runs
//! deterministic.
//!
//! The driver implements the paper's §6.1 protocol: an optional warm-start
//! phase (the two cheapest models per user) runs before the policy takes
//! over; each device, upon becoming free, immediately asks the policy for
//! the next arm.

pub(crate) mod churn;

pub use churn::{simulate_churn, ChurnResult};

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

use crate::metrics::StepCurve;
use crate::problem::{ArmId, Problem, Truth};
use crate::sched::{Incumbents, Policy, SchedContext};

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Number of devices `M`.
    pub n_devices: usize,
    /// Warm-start arms per user (paper protocol: 2 fastest). 0 disables.
    pub warm_start_per_user: usize,
    /// Report horizon `T` for the cumulative regret; defaults to the last
    /// completion time when `None`.
    pub horizon: Option<f64>,
    /// Stop the run as soon as the average instantaneous regret drops to
    /// this cutoff (the Figure-5 convergence-time protocol only needs the
    /// hitting time, not the tail of the schedule). `None` runs to
    /// exhaustion. When triggered, `cumulative_regret`/`makespan` cover
    /// only the truncated schedule.
    pub stop_at_cutoff: Option<f64>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { n_devices: 1, warm_start_per_user: 2, horizon: None, stop_at_cutoff: None }
    }
}

/// One finished evaluation.
#[derive(Clone, Debug)]
pub struct Observation {
    /// Which arm.
    pub arm: ArmId,
    /// Dispatch time.
    pub start: f64,
    /// Completion time (`start + c(arm)`).
    pub finish: f64,
    /// Revealed performance.
    pub z: f64,
    /// Device index that ran it.
    pub device: usize,
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub observations: Vec<Observation>,
    /// Instantaneous regret (average gap over users) as a step curve.
    pub inst_regret: StepCurve,
    /// Cumulative regret `Regret_T` (Eq. 2) at the report horizon.
    pub cumulative_regret: f64,
    /// Report horizon actually used.
    pub horizon: f64,
    /// Last completion time.
    pub makespan: f64,
    /// Total wall-clock time spent inside the policy (`select` +
    /// `observe`) — the scheduler-overhead metric for §Perf.
    pub decision_wall_time: Duration,
    /// Number of `select` calls answered.
    pub n_decisions: usize,
}

impl SimResult {
    /// Convergence time: first time instantaneous regret ≤ cutoff.
    pub fn time_to(&self, cutoff: f64) -> Option<f64> {
        self.inst_regret.first_time_leq(cutoff)
    }
}

/// Clone `problem` with the scheduler-visible costs replaced by the
/// estimates `ĉ(x)` (Remark 1). Construct policies against this view
/// when driving [`simulate_with_estimates`].
pub fn with_cost_estimates(problem: &Problem, estimated: &[f64]) -> Problem {
    assert_eq!(estimated.len(), problem.n_arms());
    let mut view = problem.clone();
    view.cost = estimated.to_vec();
    view.validate();
    view
}

/// Completion event ordered by time (min-heap via `Reverse`-style cmp).
/// Shared with the churn event loop (`sim::churn`).
pub(crate) struct Completion {
    pub(crate) finish: f64,
    pub(crate) device: usize,
    pub(crate) arm: ArmId,
    pub(crate) start: f64,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Completion {}
impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Completion {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        // `total_cmp` makes the order *total* (no NaN panic, no
        // platform-dependent partial_cmp escape hatch), and equal finish
        // times break deterministically by device index so identical
        // seeds replay identical schedules everywhere — the same-cost
        // warm-start burst at t = 0 would otherwise leave the completion
        // order to heap internals.
        other
            .finish
            .total_cmp(&self.finish)
            .then_with(|| other.device.cmp(&self.device))
    }
}

/// Run one simulation of `policy` on `(problem, truth)`.
///
/// Panics if the policy returns an already-selected arm (scheduler bug —
/// the paper's devices never run the same model twice).
pub fn simulate(
    problem: &Problem,
    truth: &Truth,
    policy: &mut dyn Policy,
    config: &SimConfig,
) -> SimResult {
    simulate_with_estimates(problem, truth, policy, config, None)
}

/// Like [`simulate`], but the *scheduler* sees estimated costs `ĉ(x)`
/// while devices charge the true `c(x)` — the paper's Remark 1 setting
/// ("it is easy to estimate an approximate (but high accurate) value …
/// this approximation does not degrade the performance"). The policy
/// must have been constructed against the same estimated-cost view
/// (see [`with_cost_estimates`]).
pub fn simulate_with_estimates(
    problem: &Problem,
    truth: &Truth,
    policy: &mut dyn Policy,
    config: &SimConfig,
    estimated_cost: Option<&[f64]>,
) -> SimResult {
    let view_storage;
    let view: &Problem = match estimated_cost {
        Some(est) => {
            assert_eq!(est.len(), problem.n_arms());
            view_storage = with_cost_estimates(problem, est);
            &view_storage
        }
        None => problem,
    };
    assert!(config.n_devices >= 1, "need at least one device");
    assert_eq!(truth.z.len(), problem.n_arms());

    let n_arms = problem.n_arms();
    let n_users = problem.n_users;
    let mut selected = vec![false; n_arms];
    let mut observed = vec![false; n_arms];

    // Warm-start queue (paper §6.1: the two fastest models per user).
    let mut warm: std::collections::VecDeque<ArmId> =
        problem.warm_start_arms(config.warm_start_per_user).into();

    // Per-user optimum and current incumbent for regret accounting. The
    // incumbents are Option-based ([`crate::sched::Incumbents`]): a user
    // with no observation yet is accounted against `empty_ref` — the
    // accuracy-zero convention floored at the user's worst arm — so
    // workloads with negative-valued optima keep a positive gap (the old
    // raw `EMPTY_INCUMBENT = 0.0` floor silently zeroed regret whenever
    // `z* < 0`). For the paper's non-negative workloads `empty_ref` is
    // exactly 0.0, so reports are byte-identical to the old accounting.
    let z_star: Vec<f64> = (0..n_users).map(|u| truth.best_value(problem, u)).collect();
    let empty_ref: Vec<f64> = (0..n_users)
        .map(|u| problem.user_arms[u].iter().map(|&a| truth.z[a]).fold(0.0f64, f64::min))
        .collect();
    let mut incumbents = Incumbents::new(n_users);
    let gap_sum = |inc: &Incumbents| -> f64 {
        z_star
            .iter()
            .zip(&empty_ref)
            .enumerate()
            .map(|(u, (&s, &e))| {
                let b = if inc.has_observation(u) { inc.value(u) } else { e };
                (s - b).max(0.0)
            })
            .sum()
    };

    let mut events: BinaryHeap<Completion> = BinaryHeap::new();
    let mut observations = Vec::with_capacity(n_arms);
    let mut decision_wall = Duration::ZERO;
    let mut n_decisions = 0usize;

    // Sum-gap step curve; converted to avg at the end.
    let mut sum_gap_curve = StepCurve::new(gap_sum(&incumbents));
    let mut cumulative = 0.0;
    let mut t_prev = 0.0;

    // Dispatch helper: next arm for a free device at time `now`.
    let dispatch = |now: f64,
                        device: usize,
                        selected: &mut Vec<bool>,
                        observed: &[bool],
                        warm: &mut std::collections::VecDeque<ArmId>,
                        policy: &mut dyn Policy,
                        events: &mut BinaryHeap<Completion>,
                        decision_wall: &mut Duration,
                        n_decisions: &mut usize| {
        // Drain warm-start queue first (skip anything already selected).
        while let Some(&a) = warm.front() {
            if selected[a] {
                warm.pop_front();
            } else {
                break;
            }
        }
        let arm = if let Some(a) = warm.pop_front() {
            Some(a)
        } else {
            let ctx = SchedContext { problem: view, selected, observed, now };
            let t0 = Instant::now();
            let pick = policy.select(&ctx);
            *decision_wall += t0.elapsed();
            *n_decisions += 1;
            pick
        };
        if let Some(a) = arm {
            assert!(!selected[a], "policy returned already-selected arm {a}");
            selected[a] = true;
            events.push(Completion { finish: now + problem.cost[a], device, arm: a, start: now });
        }
        // None → device retires (no candidates left).
    };

    // t = 0: all devices ask for work.
    for d in 0..config.n_devices {
        dispatch(
            0.0,
            d,
            &mut selected,
            &observed,
            &mut warm,
            policy,
            &mut events,
            &mut decision_wall,
            &mut n_decisions,
        );
    }

    // Main event loop.
    while let Some(c) = events.pop() {
        let now = c.finish;
        // Integrate regret over [t_prev, now).
        cumulative += gap_sum(&incumbents) * (now - t_prev);
        t_prev = now;

        // Observe.
        let z = truth.z[c.arm];
        observed[c.arm] = true;
        let t0 = Instant::now();
        policy.observe(view, c.arm, z);
        decision_wall += t0.elapsed();
        observations.push(Observation { arm: c.arm, start: c.start, finish: now, z, device: c.device });
        incumbents.update_arm(problem, c.arm, z);
        sum_gap_curve.push(now, gap_sum(&incumbents));

        // Early stop at the convergence cutoff (Figure-5 protocol).
        if let Some(cut) = config.stop_at_cutoff {
            if gap_sum(&incumbents) / n_users as f64 <= cut {
                break;
            }
        }

        // The freed device asks for more work.
        dispatch(
            now,
            c.device,
            &mut selected,
            &observed,
            &mut warm,
            policy,
            &mut events,
            &mut decision_wall,
            &mut n_decisions,
        );
    }

    let makespan = t_prev;
    let horizon = config.horizon.unwrap_or(makespan);
    // Extend the integral to the horizon with the final gap.
    if horizon > t_prev {
        cumulative += gap_sum(&incumbents) * (horizon - t_prev);
    } else if horizon < t_prev {
        // Re-integrate exactly over [0, horizon] from the curve, and
        // truncate the curve itself so the report KPIs (e.g.
        // `final_regret`) and the plotted series agree with the
        // truncated integral instead of leaking post-horizon tail.
        cumulative = sum_gap_curve.integral_to(horizon);
        sum_gap_curve = sum_gap_curve.truncated(horizon);
    }

    SimResult {
        policy: policy.name(),
        observations,
        inst_regret: sum_gap_curve.scaled(1.0 / n_users as f64),
        cumulative_regret: cumulative,
        horizon,
        makespan,
        decision_wall_time: decision_wall,
        n_decisions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::sched::{GpEiRoundRobin, MmGpEi, Oracle};

    fn problem_and_truth() -> (Problem, Truth) {
        // 2 users × 3 arms each, independent prior.
        let user_arms = vec![vec![0, 1, 2], vec![3, 4, 5]];
        let arm_users = Problem::compute_arm_users(6, &user_arms);
        let p = Problem {
            name: "sim".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 6],
            prior_cov: Mat::eye(6),
        };
        let t = Truth { z: vec![0.3, 0.9, 0.5, 0.7, 0.2, 0.8] };
        (p, t)
    }

    #[test]
    fn all_arms_eventually_observed() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 2, ..Default::default() });
        assert_eq!(r.observations.len(), 6);
        let mut arms: Vec<_> = r.observations.iter().map(|o| o.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn no_device_overlap() {
        let (p, t) = problem_and_truth();
        let mut pol = GpEiRoundRobin::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 2, ..Default::default() });
        // Reconstruct per-device busy intervals; they must not overlap.
        for d in 0..2 {
            let mut spans: Vec<(f64, f64)> = r
                .observations
                .iter()
                .filter(|o| o.device == d)
                .map(|o| (o.start, o.finish))
                .collect();
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-12, "device {d} overlaps: {w:?}");
            }
        }
    }

    #[test]
    fn cost_respected_in_completions() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 1, ..Default::default() });
        for o in &r.observations {
            assert!((o.finish - o.start - p.cost[o.arm]).abs() < 1e-12);
        }
    }

    #[test]
    fn single_device_is_sequential() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 1, ..Default::default() });
        // Makespan equals total cost with one device.
        let total: f64 = p.cost.iter().sum();
        assert!((r.makespan - total).abs() < 1e-9);
    }

    #[test]
    fn inst_regret_monotone_nonincreasing() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 2, ..Default::default() });
        let pts = r.inst_regret.points();
        for w in pts.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12, "incumbents only improve");
        }
        // Ends at zero: every arm observed → optimum found.
        assert_eq!(r.inst_regret.final_value(), 0.0);
    }

    #[test]
    fn warm_start_runs_cheapest_two_per_user() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 1, ..Default::default() });
        // First four dispatches must be the warm-start arms {0,1,3,4}.
        let first4: Vec<_> = {
            let mut obs = r.observations.clone();
            obs.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            obs.iter().take(4).map(|o| o.arm).collect()
        };
        let mut sorted = first4.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 3, 4], "warm start must run 2 cheapest per user");
    }

    #[test]
    fn oracle_finds_optima_first() {
        // Clairvoyance reaches zero *instantaneous* regret no later than
        // any learner (cumulative regret is schedule-dependent and a
        // greedy oracle is not cumulative-optimal — that non-triviality
        // is the paper's premise).
        let (p, t) = problem_and_truth();
        let cfg = SimConfig { n_devices: 1, warm_start_per_user: 0, horizon: Some(12.0), ..Default::default() };
        let r_oracle = simulate(&p, &t, &mut Oracle::new(&p, &t), &cfg);
        let r_mm = simulate(&p, &t, &mut MmGpEi::new(&p), &cfg);
        let r_rr = simulate(&p, &t, &mut GpEiRoundRobin::new(&p), &cfg);
        let tt = |r: &SimResult| r.time_to(1e-12).unwrap();
        assert!(tt(&r_oracle) <= tt(&r_mm) + 1e-9);
        assert!(tt(&r_oracle) <= tt(&r_rr) + 1e-9);
    }

    #[test]
    fn more_devices_never_hurt_makespan() {
        let (p, t) = problem_and_truth();
        let mk = |m: usize| {
            let mut pol = MmGpEi::new(&p);
            simulate(&p, &t, &mut pol, &SimConfig { n_devices: m, ..Default::default() }).makespan
        };
        let m1 = mk(1);
        let m2 = mk(2);
        let m6 = mk(6);
        assert!(m2 <= m1 + 1e-9);
        assert!(m6 <= m2 + 1e-9);
    }

    #[test]
    fn negative_optima_still_accrue_regret() {
        // Satellite fix: with the raw EMPTY_INCUMBENT = 0.0 floor, a
        // workload whose optima are negative reported zero gap until the
        // first observation (and forever, if all z < 0). The Option-based
        // incumbents + per-user empty reference must keep regret positive
        // and make the post-observation curve shift-invariant.
        let (p, t) = problem_and_truth();
        let shift = 5.0;
        let mut p_neg = p.clone();
        let t_neg = Truth { z: t.z.iter().map(|z| z - shift).collect() };
        for m in p_neg.prior_mean.iter_mut() {
            *m -= shift;
        }
        let cfg = SimConfig { n_devices: 1, ..Default::default() };
        let r_pos = simulate(&p, &t, &mut MmGpEi::new(&p), &cfg);
        let r_neg = simulate(&p_neg, &t_neg, &mut MmGpEi::new(&p_neg), &cfg);
        assert!(
            r_neg.cumulative_regret > 0.0,
            "negative-valued optima must not silently zero the regret"
        );
        // The shifted GP makes identical decisions (EI is shift-invariant
        // when prior and incumbents shift together), so once every user
        // has an incumbent the gap curves must match exactly.
        let arms_pos: Vec<_> = r_pos.observations.iter().map(|o| o.arm).collect();
        let arms_neg: Vec<_> = r_neg.observations.iter().map(|o| o.arm).collect();
        assert_eq!(arms_pos, arms_neg, "schedules must match under a constant shift");
        assert!(
            (r_pos.inst_regret.final_value() - r_neg.inst_regret.final_value()).abs() < 1e-9
        );
        let probe = r_pos.makespan * 0.9; // late: every user has observed
        assert!(
            (r_pos.inst_regret.value(probe) - r_neg.inst_regret.value(probe)).abs() < 1e-9,
            "gap is shift-invariant once incumbents exist"
        );
    }

    #[test]
    fn horizon_truncates_curve_and_integral_agree() {
        // Satellite fix: with horizon < makespan the returned inst_regret
        // curve must stop at the horizon, and re-integrating it must give
        // exactly the reported cumulative regret.
        let (p, t) = problem_and_truth();
        let full = simulate(&p, &t, &mut MmGpEi::new(&p), &SimConfig { n_devices: 1, ..Default::default() });
        let h = full.makespan / 2.0;
        let half = simulate(
            &p,
            &t,
            &mut MmGpEi::new(&p),
            &SimConfig { n_devices: 1, warm_start_per_user: 2, horizon: Some(h), ..Default::default() },
        );
        assert!(half.inst_regret.end_time() <= h, "curve must not extend past the horizon");
        // inst_regret is the sum-gap curve scaled by 1/n_users.
        let reintegrated = half.inst_regret.integral_to(h) * p.n_users as f64;
        assert!(
            (reintegrated - half.cumulative_regret).abs() < 1e-9,
            "curve and KPI disagree: {reintegrated} vs {}",
            half.cumulative_regret
        );
        assert!(
            half.inst_regret.final_value() >= full.inst_regret.final_value(),
            "mid-run truncation must not report the exhausted end state"
        );
    }

    #[test]
    fn horizon_truncates_cumulative_regret() {
        let (p, t) = problem_and_truth();
        let full = simulate(&p, &t, &mut MmGpEi::new(&p), &SimConfig { n_devices: 1, ..Default::default() });
        let half = simulate(
            &p,
            &t,
            &mut MmGpEi::new(&p),
            &SimConfig { n_devices: 1, warm_start_per_user: 2, horizon: Some(full.makespan / 2.0), ..Default::default() },
        );
        assert!(half.cumulative_regret <= full.cumulative_regret + 1e-9);
    }

    #[test]
    fn tied_completions_pop_in_device_order() {
        // All costs equal → every completion wave is one big tie. The
        // tie-break must hand events back in ascending device order, and
        // the whole schedule must replay identically run over run.
        let user_arms = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        let arm_users = Problem::compute_arm_users(8, &user_arms);
        let p = Problem {
            name: "ties".into(),
            n_users: 2,
            cost: vec![1.0; 8],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 8],
            prior_cov: crate::linalg::Mat::eye(8),
        };
        let t = Truth { z: vec![0.1, 0.9, 0.3, 0.7, 0.2, 0.8, 0.4, 0.6] };
        let run = || {
            let mut pol = MmGpEi::new(&p);
            simulate(
                &p,
                &t,
                &mut pol,
                &SimConfig { n_devices: 4, warm_start_per_user: 2, horizon: None, ..Default::default() },
            )
        };
        let a = run();
        let b = run();
        let key = |r: &SimResult| -> Vec<(usize, usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.device, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&a), key(&b), "identical seeds must replay identical schedules");
        // Within each tied completion wave, devices drain in index order.
        for w in a.observations.windows(2) {
            if w[0].finish == w[1].finish {
                assert!(
                    w[0].device < w[1].device,
                    "tie at t={} popped device {} before {}",
                    w[0].finish,
                    w[0].device,
                    w[1].device
                );
            }
        }
    }

    #[test]
    fn decision_accounting_populated() {
        let (p, t) = problem_and_truth();
        let r = simulate(&p, &t, &mut MmGpEi::new(&p), &SimConfig { n_devices: 2, ..Default::default() });
        assert!(r.n_decisions >= 2, "policy consulted after warm start");
    }
}
