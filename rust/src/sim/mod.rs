//! Discrete-event simulation of the multi-device AutoML service —
//! virtual-time **adapters** over the unified scheduling engine
//! ([`crate::engine`]).
//!
//! The paper's testbed runs real training jobs on real machines; for a
//! reproducible reproduction we simulate in **virtual time** (DESIGN.md
//! §3): devices are slots in an event queue, running arm `x` on device
//! `d` occupies it for `c(x)/s_d` time units (`s_d` is the device's
//! speed — 1 for the paper's uniform fleets, so the historical "exactly
//! `c(x)` time units" holds there), and the completion reveals the
//! hidden `z(x)`. Regret is a function of the schedule only, so virtual
//! time preserves every quantity the paper plots while making runs
//! deterministic.
//!
//! The drivers implement the paper's §6.1 protocol: an optional
//! warm-start phase (the two cheapest models per user) runs before the
//! policy takes over; each device, upon becoming free, immediately asks
//! the policy for the next arm. Three scenario entry points share the
//! one engine event loop:
//!
//! * [`simulate`] — the paper's static setting (`M` identical always-on
//!   devices);
//! * [`simulate_churn`] — dynamic tenancy (arrival/departure traffic);
//! * [`simulate_fleet`] — elastic heterogeneous fleets (per-device
//!   speeds, devices joining/leaving mid-run, preemption + requeue);
//! * [`simulate_faults`] — fault-injected serving (device crashes, lost
//!   jobs, stragglers, deadline kills + retry/backoff) over a
//!   [`FaultPlan`]; with an empty plan it is byte-identical to
//!   [`simulate_fleet`].

mod churn;

pub use churn::{simulate_churn, ChurnResult};

use std::time::Duration;

use crate::config::ExperimentConfig;
use crate::engine::{self, EngineParams, FaultStats, PolicyFactory, PolicyHost, Tenancy, VirtualClock};
use crate::metrics::StepCurve;
use crate::problem::{CostModel, DeviceFleet, FaultPlan, Problem, Truth};
use crate::sched::Policy;

pub use crate::engine::Observation;

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Number of devices `M` (ignored by [`simulate_fleet`], where the
    /// fleet defines the device set).
    pub n_devices: usize,
    /// Warm-start arms per user (paper protocol: 2 fastest). 0 disables.
    pub warm_start_per_user: usize,
    /// Report horizon `T` for the cumulative regret; defaults to the last
    /// completion time when `None`.
    pub horizon: Option<f64>,
    /// Stop the run as soon as the average instantaneous regret drops to
    /// this cutoff (the Figure-5 convergence-time protocol only needs the
    /// hitting time, not the tail of the schedule). `None` runs to
    /// exhaustion. When triggered, `cumulative_regret`/`makespan` cover
    /// only the truncated schedule.
    pub stop_at_cutoff: Option<f64>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { n_devices: 1, warm_start_per_user: 2, horizon: None, stop_at_cutoff: None }
    }
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Policy display name.
    pub policy: String,
    /// All completions in completion order.
    pub observations: Vec<Observation>,
    /// Instantaneous regret (average gap over users) as a step curve.
    pub inst_regret: StepCurve,
    /// Cumulative regret `Regret_T` (Eq. 2) at the report horizon.
    pub cumulative_regret: f64,
    /// Report horizon actually used.
    pub horizon: f64,
    /// Last completion time.
    pub makespan: f64,
    /// Total wall-clock time spent inside the policy (`select` +
    /// `observe`) — the scheduler-overhead metric for §Perf.
    pub decision_wall_time: Duration,
    /// Number of `select` calls answered.
    pub n_decisions: usize,
}

impl SimResult {
    /// Convergence time: first time instantaneous regret ≤ cutoff.
    pub fn time_to(&self, cutoff: f64) -> Option<f64> {
        self.inst_regret.first_time_leq(cutoff)
    }
}

/// Result of one simulated **elastic fleet** run ([`simulate_fleet`]):
/// the static-tenancy regret accounting of [`SimResult`] plus the
/// fleet-specific service metrics.
#[derive(Clone, Debug)]
pub struct FleetResult {
    /// The schedule and regret accounting (identical in meaning — and,
    /// for a unit-speed always-on fleet, identical in bytes — to a
    /// [`simulate`] run).
    pub sim: SimResult,
    /// Jobs cancelled because their device left mid-run.
    pub n_preemptions: usize,
    /// Per re-dispatched preempted arm: preemption → re-dispatch delay.
    pub requeue_latency: Vec<f64>,
    /// Fleet events the policy could not apply in place (each one cost a
    /// from-scratch rebuild + history replay). 0 for MM-GP-EI.
    pub n_rebuilds: usize,
}

/// Clone `problem` with the scheduler-visible costs replaced by the
/// estimates `ĉ(x)` (Remark 1). Construct policies against this view
/// when driving [`simulate_with_estimates`].
pub fn with_cost_estimates(problem: &Problem, estimated: &[f64]) -> Problem {
    assert_eq!(estimated.len(), problem.n_arms());
    let mut view = problem.clone();
    view.cost = estimated.to_vec();
    view.validate();
    view
}

/// Reshape an engine run in static-accounting mode into a [`SimResult`]
/// (the gap-sum curve becomes the per-user average).
pub(crate) fn sim_result_from(run: engine::EngineRun, n_users: usize) -> SimResult {
    SimResult {
        policy: run.policy,
        observations: run.observations,
        inst_regret: run.curve.scaled(1.0 / n_users as f64),
        cumulative_regret: run.cumulative_regret,
        horizon: run.horizon,
        makespan: run.makespan,
        decision_wall_time: run.decision_wall_time,
        n_decisions: run.n_decisions,
    }
}

/// Run one simulation of `policy` on `(problem, truth)`.
///
/// Panics if the policy returns an already-selected arm (scheduler bug —
/// the paper's devices never run the same model twice).
pub fn simulate(
    problem: &Problem,
    truth: &Truth,
    policy: &mut dyn Policy,
    config: &SimConfig,
) -> SimResult {
    simulate_with_estimates(problem, truth, policy, config, None)
}

/// Like [`simulate`], but the *scheduler* sees estimated costs `ĉ(x)`
/// while devices charge the true `c(x)` — the paper's Remark 1 setting
/// ("it is easy to estimate an approximate (but high accurate) value …
/// this approximation does not degrade the performance"). The policy
/// must have been constructed against the same estimated-cost view
/// (see [`with_cost_estimates`]).
pub fn simulate_with_estimates(
    problem: &Problem,
    truth: &Truth,
    policy: &mut dyn Policy,
    config: &SimConfig,
    estimated_cost: Option<&[f64]>,
) -> SimResult {
    let view_storage;
    let view: Option<&Problem> = match estimated_cost {
        Some(est) => {
            assert_eq!(est.len(), problem.n_arms());
            view_storage = with_cost_estimates(problem, est);
            Some(&view_storage)
        }
        None => None,
    };
    assert!(config.n_devices >= 1, "need at least one device");
    let fleet = ExperimentConfig::device_fleet(config.n_devices);
    let mut clock = VirtualClock::new(config.n_devices);
    let params = EngineParams {
        problem,
        truth,
        sched_view: view,
        cost_model: None,
        fleet: &fleet,
        tenancy: Tenancy::Static,
        warm_start_per_user: config.warm_start_per_user,
        horizon: config.horizon,
        stop_at_cutoff: config.stop_at_cutoff,
        time_scale: 1.0,
        collect_decision_latencies: false,
        faults: None,
        verbose: false,
    };
    let run = engine::run(&params, PolicyHost::borrowed(policy), &mut clock);
    sim_result_from(run, problem.n_users)
}

/// Run one simulation over an **elastic heterogeneous fleet**: devices
/// have speeds (`c(x)/s_d` occupancy) and join/leave per the fleet's
/// availability schedule; a device leaving mid-job preempts it and the
/// engine requeues the arm's decision (nothing is revealed).
///
/// Takes a policy *factory* (like [`simulate_churn`]) because fleet
/// events a policy cannot apply in place fall back to a from-scratch
/// rebuild — the oracle [`crate::sched::ForceRebuild`] pins the
/// in-place hooks against. `config.n_devices` is ignored: the fleet
/// defines the device set. With a unit-speed always-on fleet
/// ([`DeviceFleet::uniform`]) the result is byte-identical to
/// [`simulate`] (see `rust/tests/engine_parity.rs`).
pub fn simulate_fleet(
    problem: &Problem,
    truth: &Truth,
    fleet: &DeviceFleet,
    factory: &PolicyFactory,
    config: &SimConfig,
) -> FleetResult {
    simulate_fleet_with_cost_model(problem, truth, fleet, factory, config, None)
}

/// Like [`simulate_fleet`], but devices are charged per-(arm, class)
/// costs from `cost_model` (e.g. [`crate::problem::PerClassCost`]): a
/// device of class `k` runs arm `x` for `c(x, k)/s_d` time units, and an
/// arm the model declares infeasible on `k` never runs there — queue
/// heads are left for a fitting device, and a device-blind policy pick
/// that does not fit idles the asking device. `None` delegates to the
/// historical `problem.cost` charging (byte-identical to
/// [`simulate_fleet`]). Device-aware policies
/// ([`crate::sched::MmGpEi::with_cost_model`]) see the asking device in
/// `SchedContext::device` and rank by `EI/(c(x, class_d)/s_d)`.
pub fn simulate_fleet_with_cost_model(
    problem: &Problem,
    truth: &Truth,
    fleet: &DeviceFleet,
    factory: &PolicyFactory,
    config: &SimConfig,
    cost_model: Option<&dyn CostModel>,
) -> FleetResult {
    let mut clock = VirtualClock::new(fleet.n_devices());
    let params = EngineParams {
        problem,
        truth,
        sched_view: None,
        cost_model,
        fleet,
        tenancy: Tenancy::Static,
        warm_start_per_user: config.warm_start_per_user,
        horizon: config.horizon,
        stop_at_cutoff: config.stop_at_cutoff,
        time_scale: 1.0,
        collect_decision_latencies: false,
        faults: None,
        verbose: false,
    };
    let mut run = engine::run(&params, PolicyHost::from_factory(factory), &mut clock);
    let n_preemptions = run.n_preemptions;
    let requeue_latency = std::mem::take(&mut run.requeue_latency);
    let n_rebuilds = run.n_rebuilds;
    FleetResult {
        sim: sim_result_from(run, problem.n_users),
        n_preemptions,
        requeue_latency,
        n_rebuilds,
    }
}

/// Result of one **fault-injected** run ([`simulate_faults`]): the
/// elastic-fleet accounting of [`FleetResult`] plus the fault KPIs the
/// `fig8_faults` bench reports.
#[derive(Clone, Debug)]
pub struct FaultResult {
    /// The schedule, regret, and preemption accounting (identical in
    /// meaning — and, for an empty plan, identical in bytes — to a
    /// [`simulate_fleet`] run).
    pub fleet: FleetResult,
    /// Fault-path counters: crashes, restarts, lost jobs, deadline
    /// kills, stragglers, retries, abandoned arms, recovery latencies.
    pub fault_stats: FaultStats,
    /// Arms whose observation actually landed, over all arms — the
    /// served fraction KPI (1.0 in a fault-free static run; abandoned
    /// arms push it below 1).
    pub served_fraction: f64,
}

/// Run one simulation over an elastic fleet **under fault injection**:
/// the plan's device crashes preempt in-flight jobs (nothing revealed,
/// arm requeued), job failures and blown deadlines enter the plan's
/// bounded retry/backoff path, and stragglers stretch remaining work.
/// The run survives windows with every device down — queues are held
/// and the Eq.-2 regret integral keeps accruing until capacity returns.
///
/// An **empty** plan arms no fault machinery at all: the run is
/// byte-identical to [`simulate_fleet`] on the same inputs (the hard
/// gate in `fig8_faults`).
pub fn simulate_faults(
    problem: &Problem,
    truth: &Truth,
    fleet: &DeviceFleet,
    plan: &FaultPlan,
    factory: &PolicyFactory,
    config: &SimConfig,
) -> FaultResult {
    let mut clock = VirtualClock::new(fleet.n_devices());
    let params = EngineParams {
        problem,
        truth,
        sched_view: None,
        cost_model: None,
        fleet,
        tenancy: Tenancy::Static,
        warm_start_per_user: config.warm_start_per_user,
        horizon: config.horizon,
        stop_at_cutoff: config.stop_at_cutoff,
        time_scale: 1.0,
        collect_decision_latencies: false,
        faults: Some(plan),
        verbose: false,
    };
    let mut run = engine::run(&params, PolicyHost::from_factory(factory), &mut clock);
    let n_preemptions = run.n_preemptions;
    let requeue_latency = std::mem::take(&mut run.requeue_latency);
    let n_rebuilds = run.n_rebuilds;
    let fault_stats = std::mem::take(&mut run.fault_stats);
    let served_fraction = run.observations.len() as f64 / problem.n_arms() as f64;
    FaultResult {
        fleet: FleetResult {
            sim: sim_result_from(run, problem.n_users),
            n_preemptions,
            requeue_latency,
            n_rebuilds,
        },
        fault_stats,
        served_fraction,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::sched::{GpEiRoundRobin, MmGpEi, Oracle};

    fn problem_and_truth() -> (Problem, Truth) {
        // 2 users × 3 arms each, independent prior.
        let user_arms = vec![vec![0, 1, 2], vec![3, 4, 5]];
        let arm_users = Problem::compute_arm_users(6, &user_arms);
        let p = Problem {
            name: "sim".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 6],
            prior_cov: Mat::eye(6),
        };
        let t = Truth { z: vec![0.3, 0.9, 0.5, 0.7, 0.2, 0.8] };
        (p, t)
    }

    #[test]
    fn all_arms_eventually_observed() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 2, ..Default::default() });
        assert_eq!(r.observations.len(), 6);
        let mut arms: Vec<_> = r.observations.iter().map(|o| o.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn no_device_overlap() {
        let (p, t) = problem_and_truth();
        let mut pol = GpEiRoundRobin::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 2, ..Default::default() });
        // Reconstruct per-device busy intervals; they must not overlap.
        for d in 0..2 {
            let mut spans: Vec<(f64, f64)> = r
                .observations
                .iter()
                .filter(|o| o.device == d)
                .map(|o| (o.start, o.finish))
                .collect();
            spans.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-12, "device {d} overlaps: {w:?}");
            }
        }
    }

    #[test]
    fn cost_respected_in_completions() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 1, ..Default::default() });
        for o in &r.observations {
            assert!((o.finish - o.start - p.cost[o.arm]).abs() < 1e-12);
        }
    }

    #[test]
    fn single_device_is_sequential() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 1, ..Default::default() });
        // Makespan equals total cost with one device.
        let total: f64 = p.cost.iter().sum();
        assert!((r.makespan - total).abs() < 1e-9);
    }

    #[test]
    fn inst_regret_monotone_nonincreasing() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 2, ..Default::default() });
        let pts = r.inst_regret.points();
        for w in pts.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12, "incumbents only improve");
        }
        // Ends at zero: every arm observed → optimum found.
        assert_eq!(r.inst_regret.final_value(), 0.0);
    }

    #[test]
    fn warm_start_runs_cheapest_two_per_user() {
        let (p, t) = problem_and_truth();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 1, ..Default::default() });
        // First four dispatches must be the warm-start arms {0,1,3,4}.
        let first4: Vec<_> = {
            let mut obs = r.observations.clone();
            obs.sort_by(|a, b| a.start.total_cmp(&b.start));
            obs.iter().take(4).map(|o| o.arm).collect()
        };
        let mut sorted = first4.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 3, 4], "warm start must run 2 cheapest per user");
    }

    #[test]
    fn oracle_finds_optima_first() {
        // Clairvoyance reaches zero *instantaneous* regret no later than
        // any learner (cumulative regret is schedule-dependent and a
        // greedy oracle is not cumulative-optimal — that non-triviality
        // is the paper's premise).
        let (p, t) = problem_and_truth();
        let cfg = SimConfig { n_devices: 1, warm_start_per_user: 0, horizon: Some(12.0), ..Default::default() };
        let r_oracle = simulate(&p, &t, &mut Oracle::new(&p, &t), &cfg);
        let r_mm = simulate(&p, &t, &mut MmGpEi::new(&p), &cfg);
        let r_rr = simulate(&p, &t, &mut GpEiRoundRobin::new(&p), &cfg);
        let tt = |r: &SimResult| r.time_to(1e-12).unwrap();
        assert!(tt(&r_oracle) <= tt(&r_mm) + 1e-9);
        assert!(tt(&r_oracle) <= tt(&r_rr) + 1e-9);
    }

    #[test]
    fn more_devices_never_hurt_makespan() {
        let (p, t) = problem_and_truth();
        let mk = |m: usize| {
            let mut pol = MmGpEi::new(&p);
            simulate(&p, &t, &mut pol, &SimConfig { n_devices: m, ..Default::default() }).makespan
        };
        let m1 = mk(1);
        let m2 = mk(2);
        let m6 = mk(6);
        assert!(m2 <= m1 + 1e-9);
        assert!(m6 <= m2 + 1e-9);
    }

    #[test]
    fn negative_optima_still_accrue_regret() {
        // Satellite fix (PR 4): with the raw EMPTY_INCUMBENT = 0.0 floor,
        // a workload whose optima are negative reported zero gap until the
        // first observation (and forever, if all z < 0). The Option-based
        // incumbents + per-user empty reference must keep regret positive
        // and make the post-observation curve shift-invariant.
        let (p, t) = problem_and_truth();
        let shift = 5.0;
        let mut p_neg = p.clone();
        let t_neg = Truth { z: t.z.iter().map(|z| z - shift).collect() };
        for m in p_neg.prior_mean.iter_mut() {
            *m -= shift;
        }
        let cfg = SimConfig { n_devices: 1, ..Default::default() };
        let r_pos = simulate(&p, &t, &mut MmGpEi::new(&p), &cfg);
        let r_neg = simulate(&p_neg, &t_neg, &mut MmGpEi::new(&p_neg), &cfg);
        assert!(
            r_neg.cumulative_regret > 0.0,
            "negative-valued optima must not silently zero the regret"
        );
        // The shifted GP makes identical decisions (EI is shift-invariant
        // when prior and incumbents shift together), so once every user
        // has an incumbent the gap curves must match exactly.
        let arms_pos: Vec<_> = r_pos.observations.iter().map(|o| o.arm).collect();
        let arms_neg: Vec<_> = r_neg.observations.iter().map(|o| o.arm).collect();
        assert_eq!(arms_pos, arms_neg, "schedules must match under a constant shift");
        assert!(
            (r_pos.inst_regret.final_value() - r_neg.inst_regret.final_value()).abs() < 1e-9
        );
        let probe = r_pos.makespan * 0.9; // late: every user has observed
        assert!(
            (r_pos.inst_regret.value(probe) - r_neg.inst_regret.value(probe)).abs() < 1e-9,
            "gap is shift-invariant once incumbents exist"
        );
    }

    #[test]
    fn horizon_truncates_curve_and_integral_agree() {
        // With horizon < makespan the returned inst_regret curve must
        // stop at the horizon, and re-integrating it must give exactly
        // the reported cumulative regret.
        let (p, t) = problem_and_truth();
        let full = simulate(&p, &t, &mut MmGpEi::new(&p), &SimConfig { n_devices: 1, ..Default::default() });
        let h = full.makespan / 2.0;
        let half = simulate(
            &p,
            &t,
            &mut MmGpEi::new(&p),
            &SimConfig { n_devices: 1, warm_start_per_user: 2, horizon: Some(h), ..Default::default() },
        );
        assert!(half.inst_regret.end_time() <= h, "curve must not extend past the horizon");
        // inst_regret is the sum-gap curve scaled by 1/n_users.
        let reintegrated = half.inst_regret.integral_to(h) * p.n_users as f64;
        assert!(
            (reintegrated - half.cumulative_regret).abs() < 1e-9,
            "curve and KPI disagree: {reintegrated} vs {}",
            half.cumulative_regret
        );
        assert!(
            half.inst_regret.final_value() >= full.inst_regret.final_value(),
            "mid-run truncation must not report the exhausted end state"
        );
    }

    #[test]
    fn horizon_truncates_cumulative_regret() {
        let (p, t) = problem_and_truth();
        let full = simulate(&p, &t, &mut MmGpEi::new(&p), &SimConfig { n_devices: 1, ..Default::default() });
        let half = simulate(
            &p,
            &t,
            &mut MmGpEi::new(&p),
            &SimConfig { n_devices: 1, warm_start_per_user: 2, horizon: Some(full.makespan / 2.0), ..Default::default() },
        );
        assert!(half.cumulative_regret <= full.cumulative_regret + 1e-9);
    }

    #[test]
    fn tied_completions_pop_in_device_order() {
        // All costs equal → every completion wave is one big tie. The
        // tie-break must hand events back in ascending device order, and
        // the whole schedule must replay identically run over run.
        let user_arms = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        let arm_users = Problem::compute_arm_users(8, &user_arms);
        let p = Problem {
            name: "ties".into(),
            n_users: 2,
            cost: vec![1.0; 8],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 8],
            prior_cov: crate::linalg::Mat::eye(8),
        };
        let t = Truth { z: vec![0.1, 0.9, 0.3, 0.7, 0.2, 0.8, 0.4, 0.6] };
        let run = || {
            let mut pol = MmGpEi::new(&p);
            simulate(
                &p,
                &t,
                &mut pol,
                &SimConfig { n_devices: 4, warm_start_per_user: 2, horizon: None, ..Default::default() },
            )
        };
        let a = run();
        let b = run();
        let key = |r: &SimResult| -> Vec<(usize, usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.device, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&a), key(&b), "identical seeds must replay identical schedules");
        // Within each tied completion wave, devices drain in index order.
        for w in a.observations.windows(2) {
            if w[0].finish == w[1].finish {
                assert!(
                    w[0].device < w[1].device,
                    "tie at t={} popped device {} before {}",
                    w[0].finish,
                    w[0].device,
                    w[1].device
                );
            }
        }
    }

    #[test]
    fn decision_accounting_populated() {
        let (p, t) = problem_and_truth();
        let r = simulate(&p, &t, &mut MmGpEi::new(&p), &SimConfig { n_devices: 2, ..Default::default() });
        assert!(r.n_decisions >= 2, "policy consulted after warm start");
    }

    #[test]
    fn unit_fleet_matches_plain_simulate_bitwise() {
        // The acceptance gate in miniature (the full version lives in
        // rust/tests/engine_parity.rs): a unit-speed always-on fleet must
        // replay the plain simulator bit-for-bit.
        let (p, t) = problem_and_truth();
        let plain = simulate(&p, &t, &mut MmGpEi::new(&p), &SimConfig { n_devices: 2, ..Default::default() });
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let fleet = DeviceFleet::uniform(2);
        let elastic =
            simulate_fleet(&p, &t, &fleet, &factory, &SimConfig { n_devices: 2, ..Default::default() });
        assert_eq!(elastic.n_preemptions, 0);
        assert_eq!(elastic.n_rebuilds, 0);
        let key = |r: &SimResult| -> Vec<(usize, usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.device, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&plain), key(&elastic.sim));
        assert_eq!(plain.cumulative_regret.to_bits(), elastic.sim.cumulative_regret.to_bits());
        assert_eq!(plain.inst_regret, elastic.sim.inst_regret);
    }

    #[test]
    fn empty_fault_plan_matches_simulate_fleet_bitwise() {
        // The fig8_faults hard gate in miniature: an empty plan must arm
        // no fault machinery and replay the fleet run bit-for-bit.
        let (p, t) = problem_and_truth();
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let fleet = DeviceFleet::uniform(2);
        let cfg = SimConfig { n_devices: 2, ..Default::default() };
        let plain = simulate_fleet(&p, &t, &fleet, &factory, &cfg);
        let plan = crate::problem::FaultPlan::empty();
        let faulty = simulate_faults(&p, &t, &fleet, &plan, &factory, &cfg);
        let key = |r: &SimResult| -> Vec<(usize, usize, u64)> {
            r.observations.iter().map(|o| (o.arm, o.device, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&plain.sim), key(&faulty.fleet.sim));
        assert_eq!(
            plain.sim.cumulative_regret.to_bits(),
            faulty.fleet.sim.cumulative_regret.to_bits()
        );
        assert_eq!(plain.sim.inst_regret, faulty.fleet.sim.inst_regret);
        assert_eq!(faulty.fault_stats, FaultStats::default());
        assert_eq!(faulty.served_fraction, 1.0);
    }

    #[test]
    fn run_survives_all_devices_down_window() {
        // Graceful degradation: both devices crash into an overlapping
        // outage window; queues are held, the regret integral keeps
        // accruing, and service resumes when capacity returns.
        use crate::problem::{FaultEvent, FaultKind, FaultPlan, RetryPolicy};
        let (p, t) = problem_and_truth();
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let fleet = DeviceFleet::uniform(2);
        let plan = FaultPlan::new(
            2,
            vec![
                FaultEvent { time: 0.5, device: 0, kind: FaultKind::DeviceCrash },
                FaultEvent { time: 0.5, device: 1, kind: FaultKind::DeviceCrash },
                FaultEvent { time: 5.0, device: 0, kind: FaultKind::DeviceRestart },
                FaultEvent { time: 5.0, device: 1, kind: FaultKind::DeviceRestart },
            ],
            RetryPolicy::default(),
        );
        let cfg = SimConfig { n_devices: 2, ..Default::default() };
        let r = simulate_faults(&p, &t, &fleet, &plan, &factory, &cfg);
        assert_eq!(r.fault_stats.n_crashes, 2);
        assert_eq!(r.fault_stats.n_restarts, 2);
        // Nothing completes inside the dead window…
        for o in &r.fleet.sim.observations {
            assert!(
                o.finish <= 0.5 + 1e-12 || o.finish >= 5.0 - 1e-12,
                "completion at {} inside the all-devices-down window",
                o.finish
            );
        }
        // …but the run still serves everything afterwards.
        assert_eq!(r.served_fraction, 1.0);
        assert_eq!(r.fleet.sim.observations.len(), 6);
        assert_eq!(r.fleet.sim.inst_regret.final_value(), 0.0);
        // The dead window costs real regret relative to fault-free.
        let plain = simulate_fleet(&p, &t, &fleet, &factory, &cfg);
        assert!(
            r.fleet.sim.cumulative_regret > plain.sim.cumulative_regret,
            "Eq.-2 regret must keep integrating across the outage"
        );
    }

    #[test]
    fn uniform_cost_model_matches_no_model_bitwise() {
        // `UniformCost` wraps the problem's own cost vector, so charging
        // through it must replay the no-model run bit-for-bit.
        let (p, t) = problem_and_truth();
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let fleet = DeviceFleet::uniform(2);
        let cfg = SimConfig { n_devices: 2, ..Default::default() };
        let plain = simulate_fleet(&p, &t, &fleet, &factory, &cfg);
        let model = crate::problem::UniformCost::from_problem(&p);
        let modeled = simulate_fleet_with_cost_model(&p, &t, &fleet, &factory, &cfg, Some(&model));
        let key = |r: &FleetResult| -> Vec<(usize, usize, u64)> {
            r.sim.observations.iter().map(|o| (o.arm, o.device, o.finish.to_bits())).collect()
        };
        assert_eq!(key(&plain), key(&modeled));
        assert_eq!(
            plain.sim.cumulative_regret.to_bits(),
            modeled.sim.cumulative_regret.to_bits()
        );
        assert_eq!(plain.sim.inst_regret, modeled.sim.inst_regret);
    }
}
