//! PJRT runtime: load and execute the AOT-compiled `scheduler_step`
//! artifacts from the rust hot path.
//!
//! `make artifacts` (the only place python ever runs) lowers the Layer-2
//! JAX graph — which embeds the Layer-1 Pallas kernels — to **HLO text**
//! per (N, L) shape bucket. This module:
//!
//! 1. reads `artifacts/manifest.txt` to discover the buckets,
//! 2. compiles the right bucket once on the PJRT CPU client
//!    (`xla::PjRtClient`),
//! 3. implements [`XlaBackend`]: the [`crate::sched::EiBackend`] that
//!    pads the live scheduler state into the bucket, executes the
//!    artifact, and slices the EIrate / posterior back out.
//!
//! **Feature gating.** The PJRT pieces need the `xla` bindings crate and
//! a PJRT CPU plugin, neither of which exists in the default offline
//! build environment. They are therefore compiled only with
//! `--features xla`; without it [`XlaBackend`] is a stub whose
//! constructor returns an error, so every `--backend xla` call site
//! (CLI, benches, examples) degrades gracefully at runtime instead of
//! breaking the build. Manifest parsing and bucket selection are pure
//! rust and stay available either way.
//!
//! The padding contract (mirrored by `python/tests/test_model.py::
//! test_padding_arms_are_inert`): padded arms get an identity covariance
//! row, zero membership, unit cost, `obs = 0`, `sel = 1`; padded users
//! get zero membership. Padded arms therefore score `-1e30` and can never
//! win the argmax.

use std::fmt;
use std::path::{Path, PathBuf};

/// Score the artifact assigns to masked (selected/padding) arms.
pub const NEG_INF_SCORE: f64 = -1e30;

/// Runtime-layer error: artifact discovery, compilation, or execution.
///
/// A plain message-carrying error type — the offline build ships no
/// `anyhow`, and the runtime layer's callers only ever display or match
/// on the message.
#[derive(Clone, Debug)]
pub struct RuntimeError {
    msg: String,
}

impl RuntimeError {
    /// Build from any displayable message.
    pub fn new(msg: impl Into<String>) -> Self {
        RuntimeError { msg: msg.into() }
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for RuntimeError {}

/// Runtime-layer result.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// One artifact bucket from `manifest.txt`.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact stem (e.g. `scheduler_step_n16_l128`).
    pub name: String,
    /// Max users the bucket supports.
    pub n: usize,
    /// Max arms the bucket supports.
    pub l: usize,
    /// HLO text path.
    pub path: PathBuf,
}

/// Parse `artifacts/manifest.txt` (lines: `name N L relative-path`).
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let manifest = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&manifest).map_err(|e| {
        RuntimeError::new(format!("reading {manifest:?}: {e}; run `make artifacts` first"))
    })?;
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 4 {
            return Err(RuntimeError::new(format!(
                "manifest line {}: expected 4 fields, got {line:?}",
                lineno + 1
            )));
        }
        let parse_dim = |field: &str, what: &str| -> Result<usize> {
            field
                .parse()
                .map_err(|e| RuntimeError::new(format!("manifest {what} {field:?}: {e}")))
        };
        specs.push(ArtifactSpec {
            name: parts[0].to_string(),
            n: parse_dim(parts[1], "N")?,
            l: parse_dim(parts[2], "L")?,
            path: dir.join(parts[3]),
        });
    }
    if specs.is_empty() {
        return Err(RuntimeError::new(format!("manifest {manifest:?} lists no artifacts")));
    }
    Ok(specs)
}

/// Pick the smallest bucket that fits `(n_users, n_arms)`.
pub fn pick_bucket(specs: &[ArtifactSpec], n_users: usize, n_arms: usize) -> Result<&ArtifactSpec> {
    specs
        .iter()
        .filter(|s| s.n >= n_users && s.l >= n_arms)
        .min_by_key(|s| (s.l, s.n))
        .ok_or_else(|| {
            RuntimeError::new(format!(
                "no artifact bucket fits N={n_users}, L={n_arms}; available: {:?}",
                specs.iter().map(|s| (s.n, s.l)).collect::<Vec<_>>()
            ))
        })
}

/// Outputs of one artifact execution, sliced to the live problem size.
#[derive(Clone, Debug)]
pub struct StepOutputs {
    /// EIrate scores per arm (`NEG_INF_SCORE` where masked).
    pub eirate: Vec<f64>,
    /// Posterior mean per arm.
    pub mu: Vec<f64>,
    /// Posterior std per arm.
    pub sigma: Vec<f64>,
    /// Per-user incumbents.
    pub best: Vec<f64>,
}

/// Default artifact directory: `$MMGPEI_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("MMGPEI_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

#[cfg(feature = "xla")]
mod pjrt {
    //! The real PJRT-backed executable and backend (`--features xla`).

    use super::{load_manifest, pick_bucket, ArtifactSpec, Result, RuntimeError, StepOutputs};
    use crate::problem::{ArmId, Problem};
    use crate::sched::{DeviceView, EiBackend, ScoreMode};
    use std::path::Path;

    /// A compiled `scheduler_step` executable for one bucket.
    pub struct SchedulerStepExe {
        exe: xla::PjRtLoadedExecutable,
        /// Bucket user capacity.
        pub n: usize,
        /// Bucket arm capacity.
        pub l: usize,
    }

    impl SchedulerStepExe {
        /// Load HLO text and compile it on the given PJRT client.
        pub fn load(client: &xla::PjRtClient, spec: &ArtifactSpec) -> Result<Self> {
            let path = spec
                .path
                .to_str()
                .ok_or_else(|| RuntimeError::new("non-utf8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| RuntimeError::new(format!("parsing {:?}: {e:?}", spec.path)))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| RuntimeError::new(format!("compiling {}: {e:?}", spec.name)))?;
            Ok(SchedulerStepExe { exe, n: spec.n, l: spec.l })
        }

        /// Execute with already-padded inputs (lengths must match the bucket).
        #[allow(clippy::too_many_arguments)]
        pub fn run_padded(
            &self,
            k: &[f64],
            mu0: &[f64],
            obs_mask: &[f64],
            z: &[f64],
            sel_mask: &[f64],
            member: &[f64],
            cost: &[f64],
        ) -> Result<StepOutputs> {
            let (n, l) = (self.n, self.l);
            assert_eq!(k.len(), l * l);
            assert_eq!(member.len(), n * l);
            for (name, v) in
                [("mu0", mu0), ("obs", obs_mask), ("z", z), ("sel", sel_mask), ("cost", cost)]
            {
                assert_eq!(v.len(), l, "padded input {name}");
            }
            let lit = |data: &[f64], dims: &[i64]| -> Result<xla::Literal> {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| RuntimeError::new(format!("reshape {dims:?}: {e:?}")))
            };
            let args = [
                lit(k, &[l as i64, l as i64])?,
                lit(mu0, &[l as i64])?,
                lit(obs_mask, &[l as i64])?,
                lit(z, &[l as i64])?,
                lit(sel_mask, &[l as i64])?,
                lit(member, &[n as i64, l as i64])?,
                lit(cost, &[l as i64])?,
            ];
            let result = self
                .exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| RuntimeError::new(format!("execute: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError::new(format!("to_literal: {e:?}")))?;
            let (eirate, mu, sigma, best) = result
                .to_tuple4()
                .map_err(|e| RuntimeError::new(format!("untuple: {e:?}")))?;
            let vec = |lit: xla::Literal, what: &str| -> Result<Vec<f64>> {
                lit.to_vec::<f64>()
                    .map_err(|e| RuntimeError::new(format!("{what}: {e:?}")))
            };
            Ok(StepOutputs {
                eirate: vec(eirate, "eirate")?,
                mu: vec(mu, "mu")?,
                sigma: vec(sigma, "sigma")?,
                best: vec(best, "best")?,
            })
        }
    }

    /// [`EiBackend`] that scores decisions by executing the AOT artifact.
    ///
    /// Holds the padded prior (covariance, mean, membership, costs) as flat
    /// buffers and the mutable observation state; every `eirate` call is one
    /// PJRT execution.
    pub struct XlaBackend {
        exe: SchedulerStepExe,
        #[allow(dead_code)]
        n_users: usize,
        n_arms: usize,
        // Padded constant inputs.
        k: Vec<f64>,
        mu0: Vec<f64>,
        member: Vec<f64>,
        cost: Vec<f64>,
        // Padded mutable state.
        obs_mask: Vec<f64>,
        z: Vec<f64>,
        /// Cached outputs of the most recent execution (posterior snapshot).
        last: Option<StepOutputs>,
        /// Preallocated score output buffer ([`EiBackend::eirate`] returns
        /// a borrow of this).
        score_buf: Vec<f64>,
    }

    impl XlaBackend {
        /// Discover artifacts in `dir`, pick the bucket fitting `problem`,
        /// compile, and pre-pad the problem constants.
        pub fn new(problem: &Problem, dir: &Path) -> Result<Self> {
            let specs = load_manifest(dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError::new(format!("PJRT cpu client: {e:?}")))?;
            let spec = pick_bucket(&specs, problem.n_users, problem.n_arms())?;
            let exe = SchedulerStepExe::load(&client, spec)?;
            Ok(Self::with_exe(problem, exe))
        }

        /// Build from an already-compiled executable (shared across runs).
        pub fn with_exe(problem: &Problem, exe: SchedulerStepExe) -> Self {
            let (n, l) = (exe.n, exe.l);
            let n_users = problem.n_users;
            let n_arms = problem.n_arms();
            assert!(n_users <= n && n_arms <= l, "bucket too small");
            // K padded with identity rows (inert arms).
            let mut k = vec![0.0; l * l];
            for i in 0..l {
                for j in 0..l {
                    k[i * l + j] = if i < n_arms && j < n_arms {
                        problem.prior_cov[(i, j)]
                    } else if i == j {
                        1.0
                    } else {
                        0.0
                    };
                }
            }
            let mut mu0 = vec![0.0; l];
            mu0[..n_arms].copy_from_slice(&problem.prior_mean);
            let mut cost = vec![1.0; l];
            cost[..n_arms].copy_from_slice(&problem.cost);
            let mut member = vec![0.0; n * l];
            for (u, arms) in problem.user_arms.iter().enumerate() {
                for &a in arms {
                    member[u * l + a] = 1.0;
                }
            }
            XlaBackend {
                exe,
                n_users,
                n_arms,
                k,
                mu0,
                member,
                cost,
                obs_mask: vec![0.0; l],
                z: vec![0.0; l],
                last: None,
                score_buf: vec![super::NEG_INF_SCORE; n_arms],
            }
        }

        /// Execute the artifact against the current state.
        fn step(&mut self, selected: &[bool]) -> StepOutputs {
            let l = self.exe.l;
            let mut sel = vec![1.0; l]; // padding arms masked
            for (x, &s) in selected.iter().enumerate() {
                sel[x] = if s { 1.0 } else { 0.0 };
            }
            let out = self
                .exe
                .run_padded(&self.k, &self.mu0, &self.obs_mask, &self.z, &sel, &self.member, &self.cost)
                // pallas-lint: allow(R5) — inside the gated XLA backend; a PJRT execution failure mid-run has no recovery path, and `XlaBackend::new` already validated the artifact.
                .expect("artifact execution failed");
            self.last = Some(out.clone());
            out
        }
    }

    impl EiBackend for XlaBackend {
        fn observe(&mut self, arm: ArmId, z: f64) {
            assert!(arm < self.n_arms);
            debug_assert!(
                z >= 0.0,
                "XlaBackend incumbents floor at 0; negative performances need the native backend"
            );
            self.obs_mask[arm] = 1.0;
            self.z[arm] = z;
            self.last = None;
        }

        fn eirate(&mut self, _best: &[f64], selected: &[bool], mode: ScoreMode, device: DeviceView) -> &[f64] {
            // `best` is recomputed inside the artifact from (obs_mask, z) —
            // identical to the caller's incumbents for non-negative z.
            // The artifact's in-graph score is EI/c(x) (CostRate); the
            // other modes post-adjust the non-masked entries.
            let out = self.step(selected);
            self.score_buf.copy_from_slice(&out.eirate[..self.n_arms]);
            match mode {
                ScoreMode::CostRate => {}
                ScoreMode::EiOnly => {
                    // Undo the in-graph division for the EI-only ablation.
                    for (s, c) in self.score_buf.iter_mut().zip(&self.cost[..self.n_arms]) {
                        if *s > super::NEG_INF_SCORE {
                            *s *= c;
                        }
                    }
                }
                ScoreMode::DeviceRate => {
                    // EI/(c/s_d) = (EI/c)·s_d. The AOT artifact bakes in a
                    // single cost vector, so only the speed axis applies
                    // (class tables need the native backend); s_d = 1.0 is
                    // a bitwise no-op, preserving unit-fleet byte parity.
                    for s in self.score_buf.iter_mut() {
                        if *s > super::NEG_INF_SCORE {
                            *s *= device.speed;
                        }
                    }
                }
            }
            &self.score_buf
        }

        fn posterior(&mut self) -> (Vec<f64>, Vec<f64>) {
            let selected: Vec<bool> =
                self.obs_mask[..self.n_arms].iter().map(|&m| m > 0.5).collect();
            let out = match &self.last {
                Some(o) => o.clone(),
                None => self.step(&selected),
            };
            (out.mu[..self.n_arms].to_vec(), out.sigma[..self.n_arms].to_vec())
        }

        fn label(&self) -> &'static str {
            "xla"
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{SchedulerStepExe, XlaBackend};

#[cfg(not(feature = "xla"))]
mod stub {
    //! Default-build stand-in for the PJRT backend: constructible never,
    //! so call sites compile unchanged and fail gracefully at runtime.

    use super::{Result, RuntimeError};
    use crate::problem::{ArmId, Problem};
    use crate::sched::{DeviceView, EiBackend, ScoreMode};
    use std::path::Path;

    /// Stub [`EiBackend`]: the crate was built without the `xla` feature,
    /// so [`XlaBackend::new`] always returns an error and no value of
    /// this type can exist.
    pub struct XlaBackend {
        _unconstructible: std::convert::Infallible,
    }

    impl XlaBackend {
        /// Always fails: rebuild with `--features xla` (plus the PJRT
        /// toolchain — see `rust/Cargo.toml`) to enable the artifact path.
        pub fn new(_problem: &Problem, _dir: &Path) -> Result<Self> {
            Err(RuntimeError::new(
                "built without the `xla` feature: the PJRT scheduler_step backend is \
                 unavailable; rebuild with `cargo build --features xla` (requires the \
                 xla bindings crate and a PJRT CPU plugin — see rust/Cargo.toml)",
            ))
        }
    }

    impl EiBackend for XlaBackend {
        fn observe(&mut self, _arm: ArmId, _z: f64) {
            match self._unconstructible {}
        }

        fn eirate(&mut self, _best: &[f64], _selected: &[bool], _mode: ScoreMode, _device: DeviceView) -> &[f64] {
            match self._unconstructible {}
        }

        fn posterior(&mut self) -> (Vec<f64>, Vec<f64>) {
            match self._unconstructible {}
        }

        fn label(&self) -> &'static str {
            match self._unconstructible {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaBackend;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_and_bucket_choice() {
        let dir = std::env::temp_dir().join("mmgpei_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "a 16 128 a.hlo.txt\nb 32 512 b.hlo.txt\n\n",
        )
        .unwrap();
        let specs = load_manifest(&dir).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(pick_bucket(&specs, 9, 72).unwrap().name, "a");
        assert_eq!(pick_bucket(&specs, 9, 200).unwrap().name, "b");
        assert_eq!(pick_bucket(&specs, 20, 100).unwrap().name, "b");
        assert!(pick_bucket(&specs, 40, 100).is_err());
        assert!(pick_bucket(&specs, 4, 4000).is_err());
    }

    #[test]
    fn manifest_rejects_malformed() {
        let dir = std::env::temp_dir().join("mmgpei_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "too few fields\n").unwrap();
        assert!(load_manifest(&dir).is_err());
        let missing = std::env::temp_dir().join("mmgpei_manifest_missing");
        let _ = std::fs::remove_dir_all(&missing);
        assert!(load_manifest(&missing).is_err());
    }

    #[test]
    fn manifest_rejects_non_numeric_dims() {
        let dir = std::env::temp_dir().join("mmgpei_manifest_nan");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "a sixteen 128 a.hlo.txt\n").unwrap();
        let err = load_manifest(&dir).unwrap_err();
        assert!(err.to_string().contains("N"), "{err}");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_backend_reports_missing_feature() {
        use crate::linalg::Mat;
        use crate::problem::Problem;
        let user_arms = vec![vec![0]];
        let arm_users = Problem::compute_arm_users(1, &user_arms);
        let p = Problem {
            name: "stub".into(),
            n_users: 1,
            cost: vec![1.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.0],
            prior_cov: Mat::eye(1),
        };
        let err = XlaBackend::new(&p, std::path::Path::new("artifacts")).err().unwrap();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
