//! Scalar Gaussian analytics: `erf`, `Φ`, `φ`, and the paper's
//! `τ(u) = u·Φ(u) + φ(u)` (Lemma 1), from which the expected improvement
//! is `EI = σ·τ((μ − a)/σ)`.
//!
//! The offline toolchain provides no `libm`/`statrs`, so `erf` is
//! implemented here with W. J. Cody's rational approximations (the same
//! algorithm glibc uses), accurate to ~1e-15 relative error — verified in
//! the unit tests against high-precision reference values.

/// Error function, Cody's rational Chebyshev approximation.
pub fn erf(x: f64) -> f64 {
    let ax = x.abs();
    if ax < 0.5 {
        // erf(x) = x * P(x²)/Q(x²)
        const P: [f64; 5] = [
            3.209377589138469472562e3,
            3.774852376853020208137e2,
            1.138641541510501556495e2,
            3.161123743870565596947e0,
            1.857777061846031526730e-1,
        ];
        const Q: [f64; 4] = [
            2.844236833439170622273e3,
            1.282616526077372275645e3,
            2.440246379344441733056e2,
            2.360129095234412093499e1,
        ];
        let z = x * x;
        let num = ((((P[4] * z + P[3]) * z + P[2]) * z + P[1]) * z) + P[0];
        let den = ((((z + Q[3]) * z + Q[2]) * z + Q[1]) * z) + Q[0];
        x * num / den
    } else {
        let sign = if x < 0.0 { -1.0 } else { 1.0 };
        sign * (1.0 - erfc_positive(ax))
    }
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    if x < -0.5 {
        2.0 - erfc_positive(-x)
    } else if x < 0.5 {
        1.0 - erf(x)
    } else {
        erfc_positive(x)
    }
}

/// erfc for x ≥ 0.5 (Cody's second and third approximations).
fn erfc_positive(x: f64) -> f64 {
    debug_assert!(x >= 0.5);
    if x <= 4.0 {
        // erfc(x) = exp(-x²) P(x)/Q(x)
        const P: [f64; 9] = [
            1.23033935479799725272e3,
            2.05107837782607146532e3,
            1.71204761263407058314e3,
            8.81952221241769090411e2,
            2.98635138197400131132e2,
            6.61191906371416294775e1,
            8.88314979438837594118e0,
            5.64188496988670089180e-1,
            2.15311535474403846343e-8,
        ];
        const Q: [f64; 9] = [
            1.23033935480374942043e3,
            3.43936767414372163696e3,
            4.36261909014324715820e3,
            3.29079923573345962678e3,
            1.62138957456669018874e3,
            5.37181101862009857509e2,
            1.17693950891312499305e2,
            1.57449261107098347253e1,
            1.0,
        ];
        let mut num = P[8] * x;
        let mut den = Q[8] * x;
        for i in (1..8).rev() {
            num = (num + P[i]) * x;
            den = (den + Q[i]) * x;
        }
        (-x * x).exp() * (num + P[0]) / (den + Q[0])
    } else if x < 26.0 {
        // erfc(x) ≈ exp(-x²)/(x√π) [1 + R(1/x²)/x²]
        const P: [f64; 6] = [
            -6.58749161529837803157e-4,
            -1.60837851487422766278e-2,
            -1.25781726111229246204e-1,
            -3.60344899949804439429e-1,
            -3.05326634961232344035e-1,
            -1.63153871373020978498e-2,
        ];
        const Q: [f64; 6] = [
            2.33520497626869185443e-3,
            6.05183413124413191178e-2,
            5.27905102951428412248e-1,
            1.87295284992346047209e0,
            2.56852019228982242072e0,
            1.0,
        ];
        let z = 1.0 / (x * x);
        let mut num = P[5] * z;
        let mut den = Q[5] * z;
        for i in (1..5).rev() {
            num = (num + P[i]) * z;
            den = (den + Q[i]) * z;
        }
        let r = z * (num + P[0]) / (den + Q[0]);
        const INV_SQRT_PI: f64 = 0.564189583547756286948;
        ((-x * x).exp() / x) * (INV_SQRT_PI + r)
    } else {
        0.0
    }
}

/// Standard normal PDF `φ(x)`.
#[inline]
pub fn norm_pdf(x: f64) -> f64 {
    const INV_SQRT_2PI: f64 = 0.398942280401432677939946;
    INV_SQRT_2PI * (-0.5 * x * x).exp()
}

/// Standard normal CDF `Φ(x)`.
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// The paper's `τ(u) = u·Φ(u) + φ(u)` (Lemma 1).
///
/// `τ` is positive, strictly increasing, with `τ(u) → 0` as `u → −∞` and
/// `τ(u) ≈ u` for large `u`.
#[inline]
pub fn tau(u: f64) -> f64 {
    u * norm_cdf(u) + norm_pdf(u)
}

/// Expected improvement of a Gaussian `N(μ, σ²)` over incumbent `a`:
/// `E[max(X − a, 0)] = σ·τ((μ − a)/σ)` (paper Lemma 1), handling the
/// degenerate `σ = 0` case as `max(μ − a, 0)`.
#[inline]
pub fn expected_improvement(mu: f64, sigma: f64, a: f64) -> f64 {
    if sigma <= 0.0 {
        (mu - a).max(0.0)
    } else {
        sigma * tau((mu - a) / sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values computed with mpmath at 50 digits.
    const ERF_TABLE: &[(f64, f64)] = &[
        (0.0, 0.0),
        (0.1, 0.1124629160182848922033),
        (0.25, 0.2763263901682369017001),
        (0.5, 0.5204998778130465376827),
        (1.0, 0.8427007929497148693412),
        (1.5, 0.9661051464753107270669),
        (2.0, 0.9953222650189527341621),
        (3.0, 0.9999779095030014145586),
        (4.0, 0.9999999845827420997200),
        (5.0, 0.9999999999984625402056),
    ];

    #[test]
    fn erf_matches_reference() {
        for &(x, want) in ERF_TABLE {
            let got = erf(x);
            assert!((got - want).abs() < 1e-14, "erf({x}) = {got}, want {want}");
            assert!((erf(-x) + want).abs() < 1e-14, "erf(-x) should be -erf(x)");
        }
    }

    #[test]
    fn erfc_matches_reference_tail() {
        // erfc in the deep tail, where 1 - erf loses all precision.
        let cases = [
            (5.0, 1.5374597944280348501883e-12),
            (8.0, 1.1224297172982927079287e-29),
            (15.0, 7.2129941724512066665650e-100),
        ];
        for (x, want) in cases {
            let got = erfc(x);
            assert!(
                ((got - want) / want).abs() < 1e-12,
                "erfc({x}) = {got:e}, want {want:e}"
            );
        }
        assert_eq!(erfc(30.0), 0.0);
    }

    #[test]
    fn cdf_symmetry_and_known_points() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-15);
        // Φ(1.959963984540054) = 0.975
        assert!((norm_cdf(1.959963984540054) - 0.975).abs() < 1e-12);
        for x in [-3.0, -1.0, -0.3, 0.4, 2.2] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn pdf_known_points() {
        assert!((norm_pdf(0.0) - 0.3989422804014327).abs() < 1e-15);
        assert!((norm_pdf(1.0) - 0.24197072451914337).abs() < 1e-15);
    }

    #[test]
    fn tau_properties() {
        // τ(0) = φ(0) = 1/√(2π)
        assert!((tau(0.0) - 0.3989422804014327).abs() < 1e-14);
        // Identity used in the paper's Lemma 3: τ(u) = u + τ(−u).
        for u in [0.1, 0.7, 1.3, 2.9] {
            assert!((tau(u) - (u + tau(-u))).abs() < 1e-13, "u={u}");
        }
        // Monotone increasing, positive.
        let mut prev = tau(-10.0);
        assert!(prev >= 0.0);
        let mut u = -10.0;
        while u < 10.0 {
            u += 0.25;
            let t = tau(u);
            assert!(t >= prev, "τ must be non-decreasing at {u}");
            prev = t;
        }
        // τ(u) ≤ 1 + u for u ≥ 0 (used in Lemma 3's upper bound).
        for u in [0.0, 0.5, 1.0, 4.0] {
            assert!(tau(u) <= 1.0 + u + 1e-12);
        }
    }

    #[test]
    fn ei_degenerate_sigma() {
        assert!((expected_improvement(0.7, 0.0, 0.5) - 0.2).abs() < 1e-15);
        assert_eq!(expected_improvement(0.3, 0.0, 0.5), 0.0);
    }

    #[test]
    fn ei_monte_carlo_agreement() {
        // EI against a brute-force Monte-Carlo estimate.
        use crate::prng::Rng;
        let mut rng = Rng::new(123);
        // Miri: 400k draws per case is far over the interpreter budget;
        // fewer samples means a wider Monte-Carlo tolerance (~σ/√n).
        let (n, tol) = if cfg!(miri) {
            (4_000, 5e-2)
        } else {
            (400_000, 5e-3)
        };
        for (mu, sigma, a) in [(0.0, 1.0, 0.5), (0.6, 0.2, 0.7), (1.0, 0.5, 0.0)] {
            let mc: f64 = (0..n)
                .map(|_| (rng.normal_with(mu, sigma) - a).max(0.0))
                .sum::<f64>()
                / n as f64;
            let analytic = expected_improvement(mu, sigma, a);
            assert!(
                (mc - analytic).abs() < tol,
                "EI({mu},{sigma},{a}): mc={mc} analytic={analytic}"
            );
        }
    }

    #[test]
    fn ei_increasing_in_mu_and_sigma() {
        let a = 0.5;
        let mut prev = 0.0;
        for k in 0..20 {
            let mu = -1.0 + 0.15 * k as f64;
            let ei = expected_improvement(mu, 0.3, a);
            assert!(ei >= prev);
            prev = ei;
        }
        // For μ ≤ a, EI grows with σ.
        let mut prev = 0.0;
        for k in 1..20 {
            let ei = expected_improvement(0.2, 0.1 * k as f64, a);
            assert!(ei >= prev);
            prev = ei;
        }
    }
}
