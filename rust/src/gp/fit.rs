//! GP prior hyperparameter fitting — the "parameters of the Gaussian
//! process can be obtained from historical experiences" discussion of
//! the paper's §4.2, made concrete: maximize the log marginal likelihood
//! of holdout observations over kernel hyperparameters with an in-tree
//! Nelder–Mead optimizer (no optimization crates exist in the offline
//! environment — this is another substrate built from scratch).

use crate::kernels::{Kernel, Matern52};
use crate::linalg::{cholesky_jittered, cholesky_solve_into, logdet_from_cholesky, Mat};

/// Reusable buffers for repeated [`log_marginal_likelihood_scratch`]
/// evaluations. The Nelder–Mead fit loop evaluates the LML hundreds of
/// times at a fixed problem size; routing the triangular solves through
/// one scratch keeps the loop free of per-evaluation `Vec` churn.
#[derive(Clone, Debug, Default)]
pub struct LmlScratch {
    /// Intermediate forward-substitution result `L⁻¹ y`.
    fwd: Vec<f64>,
    /// Solution `α = K⁻¹ y`.
    alpha: Vec<f64>,
}

/// Log marginal likelihood of observations `y` under a zero-mean GP with
/// covariance `k`: `−½ yᵀK⁻¹y − ½ log|K| − n/2·log 2π`.
pub fn log_marginal_likelihood(k: &Mat, y: &[f64]) -> f64 {
    log_marginal_likelihood_scratch(k, y, &mut LmlScratch::default())
}

/// Buffer-reusing form of [`log_marginal_likelihood`]: identical floats,
/// but the triangular solves write into `scratch` instead of allocating
/// fresh `Vec`s — the form the Nelder–Mead refit loop calls.
pub fn log_marginal_likelihood_scratch(k: &Mat, y: &[f64], scratch: &mut LmlScratch) -> f64 {
    let n = y.len();
    assert_eq!(k.rows(), n);
    let (l, _) = match cholesky_jittered(k, 1e-10) {
        Ok(ok) => ok,
        Err(_) => return f64::NEG_INFINITY,
    };
    cholesky_solve_into(&l, y, &mut scratch.fwd, &mut scratch.alpha);
    let fit: f64 = y.iter().zip(&scratch.alpha).map(|(a, b)| a * b).sum();
    -0.5 * fit - 0.5 * logdet_from_cholesky(&l)
        - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
}

/// Nelder–Mead simplex minimizer (derivative-free).
///
/// Standard coefficients (reflection 1, expansion 2, contraction ½,
/// shrink ½); terminates when the simplex's objective spread drops below
/// `tol` or after `max_iter` iterations. Returns `(argmin, min)`.
/// Takes `FnMut` so objectives can carry reusable scratch buffers (see
/// [`LmlScratch`]).
pub fn nelder_mead(
    mut f: impl FnMut(&[f64]) -> f64,
    x0: &[f64],
    step: f64,
    tol: f64,
    max_iter: usize,
) -> (Vec<f64>, f64) {
    let dim = x0.len();
    assert!(dim >= 1);
    // Initial simplex: x0 plus one perturbed vertex per dimension.
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(dim + 1);
    simplex.push((x0.to_vec(), f(x0)));
    for d in 0..dim {
        let mut v = x0.to_vec();
        v[d] += step;
        let fv = f(&v);
        simplex.push((v, fv));
    }
    for _ in 0..max_iter {
        simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
        let spread = simplex[dim].1 - simplex[0].1;
        if spread.abs() < tol {
            break;
        }
        // Centroid of all but the worst.
        let mut centroid = vec![0.0; dim];
        for (v, _) in &simplex[..dim] {
            for d in 0..dim {
                centroid[d] += v[d] / dim as f64;
            }
        }
        let worst = simplex[dim].clone();
        let lerp = |t: f64| -> Vec<f64> {
            (0..dim).map(|d| centroid[d] + t * (worst.0[d] - centroid[d])).collect()
        };
        let reflect = lerp(-1.0);
        let f_reflect = f(&reflect);
        if f_reflect < simplex[0].1 {
            // Try expansion.
            let expand = lerp(-2.0);
            let f_expand = f(&expand);
            simplex[dim] = if f_expand < f_reflect {
                (expand, f_expand)
            } else {
                (reflect, f_reflect)
            };
        } else if f_reflect < simplex[dim - 1].1 {
            simplex[dim] = (reflect, f_reflect);
        } else {
            // Contraction (outside if reflection helped at all).
            let contract = if f_reflect < worst.1 { lerp(-0.5) } else { lerp(0.5) };
            let f_contract = f(&contract);
            if f_contract < worst.1.min(f_reflect) {
                simplex[dim] = (contract, f_contract);
            } else {
                // Shrink toward the best vertex.
                let best = simplex[0].0.clone();
                for item in simplex.iter_mut().skip(1) {
                    for d in 0..dim {
                        item.0[d] = best[d] + 0.5 * (item.0[d] - best[d]);
                    }
                    item.1 = f(&item.0);
                }
            }
        }
    }
    simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
    simplex[0].clone().into()
}

/// Fitted Matérn-5/2 hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct FittedMatern {
    /// Output variance σ².
    pub variance: f64,
    /// Lengthscale ℓ.
    pub lengthscale: f64,
    /// Achieved log marginal likelihood.
    pub log_marginal: f64,
}

/// Fit Matérn-5/2 `(σ², ℓ)` to zero-mean observations `y` at 1-D
/// `points` by maximizing the log marginal likelihood (optimized in
/// log-parameter space to keep both positive).
pub fn fit_matern52(points: &[Vec<f64>], y: &[f64], init: &Matern52) -> FittedMatern {
    assert_eq!(points.len(), y.len());
    // One scratch for the whole optimization: the solver re-evaluates the
    // LML hundreds of times at fixed size, so the triangular-solve
    // buffers are paid for once instead of twice per evaluation.
    let mut scratch = LmlScratch::default();
    let objective = move |log_params: &[f64]| -> f64 {
        let kern = Matern52 { variance: log_params[0].exp(), lengthscale: log_params[1].exp() };
        // Guard absurd scales that make the gram matrix degenerate.
        if !(1e-8..1e8).contains(&kern.variance) || !(1e-8..1e8).contains(&kern.lengthscale) {
            return f64::INFINITY;
        }
        -log_marginal_likelihood_scratch(&kern.gram(points), y, &mut scratch)
    };
    let x0 = [init.variance.ln(), init.lengthscale.ln()];
    let (best, neg_lml) = nelder_mead(objective, &x0, 0.4, 1e-8, 200);
    FittedMatern {
        variance: best[0].exp(),
        lengthscale: best[1].exp(),
        log_marginal: -neg_lml,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn nelder_mead_minimizes_quadratic() {
        let f = |x: &[f64]| (x[0] - 3.0).powi(2) + 2.0 * (x[1] + 1.0).powi(2) + 5.0;
        let (x, fx) = nelder_mead(f, &[0.0, 0.0], 1.0, 1e-12, 500);
        assert!((x[0] - 3.0).abs() < 1e-4, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-4);
        assert!((fx - 5.0).abs() < 1e-8);
    }

    #[test]
    fn nelder_mead_rosenbrock_1d_family() {
        // Rosenbrock in 2-D: minimum at (1, 1).
        let f = |x: &[f64]| (1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2);
        let (x, fx) = nelder_mead(f, &[-1.2, 1.0], 0.5, 1e-14, 5000);
        assert!(fx < 1e-6, "rosenbrock min {fx} at {x:?}");
    }

    #[test]
    fn lml_prefers_true_kernel() {
        // Draw from a known Matérn; its LML must beat badly wrong scales.
        let pts: Vec<Vec<f64>> = (0..30).map(|i| vec![i as f64 * 0.3]).collect();
        let truth = Matern52 { variance: 1.0, lengthscale: 1.0 };
        let gram = truth.gram(&pts);
        let (l, _) = cholesky_jittered(&gram, 1e-10).unwrap();
        let mut rng = Rng::new(44);
        let y = rng.mvn(&vec![0.0; 30], &l);
        let lml_true = log_marginal_likelihood(&gram, &y);
        for wrong in [
            Matern52 { variance: 25.0, lengthscale: 1.0 },
            Matern52 { variance: 1.0, lengthscale: 0.05 },
            Matern52 { variance: 0.05, lengthscale: 1.0 },
        ] {
            let lml_wrong = log_marginal_likelihood(&wrong.gram(&pts), &y);
            assert!(
                lml_true > lml_wrong,
                "true kernel must beat σ²={}, ℓ={}: {lml_true} vs {lml_wrong}",
                wrong.variance,
                wrong.lengthscale
            );
        }
    }

    #[test]
    fn fit_recovers_ballpark_hyperparameters() {
        // Miri: the fit is hundreds of O(n³) LML evaluations, so shrink
        // the sample and keep only the optimizer-improvement assert (the
        // recovery bounds are statistical and need the full 40 points).
        let n = if cfg!(miri) { 8 } else { 40 };
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 * 0.25]).collect();
        let truth = Matern52 { variance: 2.0, lengthscale: 0.8 };
        let gram = truth.gram(&pts);
        let (l, _) = cholesky_jittered(&gram, 1e-10).unwrap();
        let mut rng = Rng::new(7);
        let y = rng.mvn(&vec![0.0; n], &l);
        let fitted = fit_matern52(&pts, &y, &Matern52 { variance: 0.5, lengthscale: 2.0 });
        // One sample path → loose recovery bounds; order of magnitude is
        // what matters for the prior-misspecification experiment.
        if !cfg!(miri) {
            assert!(fitted.variance > 0.4 && fitted.variance < 10.0, "{fitted:?}");
            assert!(fitted.lengthscale > 0.2 && fitted.lengthscale < 3.2, "{fitted:?}");
        }
        // Fitted LML must be at least as good as the init's.
        let init_lml = log_marginal_likelihood(
            &Matern52 { variance: 0.5, lengthscale: 2.0 }.gram(&pts),
            &y,
        );
        assert!(fitted.log_marginal >= init_lml - 1e-9);
    }

    #[test]
    fn scratch_lml_matches_and_reuses_buffers() {
        let pts: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64 * 0.3]).collect();
        let kern = Matern52 { variance: 1.3, lengthscale: 0.9 };
        let gram = kern.gram(&pts);
        let (l, _) = cholesky_jittered(&gram, 1e-10).unwrap();
        let mut rng = Rng::new(55);
        let y = rng.mvn(&vec![0.0; 20], &l);
        let mut scratch = LmlScratch::default();
        let first = log_marginal_likelihood_scratch(&gram, &y, &mut scratch);
        assert_eq!(first, log_marginal_likelihood(&gram, &y), "scratch form must be bit-identical");
        let ptrs = (scratch.fwd.as_ptr(), scratch.alpha.as_ptr());
        let second = log_marginal_likelihood_scratch(&gram, &y, &mut scratch);
        assert_eq!(first, second);
        assert_eq!(ptrs, (scratch.fwd.as_ptr(), scratch.alpha.as_ptr()), "buffers must be reused");
    }

    #[test]
    fn lml_degenerate_matrix_is_neg_inf() {
        // A matrix that stays indefinite even after jitter escalation.
        let k = Mat::from_rows(&[&[1.0, 5.0], &[5.0, 1.0]]);
        assert_eq!(log_marginal_likelihood(&k, &[0.1, 0.2]), f64::NEG_INFINITY);
    }
}
