//! Gaussian-process posterior over the arm set — the native (rust)
//! posterior backend and the analytic EI machinery of the paper's §4.
//!
//! The GP prior is `z ~ GP(μ(x), k(x,x'))` over a *finite* arm set, so the
//! posterior formulas (paper Supplemental §A) reduce to dense linear
//! algebra against the kernel matrix of observed arms:
//!
//! ```text
//! μ_t(x)  = μ(x) + v_t(x)ᵀ K_t⁻¹ (z_t − μ_obs)
//! σ_t²(x) = k(x,x) − v_t(x)ᵀ K_t⁻¹ v_t(x)
//! ```
//!
//! **Hot-path design.** A naive implementation refactorizes `K_t` and
//! re-solves for every arm on every decision — `O(t³ + |𝓛|·t²)` per
//! completion. [`Gp`] instead maintains, incrementally:
//!
//! * the Cholesky factor `L` of `K_t` (rank-append, `O(t²)`),
//! * `β = L⁻¹(z − μ_obs)` (one new entry per observation),
//! * per-arm `w(x) = L⁻¹ v_t(x)` (one new entry per observation),
//!
//! so that `μ_t(x) = μ(x) + w(x)ᵀβ` and `σ_t²(x) = k(x,x) − ‖w(x)‖²` are
//! maintained with `O(|𝓛|·t)` work per observation and **O(1)** reads at
//! decision time. The `recompute_posterior_slow` method is the
//! textbook-formula oracle used by the test suite to validate the
//! incremental path.

mod fit;
mod stats;

pub use fit::{fit_matern52, log_marginal_likelihood, nelder_mead, FittedMatern};
pub use stats::{erf, erfc, expected_improvement, norm_cdf, norm_pdf, tau};

use crate::linalg::{cholesky_jittered, cholesky_solve, CholeskyFactor, Mat};
use crate::problem::ArmId;

/// Default base jitter for numerically singular kernel appends.
pub const DEFAULT_JITTER: f64 = 1e-10;

/// Incrementally updated GP posterior over a finite arm set.
#[derive(Clone, Debug)]
pub struct Gp {
    prior_mean: Vec<f64>,
    prior_cov: Mat,
    chol: CholeskyFactor,
    /// Arms observed so far, in observation order.
    obs_arms: Vec<ArmId>,
    /// `β = L⁻¹ (z − μ_obs)` (grows by one entry per observation).
    beta: Vec<f64>,
    /// `w[x] = L⁻¹ v_t(x)` per arm, stored flat with stride `n_arms`
    /// (the maximum observation count): `w[x·n + k]` is entry `k` of
    /// arm x's vector. Flat storage keeps the per-observation update a
    /// single contiguous sweep (§Perf L3 iteration 2).
    w: Vec<f64>,
    /// Current posterior mean per arm.
    mu: Vec<f64>,
    /// Current posterior variance per arm (clamped at 0).
    var: Vec<f64>,
    observed: Vec<bool>,
}

impl Gp {
    /// Fresh GP with the given prior.
    pub fn new(prior_mean: Vec<f64>, prior_cov: Mat) -> Self {
        let n = prior_mean.len();
        assert_eq!(prior_cov.rows(), n);
        assert_eq!(prior_cov.cols(), n);
        let var = (0..n).map(|i| prior_cov[(i, i)]).collect();
        Gp {
            mu: prior_mean.clone(),
            var,
            prior_mean,
            prior_cov,
            chol: CholeskyFactor::new(),
            obs_arms: Vec::new(),
            beta: Vec::new(),
            w: vec![0.0; n * n],
            observed: vec![false; n],
        }
    }

    /// Number of arms.
    pub fn n_arms(&self) -> usize {
        self.prior_mean.len()
    }

    /// Number of observations incorporated.
    pub fn n_observed(&self) -> usize {
        self.obs_arms.len()
    }

    /// Whether arm `x` has been observed.
    pub fn is_observed(&self, x: ArmId) -> bool {
        self.observed[x]
    }

    /// Posterior mean `μ_t(x)`.
    #[inline]
    pub fn posterior_mean(&self, x: ArmId) -> f64 {
        self.mu[x]
    }

    /// Posterior standard deviation `σ_t(x)`.
    #[inline]
    pub fn posterior_std(&self, x: ArmId) -> f64 {
        self.var[x].max(0.0).sqrt()
    }

    /// Prior mean `μ(x)` (Algorithm 1 line 1 uses this for warm start).
    pub fn prior_mean(&self, x: ArmId) -> f64 {
        self.prior_mean[x]
    }

    /// Incorporate the observation `z(x)`. `O(|𝓛|·t)`.
    ///
    /// Repeated observation of the same arm is a scheduler bug (the paper
    /// observes each model once, noise-free) — panics in debug, ignored in
    /// release.
    pub fn observe(&mut self, x: ArmId, z: f64) {
        debug_assert!(!self.observed[x], "arm {x} observed twice");
        if self.observed[x] {
            return;
        }
        let t = self.chol.dim();
        // Cross-covariances of the new observation against prior ones.
        let cross: Vec<f64> = self.obs_arms.iter().map(|&a| self.prior_cov[(x, a)]).collect();
        let diag = self.prior_cov[(x, x)];
        let (_, jitter) = self
            .chol
            .append_jittered(&cross, diag, DEFAULT_JITTER)
            .expect("kernel matrix irrecoverably singular");
        let _ = jitter;
        // New last entry of β: solve row t of L·β = (z − μ_obs).
        let resid = z - self.prior_mean[x];
        let row = self.chol.row(t);
        let mut acc = resid;
        for k in 0..t {
            acc -= row[k] * self.beta[k];
        }
        let ltt = row[t];
        let beta_t = acc / ltt;
        // Copy row t of L once to release the borrow on self.chol.
        let lrow: Vec<f64> = row[..t].to_vec();
        self.beta.push(beta_t);
        self.observed[x] = true;
        self.obs_arms.push(x);
        // Extend every arm's w by one entry and fold into μ/σ².
        // Hot loop of the native backend: per arm, one contiguous dot of
        // length t (flat `w` stride) against the cached L-row, reading
        // the cross-covariances from *row* x of the symmetric prior
        // (k(a,x) = k(x,a)) so the scan is fully sequential in memory.
        let n = self.n_arms();
        let covx = self.prior_cov.row(x);
        for a in 0..n {
            let wa = &self.w[a * n..a * n + t];
            let mut num = covx[a];
            for (l, w) in lrow.iter().zip(wa) {
                num -= l * w;
            }
            let w_new = num / ltt;
            self.w[a * n + t] = w_new;
            self.mu[a] += w_new * beta_t;
            self.var[a] -= w_new * w_new;
        }
        // The observed arm's posterior is exact: pin it (kills the jitter
        // residue so incumbents computed from μ match observed z).
        self.mu[x] = z;
        self.var[x] = 0.0;
    }

    /// Expected improvement of arm `x` over incumbent value `best`
    /// (paper Eq. 3 via Lemma 1).
    #[inline]
    pub fn ei(&self, x: ArmId, best: f64) -> f64 {
        expected_improvement(self.mu[x], self.posterior_std(x), best)
    }

    /// Textbook-formula posterior for *all* arms — `O(t³ + |𝓛|t²)`,
    /// used as the correctness oracle for the incremental path and as the
    /// reference the AOT XLA artifact is verified against.
    pub fn recompute_posterior_slow(&self) -> (Vec<f64>, Vec<f64>) {
        let t = self.obs_arms.len();
        let n = self.n_arms();
        if t == 0 {
            let sd = (0..n).map(|i| self.prior_cov[(i, i)].max(0.0).sqrt()).collect();
            return (self.prior_mean.clone(), sd);
        }
        let kt = Mat::from_fn(t, t, |i, j| {
            self.prior_cov[(self.obs_arms[i], self.obs_arms[j])]
        });
        let (l, _) = cholesky_jittered(&kt, DEFAULT_JITTER).expect("singular K_t");
        let resid: Vec<f64> = self
            .obs_arms
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                // z is recoverable from pinned posterior mean of observed arms.
                let _ = i;
                self.mu[a] - self.prior_mean[a]
            })
            .collect();
        let alpha = cholesky_solve(&l, &resid);
        let mut mu = vec![0.0; n];
        let mut sd = vec![0.0; n];
        for x in 0..n {
            let v: Vec<f64> = self.obs_arms.iter().map(|&a| self.prior_cov[(x, a)]).collect();
            let mut m = self.prior_mean[x];
            for k in 0..t {
                m += v[k] * alpha[k];
            }
            let w = crate::linalg::solve_lower(&l, &v);
            let var = self.prior_cov[(x, x)] - w.iter().map(|u| u * u).sum::<f64>();
            mu[x] = m;
            sd[x] = var.max(0.0).sqrt();
        }
        (mu, sd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, Matern52};
    use crate::prng::Rng;

    fn gp_on_grid(n: usize) -> (Gp, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 * 0.4]).collect();
        let kern = Matern52 { variance: 1.0, lengthscale: 1.0 };
        let cov = kern.gram(&pts);
        let l = crate::linalg::cholesky_jittered(&cov, 1e-10).unwrap().0;
        let mut rng = Rng::new(9001);
        let z = rng.mvn(&vec![0.0; n], &l);
        (Gp::new(vec![0.0; n], cov), z)
    }

    #[test]
    fn prior_posterior_before_observations() {
        let (gp, _) = gp_on_grid(5);
        for x in 0..5 {
            assert_eq!(gp.posterior_mean(x), 0.0);
            assert!((gp.posterior_std(x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn observed_arm_is_pinned() {
        let (mut gp, z) = gp_on_grid(6);
        gp.observe(2, z[2]);
        assert!((gp.posterior_mean(2) - z[2]).abs() < 1e-12);
        assert_eq!(gp.posterior_std(2), 0.0);
        assert!(gp.is_observed(2));
        assert!(!gp.is_observed(3));
    }

    #[test]
    fn incremental_matches_slow_oracle() {
        let (mut gp, z) = gp_on_grid(12);
        let order = [3usize, 7, 0, 11, 5, 9];
        for &x in &order {
            gp.observe(x, z[x]);
            let (mu_slow, sd_slow) = gp.recompute_posterior_slow();
            for a in 0..gp.n_arms() {
                assert!(
                    (gp.posterior_mean(a) - mu_slow[a]).abs() < 1e-7,
                    "mean mismatch at arm {a} after observing {x}"
                );
                assert!(
                    (gp.posterior_std(a) - sd_slow[a]).abs() < 1e-6,
                    "std mismatch at arm {a} after observing {x}: {} vs {}",
                    gp.posterior_std(a),
                    sd_slow[a]
                );
            }
        }
    }

    #[test]
    fn posterior_interpolates_neighbors() {
        // Observing a high value at arm k should raise the posterior mean
        // of its close neighbor above the prior.
        let (mut gp, _) = gp_on_grid(10);
        gp.observe(4, 2.0);
        assert!(gp.posterior_mean(5) > 0.5, "neighbor should be pulled up");
        assert!(gp.posterior_mean(9) < gp.posterior_mean(5), "far arm less affected");
        // Uncertainty shrinks near the observation.
        assert!(gp.posterior_std(5) < 1.0);
        assert!(gp.posterior_std(9) > gp.posterior_std(5));
    }

    #[test]
    fn variance_never_increases() {
        let (mut gp, z) = gp_on_grid(15);
        let mut prev: Vec<f64> = (0..15).map(|a| gp.posterior_std(a)).collect();
        for x in [0usize, 14, 7, 3, 10] {
            gp.observe(x, z[x]);
            for a in 0..15 {
                let s = gp.posterior_std(a);
                assert!(s <= prev[a] + 1e-8, "σ must shrink (arm {a})");
                prev[a] = s;
            }
        }
    }

    #[test]
    fn ei_zero_for_observed_arm() {
        let (mut gp, z) = gp_on_grid(8);
        gp.observe(3, z[3]);
        // EI of an observed arm over an incumbent ≥ its value is 0.
        assert_eq!(gp.ei(3, z[3] + 0.1), 0.0);
    }

    #[test]
    fn ei_positive_for_uncertain_arm() {
        let (gp, _) = gp_on_grid(8);
        assert!(gp.ei(0, 0.5) > 0.0, "uncertain arm always has positive EI");
    }

    #[test]
    fn handles_duplicate_correlated_arms_via_jitter() {
        // Two perfectly correlated arms: observing both must not crash.
        let cov = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let mut gp = Gp::new(vec![0.0, 0.0], cov);
        gp.observe(0, 0.7);
        // After observing arm 0, arm 1's posterior collapses onto it.
        assert!((gp.posterior_mean(1) - 0.7).abs() < 1e-6);
        assert!(gp.posterior_std(1) < 1e-4);
        gp.observe(1, 0.7);
        assert!((gp.posterior_mean(1) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn mvn_draw_consistency_full_observation() {
        // Observing every arm pins every posterior to the draw.
        let (mut gp, z) = gp_on_grid(7);
        for x in 0..7 {
            gp.observe(x, z[x]);
        }
        for x in 0..7 {
            assert!((gp.posterior_mean(x) - z[x]).abs() < 1e-9);
            assert!(gp.posterior_std(x) < 1e-9);
        }
    }
}
