//! Gaussian-process posterior over the arm set — the native (rust)
//! posterior backend and the analytic EI machinery of the paper's §4.
//!
//! The GP prior is `z ~ GP(μ(x), k(x,x'))` over a *finite* arm set, so the
//! posterior formulas (paper Supplemental §A) reduce to dense linear
//! algebra against the kernel matrix of observed arms:
//!
//! ```text
//! μ_t(x)  = μ(x) + v_t(x)ᵀ K_t⁻¹ (z_t − μ_obs)
//! σ_t²(x) = k(x,x) − v_t(x)ᵀ K_t⁻¹ v_t(x)
//! ```
//!
//! **Hot-path design.** A naive implementation refactorizes `K_t` and
//! re-solves for every arm on every decision — `O(t³ + |𝓛|·t²)` per
//! completion. [`Gp`] instead maintains, incrementally:
//!
//! * the Cholesky factor `L` of `K_t` (rank-append, `O(t²)`),
//! * `β = L⁻¹(z − μ_obs)` (one new entry per observation),
//! * per-arm `w(x) = L⁻¹ v_t(x)` (one new entry per observation),
//!
//! so that `μ_t(x) = μ(x) + w(x)ᵀβ` and `σ_t²(x) = k(x,x) − ‖w(x)‖²` are
//! maintained with `O(|𝓛|·t)` work per observation and **O(1)** reads at
//! decision time. The `recompute_posterior_slow` method is the
//! textbook-formula oracle used by the test suite to validate the
//! incremental path.
//!
//! For multi-tenant priors with the Kronecker structure `B(ρ) ⊗ C`,
//! [`ShardedGp`] (see its type-level docs) replaces the single dense
//! factor with per-tenant Cholesky shards plus a low-rank cross-tenant
//! coupling — `O(t_u²)` per observe regardless of the global observation
//! count, which is what scales the scheduler to 10⁴–10⁶ tenants. The
//! dense [`Gp`] remains the default and the parity oracle.

mod fit;
mod shard;
mod stats;

pub use fit::{fit_matern52, log_marginal_likelihood, log_marginal_likelihood_scratch, nelder_mead};
pub use fit::{FittedMatern, LmlScratch};
pub use shard::{KroneckerPrior, ShardedGp};
pub use stats::{erf, erfc, expected_improvement, norm_cdf, norm_pdf, tau};

use std::fmt;

use crate::linalg::{cholesky_jittered, cholesky_solve, CholeskyFactor, Mat};
use crate::problem::ArmId;

/// Default base jitter for numerically singular kernel appends.
pub const DEFAULT_JITTER: f64 = 1e-10;

/// Minimum Cholesky pivot (σ floor) accepted when appending an
/// observation. Pivots below this are floored by escalating jitter so the
/// posterior update's `acc / ltt` division can never overflow into ±∞
/// and emit NaN posteriors (a pivot of e.g. 1e-300 passes a plain `> 0`
/// check but poisons every arm's mean).
pub const MIN_PIVOT: f64 = 1e-8;

/// Errors from [`Gp::try_observe`].
#[derive(Clone, Debug, PartialEq)]
pub enum GpError {
    /// The arm was already observed; the paper's protocol observes each
    /// model exactly once (noise-free), so a repeat is a scheduler bug.
    AlreadyObserved(ArmId),
}

impl fmt::Display for GpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GpError::AlreadyObserved(x) => write!(f, "arm {x} observed twice"),
        }
    }
}

impl std::error::Error for GpError {}

/// Incrementally updated GP posterior over a finite arm set.
#[derive(Clone, Debug)]
pub struct Gp {
    prior_mean: Vec<f64>,
    prior_cov: Mat,
    chol: CholeskyFactor,
    /// Arms observed so far, in observation order.
    obs_arms: Vec<ArmId>,
    /// `β = L⁻¹ (z − μ_obs)` (grows by one entry per observation).
    beta: Vec<f64>,
    /// `w[x] = L⁻¹ v_t(x)` per arm, stored flat with stride `n_arms`
    /// (the maximum observation count): `w[x·n + k]` is entry `k` of
    /// arm x's vector. Flat storage keeps the per-observation update a
    /// single contiguous sweep (§Perf L3 iteration 2).
    w: Vec<f64>,
    /// Current posterior mean per arm.
    mu: Vec<f64>,
    /// Current posterior variance per arm (clamped at 0).
    var: Vec<f64>,
    observed: Vec<bool>,
    /// Per-arm posterior-maintenance flag (tenant churn). A *disabled*
    /// arm's `(w, μ, σ²)` are frozen and the observation sweep skips it;
    /// [`Gp::enable_arm`] catches a re-enabled arm up bit-exactly on the
    /// observations that arrived while it was out (see `w_len`).
    enabled: Vec<bool>,
    /// Dense ascending list of enabled arms — the observation sweep's
    /// domain. Preallocated at full capacity so churn never reallocates.
    enabled_arms: Vec<ArmId>,
    /// Observation rows already folded into each arm's `(w, μ, σ²)`.
    /// Enabled arms are always fully caught up, so this is only recorded
    /// when an arm is disabled and consumed when it is re-enabled.
    w_len: Vec<usize>,
    /// Arms whose (μ, σ²) moved beyond `change_tol` in the most recent
    /// successful observation — the dirty set incremental scorers
    /// invalidate. Reused across calls to avoid per-observation allocs.
    changed_arms: Vec<ArmId>,
    /// Change-reporting tolerance. 0.0 (the default) reports every arm
    /// whose posterior changed *at all*, which is what exact (bit-stable)
    /// downstream caching requires; a positive tolerance trades exactness
    /// for smaller dirty sets.
    change_tol: f64,
    /// Scratch for the new observation's cross-covariance vector, reused
    /// across observations (zero-allocation observe contract).
    cross_buf: Vec<f64>,
}

impl Gp {
    /// Fresh GP with the given prior.
    pub fn new(prior_mean: Vec<f64>, prior_cov: Mat) -> Self {
        let n = prior_mean.len();
        assert_eq!(prior_cov.rows(), n);
        assert_eq!(prior_cov.cols(), n);
        let var = (0..n).map(|i| prior_cov[(i, i)]).collect();
        Gp {
            mu: prior_mean.clone(),
            var,
            prior_mean,
            prior_cov,
            // Every buffer an observation touches is sized for the worst
            // case (each arm observed once) up front, so the fused
            // observe pass never allocates — see the counting-allocator
            // audit in `rust/tests/alloc_counter.rs`.
            chol: CholeskyFactor::with_capacity(n),
            obs_arms: Vec::with_capacity(n),
            beta: Vec::with_capacity(n),
            w: vec![0.0; n * n],
            observed: vec![false; n],
            enabled: vec![true; n],
            enabled_arms: (0..n).collect(),
            w_len: vec![0; n],
            changed_arms: Vec::with_capacity(n),
            change_tol: 0.0,
            cross_buf: Vec::with_capacity(n),
        }
    }

    /// Set the change-reporting tolerance (see [`Gp::observe`]). The
    /// default of 0.0 reports every arm whose posterior moved at all.
    pub fn set_change_tolerance(&mut self, tol: f64) {
        assert!(tol >= 0.0 && tol.is_finite(), "tolerance must be finite and ≥ 0");
        self.change_tol = tol;
    }

    /// Current change-reporting tolerance.
    pub fn change_tolerance(&self) -> f64 {
        self.change_tol
    }

    /// Number of arms.
    pub fn n_arms(&self) -> usize {
        self.prior_mean.len()
    }

    /// Number of observations incorporated.
    pub fn n_observed(&self) -> usize {
        self.obs_arms.len()
    }

    /// Whether arm `x` has been observed.
    pub fn is_observed(&self, x: ArmId) -> bool {
        self.observed[x]
    }

    /// Posterior mean `μ_t(x)`.
    #[inline]
    pub fn posterior_mean(&self, x: ArmId) -> f64 {
        self.mu[x]
    }

    /// Posterior standard deviation `σ_t(x)`.
    #[inline]
    pub fn posterior_std(&self, x: ArmId) -> f64 {
        self.var[x].max(0.0).sqrt()
    }

    /// Prior mean `μ(x)` (Algorithm 1 line 1 uses this for warm start).
    pub fn prior_mean(&self, x: ArmId) -> f64 {
        self.prior_mean[x]
    }

    /// Whether arm `x`'s posterior is being maintained (see
    /// [`Gp::disable_arm`] / [`Gp::enable_arm`]).
    pub fn is_enabled(&self, x: ArmId) -> bool {
        self.enabled[x]
    }

    /// Number of arms the observation sweep currently maintains.
    pub fn n_enabled(&self) -> usize {
        self.enabled_arms.len()
    }

    /// Stop maintaining arm `x`'s posterior (tenant departure): its
    /// `(w, μ, σ²)` freeze at their current values and the per-observation
    /// sweep skips it, so observe cost tracks the *active* arm count.
    /// Idempotent. The arm's observations (if any) stay in the factor —
    /// the shared posterior keeps the knowledge.
    pub fn disable_arm(&mut self, x: ArmId) {
        if !self.enabled[x] {
            return;
        }
        self.enabled[x] = false;
        // pallas-lint: allow(R5) — `enabled[x]` was true, so x is in `enabled_arms` (the two are updated together); divergence is state corruption worth aborting on.
        let pos = self.enabled_arms.binary_search(&x).expect("enabled list out of sync");
        self.enabled_arms.remove(pos);
        self.w_len[x] = self.chol.dim();
    }

    /// Resume maintaining arm `x`'s posterior (tenant join/rejoin),
    /// catching its `(w, μ, σ²)` up on every observation that arrived
    /// while it was disabled. Idempotent.
    ///
    /// **Bit-exactness contract.** The catch-up replays, row by row,
    /// exactly the float operations the live observation sweep would have
    /// performed (same covariance element, same `mul_add` forward
    /// substitution against the same stored factor row and pivot, same
    /// `μ += wβ` / `σ² −= w²` fold order), so an arm enabled late is
    /// bit-identical to one that was enabled all along — the property the
    /// churn parity gates in `rust/tests/churn.rs` and
    /// `benches/fig6_churn.rs` pin against a from-scratch rebuild oracle.
    /// Cost: `O(t²)` per arm (one forward solve), versus `O(t³ + |𝓛|t²)`
    /// for a from-scratch rebuild of the whole posterior.
    pub fn enable_arm(&mut self, x: ArmId) {
        if self.enabled[x] {
            return;
        }
        self.enabled[x] = true;
        let pos = self.enabled_arms.binary_search(&x).expect_err("enabled list out of sync");
        self.enabled_arms.insert(pos, x);
        let t = self.chol.dim();
        let n = self.prior_mean.len();
        for k in self.w_len[x]..t {
            // Row k of the factor and the pivot stored when observation k
            // was appended — the identical floats the live sweep used.
            let lrow = &self.chol.row(k)[..k];
            let ltt = self.chol.get(k, k);
            // Same storage element the live sweep read: row(obs_k)[x].
            let mut num = self.prior_cov.row(self.obs_arms[k])[x];
            let wa = &self.w[x * n..x * n + k];
            for (l, w) in lrow.iter().zip(wa) {
                num = l.mul_add(-w, num);
            }
            let w_new = num / ltt;
            self.w[x * n + k] = w_new;
            let d_mu = w_new * self.beta[k];
            let d_var = w_new * w_new;
            self.mu[x] += d_mu;
            self.var[x] -= d_var;
        }
        self.w_len[x] = t;
    }

    /// Incorporate the observation `z(x)`. `O(|𝓛|·t)`.
    ///
    /// Returns the arms whose posterior `(μ, σ²)` moved by more than the
    /// change tolerance (default 0.0 = moved at all), the dirty set an
    /// incremental scorer must invalidate. The borrow is valid until the
    /// next mutation of the GP.
    ///
    /// Repeated observation of the same arm is a scheduler bug (the paper
    /// observes each model once, noise-free) — logged to stderr and
    /// skipped, identically in debug and release builds; the returned
    /// dirty set is empty. Use [`Gp::try_observe`] to handle the error
    /// explicitly.
    pub fn observe(&mut self, x: ArmId, z: f64) -> &[ArmId] {
        match self.observe_inner(x, z) {
            Ok(()) => &self.changed_arms,
            Err(e) => {
                eprintln!("mmgpei::gp: ignoring observation: {e}");
                &[]
            }
        }
    }

    /// Fallible form of [`Gp::observe`]: returns `Err` instead of
    /// logging when the arm was already observed. On success the dirty
    /// set is readable through the returned slice.
    pub fn try_observe(&mut self, x: ArmId, z: f64) -> Result<&[ArmId], GpError> {
        self.observe_inner(x, z)?;
        Ok(&self.changed_arms)
    }

    /// Shared implementation of the observation update; populates
    /// `self.changed_arms` on success.
    ///
    /// **Fused, allocation-free pass** (§Perf L3 iteration 3): the
    /// L-append (forward substitution in place in the factor's storage),
    /// the β extension, the per-arm `w` sweep, the μ/σ² fold, and the
    /// dirty-set detection run as one pipeline over preallocated buffers
    /// — no heap allocation per observation (audited by
    /// `rust/tests/alloc_counter.rs`). Inner products use `f64::mul_add`.
    fn observe_inner(&mut self, x: ArmId, z: f64) -> Result<(), GpError> {
        if self.observed[x] {
            return Err(GpError::AlreadyObserved(x));
        }
        assert!(
            self.enabled[x],
            "observation of disabled arm {x}: the driver must not dispatch a departed tenant's arms"
        );
        let t = self.chol.dim();
        let n = self.prior_mean.len();
        // Cross-covariances of the new observation against prior ones,
        // read sequentially from row x of the symmetric prior into the
        // reusable scratch (k(a, x) = k(x, a)).
        let covx = self.prior_cov.row(x);
        self.cross_buf.clear();
        // pallas-lint: allow(R6) — extend into the just-cleared reusable scratch: capacity is pre-reserved at construction and only grows to n once, so the steady-state decision path is allocation-free (enforced dynamically by tests/alloc_counter.rs).
        self.cross_buf.extend(self.obs_arms.iter().map(|&a| covx[a]));
        let diag = covx[x];
        // Min-pivot append: guards the `acc / ltt` division below against
        // a vanishing pivot (duplicated/near-duplicated arms) by floor-
        // jittering instead of emitting NaN posteriors. The substitution
        // writes the new L-row in place (no scratch vector).
        let (ltt, _jitter) = self
            .chol
            .append_jittered_min_pivot(&self.cross_buf, diag, DEFAULT_JITTER, MIN_PIVOT)
            // pallas-lint: allow(R5) — `Problem::validate` guarantees a PSD prior and min-pivot jittering absorbs rank deficiency; failure here means the prior itself is broken. `try_observe` is the fallible twin for untrusted priors.
            .expect("kernel append failed: prior covariance irrecoverably non-PSD");
        // New last entry of β: solve row t of L·β = (z − μ_obs). The
        // L-row is borrowed straight out of the factor (disjoint fields —
        // no copy needed to satisfy the borrow checker).
        let lrow = &self.chol.row(t)[..t];
        let mut acc = z - self.prior_mean[x];
        for (l, b) in lrow.iter().zip(&self.beta) {
            acc = l.mul_add(-b, acc);
        }
        let beta_t = acc / ltt;
        // pallas-lint: allow(R6) — β and the observed-arm list are with_capacity(n) at construction and an arm is observed at most n times, so these pushes never reallocate in steady state (alloc_counter gate).
        self.beta.push(beta_t);
        self.observed[x] = true;
        // pallas-lint: allow(R6) — see the β push above: capacity n reserved up front, never exceeded.
        self.obs_arms.push(x);
        // Extend every *enabled* arm's w by one entry and fold into μ/σ²,
        // recording which arms actually moved (the dirty set) — the hot
        // loop of the native backend: per arm, one contiguous dot of
        // length t (flat `w` stride) against the in-place L-row. Disabled
        // arms (departed tenants) are skipped and caught up bit-exactly
        // by [`Gp::enable_arm`] if their tenant rejoins.
        let tol = self.change_tol;
        self.changed_arms.clear();
        for &a in &self.enabled_arms {
            let wa = &self.w[a * n..a * n + t];
            let mut num = covx[a];
            for (l, w) in lrow.iter().zip(wa) {
                num = l.mul_add(-w, num);
            }
            let w_new = num / ltt;
            self.w[a * n + t] = w_new;
            let d_mu = w_new * beta_t;
            let d_var = w_new * w_new;
            self.mu[a] += d_mu;
            self.var[a] -= d_var;
            if a != x && (d_mu.abs() > tol || d_var > tol) {
                // pallas-lint: allow(R6) — dirty-set push into a with_capacity(n) vec cleared at the top of observe; at most n arms per call, so no reallocation on the hot path (alloc_counter gate).
                self.changed_arms.push(a);
            }
        }
        // The observed arm's posterior is exact: pin it (kills the jitter
        // residue so incumbents computed from μ match observed z). Always
        // dirty — its σ collapsed to 0.
        self.mu[x] = z;
        self.var[x] = 0.0;
        // pallas-lint: allow(R6) — same with_capacity(n) dirty set as above; x was excluded from the loop, so the bound still holds.
        self.changed_arms.push(x);
        Ok(())
    }

    /// Expected improvement of arm `x` over incumbent value `best`
    /// (paper Eq. 3 via Lemma 1).
    #[inline]
    pub fn ei(&self, x: ArmId, best: f64) -> f64 {
        expected_improvement(self.mu[x], self.posterior_std(x), best)
    }

    /// Textbook-formula posterior for *all* arms — `O(t³ + |𝓛|t²)`,
    /// used as the correctness oracle for the incremental path and as the
    /// reference the AOT XLA artifact is verified against.
    pub fn recompute_posterior_slow(&self) -> (Vec<f64>, Vec<f64>) {
        let t = self.obs_arms.len();
        let n = self.n_arms();
        if t == 0 {
            let sd = (0..n).map(|i| self.prior_cov[(i, i)].max(0.0).sqrt()).collect();
            return (self.prior_mean.clone(), sd);
        }
        let kt = Mat::from_fn(t, t, |i, j| {
            self.prior_cov[(self.obs_arms[i], self.obs_arms[j])]
        });
        // pallas-lint: allow(R5) — slow-path oracle used by tests/diagnostics; K_t is a principal submatrix of the validated PSD prior, so jittered factorization cannot fail.
        let (l, _) = cholesky_jittered(&kt, DEFAULT_JITTER).expect("singular K_t");
        let resid: Vec<f64> = self
            .obs_arms
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                // z is recoverable from pinned posterior mean of observed arms.
                let _ = i;
                self.mu[a] - self.prior_mean[a]
            })
            .collect();
        let alpha = cholesky_solve(&l, &resid);
        let mut mu = vec![0.0; n];
        let mut sd = vec![0.0; n];
        for x in 0..n {
            let v: Vec<f64> = self.obs_arms.iter().map(|&a| self.prior_cov[(x, a)]).collect();
            let mut m = self.prior_mean[x];
            for k in 0..t {
                m += v[k] * alpha[k];
            }
            let w = crate::linalg::solve_lower(&l, &v);
            let var = self.prior_cov[(x, x)] - w.iter().map(|u| u * u).sum::<f64>();
            mu[x] = m;
            sd[x] = var.max(0.0).sqrt();
        }
        (mu, sd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, Matern52};
    use crate::prng::Rng;

    fn gp_on_grid(n: usize) -> (Gp, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 * 0.4]).collect();
        let kern = Matern52 { variance: 1.0, lengthscale: 1.0 };
        let cov = kern.gram(&pts);
        let l = crate::linalg::cholesky_jittered(&cov, 1e-10).unwrap().0;
        let mut rng = Rng::new(9001);
        let z = rng.mvn(&vec![0.0; n], &l);
        (Gp::new(vec![0.0; n], cov), z)
    }

    /// Miri interprets ~100× slower than native: shrink the grids of the
    /// tests that recompute O(n³) posteriors per observation so the
    /// nightly Miri job stays inside its budget.
    fn dim(native: usize) -> usize {
        if cfg!(miri) { native.min(6) } else { native }
    }

    #[test]
    fn prior_posterior_before_observations() {
        let (gp, _) = gp_on_grid(5);
        for x in 0..5 {
            assert_eq!(gp.posterior_mean(x), 0.0);
            assert!((gp.posterior_std(x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn observed_arm_is_pinned() {
        let (mut gp, z) = gp_on_grid(6);
        gp.observe(2, z[2]);
        assert!((gp.posterior_mean(2) - z[2]).abs() < 1e-12);
        assert_eq!(gp.posterior_std(2), 0.0);
        assert!(gp.is_observed(2));
        assert!(!gp.is_observed(3));
    }

    #[test]
    fn incremental_matches_slow_oracle() {
        let n = dim(12);
        let (mut gp, z) = gp_on_grid(n);
        let order = [3usize, 7, 0, 11, 5, 9];
        for &x in order.iter().filter(|&&x| x < n) {
            gp.observe(x, z[x]);
            let (mu_slow, sd_slow) = gp.recompute_posterior_slow();
            for a in 0..gp.n_arms() {
                assert!(
                    (gp.posterior_mean(a) - mu_slow[a]).abs() < 1e-7,
                    "mean mismatch at arm {a} after observing {x}"
                );
                assert!(
                    (gp.posterior_std(a) - sd_slow[a]).abs() < 1e-6,
                    "std mismatch at arm {a} after observing {x}: {} vs {}",
                    gp.posterior_std(a),
                    sd_slow[a]
                );
            }
        }
    }

    #[test]
    fn posterior_interpolates_neighbors() {
        // Observing a high value at arm k should raise the posterior mean
        // of its close neighbor above the prior.
        let (mut gp, _) = gp_on_grid(10);
        gp.observe(4, 2.0);
        assert!(gp.posterior_mean(5) > 0.5, "neighbor should be pulled up");
        assert!(gp.posterior_mean(9) < gp.posterior_mean(5), "far arm less affected");
        // Uncertainty shrinks near the observation.
        assert!(gp.posterior_std(5) < 1.0);
        assert!(gp.posterior_std(9) > gp.posterior_std(5));
    }

    #[test]
    fn variance_never_increases() {
        let n = dim(15);
        let (mut gp, z) = gp_on_grid(n);
        let mut prev: Vec<f64> = (0..n).map(|a| gp.posterior_std(a)).collect();
        for &x in [0usize, 14, 7, 3, 10].iter().filter(|&&x| x < n) {
            gp.observe(x, z[x]);
            for a in 0..n {
                let s = gp.posterior_std(a);
                assert!(s <= prev[a] + 1e-8, "σ must shrink (arm {a})");
                prev[a] = s;
            }
        }
    }

    #[test]
    fn ei_zero_for_observed_arm() {
        let (mut gp, z) = gp_on_grid(8);
        gp.observe(3, z[3]);
        // EI of an observed arm over an incumbent ≥ its value is 0.
        assert_eq!(gp.ei(3, z[3] + 0.1), 0.0);
    }

    #[test]
    fn ei_positive_for_uncertain_arm() {
        let (gp, _) = gp_on_grid(8);
        assert!(gp.ei(0, 0.5) > 0.0, "uncertain arm always has positive EI");
    }

    #[test]
    fn observe_reports_exactly_the_arms_that_moved() {
        // Block-diagonal prior: two independent 3-arm blocks. Observing
        // an arm in block 0 must dirty only block-0 arms.
        let mut cov = Mat::eye(6);
        for i in 0..3 {
            for j in 0..3 {
                cov[(i, j)] = if i == j { 1.0 } else { 0.6 };
                cov[(3 + i, 3 + j)] = if i == j { 1.0 } else { 0.6 };
            }
        }
        let mut gp = Gp::new(vec![0.0; 6], cov);
        let before: Vec<(f64, f64)> =
            (0..6).map(|a| (gp.posterior_mean(a), gp.posterior_std(a))).collect();
        let changed: Vec<usize> = gp.observe(1, 0.8).to_vec();
        let mut sorted = changed.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2], "only block-0 arms move: {changed:?}");
        // The report is exact: unreported arms are bit-identical.
        for a in 3..6 {
            assert_eq!(gp.posterior_mean(a), before[a].0, "arm {a} mean must not move");
            assert_eq!(gp.posterior_std(a), before[a].1, "arm {a} std must not move");
        }
        for &a in &changed {
            assert!(
                gp.posterior_mean(a) != before[a].0 || gp.posterior_std(a) != before[a].1,
                "reported arm {a} must actually have moved"
            );
        }
    }

    #[test]
    fn double_observe_is_skipped_consistently() {
        let (mut gp, z) = gp_on_grid(5);
        gp.observe(2, z[2]);
        let snapshot: Vec<f64> = (0..5).map(|a| gp.posterior_mean(a)).collect();
        let n_obs = gp.n_observed();
        // Second observation of the same arm: skipped (empty dirty set),
        // state untouched — identically in debug and release builds.
        let changed = gp.observe(2, 123.0).to_vec();
        assert!(changed.is_empty());
        assert_eq!(gp.n_observed(), n_obs);
        for a in 0..5 {
            assert_eq!(gp.posterior_mean(a), snapshot[a]);
        }
        // The fallible form surfaces the error explicitly.
        assert_eq!(gp.try_observe(2, 123.0).unwrap_err(), GpError::AlreadyObserved(2));
        // A fresh arm still works afterwards.
        assert!(gp.try_observe(3, z[3]).is_ok());
    }

    #[test]
    fn degenerate_pivot_never_emits_nan_posteriors() {
        // Three perfectly correlated arms: every append after the first
        // has a zero Schur complement. The min-pivot guard must keep all
        // posteriors finite (the old `> 0` check let pivots like 1e-300
        // through, overflowing β into ±∞).
        let cov = Mat::from_fn(3, 3, |_, _| 1.0);
        let mut gp = Gp::new(vec![0.0; 3], cov);
        gp.observe(0, 0.4);
        gp.observe(1, 0.4);
        gp.observe(2, 0.4);
        for a in 0..3 {
            assert!(gp.posterior_mean(a).is_finite(), "mean[{a}] finite");
            assert!(gp.posterior_std(a).is_finite(), "std[{a}] finite");
            assert!((gp.posterior_mean(a) - 0.4).abs() < 1e-6);
        }
    }

    #[test]
    fn change_tolerance_shrinks_the_dirty_set() {
        let (mut gp, z) = gp_on_grid(10);
        gp.set_change_tolerance(f64::MAX);
        assert_eq!(gp.change_tolerance(), f64::MAX);
        // With an effectively infinite tolerance only the observed arm
        // (always dirty — its σ collapses) is reported.
        let changed = gp.observe(4, z[4]).to_vec();
        assert_eq!(changed, vec![4]);
    }

    #[test]
    fn handles_duplicate_correlated_arms_via_jitter() {
        // Two perfectly correlated arms: observing both must not crash.
        let cov = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let mut gp = Gp::new(vec![0.0, 0.0], cov);
        gp.observe(0, 0.7);
        // After observing arm 0, arm 1's posterior collapses onto it.
        assert!((gp.posterior_mean(1) - 0.7).abs() < 1e-6);
        assert!(gp.posterior_std(1) < 1e-4);
        gp.observe(1, 0.7);
        assert!((gp.posterior_mean(1) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn late_enabled_arm_matches_always_enabled_bitwise() {
        // Tenant-churn contract: an arm disabled before any observation
        // and enabled after several must carry *bit-identical* (w-driven)
        // μ/σ to a GP that maintained it the whole time.
        let (mut full, z) = gp_on_grid(10);
        let (mut churned, _) = gp_on_grid(10);
        for x in [7usize, 8, 9] {
            churned.disable_arm(x);
        }
        assert_eq!(churned.n_enabled(), 7);
        assert!(!churned.is_enabled(8));
        let order = [2usize, 5, 0, 3];
        for &x in &order {
            full.observe(x, z[x]);
            churned.observe(x, z[x]);
        }
        for x in [7usize, 8, 9] {
            churned.enable_arm(x);
        }
        assert_eq!(churned.n_enabled(), 10);
        for a in 0..10 {
            assert_eq!(
                churned.posterior_mean(a).to_bits(),
                full.posterior_mean(a).to_bits(),
                "mean bits diverge at arm {a}"
            );
            assert_eq!(
                churned.posterior_std(a).to_bits(),
                full.posterior_std(a).to_bits(),
                "std bits diverge at arm {a}"
            );
        }
    }

    #[test]
    fn disable_enable_round_trip_catches_up_mid_run() {
        // Leave-then-rejoin: freeze an arm mid-run (after it moved), keep
        // observing, re-enable — still bit-identical to always-enabled,
        // including for an arm that was itself observed before leaving.
        let (mut full, z) = gp_on_grid(9);
        let (mut churned, _) = gp_on_grid(9);
        full.observe(1, z[1]);
        churned.observe(1, z[1]);
        churned.disable_arm(1); // observed arm departs
        churned.disable_arm(4); // unobserved arm departs
        for &x in &[6usize, 2, 8] {
            full.observe(x, z[x]);
            churned.observe(x, z[x]);
        }
        churned.enable_arm(1);
        churned.enable_arm(4);
        churned.enable_arm(4); // idempotent
        for a in 0..9 {
            assert_eq!(churned.posterior_mean(a).to_bits(), full.posterior_mean(a).to_bits());
            assert_eq!(churned.posterior_std(a).to_bits(), full.posterior_std(a).to_bits());
        }
        // And the caught-up GP keeps evolving identically.
        full.observe(4, z[4]);
        churned.observe(4, z[4]);
        for a in 0..9 {
            assert_eq!(churned.posterior_mean(a).to_bits(), full.posterior_mean(a).to_bits());
        }
    }

    #[test]
    fn disabled_arm_posterior_is_frozen() {
        let (mut gp, z) = gp_on_grid(6);
        gp.disable_arm(3);
        let before = (gp.posterior_mean(3), gp.posterior_std(3));
        gp.observe(2, z[2]);
        assert_eq!((gp.posterior_mean(3), gp.posterior_std(3)), before);
        // The dirty set never reports a disabled arm.
        let changed = gp.observe(4, z[4]).to_vec();
        assert!(!changed.contains(&3));
    }

    #[test]
    #[should_panic(expected = "disabled arm")]
    fn observing_a_disabled_arm_is_a_driver_bug() {
        let (mut gp, z) = gp_on_grid(4);
        gp.disable_arm(2);
        gp.observe(2, z[2]);
    }

    #[test]
    fn prop_incremental_posterior_matches_slow_oracle_on_random_priors() {
        // Case count comes from MMGPEI_PROP_CASES (the nightly Miri job
        // sets it to 4); each case draws a fresh correlation prior and a
        // fresh observation order.
        crate::testutil::check("incremental posterior matches slow oracle", |rng| {
            let n = dim(6);
            let cov = crate::testutil::gen::covariance(rng, n);
            let l = crate::linalg::cholesky_jittered(&cov, 1e-8).unwrap().0;
            let z = rng.mvn(&vec![0.0; n], &l);
            let mut gp = Gp::new(vec![0.0; n], cov);
            for &x in &rng.choose_indices(n, n / 2) {
                gp.observe(x, z[x]);
                let (mu_slow, sd_slow) = gp.recompute_posterior_slow();
                for a in 0..n {
                    assert!((gp.posterior_mean(a) - mu_slow[a]).abs() < 1e-6, "mean, arm {a}");
                    assert!((gp.posterior_std(a) - sd_slow[a]).abs() < 1e-5, "std, arm {a}");
                }
            }
        });
    }

    #[test]
    fn mvn_draw_consistency_full_observation() {
        // Observing every arm pins every posterior to the draw.
        let (mut gp, z) = gp_on_grid(7);
        for x in 0..7 {
            gp.observe(x, z[x]);
        }
        for x in 0..7 {
            assert!((gp.posterior_mean(x) - z[x]).abs() < 1e-9);
            assert!(gp.posterior_std(x) < 1e-9);
        }
    }
}
