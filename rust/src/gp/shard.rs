//! Sharded block-Kronecker GP posterior for 10⁴–10⁶ tenants.
//!
//! The dense [`Gp`](crate::gp::Gp) keeps ONE incremental Cholesky factor
//! over *all* tenants' arms: `O(t²)` per observe and `O(n²)` prior
//! storage, the wall between this repo and the million-tenant north star.
//! The multi-tenant workloads, however, draw their prior from an exactly
//! exploitable structure (`workload/churn.rs`,
//! [`crate::kernels::kronecker_arm_cov`]):
//!
//! ```text
//! K = B(ρ) ⊗ C,   B(ρ) = (1 − ρ)·I + ρ·𝟙𝟙ᵀ,   C = model gram (m × m)
//! ```
//!
//! [`ShardedGp`] factors the observed gram `K_t = A + ρ·F Fᵀ` instead,
//! where `A = blockdiag_u{(1 − ρ)·C[S_u, S_u]}` collects each tenant `u`'s
//! observed models `S_u` and row `k` of `F` is `ℓ_{s_k}` — row `s_k` of
//! `L_C = chol(C)` (so `F Fᵀ` reproduces the cross-tenant coupling
//! `C[s, s']` exactly). Each tenant gets an independent **shard**: a mini
//! Cholesky factor of `(1 − ρ)·C[S_u, S_u]` updated in `O(t_u²)` per
//! observation — *never* `O(t²)` in the global observation count — plus
//! `O(m)`-sized Woodbury feature vectors. The cross-tenant correction
//! goes through the m × m capacitance `M = I + ρ·T`, `T = Σ_u W̃_uᵀ W̃_u`,
//! `W̃_u = L_u⁻¹ F_u` (Woodbury identity), refreshed in `O(m³)` per
//! observation and applied lazily at posterior read:
//!
//! ```text
//! μ(a)  = local_mu(a) + ρ·(ℓ_i − h_a)ᵀ u,        u = M⁻¹ b̂,  b̂ = Σ_u W̃_uᵀ β_u
//! σ²(a) = local_var(a) + ρ·[C_ii − 2·h_aᵀℓ_i − ρ·ℓ_iᵀTℓ_i + p_aᵀ M⁻¹ p_a]
//! p_a   = h_a + ρ·Tℓ_i,   h_a = W̃_uᵀ (L_u⁻¹ k_local(a))
//! ```
//!
//! Every per-read quantity that needs global state (`M⁻¹`, `T·ℓ_i`,
//! `ℓ_iᵀu`, `ℓ_iᵀTℓ_i`, the cold-tenant tables) is recomputed *at observe
//! time* into preallocated buffers, so posterior reads are pure `&self`
//! with no scratch: `O(m)` for a mean, `O(m²)` for a variance, `O(1)` for
//! a tenant with no observations — which is what keeps an all-dirty
//! rescore pass `O(n)` at scale.
//!
//! **Determinism & parity.** All update loops run in a fixed order
//! (tenant-local observation order, then a fixed-order global fold), and
//! the tenant-local arithmetic mirrors `Gp::observe`'s float operations
//! verbatim (`mul_add` folds, same append/jitter ladder, same pin-on-read
//! contract). At `ρ = 0` the prior is block-diagonal and the dense
//! factor's cross-tenant entries are exact zeros, so the sharded posterior
//! is **bit-identical** to the dense one (`rust/tests/sharded_gp.rs`); at
//! `ρ > 0` the two are exact-math equal and agree to tight relative
//! tolerance. Bulk entry points ([`ShardedGp::observe_batch`],
//! [`ShardedGp::posterior_snapshot`]) distribute tenant shards across the
//! deterministic [`WorkerPool`] under its fixed-shard/fixed-merge
//! contract, so results are byte-identical at any thread width.

use super::{expected_improvement, GpError, DEFAULT_JITTER, MIN_PIVOT};
use crate::linalg::{cholesky_jittered, cholesky_lower_in_place, dot, CholeskyFactor, Mat};
use crate::pool::WorkerPool;
use crate::problem::ArmId;

/// The Kronecker prior `K = B(ρ) ⊗ C` a [`ShardedGp`] factors: an
/// exchangeable cross-tenant similarity `B(ρ) = (1 − ρ)I + ρ𝟙𝟙ᵀ` over a
/// shared per-model gram `C` (see
/// [`crate::kernels::exchangeable_user_sim`] /
/// [`crate::kernels::kronecker_arm_cov`], which build the same structure
/// densely). Arms are user-major: arm `(u, i) = u·m + i`.
#[derive(Clone, Debug)]
pub struct KroneckerPrior {
    n_users: usize,
    /// `C` — the shared m × m model covariance.
    model_cov: Mat,
    /// `L_C` with `C = L_C L_Cᵀ`; its rows are the Woodbury feature
    /// vectors `ℓ_i` (`C[i, j] = ℓ_iᵀ ℓ_j`).
    chol_c: Mat,
    rho: f64,
    /// Per-arm prior mean, user-major (`n_users · m` entries).
    prior_mean: Vec<f64>,
}

impl KroneckerPrior {
    /// Build and validate a Kronecker prior. `rho ∈ [0, 1)` (the
    /// exchangeable similarity is PD on that range — matching
    /// [`crate::kernels::exchangeable_user_sim`]); `prior_mean` is
    /// user-major with one entry per arm.
    pub fn new(n_users: usize, model_cov: Mat, rho: f64, prior_mean: Vec<f64>) -> Result<Self, String> {
        if n_users == 0 {
            return Err("KroneckerPrior: n_users must be positive".into());
        }
        let m = model_cov.rows();
        if m == 0 || model_cov.cols() != m {
            return Err(format!(
                "KroneckerPrior: model covariance must be square and non-empty, got {}x{}",
                model_cov.rows(),
                model_cov.cols()
            ));
        }
        if !(0.0..1.0).contains(&rho) {
            return Err(format!("KroneckerPrior: rho must be in [0, 1), got {rho}"));
        }
        if prior_mean.len() != n_users * m {
            return Err(format!(
                "KroneckerPrior: prior_mean has {} entries, expected n_users*m = {}",
                prior_mean.len(),
                n_users * m
            ));
        }
        let (chol_c, _jitter) = cholesky_jittered(&model_cov, DEFAULT_JITTER)
            .map_err(|e| format!("KroneckerPrior: model covariance is not PSD: {e}"))?;
        Ok(KroneckerPrior { n_users, model_cov, chol_c, rho, prior_mean })
    }

    /// [`KroneckerPrior::new`] with a constant prior mean on every arm.
    pub fn constant_mean(n_users: usize, model_cov: Mat, rho: f64, mean: f64) -> Result<Self, String> {
        let n = n_users * model_cov.rows();
        Self::new(n_users, model_cov, rho, vec![mean; n])
    }

    /// Number of tenants.
    pub fn n_users(&self) -> usize {
        self.n_users
    }

    /// Number of models per tenant (`m`).
    pub fn n_models(&self) -> usize {
        self.model_cov.rows()
    }

    /// Total number of arms (`n_users · m`).
    pub fn n_arms(&self) -> usize {
        self.n_users * self.model_cov.rows()
    }

    /// Cross-tenant correlation `ρ`.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// The shared model covariance `C`.
    pub fn model_cov(&self) -> &Mat {
        &self.model_cov
    }

    /// Per-arm prior mean (user-major).
    pub fn prior_mean(&self) -> &[f64] {
        &self.prior_mean
    }

    /// Materialize the dense `(prior_mean, B(ρ) ⊗ C)` pair — the input a
    /// dense [`Gp`](crate::gp::Gp) oracle takes. Entry-for-entry
    /// bit-identical to [`crate::kernels::kronecker_arm_cov`] over
    /// [`crate::kernels::exchangeable_user_sim`] (same `B_uv · C_ij`
    /// products), so dense-vs-sharded parity gates can use either
    /// construction. Dense-feasible sizes only: `O(n²)` memory.
    pub fn dense_prior(&self) -> (Vec<f64>, Mat) {
        let m = self.model_cov.rows();
        let n = self.n_users * m;
        let cov = Mat::from_fn(n, n, |a, b| {
            let b_uv = if a / m == b / m { 1.0 } else { self.rho };
            b_uv * self.model_cov[(a % m, b % m)]
        });
        (self.prior_mean.clone(), cov)
    }
}

/// One tenant's independent posterior state: a mini Cholesky factor over
/// the tenant's observed models (gram `(1 − ρ)·C[S_u, S_u]`) plus the
/// Woodbury feature matrices. All float state lives in ONE flat buffer so
/// the lazy per-tenant setup is a single allocation.
#[derive(Clone, Debug)]
struct Shard {
    m: usize,
    /// `L_u = chol((1 − ρ)·C[S_u, S_u])`, appended per observation.
    chol: CholeskyFactor,
    /// Model index of each tenant-local observation, in order.
    obs_models: Vec<usize>,
    /// Flat storage, layout `[w | wt | h | beta | local_mu | local_var]`:
    /// `w[i·m + k] = (L_u⁻¹ k_local(i))_k` per model i, `wt[k·m + j]` =
    /// row k of `W̃_u = L_u⁻¹ F_u`, `h[i·m + j] = (W̃_uᵀ w_i)_j`, `beta =
    /// L_u⁻¹ (z − μ₀)`, and the tenant-local posterior accumulators.
    data: Vec<f64>,
}

impl Shard {
    /// Lazy one-time per-tenant setup (first observation of the tenant).
    fn boxed(m: usize) -> Box<Shard> {
        // pallas-lint: allow(R6) — lazy one-time shard setup: a tenant's first observation allocates its O(m²) state once and never again; the steady-state observe path is allocation-free (tests/alloc_counter.rs warms every tenant before measuring).
        let data = vec![0.0; 3 * m * m + 3 * m];
        // pallas-lint: allow(R6) — same lazy one-time shard setup as `data` above.
        let obs_models = vec![0usize; m];
        let chol = CholeskyFactor::with_capacity(m);
        // pallas-lint: allow(R6) — same lazy one-time shard setup as `data` above (one box per tenant, amortized over its lifetime).
        Box::new(Shard { m, chol, obs_models, data })
    }

    #[inline]
    fn w_row(&self, i: usize) -> &[f64] {
        &self.data[i * self.m..i * self.m + self.m]
    }

    #[inline]
    fn wt_row(&self, k: usize) -> &[f64] {
        let m = self.m;
        &self.data[m * m + k * m..m * m + k * m + m]
    }

    #[inline]
    fn h_row(&self, i: usize) -> &[f64] {
        let m = self.m;
        &self.data[2 * m * m + i * m..2 * m * m + i * m + m]
    }

    #[inline]
    fn local_mu(&self, i: usize) -> f64 {
        self.data[3 * self.m * self.m + self.m + i]
    }

    #[inline]
    fn local_var(&self, i: usize) -> f64 {
        self.data[3 * self.m * self.m + 2 * self.m + i]
    }

    /// One tenant-local observation of model `s` with value `z`
    /// (`prior_mean_x` = the observed arm's prior mean). Mirrors the
    /// float-operation sequence of the dense `Gp::observe` restricted to
    /// this tenant's block — same `append_jittered_min_pivot` ladder,
    /// same `mul_add` β/w folds, same `μ += w·β` / `σ² −= w²` updates over
    /// *all* m models (eager even for disabled arms: bit-identical to the
    /// dense enable-time catch-up) — then extends the Woodbury features
    /// (`wt` row, `h` rows) when `ρ > 0`. Returns `(t, β_t)` where `t` is
    /// the tenant-local observation index. Allocation-free.
    fn ingest(
        &mut self,
        prior: &KroneckerPrior,
        s: usize,
        z: f64,
        prior_mean_x: f64,
        cross_buf: &mut [f64],
    ) -> (usize, f64) {
        let m = self.m;
        let rho = prior.rho;
        let scale = 1.0 - rho;
        let t = self.chol.dim();
        let crow = prior.model_cov.row(s);
        // Cross-covariances against the tenant's prior observations, in
        // tenant-local observation order (the shard's gram is
        // (1 − ρ)·C[S_u, S_u]).
        for (dst, &sk) in cross_buf[..t].iter_mut().zip(&self.obs_models[..t]) {
            *dst = scale * crow[sk];
        }
        let diag = scale * crow[s];
        // Same min-pivot append (and therefore the same jitter ladder and
        // NaN guard) as the dense GP — see `Gp::observe_inner`.
        let (ltt, _jitter) = self
            .chol
            .append_jittered_min_pivot(&cross_buf[..t], diag, DEFAULT_JITTER, MIN_PIVOT)
            // pallas-lint: allow(R5) — mirrors the dense Gp::observe contract: KroneckerPrior::new verified C is PSD and min-pivot jittering absorbs rank deficiency, so failure means the prior itself is broken.
            .expect("kernel append failed: model covariance irrecoverably non-PSD");
        let lrow = &self.chol.row(t)[..t];
        let (w_zone, rest) = self.data.split_at_mut(m * m);
        let (wt_zone, rest) = rest.split_at_mut(m * m);
        let (h_zone, rest) = rest.split_at_mut(m * m);
        let (beta_zone, rest) = rest.split_at_mut(m);
        let (mu_zone, var_zone) = rest.split_at_mut(m);
        // New last entry of β: solve row t of L_u·β = (z − μ₀).
        let mut acc = z - prior_mean_x;
        for (l, b) in lrow.iter().zip(&beta_zone[..t]) {
            acc = l.mul_add(-b, acc);
        }
        let beta_t = acc / ltt;
        beta_zone[t] = beta_t;
        self.obs_models[t] = s;
        if rho > 0.0 {
            // Row t of W̃_u = L_u⁻¹ F_u: forward-substitute ℓ_s against
            // the earlier W̃ rows (fixed order — deterministic).
            let (prev, tail) = wt_zone.split_at_mut(t * m);
            let wt_new = &mut tail[..m];
            wt_new.copy_from_slice(prior.chol_c.row(s));
            for (k, l) in lrow.iter().enumerate() {
                let prow = &prev[k * m..k * m + m];
                for (dst, p) in wt_new.iter_mut().zip(prow) {
                    *dst = l.mul_add(-p, *dst);
                }
            }
            for v in wt_new.iter_mut() {
                *v /= ltt;
            }
        }
        // Extend every model's w by one entry and fold into the local
        // μ/σ² accumulators — the same contiguous sweep as the dense GP's
        // per-arm loop, restricted to this tenant's m models.
        for i in 0..m {
            let wa = &mut w_zone[i * m..i * m + t + 1];
            let mut num = scale * crow[i];
            for (l, w) in lrow.iter().zip(&wa[..t]) {
                num = l.mul_add(-w, num);
            }
            let w_new = num / ltt;
            wa[t] = w_new;
            mu_zone[i] += w_new * beta_t;
            var_zone[i] -= w_new * w_new;
            if rho > 0.0 {
                // h_i ← h_i + w_i[t]·W̃_t (incremental W̃ᵀw).
                let wt_new = &wt_zone[t * m..t * m + m];
                let hrow = &mut h_zone[i * m..i * m + m];
                for (hd, wv) in hrow.iter_mut().zip(wt_new) {
                    *hd = w_new.mul_add(*wv, *hd);
                }
            }
        }
        (t, beta_t)
    }
}

/// Per-tenant work item for [`ShardedGp::observe_batch`]: the tenant's
/// shard (taken out of the table so worker chunks own disjoint state),
/// its observations, and the `(t, β_t)` results the serial global fold
/// consumes afterwards.
struct TenantWork {
    user: usize,
    shard: Box<Shard>,
    /// `(batch position, model, z, prior mean of the arm)` per observation.
    items: Vec<(usize, usize, f64, f64)>,
    /// `(t, β_t)` per item, filled by the worker.
    out: Vec<(usize, f64)>,
}

/// Sharded block-Kronecker GP posterior: the scale-out twin of the dense
/// [`Gp`](crate::gp::Gp) for priors of the form `B(ρ) ⊗ C` (see the
/// `gp/shard.rs` module docs for the factorization). Mirrors the dense
/// observe/posterior/EI/churn surface; selected behind
/// `[gp] structure = "sharded"` (the dense path remains the default and
/// the correctness oracle).
#[derive(Clone, Debug)]
pub struct ShardedGp {
    prior: KroneckerPrior,
    n_models: usize,
    n_arms: usize,
    /// Lazily created per-tenant shards (`None` until the tenant's first
    /// observation — a cold tenant costs 8 bytes and reads in O(1)).
    shards: Vec<Option<Box<Shard>>>,
    observed: Vec<bool>,
    /// Observed value per arm (valid where `observed`); posterior reads
    /// pin observed arms to `(z, 0)` exactly like the dense GP.
    observed_z: Vec<f64>,
    enabled: Vec<bool>,
    /// Dense ascending list of enabled arms (the ρ > 0 dirty superset).
    enabled_arms: Vec<ArmId>,
    /// Frozen `(arm, μ, σ²)` snapshots for *disabled, unobserved* arms,
    /// sorted by arm: a departed tenant's posterior reads stay at their
    /// disable-time values (the dense GP freezes state the same way) while
    /// the shard keeps accumulating underneath — re-enabling just drops
    /// the snapshot, which is the lazy form of the dense bit-exact
    /// catch-up.
    frozen: Vec<(ArmId, f64, f64)>,
    /// ρ = 0 dirty set of the most recent observation (`changed_len`
    /// entries; capacity m — tenant-local moves only).
    changed_arms: Vec<ArmId>,
    changed_len: usize,
    /// Scratch for the tenant-local cross-covariance vector.
    cross_buf: Vec<f64>,
    /// Global observation count.
    t_total: usize,
    /// `T = Σ_u W̃_uᵀW̃_u` (m × m, rank-1 updated per observation in
    /// arrival order — deterministic).
    tmat: Vec<f64>,
    /// `b̂ = Σ_u W̃_uᵀβ_u`.
    bhat: Vec<f64>,
    /// Scratch for the in-place factorization of `M = I + ρT`.
    mfac: Vec<f64>,
    /// `D = M⁻¹`, recomputed per observation (all posterior reads are
    /// then pure `&self` lookups — no solve at read time).
    dmat: Vec<f64>,
    /// `u = M⁻¹ b̂`.
    ucap: Vec<f64>,
    /// `tl[i·m + j] = (T·ℓ_i)_j` per model i.
    tl: Vec<f64>,
    /// `g_mu[i] = ℓ_iᵀ u` — the cold-tenant mean correction.
    g_mu: Vec<f64>,
    /// `g_q[i] = ℓ_iᵀ T ℓ_i`.
    g_q: Vec<f64>,
    /// Cold-tenant posterior variance per model:
    /// `C_ii − ρ²·g_q[i] + ρ³·(Tℓ_i)ᵀD(Tℓ_i)` — an O(1) read.
    cold_var: Vec<f64>,
    /// Forward-solve scratch for the explicit `M⁻¹` columns.
    solve_buf: Vec<f64>,
    /// Change-reporting tolerance (same contract as the dense GP).
    change_tol: f64,
}

impl ShardedGp {
    /// Fresh sharded GP over the given Kronecker prior. Allocates the
    /// O(n) per-arm tables and the O(m²) global coupling state up front;
    /// per-tenant shards (O(m²) each) are created lazily on the tenant's
    /// first observation.
    pub fn new(prior: KroneckerPrior) -> Self {
        let m = prior.n_models();
        let n = prior.n_arms();
        let mut cold_var = vec![0.0; m];
        for (i, cv) in cold_var.iter_mut().enumerate() {
            *cv = prior.model_cov[(i, i)];
        }
        let mut dmat = vec![0.0; m * m];
        for j in 0..m {
            dmat[j * m + j] = 1.0;
        }
        let mut enabled_arms = Vec::with_capacity(n);
        enabled_arms.extend(0..n);
        ShardedGp {
            n_models: m,
            n_arms: n,
            shards: (0..prior.n_users).map(|_| None).collect(),
            observed: vec![false; n],
            observed_z: vec![0.0; n],
            enabled: vec![true; n],
            enabled_arms,
            frozen: Vec::new(),
            changed_arms: vec![0; m],
            changed_len: 0,
            cross_buf: vec![0.0; m],
            t_total: 0,
            tmat: vec![0.0; m * m],
            bhat: vec![0.0; m],
            mfac: vec![0.0; m * m],
            dmat,
            ucap: vec![0.0; m],
            tl: vec![0.0; m * m],
            g_mu: vec![0.0; m],
            g_q: vec![0.0; m],
            cold_var,
            solve_buf: vec![0.0; m],
            change_tol: 0.0,
            prior,
        }
    }

    /// The prior this posterior factors.
    pub fn prior(&self) -> &KroneckerPrior {
        &self.prior
    }

    /// Total number of arms.
    pub fn n_arms(&self) -> usize {
        self.n_arms
    }

    /// Number of tenants.
    pub fn n_users(&self) -> usize {
        self.prior.n_users
    }

    /// Number of models per tenant.
    pub fn n_models(&self) -> usize {
        self.n_models
    }

    /// Number of observations so far.
    pub fn n_observed(&self) -> usize {
        self.t_total
    }

    /// Number of enabled arms.
    pub fn n_enabled(&self) -> usize {
        self.enabled_arms.len()
    }

    /// Whether arm `x` has been observed.
    pub fn is_observed(&self, x: ArmId) -> bool {
        self.observed[x]
    }

    /// Whether arm `x` is enabled (its posterior is live).
    pub fn is_enabled(&self, x: ArmId) -> bool {
        self.enabled[x]
    }

    /// Prior mean of arm `x`.
    pub fn prior_mean(&self, x: ArmId) -> f64 {
        self.prior.prior_mean[x]
    }

    /// Set the change-reporting tolerance (see the dense
    /// [`Gp::set_change_tolerance`](crate::gp::Gp::set_change_tolerance);
    /// 0.0 = exact reporting, required for bit-stable caching).
    pub fn set_change_tolerance(&mut self, tol: f64) {
        self.change_tol = tol;
    }

    /// Current change-reporting tolerance.
    pub fn change_tolerance(&self) -> f64 {
        self.change_tol
    }

    /// Lazily create tenant `u`'s shard (one allocation per tenant,
    /// amortized over its lifetime).
    fn ensure_shard(&mut self, u: usize) {
        if self.shards[u].is_some() {
            return;
        }
        let m = self.n_models;
        let scale = 1.0 - self.prior.rho;
        let mut sh = Shard::boxed(m);
        let base = u * m;
        let off = 3 * m * m;
        for i in 0..m {
            // local_mu starts at the prior mean, local_var at the
            // tenant-local prior variance (1 − ρ)·C_ii — exactly the
            // dense initialization when ρ = 0.
            sh.data[off + m + i] = self.prior.prior_mean[base + i];
            sh.data[off + 2 * m + i] = scale * self.prior.model_cov[(i, i)];
        }
        self.shards[u] = Some(sh);
    }

    /// Refresh every global read table from the current `(T, b̂)`:
    /// factor `M = I + ρT` in place, invert it explicitly (`D = M⁻¹`),
    /// and precompute `u`, `T·ℓ_i`, `ℓ_iᵀu`, `ℓ_iᵀTℓ_i` and the
    /// cold-tenant variances. `O(m³)`, allocation-free, run once per
    /// observation (ρ > 0 only) so posterior reads stay pure `&self`.
    fn refresh_cap_tables(&mut self) {
        let m = self.n_models;
        let rho = self.prior.rho;
        let Self { prior, tmat, bhat, mfac, dmat, ucap, tl, g_mu, g_q, cold_var, solve_buf, .. } = self;
        for j in 0..m {
            for k in 0..m {
                let v = rho * tmat[j * m + k];
                mfac[j * m + k] = if j == k { 1.0 + v } else { v };
            }
        }
        cholesky_lower_in_place(mfac, m)
            // pallas-lint: allow(R5) — M = I + ρT with T = ΣW̃ᵀW̃ positive semidefinite is positive definite by construction (unit diagonal shift); failure means the accumulators were corrupted, which is worth aborting on.
            .expect("capacitance I + rho*T must be positive definite");
        // D = M⁻¹ column by column: forward solve L y = e_c into scratch,
        // back-substitute Lᵀ x = y straight into D's column c.
        for c in 0..m {
            for i in 0..m {
                let mut acc = if i == c { 1.0 } else { 0.0 };
                for k in 0..i {
                    acc = mfac[i * m + k].mul_add(-solve_buf[k], acc);
                }
                solve_buf[i] = acc / mfac[i * m + i];
            }
            for i in (0..m).rev() {
                let mut acc = solve_buf[i];
                for k in i + 1..m {
                    acc = mfac[k * m + i].mul_add(-dmat[k * m + c], acc);
                }
                dmat[i * m + c] = acc / mfac[i * m + i];
            }
        }
        // u = D·b̂.
        for j in 0..m {
            let drow = &dmat[j * m..j * m + m];
            let mut acc = 0.0;
            for (dv, bv) in drow.iter().zip(bhat.iter()) {
                acc = dv.mul_add(*bv, acc);
            }
            ucap[j] = acc;
        }
        // Per-model read tables.
        for i in 0..m {
            let li = prior.chol_c.row(i);
            {
                let tli = &mut tl[i * m..i * m + m];
                for (j, dst) in tli.iter_mut().enumerate() {
                    let trow = &tmat[j * m..j * m + m];
                    let mut acc = 0.0;
                    for (tv, lv) in trow.iter().zip(li) {
                        acc = tv.mul_add(*lv, acc);
                    }
                    *dst = acc;
                }
            }
            let tli = &tl[i * m..i * m + m];
            g_mu[i] = dot(li, &ucap[..]);
            g_q[i] = dot(li, tli);
            // Cold-tenant variance: C_ii − ρ²·g_q + ρ³·tlᵀDtl (always
            // ≤ C_ii: per eigencomponent ρλ/(1 + ρλ) ≤ 1).
            let mut quad = 0.0;
            for (j, tv) in tli.iter().enumerate() {
                let drow = &dmat[j * m..j * m + m];
                let mut racc = 0.0;
                for (dv, tk) in drow.iter().zip(tli) {
                    racc = dv.mul_add(*tk, racc);
                }
                quad = tv.mul_add(racc, quad);
            }
            cold_var[i] = prior.model_cov[(i, i)] - rho * rho * g_q[i] + rho * rho * rho * quad;
        }
    }

    /// Shared observation implementation; fills the ρ = 0 dirty set.
    fn observe_inner(&mut self, x: ArmId, z: f64) -> Result<(), GpError> {
        if self.observed[x] {
            return Err(GpError::AlreadyObserved(x));
        }
        assert!(
            self.enabled[x],
            "observation of disabled arm {x}: the driver must not dispatch a departed tenant's arms"
        );
        let m = self.n_models;
        let u = x / m;
        let s = x % m;
        self.ensure_shard(u);
        let rho = self.prior.rho;
        let tol = self.change_tol;
        self.t_total += 1;
        let Self { prior, shards, cross_buf, changed_arms, changed_len, enabled, observed, observed_z, tmat, bhat, .. } =
            self;
        // pallas-lint: allow(R5) — ensure_shard above just filled this tenant's slot; an empty slot here is state corruption worth aborting on.
        let shard = shards[u].as_deref_mut().expect("tenant shard just ensured");
        let (t, beta_t) = shard.ingest(prior, s, z, prior.prior_mean[x], cross_buf);
        observed[x] = true;
        observed_z[x] = z;
        if rho == 0.0 {
            // Tenant-local dirty set, identical to the dense GP's: the
            // moved arms of the observing tenant in ascending order (same
            // d_mu/d_var threshold arithmetic), then the observed arm.
            let base = u * m;
            let mut len = 0usize;
            for i in 0..m {
                let a = base + i;
                if i == s || !enabled[a] {
                    continue;
                }
                let w_new = shard.data[i * m + t];
                let d_mu = w_new * beta_t;
                let d_var = w_new * w_new;
                if d_mu.abs() > tol || d_var > tol {
                    changed_arms[len] = a;
                    len += 1;
                }
            }
            changed_arms[len] = x;
            *changed_len = len + 1;
        } else {
            // Global coupling: fold the new W̃ row into (T, b̂) — rank-1,
            // in arrival order — then refresh the read tables. Every
            // enabled arm's posterior moves; `dirty_view` reports the
            // enabled list itself.
            *changed_len = 0;
            let wt_new = shard.wt_row(t);
            for j in 0..m {
                let wj = wt_new[j];
                bhat[j] = wj.mul_add(beta_t, bhat[j]);
                let trow = &mut tmat[j * m..j * m + m];
                for (dst, wk) in trow.iter_mut().zip(wt_new) {
                    *dst = wj.mul_add(*wk, *dst);
                }
            }
            self.refresh_cap_tables();
        }
        Ok(())
    }

    /// The dirty set of the most recent observation: at ρ = 0 the exact
    /// dense-equal tenant-local set; at ρ > 0 every enabled arm (the
    /// global coupling moves every posterior — a conservative, exact
    /// superset).
    fn dirty_view(&self) -> &[ArmId] {
        if self.prior.rho > 0.0 {
            &self.enabled_arms
        } else {
            &self.changed_arms[..self.changed_len]
        }
    }

    /// Incorporate the observation `z(x)` in `O(t_u² + m³)` — independent
    /// of the global observation count. Returns the arms whose posterior
    /// moved beyond the change tolerance (dense-equal tenant-local set at
    /// ρ = 0; every enabled arm — a conservative, exact superset — at
    /// ρ > 0). Repeat observation is logged to stderr and skipped with an
    /// empty dirty set, mirroring the dense [`Gp::observe`](crate::gp::Gp::observe).
    pub fn observe(&mut self, x: ArmId, z: f64) -> &[ArmId] {
        match self.observe_inner(x, z) {
            Ok(()) => self.dirty_view(),
            Err(e) => {
                eprintln!("mmgpei::gp: ignoring observation: {e}");
                &[]
            }
        }
    }

    /// Fallible form of [`ShardedGp::observe`]: returns `Err` instead of
    /// logging when the arm was already observed.
    pub fn try_observe(&mut self, x: ArmId, z: f64) -> Result<&[ArmId], GpError> {
        self.observe_inner(x, z)?;
        Ok(self.dirty_view())
    }

    /// Bulk observation: tenant-local updates run in parallel across the
    /// [`WorkerPool`] (each tenant's shard is independent state), then the
    /// global `(T, b̂)` rank-1 folds are applied serially in the original
    /// batch order and the read tables refreshed once. The final state is
    /// **bit-identical** to calling [`ShardedGp::observe`] on the batch in
    /// order, at any thread width (fixed-shard/fixed-merge contract).
    ///
    /// All-or-nothing: any already-observed, batch-duplicated, or
    /// disabled arm fails the whole batch before any state changes.
    pub fn observe_batch(&mut self, pool: &WorkerPool, obs: &[(ArmId, f64)]) -> Result<(), GpError> {
        let m = self.n_models;
        for &(x, _) in obs {
            if self.observed[x] {
                return Err(GpError::AlreadyObserved(x));
            }
            assert!(
                self.enabled[x],
                "observation of disabled arm {x}: the driver must not dispatch a departed tenant's arms"
            );
        }
        let mut order: Vec<usize> = (0..obs.len()).collect();
        order.sort_unstable_by_key(|&k| (obs[k].0, k));
        for pair in order.windows(2) {
            if obs[pair[0]].0 == obs[pair[1]].0 {
                return Err(GpError::AlreadyObserved(obs[pair[0]].0));
            }
        }
        // Group by tenant (ascending user, batch order within a tenant),
        // taking each shard out of the table so chunks own disjoint state.
        order.sort_unstable_by_key(|&k| (obs[k].0 / m, k));
        let mut groups: Vec<TenantWork> = Vec::new();
        for &k in &order {
            let (x, z) = obs[k];
            let u = x / m;
            if groups.last().map(|g| g.user) != Some(u) {
                self.ensure_shard(u);
                // pallas-lint: allow(R5) — ensure_shard above just filled this tenant's slot; an empty slot is state corruption worth aborting on.
                let shard = self.shards[u].take().expect("tenant shard just ensured");
                groups.push(TenantWork { user: u, shard, items: Vec::new(), out: Vec::new() });
            }
            // pallas-lint: allow(R5) — the loop above pushed at least one group.
            let g = groups.last_mut().expect("group just pushed");
            g.items.push((k, x % m, z, self.prior.prior_mean[x]));
        }
        // Parallel tenant-local phase: deterministic regardless of chunk
        // boundaries — each tenant's update touches only its own shard.
        let prior = &self.prior;
        pool.for_each_chunk_mut(&mut groups, |chunk| {
            let mut cross = vec![0.0; m];
            for tw in chunk {
                for &(_, s, z, mu0) in &tw.items {
                    let r = tw.shard.ingest(prior, s, z, mu0, &mut cross);
                    tw.out.push(r);
                }
            }
        });
        // Reinstall the shards, mark observations, and collect the per-
        // observation (tenant, t, β_t) triples in batch order.
        let mut per_obs: Vec<(usize, usize, f64)> = vec![(0, 0, 0.0); obs.len()];
        for tw in groups {
            for (&(k, _, z, _), &(t, beta_t)) in tw.items.iter().zip(&tw.out) {
                per_obs[k] = (tw.user, t, beta_t);
                let x = obs[k].0;
                self.observed[x] = true;
                self.observed_z[x] = z;
            }
            self.shards[tw.user] = Some(tw.shard);
        }
        self.t_total += obs.len();
        self.changed_len = 0;
        if self.prior.rho > 0.0 {
            // Serial global fold in the original batch order: the same
            // rank-1 update sequence sequential observes would have run,
            // so (T, b̂) — and every table derived from them — match the
            // sequential path bit for bit.
            let Self { shards, tmat, bhat, .. } = self;
            for &(u, t, beta_t) in &per_obs {
                // pallas-lint: allow(R5) — the shard was reinstalled by the loop above.
                let shard = shards[u].as_deref().expect("tenant shard reinstalled");
                let wt_new = shard.wt_row(t);
                for j in 0..m {
                    let wj = wt_new[j];
                    bhat[j] = wj.mul_add(beta_t, bhat[j]);
                    let trow = &mut tmat[j * m..j * m + m];
                    for (dst, wk) in trow.iter_mut().zip(wt_new) {
                        *dst = wj.mul_add(*wk, *dst);
                    }
                }
            }
            self.refresh_cap_tables();
        }
        Ok(())
    }

    /// Posterior mean of arm `x`: pinned `z` for observed arms, the
    /// frozen snapshot for disabled arms, else the lazy sharded read
    /// (`O(1)` cold tenant, `O(m)` warm).
    pub fn posterior_mean(&self, x: ArmId) -> f64 {
        if self.observed[x] {
            return self.observed_z[x];
        }
        if !self.enabled[x] {
            if let Ok(k) = self.frozen.binary_search_by(|e| e.0.cmp(&x)) {
                return self.frozen[k].1;
            }
        }
        self.live_mean(x)
    }

    /// Posterior standard deviation of arm `x` (0 for observed arms,
    /// frozen for disabled arms; variance clamped at 0 like the dense GP).
    pub fn posterior_std(&self, x: ArmId) -> f64 {
        self.posterior_var(x).max(0.0).sqrt()
    }

    fn posterior_var(&self, x: ArmId) -> f64 {
        if self.observed[x] {
            return 0.0;
        }
        if !self.enabled[x] {
            if let Ok(k) = self.frozen.binary_search_by(|e| e.0.cmp(&x)) {
                return self.frozen[k].2;
            }
        }
        self.live_var(x)
    }

    /// Live (unpinned, unfrozen) posterior mean.
    fn live_mean(&self, x: ArmId) -> f64 {
        let m = self.n_models;
        let (u, i) = (x / m, x % m);
        let rho = self.prior.rho;
        match &self.shards[u] {
            Some(sh) => {
                let local = sh.local_mu(i);
                if rho == 0.0 {
                    local
                } else {
                    // μ = local + ρ·(ℓ_i − h_a)ᵀu, with ℓ_iᵀu precomputed.
                    let h = sh.h_row(i);
                    let mut hc = 0.0;
                    for (hv, uv) in h.iter().zip(&self.ucap) {
                        hc = hv.mul_add(*uv, hc);
                    }
                    rho.mul_add(self.g_mu[i] - hc, local)
                }
            }
            None => {
                let mu0 = self.prior.prior_mean[x];
                if rho == 0.0 {
                    mu0
                } else {
                    rho.mul_add(self.g_mu[i], mu0)
                }
            }
        }
    }

    /// Live (unpinned, unfrozen) posterior variance.
    fn live_var(&self, x: ArmId) -> f64 {
        let m = self.n_models;
        let (u, i) = (x / m, x % m);
        let rho = self.prior.rho;
        match &self.shards[u] {
            Some(sh) => {
                let lv = sh.local_var(i);
                if rho == 0.0 {
                    return lv;
                }
                // σ² = local + ρ·[C_ii − 2hᵀℓ_i − ρ·g_q + pᵀDp],
                // p = h + ρ·Tℓ_i — all from precomputed tables, O(m²).
                let h = sh.h_row(i);
                let li = self.prior.chol_c.row(i);
                let tli = &self.tl[i * m..i * m + m];
                let mut hl = 0.0;
                for (hv, lv2) in h.iter().zip(li) {
                    hl = hv.mul_add(*lv2, hl);
                }
                let mut quad = 0.0;
                for j in 0..m {
                    let pj = rho.mul_add(tli[j], h[j]);
                    let drow = &self.dmat[j * m..j * m + m];
                    let mut racc = 0.0;
                    for (k, dv) in drow.iter().enumerate() {
                        let pk = rho.mul_add(tli[k], h[k]);
                        racc = dv.mul_add(pk, racc);
                    }
                    quad = pj.mul_add(racc, quad);
                }
                let cross = self.prior.model_cov[(i, i)] - 2.0 * hl - rho * self.g_q[i] + quad;
                rho.mul_add(cross, lv)
            }
            None => {
                if rho == 0.0 {
                    self.prior.model_cov[(i, i)]
                } else {
                    self.cold_var[i]
                }
            }
        }
    }

    /// Expected improvement of arm `x` over incumbent `best` (paper
    /// Eq. 3 via Lemma 1) — same formula path as the dense GP.
    pub fn ei(&self, x: ArmId, best: f64) -> f64 {
        expected_improvement(self.posterior_mean(x), self.posterior_std(x), best)
    }

    /// Stop maintaining arm `x`'s visible posterior (tenant departure):
    /// reads freeze at the current `(μ, σ²)` while the shard keeps
    /// accumulating underneath (the shared posterior keeps the
    /// knowledge). Idempotent; mirrors the dense
    /// [`Gp::disable_arm`](crate::gp::Gp::disable_arm) freeze semantics.
    pub fn disable_arm(&mut self, x: ArmId) {
        if !self.enabled[x] {
            return;
        }
        if !self.observed[x] {
            let mu = self.live_mean(x);
            let var = self.live_var(x);
            if let Err(pos) = self.frozen.binary_search_by(|e| e.0.cmp(&x)) {
                self.frozen.insert(pos, (x, mu, var));
            }
        }
        self.enabled[x] = false;
        // pallas-lint: allow(R5) — mirrors dense Gp::disable_arm: enabled[x] was true so x is in enabled_arms (the two are updated together); divergence is state corruption worth aborting on.
        let pos = self.enabled_arms.binary_search(&x).expect("enabled list out of sync");
        self.enabled_arms.remove(pos);
    }

    /// Resume maintaining arm `x`'s posterior (tenant join/rejoin):
    /// drops the frozen snapshot, so the next read sees the fully
    /// caught-up lazy posterior — at ρ = 0 bit-identical to the dense
    /// GP's replay-based catch-up (the shard accumulators never stopped
    /// running the same float sequence). Idempotent.
    pub fn enable_arm(&mut self, x: ArmId) {
        if self.enabled[x] {
            return;
        }
        self.enabled[x] = true;
        if let Err(pos) = self.enabled_arms.binary_search(&x) {
            self.enabled_arms.insert(pos, x);
        }
        if let Ok(pos) = self.frozen.binary_search_by(|e| e.0.cmp(&x)) {
            self.frozen.remove(pos);
        }
    }

    /// Materialize the full posterior `(mean, std)` — the bulk read the
    /// bench harnesses and diagnostics use. Arm ranges are distributed
    /// across the [`WorkerPool`] (`map_chunks`, fixed shards merged in
    /// range order), and every entry is a pure `&self` read, so the
    /// result is byte-identical at any thread width.
    pub fn posterior_snapshot(&self, pool: &WorkerPool) -> (Vec<f64>, Vec<f64>) {
        let n = self.n_arms;
        let chunks = pool.map_chunks(n, |range| {
            let mut mu = Vec::with_capacity(range.len());
            let mut sd = Vec::with_capacity(range.len());
            for x in range {
                mu.push(self.posterior_mean(x));
                sd.push(self.posterior_std(x));
            }
            (mu, sd)
        });
        let mut mu = Vec::with_capacity(n);
        let mut sd = Vec::with_capacity(n);
        for (cm, cs) in chunks {
            mu.extend_from_slice(&cm);
            sd.extend_from_slice(&cs);
        }
        (mu, sd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::Gp;
    use crate::kernels::{exchangeable_user_sim, kronecker_arm_cov, Kernel, Matern52};

    /// Shared Matérn-5/2 model gram on the workload's grid `[i·0.25]`.
    fn model_gram(m: usize) -> Mat {
        let pts: Vec<Vec<f64>> = (0..m).map(|i| vec![i as f64 * 0.25]).collect();
        Matern52 { variance: 1.0, lengthscale: 0.8 }.gram(&pts)
    }

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn dense_prior_matches_kronecker_arm_cov_bitwise() {
        let (nu, m) = (3, 3);
        let c = model_gram(m);
        let prior = KroneckerPrior::constant_mean(nu, c.clone(), 0.3, 0.5).unwrap();
        let (_, dense) = prior.dense_prior();
        let arms: Vec<(usize, usize)> = (0..nu * m).map(|a| (a / m, a % m)).collect();
        let oracle = kronecker_arm_cov(&arms, &exchangeable_user_sim(nu, 0.3), &c);
        for a in 0..nu * m {
            for b in 0..nu * m {
                assert_eq!(dense[(a, b)].to_bits(), oracle[(a, b)].to_bits(), "({a},{b})");
            }
        }
    }

    #[test]
    fn rho_zero_matches_dense_bitwise() {
        let (nu, m) = (3, 3);
        let prior = KroneckerPrior::constant_mean(nu, model_gram(m), 0.0, 0.5).unwrap();
        let (mean, cov) = prior.dense_prior();
        let mut dense = Gp::new(mean, cov);
        let mut sharded = ShardedGp::new(prior);
        let obs = [(0usize, 0.7), (4, 0.4), (1, 0.9), (8, 0.2), (3, 0.6)];
        for &(x, z) in &obs {
            let d: Vec<ArmId> = dense.observe(x, z).to_vec();
            let s: Vec<ArmId> = sharded.observe(x, z).to_vec();
            assert_eq!(d, s, "dirty set after arm {x}");
            for a in 0..nu * m {
                assert_eq!(dense.posterior_mean(a).to_bits(), sharded.posterior_mean(a).to_bits(), "mu[{a}]");
                assert_eq!(dense.posterior_std(a).to_bits(), sharded.posterior_std(a).to_bits(), "sd[{a}]");
            }
        }
    }

    #[test]
    fn rho_positive_matches_dense_to_rel_tol() {
        let (nu, m) = (4, 3);
        let prior = KroneckerPrior::constant_mean(nu, model_gram(m), 0.35, 0.5).unwrap();
        let (mean, cov) = prior.dense_prior();
        let mut dense = Gp::new(mean, cov);
        let mut sharded = ShardedGp::new(prior);
        let obs = [(0usize, 0.7), (5, 0.4), (1, 0.9), (10, 0.2), (7, 0.6)];
        for &(x, z) in &obs {
            dense.observe(x, z);
            sharded.observe(x, z);
            for a in 0..nu * m {
                let (dm, sm) = (dense.posterior_mean(a), sharded.posterior_mean(a));
                let (ds, ss) = (dense.posterior_std(a), sharded.posterior_std(a));
                assert!(rel_close(dm, sm, 1e-9), "mu[{a}]: {dm} vs {sm}");
                assert!(rel_close(ds, ss, 1e-8), "sd[{a}]: {ds} vs {ss}");
                assert!(rel_close(dense.ei(a, 0.5), sharded.ei(a, 0.5), 1e-7), "ei[{a}]");
            }
        }
        // Cold tenant 3 was never observed: its reads came from the O(1)
        // tables (checked above) and cost no shard.
        assert!(sharded.shards[3].is_none());
    }

    #[test]
    fn double_observe_is_logged_and_skipped() {
        let prior = KroneckerPrior::constant_mean(2, model_gram(2), 0.3, 0.0).unwrap();
        let mut gp = ShardedGp::new(prior);
        assert!(!gp.observe(1, 0.4).is_empty());
        let mu = gp.posterior_mean(0);
        assert_eq!(gp.try_observe(1, 0.9), Err(GpError::AlreadyObserved(1)));
        assert!(gp.observe(1, 0.9).is_empty());
        assert_eq!(gp.posterior_mean(0).to_bits(), mu.to_bits(), "state must not move on a repeat");
        assert_eq!(gp.posterior_mean(1), 0.4);
        assert_eq!(gp.n_observed(), 1);
    }

    #[test]
    fn disable_freezes_and_enable_catches_up() {
        let (nu, m) = (3, 3);
        let prior = KroneckerPrior::constant_mean(nu, model_gram(m), 0.0, 0.5).unwrap();
        let (mean, cov) = prior.dense_prior();
        let mut dense = Gp::new(mean, cov);
        let mut sharded = ShardedGp::new(prior);
        dense.observe(0, 0.7);
        sharded.observe(0, 0.7);
        dense.disable_arm(1);
        sharded.disable_arm(1);
        assert!(!sharded.is_enabled(1));
        let frozen_mu = sharded.posterior_mean(1);
        let frozen_sd = sharded.posterior_std(1);
        // More same-tenant observations move the live posterior but not
        // the frozen read — in both implementations.
        dense.observe(2, 0.9);
        sharded.observe(2, 0.9);
        assert_eq!(sharded.posterior_mean(1).to_bits(), frozen_mu.to_bits());
        assert_eq!(sharded.posterior_std(1).to_bits(), frozen_sd.to_bits());
        assert_eq!(dense.posterior_mean(1).to_bits(), frozen_mu.to_bits());
        // Re-enable: both catch up bit-identically.
        dense.enable_arm(1);
        sharded.enable_arm(1);
        for a in 0..nu * m {
            assert_eq!(dense.posterior_mean(a).to_bits(), sharded.posterior_mean(a).to_bits(), "mu[{a}]");
            assert_eq!(dense.posterior_std(a).to_bits(), sharded.posterior_std(a).to_bits(), "sd[{a}]");
        }
    }

    #[test]
    fn observe_batch_matches_sequential_bitwise() {
        let (nu, m) = (4, 3);
        let c = model_gram(m);
        let prior = KroneckerPrior::constant_mean(nu, c, 0.4, 0.5).unwrap();
        let mut seq = ShardedGp::new(prior.clone());
        let mut bat = ShardedGp::new(prior);
        let obs = [(0usize, 0.7), (5, 0.4), (1, 0.9), (10, 0.2), (7, 0.6), (3, 0.1)];
        for &(x, z) in &obs {
            seq.observe(x, z);
        }
        let pool = WorkerPool::new(2);
        bat.observe_batch(&pool, &obs).unwrap();
        for a in 0..nu * m {
            assert_eq!(seq.posterior_mean(a).to_bits(), bat.posterior_mean(a).to_bits(), "mu[{a}]");
            assert_eq!(seq.posterior_std(a).to_bits(), bat.posterior_std(a).to_bits(), "sd[{a}]");
        }
        // Batch validation is all-or-nothing.
        assert_eq!(bat.observe_batch(&pool, &[(2, 0.5), (0, 0.1)]), Err(GpError::AlreadyObserved(0)));
        assert_eq!(bat.observe_batch(&pool, &[(2, 0.5), (2, 0.6)]), Err(GpError::AlreadyObserved(2)));
        assert!(!bat.is_observed(2), "failed batch must not partially apply");
    }

    #[test]
    fn prior_validation_rejects_bad_inputs() {
        assert!(KroneckerPrior::constant_mean(0, model_gram(2), 0.0, 0.0).is_err());
        assert!(KroneckerPrior::constant_mean(2, model_gram(2), 1.0, 0.0).is_err());
        assert!(KroneckerPrior::constant_mean(2, model_gram(2), -0.1, 0.0).is_err());
        assert!(KroneckerPrior::new(2, model_gram(2), 0.3, vec![0.0; 3]).is_err());
    }
}
