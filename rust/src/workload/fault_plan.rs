//! Fault-injection workload: a seeded generator for [`FaultPlan`]s.
//!
//! Models the failure regimes a multi-tenant serving fleet actually
//! sees: per-device crash/restart cycles with exponential inter-crash
//! gaps (spot reclaims, OOM kills), fleet-wide job failures arriving as
//! a Poisson-like stream (flaky trainer processes), and stragglers whose
//! remaining work is stretched by a uniform slowdown factor (noisy
//! neighbors, thermal throttling). Deterministic per `(config, seed)`;
//! validation and total ordering live in [`FaultPlan::new`].

use crate::prng::Rng;
use crate::problem::{FaultEvent, FaultKind, FaultPlan, RetryPolicy};

use super::fleet::exp_gap;

/// Parameters of the fault-plan generator. A mean gap of `0.0` disables
/// that fault channel entirely; with all three channels disabled the
/// generator returns [`FaultPlan::empty`] (the engine's byte-inert
/// fault-free mode).
#[derive(Clone, Debug)]
pub struct FaultsConfig {
    /// Mean exponential gap between crashes of one device (mean time
    /// between failures); `0.0` disables crash injection.
    pub mtbf: f64,
    /// Mean exponential downtime between a crash and its restart. Must
    /// be positive when `mtbf` is.
    pub mean_downtime: f64,
    /// Mean exponential gap between fleet-wide job-failure events;
    /// `0.0` disables job-failure injection.
    pub job_failure_gap: f64,
    /// Mean exponential gap between fleet-wide straggler events; `0.0`
    /// disables straggler injection.
    pub straggler_gap: f64,
    /// Uniform straggler slowdown factor range `[lo, hi)`, `1 ≤ lo < hi`.
    pub slowdown: (f64, f64),
    /// Generate fault events in `[0, horizon)`; an event at or past the
    /// horizon is dropped (a trailing crash leaves its device down).
    pub horizon: f64,
    /// Deadline/retry semantics the plan's jobs run under.
    pub retry: RetryPolicy,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            mtbf: 60.0,
            mean_downtime: 8.0,
            job_failure_gap: 15.0,
            straggler_gap: 25.0,
            slowdown: (1.5, 4.0),
            horizon: 240.0,
            retry: RetryPolicy::default(),
        }
    }
}

impl FaultsConfig {
    /// Sanity-check the knob ranges (mirrors `FleetConfig::validate`).
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("mtbf", self.mtbf),
            ("job_failure_gap", self.job_failure_gap),
            ("straggler_gap", self.straggler_gap),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!(
                    "faults: {name} must be finite and >= 0 (0 disables), got {v}"
                ));
            }
        }
        if self.mtbf > 0.0 && !(self.mean_downtime.is_finite() && self.mean_downtime > 0.0) {
            return Err(format!(
                "faults: mean_downtime must be finite and positive when mtbf > 0, got {}",
                self.mean_downtime
            ));
        }
        if self.straggler_gap > 0.0
            && (!(self.slowdown.0 >= 1.0) || !(self.slowdown.1 > self.slowdown.0))
        {
            return Err(format!(
                "faults: slowdown range must satisfy 1 <= lo < hi, got {:?}",
                self.slowdown
            ));
        }
        if !(self.horizon > 0.0) {
            return Err("faults: horizon must be positive".into());
        }
        if !(self.retry.deadline_factor.is_finite() && self.retry.deadline_factor > 1.0) {
            return Err(format!(
                "faults: retry deadline_factor must be finite and > 1, got {}",
                self.retry.deadline_factor
            ));
        }
        if !(self.retry.backoff_base.is_finite() && self.retry.backoff_base > 0.0) {
            return Err(format!(
                "faults: retry backoff_base must be finite and positive, got {}",
                self.retry.backoff_base
            ));
        }
        if !(self.retry.backoff_cap.is_finite() && self.retry.backoff_cap >= self.retry.backoff_base)
        {
            return Err(format!(
                "faults: retry backoff_cap must be finite and >= backoff_base, got {}",
                self.retry.backoff_cap
            ));
        }
        Ok(())
    }

    /// Whether any fault channel is active. When false the generated
    /// plan is empty and the engine's fault machinery stays disarmed.
    pub fn any_channel_active(&self) -> bool {
        self.mtbf > 0.0 || self.job_failure_gap > 0.0 || self.straggler_gap > 0.0
    }
}

/// Generate a validated fault plan for a fleet of `n_devices` slots.
/// Deterministic per `(config, n_devices, seed)`: each device's
/// crash/restart timeline is drawn in device-index order, then the
/// job-failure stream, then the straggler stream — fixed draw order, so
/// adding knobs later cannot silently reshuffle earlier draws (the same
/// discipline as `fleet_schedule`).
pub fn fault_plan(config: &FaultsConfig, n_devices: usize, seed: u64) -> FaultPlan {
    // pallas-lint: allow(R5) — generator precondition: configs come from `ExperimentConfig::validate`d TOML or test literals; an invalid one is a caller bug surfaced at startup, not at serve time.
    config.validate().expect("invalid faults config");
    assert!(n_devices > 0, "fault plan needs at least one device slot");
    let mut rng = Rng::new(seed);
    let mut events = Vec::new();

    // Per-device crash/restart alternation (always starts with a crash;
    // a trailing crash without a restart leaves the device down).
    if config.mtbf > 0.0 {
        for d in 0..n_devices {
            let mut t = 0.0;
            loop {
                t += exp_gap(&mut rng, config.mtbf);
                if t >= config.horizon {
                    break;
                }
                events.push(FaultEvent { time: t, device: d, kind: FaultKind::DeviceCrash });
                t += exp_gap(&mut rng, config.mean_downtime);
                if t >= config.horizon {
                    break;
                }
                events.push(FaultEvent { time: t, device: d, kind: FaultKind::DeviceRestart });
            }
        }
    }

    // Fleet-wide job-failure stream: each event picks its victim device
    // uniformly (a kill landing on an idle or crashed device is a no-op
    // at run time — the engine's handlers are idempotent).
    if config.job_failure_gap > 0.0 {
        let mut t = 0.0;
        loop {
            t += exp_gap(&mut rng, config.job_failure_gap);
            if t >= config.horizon {
                break;
            }
            let device = rng.below(n_devices);
            events.push(FaultEvent { time: t, device, kind: FaultKind::JobFailure });
        }
    }

    // Fleet-wide straggler stream with per-event slowdown factors.
    if config.straggler_gap > 0.0 {
        let mut t = 0.0;
        loop {
            t += exp_gap(&mut rng, config.straggler_gap);
            if t >= config.horizon {
                break;
            }
            let device = rng.below(n_devices);
            let factor = rng.uniform_in(config.slowdown.0, config.slowdown.1);
            events.push(FaultEvent { time: t, device, kind: FaultKind::Straggler(factor) });
        }
    }

    FaultPlan::new(n_devices, events, config.retry)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> FaultsConfig {
        FaultsConfig {
            mtbf: 20.0,
            mean_downtime: 4.0,
            job_failure_gap: 10.0,
            straggler_gap: 12.0,
            slowdown: (1.5, 3.0),
            horizon: 80.0,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = fault_plan(&small(), 4, 9);
        let b = fault_plan(&small(), 4, 9);
        let c = fault_plan(&small(), 4, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn events_respect_horizon_and_devices() {
        let cfg = small();
        for seed in 0..6 {
            let plan = fault_plan(&cfg, 3, seed);
            for e in plan.events() {
                assert!(e.time < cfg.horizon, "event at {} past horizon", e.time);
                assert!(e.device < 3);
                if let FaultKind::Straggler(f) = e.kind {
                    assert!(f >= cfg.slowdown.0 && f < cfg.slowdown.1);
                }
            }
        }
    }

    #[test]
    fn all_three_channels_fire_across_seeds() {
        let cfg = small();
        let (mut crash, mut kill, mut slow) = (false, false, false);
        for seed in 0..10 {
            for e in fault_plan(&cfg, 4, seed).events() {
                match e.kind {
                    FaultKind::DeviceCrash => crash = true,
                    FaultKind::JobFailure => kill = true,
                    FaultKind::Straggler(_) => slow = true,
                    FaultKind::DeviceRestart => {}
                }
            }
        }
        assert!(crash && kill && slow, "gaps well under the horizon must produce all kinds");
    }

    #[test]
    fn disabled_channels_produce_empty_plan() {
        let cfg = FaultsConfig {
            mtbf: 0.0,
            job_failure_gap: 0.0,
            straggler_gap: 0.0,
            ..small()
        };
        assert!(!cfg.any_channel_active());
        let plan = fault_plan(&cfg, 4, 1);
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::empty());
    }

    #[test]
    fn single_channel_configs_generate_only_that_kind() {
        let cfg = FaultsConfig { mtbf: 0.0, straggler_gap: 0.0, ..small() };
        let plan = fault_plan(&cfg, 2, 3);
        assert!(!plan.is_empty(), "job-failure gap 10 against horizon 80 must fire");
        assert!(plan.events().iter().all(|e| e.kind == FaultKind::JobFailure));
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(FaultsConfig { mtbf: -1.0, ..small() }.validate().is_err());
        assert!(FaultsConfig { mtbf: f64::NAN, ..small() }.validate().is_err());
        assert!(FaultsConfig { mean_downtime: 0.0, ..small() }.validate().is_err());
        assert!(FaultsConfig { job_failure_gap: -0.5, ..small() }.validate().is_err());
        assert!(FaultsConfig { slowdown: (0.5, 2.0), ..small() }.validate().is_err());
        assert!(FaultsConfig { slowdown: (2.0, 2.0), ..small() }.validate().is_err());
        assert!(FaultsConfig { horizon: 0.0, ..small() }.validate().is_err());
        let bad_retry =
            RetryPolicy { deadline_factor: 1.0, ..RetryPolicy::default() };
        assert!(FaultsConfig { retry: bad_retry, ..small() }.validate().is_err());
        let bad_cap = RetryPolicy { backoff_cap: 0.1, ..RetryPolicy::default() };
        assert!(FaultsConfig { retry: bad_cap, ..small() }.validate().is_err());
        assert!(small().validate().is_ok());
    }

    #[test]
    fn mean_downtime_ignored_when_crashes_disabled() {
        // With mtbf = 0 the downtime knob is dead; don't reject it.
        let cfg = FaultsConfig { mtbf: 0.0, mean_downtime: 0.0, ..small() };
        assert!(cfg.validate().is_ok());
    }
}
