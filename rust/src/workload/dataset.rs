//! Raw benchmark tables and the paper's §6.1 evaluation protocol.

use crate::kernels::{empirical_model_cov, exchangeable_user_sim, kronecker_arm_cov};
use crate::linalg::Mat;
use crate::problem::{Problem, Truth};
use crate::prng::Rng;

/// A model-selection benchmark table: accuracy and runtime of every model
/// on every user's dataset (what ease.ml collected and the paper replays).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Dataset label ("deeplearning", "azure", ...).
    pub name: String,
    /// Model (architecture / classifier) names, length `n_models`.
    pub model_names: Vec<String>,
    /// `accuracy[(u, m)]` — performance of model m on user u's task.
    pub accuracy: Mat,
    /// `cost[(u, m)]` — training time of model m on user u's data
    /// (abstract time units; Remark 1 treats these as known).
    pub cost: Mat,
}

/// The paper's protocol split: 8 users isolated to estimate the prior,
/// the rest served.
#[derive(Clone, Debug)]
pub struct ProtocolSplit {
    /// Users used to estimate the GP prior.
    pub holdout: Vec<usize>,
    /// Users actually served by the scheduler.
    pub serve: Vec<usize>,
}

impl Dataset {
    /// Number of users (rows).
    pub fn n_users(&self) -> usize {
        self.accuracy.rows()
    }

    /// Number of models (columns).
    pub fn n_models(&self) -> usize {
        self.accuracy.cols()
    }

    /// Average over users of the per-user std of model accuracies — the
    /// statistic the paper uses to contrast Azure (≈0.12) with
    /// DeepLearning (≈0.04) in §6.2.
    pub fn mean_per_user_accuracy_std(&self) -> f64 {
        let m = self.n_models() as f64;
        let mut acc = 0.0;
        for u in 0..self.n_users() {
            let row = self.accuracy.row(u);
            let mean = row.iter().sum::<f64>() / m;
            let var = row.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / m;
            acc += var.sqrt();
        }
        acc / self.n_users() as f64
    }

    /// Randomly split users into `n_holdout` prior-estimation users and
    /// the served remainder (paper §6.1: `n_holdout = 8`).
    pub fn protocol_split(&self, rng: &mut Rng, n_holdout: usize) -> ProtocolSplit {
        assert!(n_holdout < self.n_users(), "must leave at least one served user");
        let holdout = rng.choose_indices(self.n_users(), n_holdout);
        let serve: Vec<usize> =
            (0..self.n_users()).filter(|u| !holdout.contains(u)).collect();
        ProtocolSplit { holdout, serve }
    }

    /// Estimate the cross-user correlation ρ from the holdout rows: the
    /// average Pearson correlation between pairs of users' accuracy
    /// vectors, clamped to a PD-safe range. This is the "similarity of
    /// users' datasets" factor of the paper's §4.2 prior discussion.
    pub fn estimate_user_rho(&self, holdout: &[usize]) -> f64 {
        let m = self.n_models();
        let center = |u: usize| -> Vec<f64> {
            let row = self.accuracy.row(u);
            let mean = row.iter().sum::<f64>() / m as f64;
            row.iter().map(|a| a - mean).collect()
        };
        let mut acc = 0.0;
        let mut count = 0usize;
        for (i, &u) in holdout.iter().enumerate() {
            let cu = center(u);
            let nu = cu.iter().map(|v| v * v).sum::<f64>().sqrt();
            for &v in &holdout[i + 1..] {
                let cv = center(v);
                let nv = cv.iter().map(|x| x * x).sum::<f64>().sqrt();
                if nu > 1e-12 && nv > 1e-12 {
                    acc += crate::linalg::dot(&cu, &cv) / (nu * nv);
                    count += 1;
                }
            }
        }
        if count == 0 {
            return 0.0;
        }
        (acc / count as f64).clamp(0.0, 0.9)
    }

    /// Apply the paper's protocol: estimate the GP prior (per-model mean,
    /// model covariance via [`empirical_model_cov`], user similarity via
    /// [`Dataset::estimate_user_rho`]) from the holdout rows and build the
    /// MDMT problem over the served users. Arms are (served-user, model)
    /// pairs in user-major order.
    pub fn make_problem(&self, split: &ProtocolSplit) -> (Problem, Truth) {
        let n_models = self.n_models();
        let history: Vec<Vec<f64>> =
            split.holdout.iter().map(|&u| self.accuracy.row(u).to_vec()).collect();
        let (model_mean, model_cov) = empirical_model_cov(&history, 1e-6);
        let rho = self.estimate_user_rho(&split.holdout);
        let n_serve = split.serve.len();
        let user_sim = exchangeable_user_sim(n_serve, rho);
        let arms: Vec<(usize, usize)> = (0..n_serve)
            .flat_map(|u| (0..n_models).map(move |m| (u, m)))
            .collect();
        let prior_cov = kronecker_arm_cov(&arms, &user_sim, &model_cov);
        let prior_mean: Vec<f64> =
            arms.iter().map(|&(_, m)| model_mean[m]).collect();
        let cost: Vec<f64> = split
            .serve
            .iter()
            .flat_map(|&u| (0..n_models).map(move |m| self.cost[(u, m)]))
            .collect();
        let z: Vec<f64> = split
            .serve
            .iter()
            .flat_map(|&u| (0..n_models).map(move |m| self.accuracy[(u, m)]))
            .collect();
        let user_arms: Vec<Vec<usize>> = (0..n_serve)
            .map(|u| (0..n_models).map(|m| u * n_models + m).collect())
            .collect();
        let arm_users = Problem::compute_arm_users(arms.len(), &user_arms);
        let problem = Problem {
            name: format!("{}[serve {} of {}]", self.name, n_serve, self.n_users()),
            n_users: n_serve,
            cost,
            user_arms,
            arm_users,
            prior_mean,
            prior_cov,
        };
        problem.validate();
        (problem, Truth { z })
    }

    /// Serialize to CSV: header then one `user,model,accuracy,cost` row
    /// per cell. Round-trips with [`Dataset::from_csv`].
    pub fn to_csv(&self) -> String {
        let mut out = String::from("user,model,accuracy,cost\n");
        for u in 0..self.n_users() {
            for m in 0..self.n_models() {
                out.push_str(&format!(
                    "{},{},{:.17},{:.17}\n",
                    u, self.model_names[m], self.accuracy[(u, m)], self.cost[(u, m)]
                ));
            }
        }
        out
    }

    /// Parse the CSV format produced by [`Dataset::to_csv`].
    pub fn from_csv(name: &str, text: &str) -> Result<Dataset, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty csv")?;
        if header.trim() != "user,model,accuracy,cost" {
            return Err(format!("unexpected header: {header}"));
        }
        let mut model_names: Vec<String> = Vec::new();
        let mut cells: Vec<(usize, usize, f64, f64)> = Vec::new();
        let mut n_users = 0usize;
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split(',').collect();
            if parts.len() != 4 {
                return Err(format!("line {}: expected 4 fields", lineno + 2));
            }
            let u: usize =
                parts[0].trim().parse().map_err(|e| format!("line {}: {e}", lineno + 2))?;
            let model = parts[1].trim().to_string();
            let m = match model_names.iter().position(|n| *n == model) {
                Some(i) => i,
                None => {
                    model_names.push(model);
                    model_names.len() - 1
                }
            };
            let acc: f64 =
                parts[2].trim().parse().map_err(|e| format!("line {}: {e}", lineno + 2))?;
            let cost: f64 =
                parts[3].trim().parse().map_err(|e| format!("line {}: {e}", lineno + 2))?;
            n_users = n_users.max(u + 1);
            cells.push((u, m, acc, cost));
        }
        let n_models = model_names.len();
        if n_users * n_models != cells.len() {
            return Err(format!(
                "expected {} cells for {}x{}, got {}",
                n_users * n_models,
                n_users,
                n_models,
                cells.len()
            ));
        }
        let mut accuracy = Mat::zeros(n_users, n_models);
        let mut cost = Mat::zeros(n_users, n_models);
        for (u, m, a, c) in cells {
            accuracy[(u, m)] = a;
            cost[(u, m)] = c;
        }
        Ok(Dataset { name: name.to_string(), model_names, accuracy, cost })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            model_names: vec!["a".into(), "b".into()],
            accuracy: Mat::from_rows(&[&[0.5, 0.7], &[0.6, 0.8], &[0.55, 0.75], &[0.5, 0.6]]),
            cost: Mat::from_rows(&[&[1.0, 2.0], &[1.5, 2.5], &[1.2, 2.2], &[1.1, 2.1]]),
        }
    }

    #[test]
    fn csv_roundtrip() {
        let d = tiny();
        let csv = d.to_csv();
        let back = Dataset::from_csv("tiny", &csv).unwrap();
        assert_eq!(back.model_names, d.model_names);
        assert_eq!(back.accuracy.as_slice(), d.accuracy.as_slice());
        assert_eq!(back.cost.as_slice(), d.cost.as_slice());
    }

    #[test]
    fn csv_rejects_bad_header() {
        assert!(Dataset::from_csv("x", "nope\n").is_err());
        assert!(Dataset::from_csv("x", "").is_err());
    }

    #[test]
    fn csv_rejects_ragged() {
        let bad = "user,model,accuracy,cost\n0,a,0.5,1.0\n0,b,0.6\n";
        assert!(Dataset::from_csv("x", bad).is_err());
    }

    #[test]
    fn rho_estimate_in_range() {
        let d = tiny();
        let rho = d.estimate_user_rho(&[0, 1, 2, 3]);
        assert!((0.0..=0.9).contains(&rho));
        // These users' accuracy profiles are strongly aligned (model b
        // always better) → high estimated correlation.
        assert!(rho > 0.5, "aligned users should correlate, got {rho}");
    }

    #[test]
    fn per_user_std_hand_check() {
        let d = Dataset {
            name: "s".into(),
            model_names: vec!["a".into(), "b".into()],
            accuracy: Mat::from_rows(&[&[0.4, 0.6]]),
            cost: Mat::from_rows(&[&[1.0, 1.0]]),
        };
        // std of {0.4, 0.6} (population) = 0.1
        assert!((d.mean_per_user_accuracy_std() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn make_problem_shared_nothing_between_users() {
        let d = tiny();
        let split = ProtocolSplit { holdout: vec![0, 1], serve: vec![2, 3] };
        let (p, t) = d.make_problem(&split);
        p.validate();
        assert_eq!(p.n_users, 2);
        assert_eq!(p.n_arms(), 4);
        assert_eq!(t.z[0], d.accuracy[(2, 0)]);
        assert_eq!(t.z[3], d.accuracy[(3, 1)]);
        // Kronecker structure: same-user same-model diag entries equal
        // model variances.
        assert!(p.prior_cov[(0, 0)] > 0.0);
    }
}
