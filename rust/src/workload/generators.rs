//! Seeded generators for the DeepLearning and Azure tables.
//!
//! **Substitution note (DESIGN.md §3).** The ease.ml tables the paper
//! replays are not public. What the paper's analysis actually consumes is
//! (a) the table *shape* (22×8 / 17×8), (b) the per-user accuracy spread
//! (σ≈0.04 for DeepLearning, σ≈0.12 for Azure — quoted in §6.2 as the
//! explanation for Figure 2's contrast), (c) cross-user transfer of model
//! quality (what makes the GP prior useful), and (d) heterogeneous
//! runtimes (what makes EIrate differ from EI). The generators below
//! reproduce exactly those statistics from a fixed seed, so every run of
//! the benchmark suite sees the same tables.
//!
//! Accuracy model per table:
//! `acc[u][m] = clip(base_u + σ_target·(a·g_m + b·h_{u,m}), lo, hi)`
//! with `g_m` a fixed model-quality profile (shared across users — the
//! transferable signal), `h` i.i.d. noise, and `a² + b² = 1` controlling
//! how much of the spread transfers across users.

use super::Dataset;
use crate::linalg::Mat;
use crate::prng::Rng;

/// The 8 CNN architectures of the DeepLearning dataset (paper §6.1).
pub const DEEPLEARNING_MODELS: [&str; 8] = [
    "NIN",
    "GoogLeNet",
    "ResNet-50",
    "AlexNet",
    "BN-AlexNet",
    "ResNet-18",
    "VGG-16",
    "SqueezeNet",
];

/// The 8 Azure ML Studio binary classifiers (paper §6.1).
pub const AZURE_MODELS: [&str; 8] = [
    "Averaged Perceptron",
    "Bayes Point Machine",
    "Boosted Decision Tree",
    "Decision Forest",
    "Decision Jungle",
    "Logistic Regression",
    "Neural Network",
    "SVM",
];

/// Normalized model-quality profile for the CNNs (zero-mean, unit-std):
/// ResNet-50 > GoogLeNet > ResNet-18 > VGG-16 > NIN > BN-AlexNet >
/// AlexNet > SqueezeNet — the ordering reported across the image-
/// classification literature the dataset draws from.
const DL_QUALITY: [f64; 8] = [-0.2, 1.0, 1.4, -1.3, -0.6, 0.8, 0.3, -1.4];

/// Relative training cost of each CNN (bigger nets slower), scaled by a
/// per-user dataset-size factor at generation time.
const DL_COST: [f64; 8] = [3.0, 6.0, 8.0, 1.5, 1.8, 4.0, 10.0, 1.2];

/// Quality profile for the Azure classifiers: boosted trees / forests
/// lead, linear models trail on typical Kaggle tabular tasks.
const AZ_QUALITY: [f64; 8] = [-0.9, -0.4, 1.5, 1.1, 0.6, -1.0, 0.3, -1.2];

/// Relative training cost of the classifiers (tree ensembles and neural
/// nets slower than linear models).
const AZ_COST: [f64; 8] = [0.3, 0.5, 2.0, 1.6, 1.2, 0.25, 2.5, 1.0];

/// Shared generator core.
///
/// `sigma_range`: the per-user accuracy spread is `mean(sigma_range)`
/// for every user — constant-σ tables calibrated to the paper's reported
/// average (§6.2). (A heterogeneous-σ variant was evaluated and rejected:
/// it mis-calibrates the shared holdout prior and erases the MDMT
/// advantage the paper observes; see EXPERIMENTS.md notes.)
fn generate(
    name: &str,
    models: &[&str],
    quality: &[f64],
    cost_base: &[f64],
    n_users: usize,
    sigma_range: (f64, f64),
    transfer: f64, // `a` in the docstring; fraction of spread shared across users
    base_range: (f64, f64),
    clip: (f64, f64),
    seed: u64,
) -> Dataset {
    let n_models = models.len();
    let mut rng = Rng::new(seed);
    // Normalize quality profile to zero mean / unit std so σ_target is
    // hit exactly in expectation.
    let qm = {
        let mean = quality.iter().sum::<f64>() / n_models as f64;
        let var =
            quality.iter().map(|q| (q - mean) * (q - mean)).sum::<f64>() / n_models as f64;
        let std = var.sqrt();
        quality.iter().map(|q| (q - mean) / std).collect::<Vec<f64>>()
    };
    let b = (1.0 - transfer * transfer).sqrt();
    let mut accuracy = Mat::zeros(n_users, n_models);
    let mut cost = Mat::zeros(n_users, n_models);
    for u in 0..n_users {
        let base = rng.uniform_in(base_range.0, base_range.1);
        let sigma_u = 0.5 * (sigma_range.0 + sigma_range.1);
        // Dataset size / hardware factor: scales all models' runtimes.
        let size_factor = rng.uniform_in(0.5, 2.0);
        for m in 0..n_models {
            let e = transfer * qm[m] + b * rng.normal();
            accuracy[(u, m)] = (base + sigma_u * e).clamp(clip.0, clip.1);
            // ±15% per-cell runtime jitter around the model's base cost.
            cost[(u, m)] = cost_base[m] * size_factor * rng.uniform_in(0.85, 1.15);
        }
    }
    Dataset {
        name: name.to_string(),
        model_names: models.iter().map(|s| s.to_string()).collect(),
        accuracy,
        cost,
    }
}

/// The DeepLearning workload: 22 users × 8 CNNs, per-user accuracy spread
/// σ ≈ 0.04, strongly transferable model quality (image classification
/// architectures rank similarly across datasets).
pub fn deeplearning() -> Dataset {
    generate(
        "deeplearning",
        &DEEPLEARNING_MODELS,
        &DL_QUALITY,
        &DL_COST,
        22,
        (0.02, 0.06), // mean 0.04 = the paper's reported per-user σ
        0.8,
        (0.60, 0.90),
        (0.05, 0.99),
        0xD1_2018,
    )
}

/// The Azure workload: 17 users × 8 classifiers, per-user spread σ ≈ 0.12
/// (the paper's explanation for why MM-GP-EI wins big here), moderately
/// transferable quality (tabular tasks are more idiosyncratic).
pub fn azure() -> Dataset {
    generate(
        "azure",
        &AZURE_MODELS,
        &AZ_QUALITY,
        &AZ_COST,
        17,
        (0.04, 0.20), // mean 0.12; wide spread = heterogeneous headroom
        0.6,
        (0.55, 0.80),
        (0.05, 0.99),
        0xA2_2018,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_lists_have_eight_entries() {
        assert_eq!(DEEPLEARNING_MODELS.len(), 8);
        assert_eq!(AZURE_MODELS.len(), 8);
    }

    #[test]
    fn cost_heterogeneity_realistic() {
        let d = deeplearning();
        // VGG-16 must be the slowest architecture on average; SqueezeNet
        // the fastest — the ratio drives the EIrate-vs-EI ablation.
        let avg_cost = |m: usize| -> f64 {
            (0..d.n_users()).map(|u| d.cost[(u, m)]).sum::<f64>() / d.n_users() as f64
        };
        let vgg = avg_cost(6);
        let squeeze = avg_cost(7);
        assert!(vgg / squeeze > 4.0, "VGG vs SqueezeNet cost ratio: {}", vgg / squeeze);
    }

    #[test]
    fn quality_transfer_across_users() {
        // The best model on average should be best (top-2) for most
        // users in the DeepLearning table — that's what makes the
        // holdout prior informative.
        let d = deeplearning();
        let n_models = d.n_models();
        let avg_acc: Vec<f64> = (0..n_models)
            .map(|m| (0..d.n_users()).map(|u| d.accuracy[(u, m)]).sum::<f64>() / 22.0)
            .collect();
        let best_model = (0..n_models)
            .max_by(|&a, &b| avg_acc[a].total_cmp(&avg_acc[b]))
            .unwrap();
        let mut top2_hits = 0;
        for u in 0..d.n_users() {
            let mut order: Vec<usize> = (0..n_models).collect();
            order.sort_by(|&a, &b| d.accuracy[(u, b)].total_cmp(&d.accuracy[(u, a)]));
            if order[..2].contains(&best_model) {
                top2_hits += 1;
            }
        }
        assert!(
            top2_hits >= 11,
            "global best should be per-user top-2 for most users ({top2_hits}/22)"
        );
    }

    #[test]
    fn azure_more_idiosyncratic_than_deeplearning() {
        let az = azure();
        let dl = deeplearning();
        assert!(az.mean_per_user_accuracy_std() > 2.0 * dl.mean_per_user_accuracy_std());
    }
}
