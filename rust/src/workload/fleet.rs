//! Elastic-fleet workload: a seeded generator for heterogeneous device
//! fleets with spot-style availability churn.
//!
//! Models the GPU-cluster regime of the related ensemble/cluster work
//! (mixed device generations, preemptible capacity): each device draws a
//! speed from a uniform range, a base cohort is online at t = 0, later
//! devices join with exponential (Poisson-like) gaps, and every device
//! then alternates bounded uniform uptimes with bounded uniform outages
//! until the generation horizon. Deterministic per `(config, seed)`;
//! validation and ordering live in [`DeviceFleet`].

use crate::prng::Rng;
use crate::problem::{DeviceFleet, FleetEvent, FleetEventKind};

/// Parameters of the fleet generator.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Devices that ever exist (online or not).
    pub n_devices: usize,
    /// Devices online at t = 0 (the always-started base cohort).
    pub initial_online: usize,
    /// Uniform per-device speed range `[lo, hi)` — `s_d` in the
    /// `c(x)/s_d` occupancy rule.
    pub speed_range: (f64, f64),
    /// Mean exponential gap between later device joins.
    pub arrival_gap: f64,
    /// Bounded uniform online span `[lo, hi)` before a device leaves.
    pub uptime: (f64, f64),
    /// Bounded uniform offline span `[lo, hi)` before it rejoins.
    pub outage: (f64, f64),
    /// Generate availability events in `[0, horizon)`; a device keeps
    /// its last state afterwards (an event exactly at or past the
    /// horizon is dropped).
    pub horizon: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            n_devices: 6,
            initial_online: 4,
            speed_range: (0.5, 2.0),
            arrival_gap: 10.0,
            uptime: (40.0, 120.0),
            outage: (5.0, 20.0),
            horizon: 240.0,
        }
    }
}

impl FleetConfig {
    /// Sanity-check the knob ranges (mirrors `ChurnConfig::validate`).
    pub fn validate(&self) -> Result<(), String> {
        if self.n_devices == 0 {
            return Err("fleet: n_devices must be ≥ 1".into());
        }
        if self.initial_online == 0 || self.initial_online > self.n_devices {
            return Err(format!(
                "fleet: initial_online must be in 1..={}, got {}",
                self.n_devices, self.initial_online
            ));
        }
        if !(self.speed_range.0 > 0.0) || !(self.speed_range.1 > self.speed_range.0) {
            return Err(format!(
                "fleet: speed range must satisfy 0 < lo < hi, got {:?}",
                self.speed_range
            ));
        }
        if !(self.arrival_gap > 0.0) {
            return Err("fleet: arrival_gap must be positive".into());
        }
        if !(self.uptime.0 > 0.0) || !(self.uptime.1 > self.uptime.0) {
            return Err(format!("fleet: uptime range must satisfy 0 < lo < hi, got {:?}", self.uptime));
        }
        if !(self.outage.0 > 0.0) || !(self.outage.1 > self.outage.0) {
            return Err(format!("fleet: outage range must satisfy 0 < lo < hi, got {:?}", self.outage));
        }
        if !(self.horizon > 0.0) {
            return Err("fleet: horizon must be positive".into());
        }
        Ok(())
    }
}

/// Exponential gap with the given mean (inverse-CDF; the `u = 0` corner
/// is rejected so `ln` stays finite). Shared with the fault-plan
/// generator, which models crash arrivals the same way.
pub(crate) fn exp_gap(rng: &mut Rng, mean: f64) -> f64 {
    let mut u = rng.uniform();
    while u <= f64::MIN_POSITIVE {
        u = rng.uniform();
    }
    -mean * u.ln()
}

/// Round-robin device-class assignment `d ↦ d mod n_classes` — the
/// canonical mapping the CLI and benches use to spread a cost model's
/// device classes over a fleet of any size (class 0 always exists, so
/// `PerClassCost`'s every-arm-feasible-somewhere invariant can be
/// checked against real classes).
pub fn round_robin_classes(n_devices: usize, n_classes: usize) -> Vec<usize> {
    assert!(n_classes > 0, "need at least one device class");
    (0..n_devices).map(|d| d % n_classes).collect()
}

/// Generate a validated elastic fleet. Deterministic per
/// `(config, seed)`: speeds first (one draw per device in index order),
/// then each device's availability timeline in index order, so adding
/// knobs later cannot silently reshuffle earlier draws.
pub fn fleet_schedule(config: &FleetConfig, seed: u64) -> DeviceFleet {
    // pallas-lint: allow(R5) — generator precondition: configs come from `ExperimentConfig::validate`d TOML or test literals; an invalid one is a caller bug surfaced at startup, not at serve time.
    config.validate().expect("invalid fleet config");
    let n = config.n_devices;
    let mut rng = Rng::new(seed);
    let speeds: Vec<f64> =
        (0..n).map(|_| rng.uniform_in(config.speed_range.0, config.speed_range.1)).collect();
    let online_at_start: Vec<bool> = (0..n).map(|d| d < config.initial_online).collect();

    let mut events = Vec::new();
    let mut t_arrive = 0.0;
    for d in 0..n {
        // Later devices join with exponential gaps after the base cohort.
        let mut t = if d < config.initial_online {
            0.0
        } else {
            t_arrive += exp_gap(&mut rng, config.arrival_gap);
            if t_arrive >= config.horizon {
                // A join at/after the horizon never materializes: the
                // device stays offline for the whole run.
                continue;
            }
            events.push(FleetEvent { time: t_arrive, device: d, kind: FleetEventKind::Join });
            t_arrive
        };
        // Alternate bounded uptimes and outages until the horizon.
        loop {
            t += rng.uniform_in(config.uptime.0, config.uptime.1);
            if t >= config.horizon {
                break;
            }
            events.push(FleetEvent { time: t, device: d, kind: FleetEventKind::Leave });
            t += rng.uniform_in(config.outage.0, config.outage.1);
            if t >= config.horizon {
                break;
            }
            events.push(FleetEvent { time: t, device: d, kind: FleetEventKind::Join });
        }
    }
    DeviceFleet::new(speeds, online_at_start, events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> FleetConfig {
        FleetConfig {
            n_devices: 5,
            initial_online: 3,
            arrival_gap: 5.0,
            uptime: (10.0, 30.0),
            outage: (2.0, 8.0),
            horizon: 100.0,
            ..Default::default()
        }
    }

    #[test]
    fn round_robin_classes_cycle() {
        assert_eq!(round_robin_classes(5, 2), vec![0, 1, 0, 1, 0]);
        assert_eq!(round_robin_classes(3, 1), vec![0, 0, 0]);
        assert_eq!(round_robin_classes(0, 4), Vec::<usize>::new());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = fleet_schedule(&small(), 11);
        let b = fleet_schedule(&small(), 11);
        let c = fleet_schedule(&small(), 12);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn base_cohort_online_and_speeds_in_range() {
        let cfg = small();
        let f = fleet_schedule(&cfg, 3);
        assert_eq!(f.n_devices(), cfg.n_devices);
        assert_eq!(f.n_online_at_start(), cfg.initial_online);
        for d in 0..f.n_devices() {
            let s = f.speed(d);
            assert!(s >= cfg.speed_range.0 && s < cfg.speed_range.1, "speed {s} out of range");
        }
    }

    #[test]
    fn events_respect_horizon_and_validate() {
        let cfg = small();
        // A handful of seeds: validation runs inside DeviceFleet::new, so
        // reaching here at all proves alternation/order; check the
        // horizon bound and that churn actually happens.
        let mut any_leave = false;
        for seed in 0..8 {
            let f = fleet_schedule(&cfg, seed);
            for e in f.events() {
                assert!(e.time < cfg.horizon, "event at {} past horizon", e.time);
            }
            any_leave |= f.events().iter().any(|e| e.kind == FleetEventKind::Leave);
        }
        assert!(any_leave, "uptime ≤ 30 against horizon 100 must produce leaves");
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(FleetConfig { n_devices: 0, ..small() }.validate().is_err());
        assert!(FleetConfig { initial_online: 0, ..small() }.validate().is_err());
        assert!(FleetConfig { initial_online: 99, ..small() }.validate().is_err());
        assert!(FleetConfig { speed_range: (0.0, 1.0), ..small() }.validate().is_err());
        assert!(FleetConfig { speed_range: (2.0, 1.0), ..small() }.validate().is_err());
        assert!(FleetConfig { uptime: (5.0, 5.0), ..small() }.validate().is_err());
        assert!(FleetConfig { outage: (-1.0, 5.0), ..small() }.validate().is_err());
        assert!(FleetConfig { horizon: 0.0, ..small() }.validate().is_err());
        assert!(small().validate().is_ok());
    }
}
