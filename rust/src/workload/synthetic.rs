//! The paper's Figure-5 synthetic workload: N users × L models, model
//! performance drawn per user from a zero-mean GP with a Matérn ν = 5/2
//! covariance, shifted upwards to be non-negative.

use crate::kernels::{Kernel, Matern52};
use crate::linalg::{cholesky_jittered, Mat};
use crate::problem::{Problem, Truth};
use crate::prng::Rng;

/// Parameters of the synthetic GP workload.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// Number of users N (paper: 50).
    pub n_users: usize,
    /// Number of models per user (paper: 50).
    pub n_models: usize,
    /// Matérn output variance.
    pub variance: f64,
    /// Matérn lengthscale over the 1-D model embedding.
    pub lengthscale: f64,
    /// Cost range `[lo, hi)` for per-arm runtimes (the paper does not
    /// specify synthetic runtimes; heterogeneous costs keep the EIrate
    /// mechanism active — see DESIGN.md §3).
    pub cost_range: (f64, f64),
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            n_users: 50,
            n_models: 50,
            variance: 1.0,
            lengthscale: 0.8,
            cost_range: (0.5, 2.0),
        }
    }
}

/// Generate the synthetic instance.
///
/// Models are embedded at 1-D positions `m·0.25` and share one Matérn
/// gram matrix `C`; each user's performance vector is an **independent**
/// draw `z_u ~ N(0, C)` ("generate random samples independently for each
/// user"), then the whole table is shifted by its global minimum so all
/// values are non-negative. The scheduler's prior is exactly the
/// generative model: block-diagonal `diag(C, …, C)` with the shift folded
/// into the prior mean — the well-specified case the theory assumes.
pub fn synthetic_gp(config: &SyntheticConfig, seed: u64) -> (Problem, Truth) {
    let n = config.n_users;
    let l = config.n_models;
    let mut rng = Rng::new(seed);
    let pts: Vec<Vec<f64>> = (0..l).map(|m| vec![m as f64 * 0.25]).collect();
    let kern = Matern52 { variance: config.variance, lengthscale: config.lengthscale };
    let c = kern.gram(&pts);
    // pallas-lint: allow(R5) — a Matérn-5/2 gram matrix is PSD by construction and the jitter absorbs roundoff; failure means the kernel implementation broke.
    let (lchol, _) = cholesky_jittered(&c, 1e-10).expect("Matérn gram must be PSD");
    // Independent per-user draws.
    let zero = vec![0.0; l];
    let mut draws: Vec<Vec<f64>> = (0..n).map(|_| rng.mvn(&zero, &lchol)).collect();
    // Shift upwards to be non-negative (paper §6.3).
    let min = draws
        .iter()
        .flat_map(|d| d.iter().copied())
        .fold(f64::INFINITY, f64::min);
    let shift = if min < 0.0 { -min } else { 0.0 };
    for d in draws.iter_mut() {
        for v in d.iter_mut() {
            *v += shift;
        }
    }
    // Arms user-major; block-diagonal prior covariance.
    let n_arms = n * l;
    let mut prior_cov = Mat::zeros(n_arms, n_arms);
    for u in 0..n {
        for i in 0..l {
            for j in 0..l {
                prior_cov[(u * l + i, u * l + j)] = c[(i, j)];
            }
        }
    }
    let prior_mean = vec![shift; n_arms];
    let cost: Vec<f64> =
        (0..n_arms).map(|_| rng.uniform_in(config.cost_range.0, config.cost_range.1)).collect();
    let user_arms: Vec<Vec<usize>> =
        (0..n).map(|u| (0..l).map(|m| u * l + m).collect()).collect();
    let arm_users = Problem::compute_arm_users(n_arms, &user_arms);
    let problem = Problem {
        name: format!("synthetic-{n}x{l}"),
        n_users: n,
        cost,
        user_arms,
        arm_users,
        prior_mean,
        prior_cov,
    };
    let z: Vec<f64> = draws.into_iter().flatten().collect();
    (problem, Truth { z })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_scale() {
        let c = SyntheticConfig::default();
        assert_eq!(c.n_users, 50);
        assert_eq!(c.n_models, 50);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SyntheticConfig { n_users: 4, n_models: 6, ..Default::default() };
        let (_, a) = synthetic_gp(&cfg, 9);
        let (_, b) = synthetic_gp(&cfg, 9);
        let (_, c) = synthetic_gp(&cfg, 10);
        assert_eq!(a.z, b.z);
        assert_ne!(a.z, c.z);
    }

    #[test]
    fn prior_is_block_diagonal() {
        let cfg = SyntheticConfig { n_users: 3, n_models: 4, ..Default::default() };
        let (p, _) = synthetic_gp(&cfg, 1);
        // Cross-user blocks are exactly zero.
        for i in 0..4 {
            for j in 4..8 {
                assert_eq!(p.prior_cov[(i, j)], 0.0);
            }
        }
        // Within-user block is the Matérn gram (same for all users).
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(p.prior_cov[(i, j)], p.prior_cov[(4 + i, 4 + j)]);
            }
        }
    }

    #[test]
    fn nearby_models_correlate() {
        let cfg = SyntheticConfig { n_users: 1, n_models: 10, ..Default::default() };
        let (p, _) = synthetic_gp(&cfg, 3);
        assert!(p.prior_cov[(0, 1)] > p.prior_cov[(0, 5)]);
        assert!(p.prior_cov[(0, 5)] > p.prior_cov[(0, 9)]);
    }

    #[test]
    fn shift_folded_into_prior_mean() {
        let cfg = SyntheticConfig { n_users: 5, n_models: 8, ..Default::default() };
        let (p, t) = synthetic_gp(&cfg, 4);
        // Prior mean equals the applied shift; the minimum sample is 0.
        let min = t.z.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(min.abs() < 1e-12);
        assert!(p.prior_mean.iter().all(|&m| (m - p.prior_mean[0]).abs() < 1e-12));
        assert!(p.prior_mean[0] >= 0.0);
    }

    #[test]
    fn costs_in_configured_range() {
        let cfg = SyntheticConfig {
            n_users: 3,
            n_models: 3,
            cost_range: (2.0, 3.0),
            ..Default::default()
        };
        let (p, _) = synthetic_gp(&cfg, 5);
        for &c in &p.cost {
            assert!((2.0..3.0).contains(&c));
        }
    }
}
