//! Tenant-churn workload: the service-under-traffic scenario.
//!
//! The paper's experiments freeze the tenant cohort; a *service* (the
//! ease.ml regime PAPERS.md describes) sees tenants arrive and depart
//! continuously. This generator produces, from one seed:
//!
//! * a [`Problem`] over the **full tenant universe** (every tenant that
//!   will ever appear), user-major disjoint arm blocks, with a
//!   **shared-prior cross-covariance**: `K[(u,i),(v,j)] = B[u][v]·C[i][j]`
//!   where `C` is a Matérn ν = 5/2 gram over the model embedding and `B`
//!   an exchangeable user-similarity matrix (`B[u][v] = ρ` off-diagonal)
//!   — so observations of one tenant's models transfer to later arrivals;
//! * a [`Truth`] drawn from exactly that prior (Kronecker-factored
//!   sampling: `Z = L_B · G · L_Cᵀ`, `G` i.i.d. standard normal), shifted
//!   non-negative with the shift folded into the prior mean (the paper's
//!   §6.3 convention, keeping the well-specified-prior regime);
//! * a [`ChurnSchedule`]: an initial cohort arriving at t = 0, later
//!   tenants with Poisson-like (exponential-gap) arrivals, bounded
//!   uniform sojourns, and an optional single rejoin per tenant (the
//!   leave-then-rejoin case the parity tests pin).

use crate::kernels::{exchangeable_user_sim, kronecker_arm_cov, Kernel, Matern52};
use crate::linalg::cholesky_jittered;
use crate::problem::{ChurnEvent, ChurnEventKind, ChurnSchedule, Problem, Truth};
use crate::prng::Rng;

/// Parameters of the churn workload.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    /// Total tenants that ever appear (the problem's user universe).
    pub n_users: usize,
    /// Models (arms) per tenant.
    pub n_models: usize,
    /// Cohort already present at t = 0.
    pub initial_users: usize,
    /// Mean gap between later arrivals (exponential, i.e. Poisson-like
    /// arrival process).
    pub arrival_gap: f64,
    /// Sojourn bounds `[lo, hi)`: each tenant stays a uniform draw from
    /// this range (bounded — no tenant lingers forever).
    pub sojourn: (f64, f64),
    /// Probability a departed tenant rejoins once.
    pub rejoin_prob: f64,
    /// Mean away-time before a rejoin (exponential gap).
    pub rejoin_gap: f64,
    /// Cross-tenant prior correlation ρ ∈ [0, 1) (the shared prior that
    /// lets the service warm-start late arrivals).
    pub user_corr: f64,
    /// Matérn output variance.
    pub variance: f64,
    /// Matérn lengthscale over the 1-D model embedding.
    pub lengthscale: f64,
    /// Cost range `[lo, hi)` for per-arm runtimes.
    pub cost_range: (f64, f64),
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            n_users: 24,
            n_models: 8,
            initial_users: 8,
            arrival_gap: 4.0,
            sojourn: (30.0, 90.0),
            rejoin_prob: 0.25,
            rejoin_gap: 10.0,
            user_corr: 0.3,
            variance: 1.0,
            lengthscale: 0.8,
            cost_range: (0.5, 2.0),
        }
    }
}

impl ChurnConfig {
    /// Sanity-check the knob ranges (mirrors `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<(), String> {
        if self.n_users == 0 || self.n_models == 0 {
            return Err("churn: n_users and n_models must be ≥ 1".into());
        }
        if self.initial_users == 0 || self.initial_users > self.n_users {
            return Err(format!(
                "churn: initial_users must be in 1..={}, got {}",
                self.n_users, self.initial_users
            ));
        }
        if !(self.arrival_gap > 0.0) || !(self.rejoin_gap > 0.0) {
            return Err("churn: arrival_gap and rejoin_gap must be positive".into());
        }
        if !(self.sojourn.0 > 0.0) || !(self.sojourn.1 > self.sojourn.0) {
            return Err(format!("churn: sojourn range must satisfy 0 < lo < hi, got {:?}", self.sojourn));
        }
        if !(0.0..=1.0).contains(&self.rejoin_prob) {
            return Err(format!("churn: rejoin_prob must be in [0, 1], got {}", self.rejoin_prob));
        }
        if !(0.0..1.0).contains(&self.user_corr) {
            return Err(format!("churn: user_corr must be in [0, 1), got {}", self.user_corr));
        }
        if !(self.variance > 0.0) || !(self.lengthscale > 0.0) {
            return Err("churn: variance and lengthscale must be positive".into());
        }
        if !(self.cost_range.0 > 0.0) || !(self.cost_range.1 > self.cost_range.0) {
            return Err(format!("churn: cost range must satisfy 0 < lo < hi, got {:?}", self.cost_range));
        }
        Ok(())
    }
}

/// Exponential gap with the given mean (inverse-CDF; the `u = 0` corner
/// is rejected so `ln` stays finite).
fn exp_gap(rng: &mut Rng, mean: f64) -> f64 {
    let mut u = rng.uniform();
    while u <= f64::MIN_POSITIVE {
        u = rng.uniform();
    }
    -mean * u.ln()
}

/// Generate the churn instance: `(problem, truth, schedule)`.
///
/// Deterministic per `(config, seed)`. The problem spans the full tenant
/// universe; the schedule decides who is being *served* when — drivers
/// replay it through `sim::simulate_churn` / `coordinator::serve_churn`.
pub fn churn_workload(config: &ChurnConfig, seed: u64) -> (Problem, Truth, ChurnSchedule) {
    // pallas-lint: allow(R5) — generator precondition: configs are validated TOML or test literals; failing fast at workload build time is the contract.
    config.validate().expect("invalid churn config");
    let n = config.n_users;
    let l = config.n_models;
    let n_arms = n * l;
    let mut rng = Rng::new(seed);

    // Shared prior: B ⊗ C over user-major (u, m) arms.
    let pts: Vec<Vec<f64>> = (0..l).map(|m| vec![m as f64 * 0.25]).collect();
    let kern = Matern52 { variance: config.variance, lengthscale: config.lengthscale };
    let model_cov = kern.gram(&pts);
    let user_sim = exchangeable_user_sim(n, config.user_corr);
    let arms: Vec<(usize, usize)> =
        (0..n).flat_map(|u| (0..l).map(move |m| (u, m))).collect();
    let prior_cov = kronecker_arm_cov(&arms, &user_sim, &model_cov);

    // Truth ~ N(0, B ⊗ C) via the Kronecker factor: Z = L_B · G · L_Cᵀ.
    // (Row-major vec(Z) then has covariance B ⊗ C — one O(n²l + nl²)
    // pass instead of factorizing the nl × nl matrix.)
    // pallas-lint: allow(R5) — both factors are PSD by construction (exchangeable similarity with ρ ∈ [0,1); Matérn gram) and jitter absorbs roundoff.
    let (lb, _) = cholesky_jittered(&user_sim, 1e-10).expect("user similarity must be PSD");
    // pallas-lint: allow(R5) — same argument as the user-similarity factor above.
    let (lc, _) = cholesky_jittered(&model_cov, 1e-10).expect("Matérn gram must be PSD");
    let mut g = vec![0.0; n_arms];
    for slot in g.iter_mut() {
        *slot = rng.normal();
    }
    // H = G · L_Cᵀ  (H[u][j] = Σ_i G[u][i] · L_C[j][i]).
    let mut h = vec![0.0; n_arms];
    for u in 0..n {
        for j in 0..l {
            let mut acc = 0.0;
            for i in 0..=j {
                acc += g[u * l + i] * lc[(j, i)];
            }
            h[u * l + j] = acc;
        }
    }
    // Z = L_B · H  (Z[u][j] = Σ_v L_B[u][v] · H[v][j]).
    let mut z = vec![0.0; n_arms];
    for u in 0..n {
        for j in 0..l {
            let mut acc = 0.0;
            for v in 0..=u {
                acc += lb[(u, v)] * h[v * l + j];
            }
            z[u * l + j] = acc;
        }
    }
    // Shift non-negative, folding the shift into the prior mean (§6.3).
    let min = z.iter().copied().fold(f64::INFINITY, f64::min);
    let shift = if min < 0.0 { -min } else { 0.0 };
    for v in z.iter_mut() {
        *v += shift;
    }
    let prior_mean = vec![shift; n_arms];

    let cost: Vec<f64> =
        (0..n_arms).map(|_| rng.uniform_in(config.cost_range.0, config.cost_range.1)).collect();
    let user_arms: Vec<Vec<usize>> =
        (0..n).map(|u| (0..l).map(|m| u * l + m).collect()).collect();
    let arm_users = Problem::compute_arm_users(n_arms, &user_arms);
    let problem = Problem {
        name: format!("churn-{n}x{l}"),
        n_users: n,
        cost,
        user_arms,
        arm_users,
        prior_mean,
        prior_cov,
    };
    problem.validate();

    // Arrival/departure timeline: initial cohort at t = 0, later tenants
    // with exponential inter-arrival gaps, bounded uniform sojourns, and
    // an optional single rejoin per tenant.
    let mut events = Vec::with_capacity(2 * n);
    let mut t_arrive = 0.0;
    for u in 0..n {
        let arrival = if u < config.initial_users {
            0.0
        } else {
            t_arrive += exp_gap(&mut rng, config.arrival_gap);
            t_arrive
        };
        let sojourn = rng.uniform_in(config.sojourn.0, config.sojourn.1);
        let departure = arrival + sojourn;
        events.push(ChurnEvent { time: arrival, user: u, kind: ChurnEventKind::Arrival });
        events.push(ChurnEvent { time: departure, user: u, kind: ChurnEventKind::Departure });
        if rng.uniform() < config.rejoin_prob {
            let back = departure + exp_gap(&mut rng, config.rejoin_gap).max(1e-6);
            let second = rng.uniform_in(config.sojourn.0, config.sojourn.1);
            events.push(ChurnEvent { time: back, user: u, kind: ChurnEventKind::Arrival });
            events.push(ChurnEvent { time: back + second, user: u, kind: ChurnEventKind::Departure });
        }
    }
    (problem, Truth { z }, ChurnSchedule::new(events))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ChurnConfig {
        ChurnConfig { n_users: 6, n_models: 4, initial_users: 2, ..Default::default() }
    }

    #[test]
    fn deterministic_per_seed() {
        let (pa, ta, sa) = churn_workload(&small(), 11);
        let (pb, tb, sb) = churn_workload(&small(), 11);
        let (_, tc, _) = churn_workload(&small(), 12);
        assert_eq!(ta.z, tb.z);
        assert_eq!(pa.cost, pb.cost);
        assert_eq!(sa, sb);
        assert_ne!(ta.z, tc.z);
    }

    #[test]
    fn prior_has_kronecker_cross_covariance() {
        let cfg = small();
        let (p, _, _) = churn_workload(&cfg, 3);
        let kern = Matern52 { variance: cfg.variance, lengthscale: cfg.lengthscale };
        let pts: Vec<Vec<f64>> = (0..cfg.n_models).map(|m| vec![m as f64 * 0.25]).collect();
        let c = kern.gram(&pts);
        let l = cfg.n_models;
        // Same-user block is the Matérn gram; cross-user blocks are the
        // ρ-scaled gram — the shared prior that transfers knowledge.
        for i in 0..l {
            for j in 0..l {
                assert!((p.prior_cov[(i, j)] - c[(i, j)]).abs() < 1e-12);
                assert!(
                    (p.prior_cov[(i, l + j)] - cfg.user_corr * c[(i, j)]).abs() < 1e-12,
                    "cross-tenant covariance must be ρ·C"
                );
            }
        }
    }

    #[test]
    fn truth_is_shifted_non_negative_with_mean_folded() {
        let (p, t, _) = churn_workload(&small(), 7);
        let min = t.z.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(min.abs() < 1e-12, "global minimum shifts to exactly 0");
        assert!(p.prior_mean.iter().all(|&m| m == p.prior_mean[0] && m >= 0.0));
    }

    #[test]
    fn schedule_covers_every_tenant_with_initial_cohort_at_zero() {
        let cfg = small();
        let (_, _, s) = churn_workload(&cfg, 5);
        let at_zero = s
            .events()
            .iter()
            .filter(|e| e.time == 0.0 && e.kind == ChurnEventKind::Arrival)
            .count();
        assert_eq!(at_zero, cfg.initial_users);
        let mut seen = vec![false; cfg.n_users];
        for e in s.events() {
            seen[e.user] = true;
        }
        assert!(seen.iter().all(|&s| s), "every tenant appears in the timeline");
        // Balanced: equal arrivals and departures per tenant (sojourns
        // are bounded — everyone leaves).
        for u in 0..cfg.n_users {
            let arr = s
                .events()
                .iter()
                .filter(|e| e.user == u && e.kind == ChurnEventKind::Arrival)
                .count();
            let dep = s
                .events()
                .iter()
                .filter(|e| e.user == u && e.kind == ChurnEventKind::Departure)
                .count();
            assert_eq!(arr, dep, "tenant {u} must depart as often as it arrives");
        }
    }

    #[test]
    fn rejoins_appear_with_high_probability_knob() {
        let cfg = ChurnConfig { rejoin_prob: 1.0, ..small() };
        let (_, _, s) = churn_workload(&cfg, 9);
        // Every tenant rejoins once → 4 events each.
        assert_eq!(s.len(), 4 * cfg.n_users);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(ChurnConfig { initial_users: 0, ..small() }.validate().is_err());
        assert!(ChurnConfig { initial_users: 99, ..small() }.validate().is_err());
        assert!(ChurnConfig { user_corr: 1.0, ..small() }.validate().is_err());
        assert!(ChurnConfig { sojourn: (5.0, 5.0), ..small() }.validate().is_err());
        assert!(ChurnConfig { rejoin_prob: 1.5, ..small() }.validate().is_err());
        assert!(small().validate().is_ok());
    }
}
