//! Workload substrates: the paper's three experimental datasets.
//!
//! The paper evaluates on two tables collected by the ease.ml authors —
//! **DeepLearning** (22 image-classification users × 8 CNN architectures)
//! and **Azure** (17 Kaggle users × 8 Azure ML Studio classifiers) — plus
//! a **synthetic** 50-user × 50-model Matérn GP workload (Figure 5). The
//! real tables are not public; per DESIGN.md §3 we substitute seeded
//! generators calibrated to the statistics the paper itself reports and
//! analyzes (per-user accuracy spread σ≈0.04 for DeepLearning vs σ≈0.12
//! for Azure — the quantity the paper uses to explain Figure 2), with
//! heterogeneous runtimes at realistic scale ratios.
//!
//! A [`Dataset`] is the raw table (accuracy + runtime per user×model);
//! [`Dataset::make_problem`] applies the paper's §6.1 protocol — isolate
//! holdout users, estimate the GP prior from their rows, serve the rest.

mod churn;
mod dataset;
mod fault_plan;
mod fleet;
mod generators;
mod synthetic;

pub use churn::{churn_workload, ChurnConfig};
pub use dataset::{Dataset, ProtocolSplit};
pub use fault_plan::{fault_plan, FaultsConfig};
pub use fleet::{fleet_schedule, round_robin_classes, FleetConfig};
pub use generators::{azure, deeplearning, AZURE_MODELS, DEEPLEARNING_MODELS};
pub use synthetic::{synthetic_gp, SyntheticConfig};

use crate::prng::Rng;
use crate::problem::Problem;

/// Noisy runtime estimates `ĉ(x) = c(x)·exp(rel_std·ε)`, ε ~ N(0,1) —
/// the paper's Remark-1 setting where the scheduler only knows an
/// approximate cost model. Log-normal noise keeps estimates positive and
/// is how runtime predictors actually err (multiplicatively).
pub fn noisy_cost_estimates(problem: &Problem, rel_std: f64, rng: &mut Rng) -> Vec<f64> {
    problem.cost.iter().map(|&c| c * (rel_std * rng.normal()).exp()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn deeplearning_matches_paper_statistics() {
        let d = deeplearning();
        assert_eq!(d.n_users(), 22);
        assert_eq!(d.n_models(), 8);
        // Paper §6.2: average per-user accuracy std ≈ 0.04.
        let avg_std = d.mean_per_user_accuracy_std();
        assert!(
            (avg_std - 0.04).abs() < 0.01,
            "DeepLearning per-user σ should be ≈0.04, got {avg_std}"
        );
        // Accuracies are valid probabilities.
        for u in 0..22 {
            for m in 0..8 {
                let a = d.accuracy[(u, m)];
                assert!((0.0..=1.0).contains(&a));
                assert!(d.cost[(u, m)] > 0.0);
            }
        }
    }

    #[test]
    fn azure_matches_paper_statistics() {
        let d = azure();
        assert_eq!(d.n_users(), 17);
        assert_eq!(d.n_models(), 8);
        // Paper §6.2: average per-user accuracy std ≈ 0.12.
        let avg_std = d.mean_per_user_accuracy_std();
        assert!(
            (avg_std - 0.12).abs() < 0.025,
            "Azure per-user σ should be ≈0.12, got {avg_std}"
        );
    }

    #[test]
    fn generators_are_deterministic() {
        let a = deeplearning();
        let b = deeplearning();
        assert_eq!(a.accuracy.as_slice(), b.accuracy.as_slice());
        assert_eq!(a.cost.as_slice(), b.cost.as_slice());
    }

    #[test]
    fn protocol_split_respects_paper() {
        let d = azure();
        let mut rng = Rng::new(5);
        let split = d.protocol_split(&mut rng, 8);
        assert_eq!(split.holdout.len(), 8);
        assert_eq!(split.serve.len(), 9); // 17 − 8
        let mut all: Vec<usize> = split.holdout.iter().chain(&split.serve).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn make_problem_produces_valid_instance() {
        let d = azure();
        let mut rng = Rng::new(11);
        let split = d.protocol_split(&mut rng, 8);
        let (p, t) = d.make_problem(&split);
        p.validate();
        assert_eq!(p.n_users, 9);
        assert_eq!(p.n_arms(), 9 * 8);
        assert_eq!(t.z.len(), p.n_arms());
        // Truth must match the table rows of the served users.
        for (i, &u) in split.serve.iter().enumerate() {
            for m in 0..8 {
                assert_eq!(t.z[i * 8 + m], d.accuracy[(u, m)]);
                assert_eq!(p.cost[i * 8 + m], d.cost[(u, m)]);
            }
        }
    }

    #[test]
    fn prior_is_estimated_from_holdout_only() {
        let d = azure();
        let mut rng = Rng::new(11);
        let split = d.protocol_split(&mut rng, 8);
        let (p, _) = d.make_problem(&split);
        // Prior mean per model = holdout mean, replicated across users.
        for m in 0..8 {
            let want: f64 = split.holdout.iter().map(|&u| d.accuracy[(u, m)]).sum::<f64>()
                / split.holdout.len() as f64;
            assert!((p.prior_mean[m] - want).abs() < 1e-12);
            assert!((p.prior_mean[8 + m] - want).abs() < 1e-12, "replicated per user");
        }
    }

    #[test]
    fn synthetic_shape_and_nonnegativity() {
        let cfg = SyntheticConfig { n_users: 10, n_models: 12, ..Default::default() };
        let (p, t) = synthetic_gp(&cfg, 42);
        p.validate();
        assert_eq!(p.n_users, 10);
        assert_eq!(p.n_arms(), 120);
        // Paper: "Each generated sample is [shifted] upwards in order to
        // be non-negative."
        for &z in &t.z {
            assert!(z >= 0.0, "synthetic samples must be non-negative");
        }
    }

    #[test]
    fn synthetic_users_draw_independent_samples() {
        let cfg = SyntheticConfig { n_users: 2, n_models: 30, ..Default::default() };
        let (_, t) = synthetic_gp(&cfg, 7);
        // Same model set, independent draws → the two users' vectors differ.
        let u0: Vec<f64> = t.z[..30].to_vec();
        let u1: Vec<f64> = t.z[30..].to_vec();
        assert_ne!(u0, u1);
    }
}
