//! The unified discrete-event scheduling engine.
//!
//! Before this module, the event-loop logic was copy-adapted across four
//! files (`sim/mod.rs`, `sim/churn.rs`, `coordinator/mod.rs`,
//! `coordinator/churn.rs`): every new scenario cost two more loop forks.
//! The engine owns the loop **once** — event heap / channel wake-ups
//! (behind the [`Clock`] trait), device states, the tenant mask,
//! warm-start, dispatch, Eq.-2 regret accounting, and horizon clipping —
//! and the four public entry points are thin adapters over [`run`]:
//!
//! ```text
//!                       ┌─────────────────────────┐
//!  sim::simulate ─────► │                         │ ◄──── coordinator::serve
//!  sim::simulate_churn ►│      engine::run        │ ◄──── coordinator::serve_churn
//!  sim::simulate_fleet ►│  (one event loop, one   │
//!                       │   accounting substrate) │
//!                       └───────────┬─────────────┘
//!                 Clock: VirtualClock │ WallClock │ MockClock
//! ```
//!
//! The engine is parameterized over three event streams beyond
//! completions: **tenant churn** ([`Tenancy::Churn`], PR 4's
//! arrival/departure timeline), **device fleet availability**
//! ([`crate::problem::DeviceFleet`] — elastic heterogeneous capacity),
//! and **fault injection** ([`crate::problem::FaultPlan`] — device
//! crashes/restarts, lost jobs, stragglers, with deadline/retry
//! semantics). The merged timed-event order is deterministic:
//! `(time, rank, id)` with rank `DeviceLeave < TenantDeparture <
//! TenantArrival < DeviceJoin < FaultCrash < FaultJobKill <
//! FaultStraggler < FaultRestart` — capacity shrinks first, the cohort
//! turns over, a joining device asks for work against the post-churn
//! arm set, and injected faults land last so they see the scheduled
//! world.
//!
//! **Heterogeneous speeds.** A job on device `d` occupies it for
//! `c(x)/s_d` time units; the *policy* still sees the (estimated) costs
//! of Remark 1 — speed is a property of the device, not the arm.
//! Free-device wake order is (speed desc, index asc); with unit speeds
//! this is the historical ascending-index order, which is what keeps
//! fleet-free runs **byte-identical** to the pre-engine loops (pinned by
//! `rust/tests/engine_parity.rs` and the CI determinism gate).
//!
//! **Preemption.** A device that leaves (or crashes) mid-job cancels
//! the job and requeues the in-flight arm's decision into a FIFO
//! consulted *before* the warm-start queue — the decision was already
//! made, it just never ran. Nothing is revealed: the
//! revealed-on-completion contract holds, a preempted arm is simply
//! unselected again. The [`VirtualClock`] filters the cancelled
//! completion lazily; the [`WallClock`] aborts the worker's wait
//! eagerly (condvar + cancel generation), so the device is free for its
//! next dispatch immediately — either way the completion is never
//! delivered.
//!
//! **Faults.** With a non-empty [`crate::problem::FaultPlan`], jobs can
//! die (`JobFailure` — completion lost, nothing revealed, the arm
//! retried with capped exponential backoff and abandoned after
//! `max_retries`), slow down (`Straggler` — remaining cost stretched),
//! and every dispatch gets the deadline `k × ĉ(x, class_d)/s_d` over the
//! *scheduler-visible* cost estimate; blowing it counts as a failure.
//! An **empty** plan arms none of this machinery — no deadlines, no
//! extra wake-ups — so empty-plan runs are byte-identical to runs with
//! no plan at all (the `fig8_faults` hard gate).
//!
//! **Regret accounting.** Two modes, bit-compatible with the historical
//! loops: the static paper setting integrates the all-user gap sum
//! (scaled to an average by the adapters), tenant churn integrates per
//! user over active windows only, with exact horizon clipping.

mod clock;

pub use clock::{Clock, Completion, MockClock, Step, VirtualClock, WallClock};

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::metrics::StepCurve;
use crate::problem::{
    ArmId, ChurnEventKind, ChurnSchedule, CostModel, DeviceFleet, FaultKind, FaultPlan,
    FleetEventKind, Problem, TenantSet, Truth, UserId,
};
use crate::sched::{DeviceView, Incumbents, Policy, SchedContext};

/// One finished evaluation (driver-side record; the policy learns the
/// same `z` through [`Policy::observe`]).
#[derive(Clone, Debug)]
pub struct Observation {
    /// Which arm.
    pub arm: ArmId,
    /// Dispatch time.
    pub start: f64,
    /// Completion time: `start + c(arm)/s_d` in virtual time, where
    /// `s_d` is the running device's speed (1 for the paper's uniform
    /// fleets, so the historical `start + c(arm)` holds there); on the
    /// wall clock, the measured completion offset.
    pub finish: f64,
    /// Revealed performance.
    pub z: f64,
    /// Device index that ran it.
    pub device: usize,
}

/// A policy factory: how the engine reconstructs a policy for the
/// from-scratch rebuild fallback (churn/fleet events a policy cannot
/// apply in place).
pub type PolicyFactory = dyn Fn(&Problem) -> Box<dyn Policy>;

/// Who owns the tenant timeline.
pub enum Tenancy<'a> {
    /// The paper's static cohort: every user active from t = 0.
    Static,
    /// PR 4's dynamic tenancy: everyone starts inactive and the
    /// validated timeline drives arrivals/departures.
    Churn(&'a ChurnSchedule),
}

/// Everything [`run`] needs beyond the policy and the clock.
pub struct EngineParams<'a> {
    /// Problem instance (true costs — what devices charge).
    pub problem: &'a Problem,
    /// Hidden ground truth, revealed on completion.
    pub truth: &'a Truth,
    /// Scheduler-visible cost view (Remark 1 estimated costs); `None`
    /// means the policy sees the true problem.
    pub sched_view: Option<&'a Problem>,
    /// Per-(arm, device-class) true-cost model the engine charges
    /// devices from; `None` keeps the historical `problem.cost` vector
    /// (equivalently [`crate::problem::UniformCost`], byte-for-byte).
    /// An arm the model declares infeasible on a device's class never
    /// runs there: queue heads are left for a fitting device and a
    /// policy pick that does not fit simply idles the asking device.
    pub cost_model: Option<&'a dyn CostModel>,
    /// The device fleet (speeds + availability schedule). The clock must
    /// have been constructed over `fleet.n_devices()` device slots.
    pub fleet: &'a DeviceFleet,
    /// Static cohort or churn timeline.
    pub tenancy: Tenancy<'a>,
    /// Warm-start arms per user (paper protocol: 2 fastest). 0 disables.
    pub warm_start_per_user: usize,
    /// Report horizon `T` for Eq. 2; defaults to the makespan.
    pub horizon: Option<f64>,
    /// Static mode only: stop once the average instantaneous regret
    /// drops to this cutoff (the Figure-5 hitting-time protocol).
    pub stop_at_cutoff: Option<f64>,
    /// Clock units per cost unit: 1 for virtual time, the coordinator's
    /// `time_scale` (wall seconds per cost unit) for live serving. Job
    /// durations and timed-event deadlines are scaled by it.
    pub time_scale: f64,
    /// Collect the per-decision latency vector (the serve reports'
    /// metric). Virtual-time adapters leave this off — they only need
    /// the decision count and the accumulated wall total, so the
    /// dominant bench-sweep path does not grow a throwaway `Vec`.
    pub collect_decision_latencies: bool,
    /// Deterministic fault injection (crashes/restarts, job failures,
    /// stragglers) plus the deadline/retry semantics jobs run under.
    /// `None` — or an **empty** plan — disables the whole fault layer:
    /// no deadlines are armed and no extra wake-ups occur, so such runs
    /// are byte-identical to the historical fault-free engine.
    pub faults: Option<&'a FaultPlan>,
    /// Print progress lines to stderr (live serving).
    pub verbose: bool,
}

/// The engine's policy handle: either a caller-owned borrow (static
/// entry points — no rebuild possible, none needed) or a factory-owned
/// policy with the observation history needed for the from-scratch
/// rebuild fallback when a churn/fleet hook reports "not applied in
/// place".
pub struct PolicyHost<'a> {
    inner: HostInner<'a>,
    history: Vec<(ArmId, f64)>,
    n_rebuilds: usize,
}

enum HostInner<'a> {
    Borrowed(&'a mut dyn Policy),
    /// `policy` is `None` until the engine initializes it against the
    /// scheduler-visible view — construction is deferred so the initial
    /// policy and every rebuild are *structurally* guaranteed to see
    /// the same (possibly estimated-cost) problem.
    Factory { policy: Option<Box<dyn Policy>>, factory: &'a PolicyFactory },
}

impl<'a> PolicyHost<'a> {
    /// Host a caller-owned policy. Events the policy cannot apply in
    /// place panic (there is no factory to rebuild from) — use
    /// [`PolicyHost::from_factory`] for churn/fleet runs.
    pub fn borrowed(policy: &'a mut dyn Policy) -> Self {
        PolicyHost { inner: HostInner::Borrowed(policy), history: Vec::new(), n_rebuilds: 0 }
    }

    /// Keep `factory` for the initial construction and for rebuilds.
    /// The engine constructs the policy at run start against the
    /// scheduler-visible problem (`EngineParams::sched_view` when set),
    /// so the initial policy and every rebuilt policy are guaranteed to
    /// see the same cost view — the invariant the in-place-vs-rebuild
    /// parity oracle depends on.
    pub fn from_factory(factory: &'a PolicyFactory) -> Self {
        PolicyHost {
            inner: HostInner::Factory { policy: None, factory },
            history: Vec::new(),
            n_rebuilds: 0,
        }
    }

    /// Construct the factory-owned policy against `view` (no-op for a
    /// borrowed policy or if already initialized). Called once by the
    /// engine before any policy interaction.
    fn init(&mut self, view: &Problem) {
        if let HostInner::Factory { policy, factory } = &mut self.inner {
            if policy.is_none() {
                *policy = Some((*factory)(view));
            }
        }
    }

    fn policy_mut(&mut self) -> &mut dyn Policy {
        match &mut self.inner {
            HostInner::Borrowed(p) => &mut **p,
            HostInner::Factory { policy, .. } => {
                // pallas-lint: allow(R5) — `ensure_policy` runs in every engine entry point before this accessor; a None here is an internal ordering bug worth aborting on.
                policy.as_mut().expect("engine initializes the policy before use").as_mut()
            }
        }
    }

    fn policy_ref(&self) -> &dyn Policy {
        match &self.inner {
            HostInner::Borrowed(p) => &**p,
            HostInner::Factory { policy, .. } => {
                // pallas-lint: allow(R5) — same invariant as `policy_mut`: the factory is instantiated before any read.
                policy.as_deref().expect("engine initializes the policy before use")
            }
        }
    }

    /// Feed an observation through the policy, recording it for replay
    /// (factory mode only — a borrowed policy can never be rebuilt).
    fn observe(&mut self, view: &Problem, arm: ArmId, z: f64) {
        self.policy_mut().observe(view, arm, z);
        if matches!(self.inner, HostInner::Factory { .. }) {
            self.history.push((arm, z));
        }
    }

    /// From-scratch rebuild: reconstruct via the factory, replay the
    /// observation history in completion order, then replay the current
    /// tenant set (so churn-capable policies freeze absent tenants).
    /// A fresh policy with an empty history is already "rebuilt", so the
    /// call is a no-op then — the same rule both historical loops
    /// applied, keeping the `rebuilds` KPI comparable.
    ///
    /// `view` is the *scheduler-visible* problem (the Remark-1 estimated
    /// cost view when one is set): the rebuild must construct and replay
    /// against exactly what the live policy saw, or a rebuilt policy's
    /// cost-sensitive state would silently diverge from the in-place
    /// path.
    fn rebuild(&mut self, view: &Problem, tenants: &TenantSet) {
        if self.history.is_empty() {
            return;
        }
        match &mut self.inner {
            HostInner::Factory { policy, factory } => {
                self.n_rebuilds += 1;
                let mut fresh = (*factory)(view);
                for &(a, z) in &self.history {
                    fresh.observe(view, a, z);
                }
                for u in 0..view.n_users {
                    if !tenants.is_active(u) {
                        let _ = fresh.user_left(view, u);
                    }
                }
                *policy = Some(fresh);
            }
            HostInner::Borrowed(_) => panic!(
                "policy cannot apply a churn/fleet event in place and the engine holds a \
                 borrowed policy (no factory to rebuild from) — use a factory-based entry point"
            ),
        }
    }

    fn user_joined(&mut self, view: &Problem, tenants: &TenantSet, user: UserId) {
        if !self.policy_mut().user_joined(view, user) {
            self.rebuild(view, tenants);
        }
    }

    fn user_left(&mut self, view: &Problem, tenants: &TenantSet, user: UserId) {
        if !self.policy_mut().user_left(view, user) {
            self.rebuild(view, tenants);
        }
    }

    fn device_joined(&mut self, view: &Problem, tenants: &TenantSet, device: usize) {
        if !self.policy_mut().device_joined(view, device) {
            self.rebuild(view, tenants);
        }
    }

    fn device_left(&mut self, view: &Problem, tenants: &TenantSet, device: usize) {
        if !self.policy_mut().device_left(view, device) {
            self.rebuild(view, tenants);
        }
    }
}

/// Raw engine output; the `sim`/`coordinator` adapters reshape it into
/// their historical result types.
#[derive(Clone, Debug)]
pub struct EngineRun {
    /// Policy display name (of the final policy — rebuilds keep it).
    pub policy: String,
    /// All completions in completion order (preempted jobs excluded —
    /// they never complete).
    pub observations: Vec<Observation>,
    /// Regret step curve in clock units: the all-user **gap sum** in
    /// static mode (adapters scale to the average), the active-tenant
    /// **average** under churn.
    pub curve: StepCurve,
    /// Eq. 2 at the horizon: the gap-sum integral (static) or the sum of
    /// [`EngineRun::per_user_regret`] (churn).
    pub cumulative_regret: f64,
    /// Per-tenant `∫ gap_u(t) dt` over active windows (churn mode; empty
    /// in static mode).
    pub per_user_regret: Vec<f64>,
    /// Time from a tenant's (first unserved) arrival to the first
    /// dispatch of one of its arms (churn mode; `None` = never served).
    pub join_latency: Vec<Option<f64>>,
    /// Report horizon actually used.
    pub horizon: f64,
    /// Static mode: last completion time (trailing fleet availability
    /// events are not service). Churn mode: last event time (the cohort
    /// timeline is part of the run — the historical convention).
    pub makespan: f64,
    /// Wall-clock latency of every [`Policy::select`] call (empty
    /// unless `EngineParams::collect_decision_latencies` was set).
    pub decision_latencies: Vec<Duration>,
    /// Total wall time inside the policy (`select` + `observe`).
    pub decision_wall_time: Duration,
    /// Number of `select` calls answered.
    pub n_decisions: usize,
    /// Churn/fleet events served through the rebuild fallback.
    pub n_rebuilds: usize,
    /// Jobs cancelled by a device leaving mid-run.
    pub n_preemptions: usize,
    /// Per re-dispatched preempted arm: preemption → re-dispatch delay.
    /// (An arm whose tenant retired before re-dispatch never reappears
    /// here.)
    pub requeue_latency: Vec<f64>,
    /// Fault-injection counters (all zero / empty in fault-free runs).
    pub fault_stats: FaultStats,
}

/// Counters for the fault-injection layer, reported alongside the run.
/// Every field stays at its default in fault-free (and empty-plan) runs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultStats {
    /// Devices dropped offline by an injected crash.
    pub n_crashes: usize,
    /// Crashed devices brought back by an injected restart.
    pub n_restarts: usize,
    /// In-flight jobs killed by an injected [`FaultKind::JobFailure`].
    pub n_job_failures: usize,
    /// In-flight jobs killed for blowing their retry-policy deadline.
    pub n_deadline_kills: usize,
    /// In-flight jobs slowed by an injected straggler event.
    pub n_stragglers: usize,
    /// Retry re-dispatches scheduled (each failed attempt below the
    /// retry cap schedules exactly one).
    pub n_retries: usize,
    /// Arms abandoned after exhausting `max_retries` failed attempts.
    pub n_abandoned: usize,
    /// Per recovered arm (failed at least once, eventually completed):
    /// first failure → eventual completion delay, in completion order.
    pub recovery_latency: Vec<f64>,
}

/// Merged timed-event kinds, in deterministic tie-break order.
#[derive(Clone, Copy, Debug)]
enum TimedKind {
    DeviceLeave(usize),
    TenantDeparture(UserId),
    TenantArrival(UserId),
    DeviceJoin(usize),
    FaultCrash(usize),
    FaultJobKill(usize),
    /// Device index + slowdown factor on the remaining cost.
    FaultStraggler(usize, f64),
    FaultRestart(usize),
}

impl TimedKind {
    fn rank(self) -> u8 {
        match self {
            TimedKind::DeviceLeave(_) => 0,
            TimedKind::TenantDeparture(_) => 1,
            TimedKind::TenantArrival(_) => 2,
            TimedKind::DeviceJoin(_) => 3,
            TimedKind::FaultCrash(_) => 4,
            TimedKind::FaultJobKill(_) => 5,
            TimedKind::FaultStraggler(..) => 6,
            TimedKind::FaultRestart(_) => 7,
        }
    }

    fn id(self) -> usize {
        match self {
            TimedKind::DeviceLeave(d)
            | TimedKind::DeviceJoin(d)
            | TimedKind::FaultCrash(d)
            | TimedKind::FaultJobKill(d)
            | TimedKind::FaultStraggler(d, _)
            | TimedKind::FaultRestart(d) => d,
            TimedKind::TenantDeparture(u) | TimedKind::TenantArrival(u) => u,
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct Timed {
    time: f64,
    kind: TimedKind,
}

/// Per-device engine state.
struct DeviceState {
    speed: f64,
    /// Device class — the row this device reads in the cost model's
    /// `(arm, class)` table (0 for the paper's homogeneous fleets).
    class: usize,
    online: bool,
    /// The in-flight job, if any.
    job: Option<InFlight>,
}

/// Engine-side record of one dispatched, not-yet-completed job.
struct InFlight {
    job: u64,
    arm: ArmId,
    /// Dispatch time (clock units) — the Observation's `start` when a
    /// straggler re-dispatch makes the clock's reconstruction wrong.
    start: f64,
    /// Estimated completion time: dispatch + scaled duration, stretched
    /// by stragglers. Exact in virtual time; on the wall clock the same
    /// arithmetic over the requested sleep.
    finish_est: f64,
    /// Absolute kill time `start + k × ĉ/s_d` (faults enabled only).
    deadline: Option<f64>,
    /// Whether a straggler re-dispatched this job mid-flight.
    slowed: bool,
}

/// Drive one full run of the engine. The clock must have been
/// constructed over `params.fleet.n_devices()` device slots.
///
/// Panics on inconsistent inputs (mismatched truth length, churn over
/// shared arm blocks, a borrowed policy hitting a rebuild) — driver
/// bugs, not runtime conditions.
pub fn run(params: &EngineParams<'_>, host: PolicyHost<'_>, clock: &mut dyn Clock) -> EngineRun {
    Engine::new(params, host, clock).run()
}

struct Engine<'a, 'c> {
    problem: &'a Problem,
    view: &'a Problem,
    truth: &'a Truth,
    cost_model: Option<&'a dyn CostModel>,
    clock: &'c mut dyn Clock,
    host: PolicyHost<'a>,
    static_mode: bool,
    horizon: Option<f64>,
    stop_at_cutoff: Option<f64>,
    time_scale: f64,
    warm_start_per_user: usize,
    verbose: bool,
    collect_decision_latencies: bool,

    devices: Vec<DeviceState>,
    wake_order: Vec<usize>,
    next_job: u64,

    tenants: TenantSet,
    retired: Vec<bool>,
    selected: Vec<bool>,
    /// The mask policies see: `selected ∪ retired`.
    blocked: Vec<bool>,
    observed: Vec<bool>,
    warm: VecDeque<ArmId>,
    requeue: VecDeque<(ArmId, f64)>,

    timed: Vec<Timed>,
    next_timed: usize,

    z_star: Vec<f64>,
    empty_ref: Vec<f64>,
    incumbents: Incumbents,
    curve: StepCurve,
    cumulative: f64,
    per_user_regret: Vec<f64>,
    t_prev: f64,

    arrival_time: Vec<f64>,
    waiting_first_dispatch: Vec<bool>,
    join_latency: Vec<Option<f64>>,

    observations: Vec<Observation>,
    decision_latencies: Vec<Duration>,
    decision_wall: Duration,
    n_decisions: usize,
    n_preemptions: usize,
    requeue_latency: Vec<f64>,
    stopped: bool,

    /// The fault plan, pre-filtered: `None` when the caller passed no
    /// plan *or an empty one*, so every fault-path branch below is
    /// byte-inert exactly when the plan injects nothing.
    faults: Option<&'a FaultPlan>,
    /// Pending retry releases, sorted ascending by `(time, arm)`.
    retry_pending: Vec<(f64, ArmId)>,
    /// Failed attempts per arm (deadline kills + injected job failures).
    attempts: Vec<usize>,
    /// First failure time per arm, cleared on eventual completion (feeds
    /// the recovery-latency KPI).
    first_fault: Vec<Option<f64>>,
    fault_stats: FaultStats,
}

impl<'a, 'c> Engine<'a, 'c> {
    fn new(params: &EngineParams<'a>, mut host: PolicyHost<'a>, clock: &'c mut dyn Clock) -> Self {
        let problem = params.problem;
        let n_arms = problem.n_arms();
        let n_users = problem.n_users;
        assert_eq!(params.truth.z.len(), n_arms, "truth length must match the arm set");
        assert!(params.time_scale > 0.0, "time scale must be positive");
        let view = match params.sched_view {
            Some(v) => {
                assert_eq!(v.n_arms(), n_arms, "cost-estimate view must match the arm set");
                v
            }
            None => problem,
        };
        host.init(view);
        let static_mode = matches!(params.tenancy, Tenancy::Static);
        if let Tenancy::Churn(schedule) = params.tenancy {
            assert!(
                schedule.n_users_seen() <= n_users,
                "schedule references user {} but the problem has {} users",
                schedule.n_users_seen().saturating_sub(1),
                n_users
            );
            assert_disjoint_tenancy(problem);
        }

        // Merged deterministic timed-event timeline.
        let mut timed: Vec<Timed> = Vec::new();
        if let Tenancy::Churn(schedule) = params.tenancy {
            for e in schedule.events() {
                let kind = match e.kind {
                    ChurnEventKind::Arrival => TimedKind::TenantArrival(e.user),
                    ChurnEventKind::Departure => TimedKind::TenantDeparture(e.user),
                };
                timed.push(Timed { time: e.time, kind });
            }
        }
        for e in params.fleet.events() {
            let kind = match e.kind {
                FleetEventKind::Join => TimedKind::DeviceJoin(e.device),
                FleetEventKind::Leave => TimedKind::DeviceLeave(e.device),
            };
            timed.push(Timed { time: e.time, kind });
        }
        // An empty plan must be indistinguishable from no plan at all
        // (the byte-identity gate), so filter it out up front.
        let faults = params.faults.filter(|plan| !plan.is_empty());
        if let Some(plan) = faults {
            for e in plan.events() {
                assert!(
                    e.device < params.fleet.n_devices(),
                    "fault plan references out-of-range device {}",
                    e.device
                );
                let kind = match e.kind {
                    FaultKind::DeviceCrash => TimedKind::FaultCrash(e.device),
                    FaultKind::JobFailure => TimedKind::FaultJobKill(e.device),
                    FaultKind::Straggler(f) => TimedKind::FaultStraggler(e.device, f),
                    FaultKind::DeviceRestart => TimedKind::FaultRestart(e.device),
                };
                timed.push(Timed { time: e.time, kind });
            }
        }
        timed.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then_with(|| a.kind.rank().cmp(&b.kind.rank()))
                .then_with(|| a.kind.id().cmp(&b.kind.id()))
        });

        let tenants =
            if static_mode { TenantSet::all_active(n_users) } else { TenantSet::none_active(n_users) };
        let retired = vec![!static_mode; n_arms];
        let blocked = retired.clone();
        let warm: VecDeque<ArmId> = if static_mode {
            problem.warm_start_arms(params.warm_start_per_user).into()
        } else {
            VecDeque::new()
        };

        let devices: Vec<DeviceState> = (0..params.fleet.n_devices())
            .map(|d| DeviceState {
                speed: params.fleet.speed(d),
                class: params.fleet.class(d),
                online: params.fleet.online_at_start(d),
                job: None,
            })
            .collect();
        if let Some(model) = params.cost_model {
            for d in &devices {
                assert!(
                    d.class < model.n_classes(),
                    "fleet assigns device class {} but the cost model has {} classes",
                    d.class,
                    model.n_classes()
                );
            }
        }

        // Per-user optimum and the accuracy-zero empty reference floored
        // at the user's worst arm — the Option-based incumbent
        // accounting shared by every adapter (see `sched::Incumbents`).
        let z_star: Vec<f64> =
            (0..n_users).map(|u| params.truth.best_value(problem, u)).collect();
        let empty_ref: Vec<f64> = (0..n_users)
            .map(|u| {
                problem.user_arms[u].iter().map(|&a| params.truth.z[a]).fold(0.0f64, f64::min)
            })
            .collect();
        let incumbents = Incumbents::new(n_users);

        let mut engine = Engine {
            problem,
            view,
            truth: params.truth,
            cost_model: params.cost_model,
            clock,
            host,
            static_mode,
            horizon: params.horizon,
            stop_at_cutoff: if static_mode { params.stop_at_cutoff } else { None },
            time_scale: params.time_scale,
            warm_start_per_user: params.warm_start_per_user,
            verbose: params.verbose,
            collect_decision_latencies: params.collect_decision_latencies,
            devices,
            wake_order: params.fleet.wake_order(),
            next_job: 0,
            tenants,
            retired,
            selected: vec![false; n_arms],
            blocked,
            observed: vec![false; n_arms],
            warm,
            requeue: VecDeque::new(),
            timed,
            next_timed: 0,
            z_star,
            empty_ref,
            incumbents,
            curve: StepCurve::new(0.0),
            cumulative: 0.0,
            per_user_regret: vec![0.0; n_users],
            t_prev: 0.0,
            arrival_time: vec![0.0; n_users],
            waiting_first_dispatch: vec![false; n_users],
            join_latency: vec![None; n_users],
            observations: Vec::with_capacity(n_arms),
            decision_latencies: Vec::new(),
            decision_wall: Duration::ZERO,
            n_decisions: 0,
            n_preemptions: 0,
            requeue_latency: Vec::new(),
            stopped: false,
            faults,
            retry_pending: Vec::new(),
            attempts: vec![0; n_arms],
            first_fault: vec![None; n_arms],
            fault_stats: FaultStats::default(),
        };
        if engine.static_mode {
            // Historical static curve: starts at the empty-incumbent gap
            // sum (all users active from t = 0).
            engine.curve = StepCurve::new(engine.gap_sum());
        }
        engine
    }

    /// All-user gap sum `Σ_u (z* − incumbent)⁺` — the static-mode regret
    /// integrand (float order identical to the pre-engine loop).
    fn gap_sum(&self) -> f64 {
        let incumbents = &self.incumbents;
        self.z_star
            .iter()
            .zip(&self.empty_ref)
            .enumerate()
            .map(|(u, (&s, &e))| {
                let b = if incumbents.has_observation(u) { incumbents.value(u) } else { e };
                (s - b).max(0.0)
            })
            .sum()
    }

    /// One tenant's current gap.
    fn user_gap(&self, u: UserId) -> f64 {
        let b = if self.incumbents.has_observation(u) {
            self.incumbents.value(u)
        } else {
            self.empty_ref[u]
        };
        (self.z_star[u] - b).max(0.0)
    }

    /// Average gap over the currently active tenants (0 when none) — the
    /// churn-mode curve value.
    fn avg_active_gap(&self) -> f64 {
        if self.tenants.n_active() == 0 {
            0.0
        } else {
            self.tenants.active_users().map(|u| self.user_gap(u)).sum::<f64>()
                / self.tenants.n_active() as f64
        }
    }

    /// Integrate regret over `[t_prev, now)` and advance `t_prev`.
    /// Static mode: the gap-sum integral, unclipped during the loop (the
    /// horizon is applied at the end, exactly like the historical
    /// simulator). Churn mode: per tenant over active windows, clipped
    /// at the horizon.
    fn integrate_to(&mut self, now: f64) {
        if self.static_mode {
            self.cumulative += self.gap_sum() * (now - self.t_prev);
        } else {
            let (lo, hi) = match self.horizon {
                Some(h) => (self.t_prev.min(h), now.min(h)),
                None => (self.t_prev, now),
            };
            let dt = (hi - lo).max(0.0);
            if dt > 0.0 {
                for u in 0..self.problem.n_users {
                    if self.tenants.is_active(u) {
                        self.per_user_regret[u] += self.user_gap(u) * dt;
                    }
                }
            }
        }
        self.t_prev = now;
    }

    /// Push the mode-appropriate curve value at `now`.
    fn push_curve(&mut self, now: f64) {
        let v = if self.static_mode { self.gap_sum() } else { self.avg_active_gap() };
        self.curve.push(now, v);
    }

    /// True execution cost of `arm` on a device of `class`: the cost
    /// model's `(arm, class)` entry when one is set (`None` =
    /// infeasible there), else the problem's historical cost vector
    /// (always feasible).
    fn true_cost(&self, arm: ArmId, class: usize) -> Option<f64> {
        match self.cost_model {
            Some(m) => m.cost(arm, class),
            None => Some(self.problem.cost[arm]),
        }
    }

    /// *Scheduler-visible* cost estimate `ĉ(arm, class)` — the Remark-1
    /// split the retry deadline is computed from: the estimated base
    /// cost (`sched_view` when set), scaled by the cost model's
    /// class multiplier when one is in force. Falls back to the base
    /// estimate if the model calls the pair infeasible (the dispatch
    /// path has already ruled that out).
    fn est_cost(&self, arm: ArmId, class: usize) -> f64 {
        let base = self.view.cost[arm];
        match self.cost_model {
            Some(m) => match m.cost(arm, class) {
                Some(c) => c * (base / self.problem.cost[arm]),
                None => base,
            },
            None => base,
        }
    }

    /// Ask `device` for work at `now`: requeued preempted decisions
    /// first, then the warm-start queue, then the policy. A device with
    /// no candidate parks (idle devices are re-asked after every timed
    /// tick; in the static paper setting no tick ever comes, so an
    /// exhausted device simply retires — the historical behavior).
    ///
    /// A queue head infeasible on this device's class is *left in
    /// place* for a device that fits it — only blocked (retired) heads
    /// are dropped — and the asker falls through to the next source.
    fn dispatch_device(&mut self, device: usize, now: f64) {
        let problem = self.problem;
        let class = self.devices[device].class;
        while let Some(&(a, _)) = self.requeue.front() {
            if self.blocked[a] {
                self.requeue.pop_front();
            } else {
                break;
            }
        }
        let mut requeued_at = None;
        let mut arm = None;
        if let Some(&(a, t_pre)) = self.requeue.front() {
            if self.true_cost(a, class).is_some() {
                self.requeue.pop_front();
                requeued_at = Some(t_pre);
                arm = Some(a);
            }
        }
        if arm.is_none() {
            while let Some(&a) = self.warm.front() {
                if self.blocked[a] {
                    self.warm.pop_front();
                } else {
                    break;
                }
            }
            if let Some(&a) = self.warm.front() {
                if self.true_cost(a, class).is_some() {
                    self.warm.pop_front();
                    arm = Some(a);
                }
            }
        }
        if arm.is_none() {
            let ctx = SchedContext {
                problem: self.view,
                selected: &self.blocked,
                observed: &self.observed,
                now,
                device: DeviceView { id: device, speed: self.devices[device].speed, class },
            };
            // pallas-lint: allow(R3) — measures decision latency for the ns/decision KPI; the reading never feeds scheduling or virtual time.
            let t0 = Instant::now();
            let pick = self.host.policy_mut().select(&ctx);
            let dt = t0.elapsed();
            if self.collect_decision_latencies {
                self.decision_latencies.push(dt);
            }
            self.n_decisions += 1;
            self.decision_wall += dt;
            arm = pick;
        }
        if let Some(a) = arm {
            assert!(!self.blocked[a], "policy returned a blocked (selected/retired) arm {a}");
            let Some(true_c) = self.true_cost(a, class) else {
                // A device-blind policy picked an arm infeasible on this
                // device's class. Don't dispatch — the arm stays
                // unselected for a device that fits it and this device
                // idles until the next event re-asks it.
                return;
            };
            self.selected[a] = true;
            self.blocked[a] = true;
            if let Some(t_pre) = requeued_at {
                self.requeue_latency.push(now - t_pre);
            }
            for &u in &problem.arm_users[a] {
                if self.waiting_first_dispatch[u] {
                    self.waiting_first_dispatch[u] = false;
                    self.join_latency[u] = Some(now - self.arrival_time[u]);
                }
            }
            self.next_job += 1;
            let job = self.next_job;
            let dur = (true_c / self.devices[device].speed) * self.time_scale;
            // Faults armed → every job gets the deadline
            // `k × ĉ(x, class_d)/s_d` over the scheduler-visible
            // estimate. Fault-free, `deadline` stays `None` and no
            // deadline machinery ever wakes the loop.
            let deadline = self.faults.map(|plan| {
                let est = self.est_cost(a, self.devices[device].class);
                now + plan.retry().deadline_factor * (est / self.devices[device].speed)
                    * self.time_scale
            });
            self.devices[device].job = Some(InFlight {
                job,
                arm: a,
                start: now,
                finish_est: now + dur,
                deadline,
                slowed: false,
            });
            self.clock.dispatch(device, a, dur, job);
        }
    }

    /// Ask every idle online device for work, in fleet wake order
    /// (speed desc, index asc).
    fn wake_idle(&mut self, now: f64) {
        // Temporarily take the order out so the loop can borrow `self`
        // mutably per dispatch.
        let order = std::mem::take(&mut self.wake_order);
        for &d in &order {
            if self.devices[d].online && self.devices[d].job.is_none() {
                self.dispatch_device(d, now);
            }
        }
        self.wake_order = order;
    }

    /// Apply every timed event whose (scaled) deadline is ≤ `now`, in
    /// the merged deterministic order.
    fn drain_due_events(&mut self, now: f64) {
        let problem = self.problem;
        let view = self.view;
        while self.next_timed < self.timed.len()
            && self.timed[self.next_timed].time * self.time_scale <= now
        {
            let ev = self.timed[self.next_timed];
            self.next_timed += 1;
            match ev.kind {
                TimedKind::TenantArrival(u) => {
                    if !self.tenants.activate(u) {
                        continue;
                    }
                    self.host.user_joined(view, &self.tenants, u);
                    self.tenants.refresh_retired_for_user(problem, u, &mut self.retired);
                    for &x in &problem.user_arms[u] {
                        self.blocked[x] = self.selected[x] || self.retired[x];
                    }
                    enqueue_warm_arms(
                        problem,
                        u,
                        self.warm_start_per_user,
                        &self.selected,
                        &mut self.warm,
                    );
                    if self.join_latency[u].is_none() {
                        self.arrival_time[u] = now;
                        self.waiting_first_dispatch[u] = true;
                    }
                    if self.verbose {
                        eprintln!("[{now:8.3}s] tenant {u} joined");
                    }
                }
                TimedKind::TenantDeparture(u) => {
                    if !self.tenants.deactivate(u) {
                        continue;
                    }
                    self.host.user_left(view, &self.tenants, u);
                    self.tenants.refresh_retired_for_user(problem, u, &mut self.retired);
                    for &x in &problem.user_arms[u] {
                        self.blocked[x] = self.selected[x] || self.retired[x];
                    }
                    self.waiting_first_dispatch[u] = false;
                    if self.verbose {
                        eprintln!("[{now:8.3}s] tenant {u} left");
                    }
                }
                TimedKind::DeviceJoin(d) | TimedKind::FaultRestart(d) => {
                    // A fleet schedule alone never double-joins (it is
                    // validated), but a fault plan's crash/restart cycle
                    // can overlap it — state transitions are idempotent,
                    // so an already-online device simply skips the event.
                    if self.devices[d].online {
                        continue;
                    }
                    self.devices[d].online = true;
                    if matches!(ev.kind, TimedKind::FaultRestart(_)) {
                        self.fault_stats.n_restarts += 1;
                    }
                    self.host.device_joined(view, &self.tenants, d);
                    if self.verbose {
                        eprintln!("[{now:8.3}s] device {d} joined (speed {})", self.devices[d].speed);
                    }
                }
                TimedKind::DeviceLeave(d) | TimedKind::FaultCrash(d) => {
                    // Same idempotence as joins: a crash landing on a
                    // device the fleet schedule already took offline (or
                    // vice versa) is a no-op, not a validation failure.
                    if !self.devices[d].online {
                        continue;
                    }
                    self.devices[d].online = false;
                    if matches!(ev.kind, TimedKind::FaultCrash(_)) {
                        self.fault_stats.n_crashes += 1;
                    }
                    if let Some(inflight) = self.devices[d].job.take() {
                        // Preemption: cancel the job (nothing is
                        // revealed) and requeue the arm's decision.
                        let (job, arm) = (inflight.job, inflight.arm);
                        self.clock.cancel(d, job);
                        self.selected[arm] = false;
                        self.blocked[arm] = self.retired[arm];
                        self.requeue.push_back((arm, now));
                        self.n_preemptions += 1;
                        if self.verbose {
                            eprintln!("[{now:8.3}s] device {d} left; arm {arm} preempted");
                        }
                    } else if self.verbose {
                        eprintln!("[{now:8.3}s] device {d} left");
                    }
                    self.host.device_left(view, &self.tenants, d);
                }
                TimedKind::FaultJobKill(d) => {
                    // The in-flight job dies: completion lost, nothing
                    // revealed, the arm enters the retry path. Hitting
                    // an idle (or offline) device is a no-op.
                    if let Some(inflight) = self.devices[d].job.take() {
                        self.clock.cancel(d, inflight.job);
                        self.fault_stats.n_job_failures += 1;
                        self.fail_job(inflight.arm, now);
                        if self.verbose {
                            eprintln!("[{now:8.3}s] job on device {d} failed (arm {})", inflight.arm);
                        }
                    }
                }
                TimedKind::FaultStraggler(d, factor) => {
                    // The in-flight job slows down: cancel it and
                    // re-dispatch the *remaining* cost stretched by the
                    // factor, under a fresh job id. The original start
                    // and deadline are kept — a straggler can still blow
                    // its deadline later.
                    if let Some(mut inflight) = self.devices[d].job.take() {
                        self.clock.cancel(d, inflight.job);
                        let remaining = (inflight.finish_est - now).max(0.0) * factor;
                        self.next_job += 1;
                        inflight.job = self.next_job;
                        inflight.finish_est = now + remaining;
                        inflight.slowed = true;
                        let (job, arm) = (inflight.job, inflight.arm);
                        self.devices[d].job = Some(inflight);
                        self.clock.dispatch(d, arm, remaining, job);
                        self.fault_stats.n_stragglers += 1;
                        if self.verbose {
                            eprintln!("[{now:8.3}s] arm {arm} on device {d} straggling ({factor}×)");
                        }
                    }
                }
            }
        }
    }

    /// One failed attempt of `arm` at `now` (injected job failure or a
    /// blown deadline): nothing is revealed; the arm stays blocked while
    /// it backs off and is released into the requeue FIFO after
    /// `min(base × 2^attempt, cap)` scaled clock units — or abandoned
    /// for the rest of the run once `max_retries` attempts failed (its
    /// user's regret keeps integrating; the service degrades instead of
    /// spinning).
    fn fail_job(&mut self, arm: ArmId, now: f64) {
        // pallas-lint: allow(R5) — `fail_job` is only reachable from fault handlers, which the empty-filtered plan gates.
        let retry = self.faults.expect("fault machinery runs only with a non-empty plan").retry();
        if self.first_fault[arm].is_none() {
            self.first_fault[arm] = Some(now);
        }
        let attempt = self.attempts[arm];
        self.attempts[arm] += 1;
        if attempt < retry.max_retries {
            let release = now + retry.backoff(attempt) * self.time_scale;
            let pos = self.retry_pending.partition_point(|&(t, a)| {
                t.total_cmp(&release).is_lt() || (t.total_cmp(&release).is_eq() && a < arm)
            });
            self.retry_pending.insert(pos, (release, arm));
            self.fault_stats.n_retries += 1;
        } else {
            // Abandoned: the arm stays selected/blocked forever.
            self.fault_stats.n_abandoned += 1;
            if self.verbose {
                eprintln!("arm {arm} abandoned after {} failed attempts", self.attempts[arm]);
            }
        }
    }

    /// Kill every in-flight job whose deadline is due at `now` (ascending
    /// device order — deterministic), then hand the freed device its next
    /// job. Only meaningful with faults armed; fault-free runs never set
    /// a deadline.
    fn apply_due_deadline_kills(&mut self, now: f64) {
        for d in 0..self.devices.len() {
            let due = match &self.devices[d].job {
                Some(j) => matches!(j.deadline, Some(t) if t <= now) && j.finish_est > now,
                None => false,
            };
            if !due {
                continue;
            }
            if let Some(inflight) = self.devices[d].job.take() {
                self.clock.cancel(d, inflight.job);
                self.fault_stats.n_deadline_kills += 1;
                if self.verbose {
                    eprintln!("[{now:8.3}s] arm {} blew its deadline on device {d}", inflight.arm);
                }
                self.fail_job(inflight.arm, now);
                if self.devices[d].online {
                    self.dispatch_device(d, now);
                }
            }
        }
    }

    /// Unblock every backed-off arm whose release time is due at `now`,
    /// in `(release, arm)` order, into the requeue FIFO (ahead of the
    /// warm-start queue — the decision was already made once).
    fn release_due_retries(&mut self, now: f64) {
        while let Some(&(t, arm)) = self.retry_pending.first() {
            if t > now {
                break;
            }
            self.retry_pending.remove(0);
            self.selected[arm] = false;
            self.blocked[arm] = self.retired[arm];
            self.requeue.push_back((arm, now));
        }
    }

    /// One completed job: integrate regret, reveal `z`, feed the policy
    /// and incumbents, push the curve, check the cutoff.
    fn handle_completion(&mut self, c: Completion) {
        let problem = self.problem;
        let now = c.finish;
        let in_flight = self.devices[c.device].job.take();
        // A straggler re-dispatch covered only the *remaining* cost, so
        // the clock's start is the re-dispatch instant — report the
        // engine-recorded original dispatch time instead. Fault-free,
        // `slowed` is never set and the historical clock-side start is
        // used untouched (byte identity).
        let start = match &in_flight {
            Some(j) if j.slowed => j.start,
            _ => c.start,
        };
        if let Some(t0) = self.first_fault[c.arm].take() {
            self.fault_stats.recovery_latency.push(now - t0);
        }
        let z = self.truth.z[c.arm];
        self.observed[c.arm] = true;
        // pallas-lint: allow(R3) — measures observe latency for the decision-wall KPI; never read by scheduling or virtual time.
        let t0 = Instant::now();
        self.host.observe(self.view, c.arm, z);
        self.decision_wall += t0.elapsed();
        self.observations.push(Observation {
            arm: c.arm,
            start,
            finish: now,
            z,
            device: c.device,
        });
        self.incumbents.update_arm(problem, c.arm, z);
        self.push_curve(now);
        if self.verbose {
            let avg = if self.static_mode {
                self.gap_sum() / problem.n_users as f64
            } else {
                self.avg_active_gap()
            };
            eprintln!(
                "[{now:8.3}s] device {} finished arm {} (z = {z:.4}); avg regret {avg:.4}",
                c.device, c.arm
            );
        }
        if let Some(cut) = self.stop_at_cutoff {
            if self.gap_sum() / problem.n_users as f64 <= cut {
                self.stopped = true;
            }
        }
    }

    /// Next TimedDue wake-up deadline for the clock: the next merged
    /// timed event, plus — with faults armed — any in-flight job's kill
    /// deadline that will actually fire (strictly before the job's own
    /// estimated completion) and the earliest pending retry release.
    /// Fault-free (or empty-plan), this is exactly the historical
    /// next-timed-event deadline: zero extra wake-ups, byte identity.
    fn next_wakeup(&self) -> Option<f64> {
        let mut dl = self.timed.get(self.next_timed).map(|e| e.time * self.time_scale);
        if self.faults.is_some() {
            let mut fold = |t: f64| {
                dl = Some(match dl {
                    Some(x) if x <= t => x,
                    _ => t,
                });
            };
            for d in &self.devices {
                if let Some(j) = &d.job {
                    if let Some(t) = j.deadline {
                        if t < j.finish_est {
                            fold(t);
                        }
                    }
                }
            }
            if let Some(&(t, _)) = self.retry_pending.first() {
                fold(t);
            }
        }
        dl
    }

    fn run(mut self) -> EngineRun {
        // t = 0: churn mode starts with everyone inactive (a fresh
        // policy with an empty history is already "rebuilt", so
        // unsupported hooks are simply ignored here).
        if !self.static_mode {
            for u in 0..self.problem.n_users {
                let _ = self.host.policy_mut().user_left(self.view, u);
            }
        }
        // Apply due t = 0 events (initial cohort, t = 0 fleet changes),
        // seed the curve, then every online device asks for work. The
        // pre-drain integration is a no-op in virtual time (now0 = 0)
        // and advances `t_prev` past the startup jitter on the wall
        // clock, matching the historical serve loop.
        let now0 = self.clock.now();
        self.integrate_to(now0);
        self.drain_due_events(now0);
        if !self.static_mode {
            self.push_curve(now0);
        }
        self.wake_idle(now0);

        // Main event loop: next event is the earliest of the next timed
        // deadline, the next job-kill deadline / retry release (faults
        // armed only), and the next completion; timed events apply first
        // on ties.
        loop {
            let deadline = self.next_wakeup();
            match self.clock.next_event(deadline) {
                Step::Exhausted => break,
                Step::TimedDue(now) => {
                    self.integrate_to(now);
                    self.drain_due_events(now);
                    if self.faults.is_some() {
                        self.apply_due_deadline_kills(now);
                        self.release_due_retries(now);
                    }
                    if !self.static_mode {
                        self.push_curve(now);
                    }
                    self.wake_idle(now);
                }
                Step::Completed(c) => {
                    let device = c.device;
                    let now = c.finish;
                    self.integrate_to(now);
                    self.handle_completion(c);
                    if self.stopped {
                        break;
                    }
                    if self.devices[device].online {
                        self.dispatch_device(device, now);
                    }
                }
            }
        }
        self.finish()
    }

    fn finish(mut self) -> EngineRun {
        // Static mode reports the last *completion* time (trailing fleet
        // availability events after the work is done are not service —
        // and for a unit fleet there are none, so this is exactly the
        // historical `t_prev`). Churn mode keeps the historical
        // last-event convention: the cohort timeline is part of the run.
        let makespan = if self.static_mode {
            self.observations.last().map(|o| o.finish).unwrap_or(0.0)
        } else {
            self.t_prev
        };
        let horizon = self.horizon.unwrap_or(makespan);
        if self.static_mode {
            if horizon > self.t_prev {
                // Extend the integral to the horizon with the final gap.
                self.cumulative += self.gap_sum() * (horizon - self.t_prev);
            }
            if horizon < self.t_prev {
                // Re-integrate exactly over [0, horizon] from the curve
                // and truncate the curve itself, so the report KPIs and
                // the plotted series agree with the truncated integral.
                self.cumulative = self.curve.integral_to(horizon);
                let truncated = self.curve.truncated(horizon);
                self.curve = truncated;
            }
        } else {
            if horizon > makespan {
                // Extend each still-active tenant's window with its
                // final gap.
                for u in 0..self.problem.n_users {
                    if self.tenants.is_active(u) {
                        self.per_user_regret[u] += self.user_gap(u) * (horizon - makespan);
                    }
                }
            }
            if horizon < makespan {
                let truncated = self.curve.truncated(horizon);
                self.curve = truncated;
            }
            self.cumulative = self.per_user_regret.iter().sum();
        }
        let n_decisions = self.n_decisions;
        EngineRun {
            policy: self.host.policy_ref().name(),
            observations: self.observations,
            curve: self.curve,
            cumulative_regret: self.cumulative,
            per_user_regret: if self.static_mode { Vec::new() } else { self.per_user_regret },
            join_latency: self.join_latency,
            horizon,
            makespan,
            decision_latencies: self.decision_latencies,
            decision_wall_time: self.decision_wall,
            n_decisions,
            n_rebuilds: self.host.n_rebuilds,
            n_preemptions: self.n_preemptions,
            requeue_latency: self.requeue_latency,
            fault_stats: self.fault_stats,
        }
    }
}

/// Churn requires **disjoint per-tenant arm blocks**: an arm shared by
/// tenants that churn independently has no well-defined incremental
/// semantics (the departed owner's dropped incumbent would still price
/// the arm for the remaining owner, diverging from the rebuild oracle).
/// The engine fails loudly instead of silently diverging.
fn assert_disjoint_tenancy(problem: &Problem) {
    for (x, owners) in problem.arm_users.iter().enumerate() {
        assert!(
            owners.len() == 1,
            "churn requires disjoint per-tenant arm blocks; arm {x} is shared by users {owners:?}"
        );
    }
}

/// Enqueue `per_user` cheapest not-yet-run arms of `user` (ties broken
/// by arm id — the same order `Problem::warm_start_arms` uses), the
/// paper's warm-start protocol applied at each arrival.
fn enqueue_warm_arms(
    problem: &Problem,
    user: UserId,
    per_user: usize,
    selected: &[bool],
    warm: &mut VecDeque<ArmId>,
) {
    if per_user == 0 {
        return;
    }
    let mut arms: Vec<ArmId> =
        problem.user_arms[user].iter().copied().filter(|&a| !selected[a]).collect();
    arms.sort_by(|&a, &b| problem.cost[a].total_cmp(&problem.cost[b]).then(a.cmp(&b)));
    for &a in arms.iter().take(per_user) {
        warm.push_back(a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::problem::{FaultEvent, FleetEvent, RetryPolicy};
    use crate::sched::MmGpEi;

    fn problem_and_truth() -> (Problem, Truth) {
        let user_arms = vec![vec![0, 1, 2], vec![3, 4, 5]];
        let arm_users = Problem::compute_arm_users(6, &user_arms);
        let p = Problem {
            name: "engine".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 6],
            prior_cov: Mat::eye(6),
        };
        let t = Truth { z: vec![0.3, 0.9, 0.5, 0.7, 0.2, 0.8] };
        (p, t)
    }

    fn static_params<'a>(p: &'a Problem, t: &'a Truth, fleet: &'a DeviceFleet) -> EngineParams<'a> {
        EngineParams {
            problem: p,
            truth: t,
            sched_view: None,
            cost_model: None,
            fleet,
            tenancy: Tenancy::Static,
            warm_start_per_user: 2,
            horizon: None,
            stop_at_cutoff: None,
            time_scale: 1.0,
            collect_decision_latencies: false,
            faults: None,
            verbose: false,
        }
    }

    #[test]
    fn static_unit_fleet_serves_every_arm() {
        let (p, t) = problem_and_truth();
        let fleet = DeviceFleet::uniform(2);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let mut clock = VirtualClock::new(2);
        let run = run(
            &static_params(&p, &t, &fleet),
            PolicyHost::from_factory(&factory),
            &mut clock,
        );
        assert_eq!(run.observations.len(), 6);
        assert_eq!(run.n_preemptions, 0);
        assert_eq!(run.n_rebuilds, 0);
        assert_eq!(run.curve.final_value(), 0.0);
    }

    #[test]
    fn speeds_scale_completion_times() {
        let (p, t) = problem_and_truth();
        // One double-speed device: every job takes c/2, sequentially.
        let fleet = DeviceFleet::new(vec![2.0], vec![true], Vec::new());
        let mut pol = MmGpEi::new(&p);
        let mut clock = VirtualClock::new(1);
        let run = run(&static_params(&p, &t, &fleet), PolicyHost::borrowed(&mut pol), &mut clock);
        for o in &run.observations {
            assert!((o.finish - o.start - p.cost[o.arm] / 2.0).abs() < 1e-12);
        }
        let total: f64 = p.cost.iter().sum();
        assert!((run.makespan - total / 2.0).abs() < 1e-9);
    }

    #[test]
    fn preemption_requeues_without_revealing() {
        let (p, t) = problem_and_truth();
        // Device 0 leaves at t = 0.5 mid-job and device 1 joins at the
        // same instant: the preempted arm is requeued and re-dispatched;
        // every arm is still revealed exactly once, on completion.
        let fleet = DeviceFleet::new(
            vec![1.0, 1.0],
            vec![true, false],
            vec![
                FleetEvent { time: 0.5, device: 0, kind: FleetEventKind::Leave },
                FleetEvent { time: 0.5, device: 1, kind: FleetEventKind::Join },
            ],
        );
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let mut clock = VirtualClock::new(2);
        let run = run(
            &static_params(&p, &t, &fleet),
            PolicyHost::from_factory(&factory),
            &mut clock,
        );
        assert_eq!(run.n_preemptions, 1);
        assert_eq!(run.requeue_latency.len(), 1);
        assert!(run.requeue_latency[0] >= 0.0);
        // The preempted arm's eventual observation starts at/after the
        // preemption instant, and every arm completes exactly once.
        let mut arms: Vec<_> = run.observations.iter().map(|o| o.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3, 4, 5]);
        // No observation can have been produced by device 0 after 0.5.
        for o in &run.observations {
            if o.device == 0 {
                assert!(o.finish <= 0.5 + 1e-12, "device 0 was offline after t = 0.5");
            }
        }
        assert_eq!(run.n_rebuilds, 0, "MM-GP-EI applies device churn in place");
    }

    #[test]
    fn borrowed_policy_panics_on_rebuild_demand() {
        let (p, t) = problem_and_truth();
        // The leave at t = 3.5 lands after completions exist (non-empty
        // replay history), so the default (rebuild) device hook demands a
        // rebuild the borrowed host cannot perform.
        let fleet = DeviceFleet::new(
            vec![1.0],
            vec![true],
            vec![
                FleetEvent { time: 0.5, device: 0, kind: FleetEventKind::Leave },
                FleetEvent { time: 1.0, device: 0, kind: FleetEventKind::Join },
                FleetEvent { time: 3.5, device: 0, kind: FleetEventKind::Leave },
            ],
        );
        // GpEiRoundRobin keeps the default (rebuild) device hooks; with a
        // borrowed host and a non-empty history the engine must fail
        // loudly instead of silently continuing with stale state.
        let mut pol = crate::sched::GpEiRoundRobin::new(&p);
        let mut clock = VirtualClock::new(1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run(&static_params(&p, &t, &fleet), PolicyHost::borrowed(&mut pol), &mut clock)
        }));
        assert!(result.is_err(), "borrowed host must refuse the rebuild fallback");
    }

    #[test]
    fn cost_model_routes_infeasible_arms_to_fitting_class() {
        let (p, t) = problem_and_truth();
        // Class 1 is memory-limited to base cost ≤ 1: arms 1, 2, 4, 5
        // (costs 2 and 3) only fit class-0 devices. A device-aware
        // policy must still reveal every arm, all heavy ones on device 0.
        let model =
            crate::problem::PerClassCost::from_problem(&p, vec![1.0, 1.0], vec![f64::INFINITY, 1.0]);
        let fleet = DeviceFleet::uniform(2).with_classes(vec![0, 1]);
        let factory =
            |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::with_cost_model(p, &model)) };
        let mut params = static_params(&p, &t, &fleet);
        params.cost_model = Some(&model);
        let mut clock = VirtualClock::new(2);
        let run = run(&params, PolicyHost::from_factory(&factory), &mut clock);
        let mut arms: Vec<_> = run.observations.iter().map(|o| o.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3, 4, 5], "every arm still completes exactly once");
        for o in &run.observations {
            if p.cost[o.arm] > 1.0 {
                assert_eq!(o.device, 0, "arm {} exceeds class 1's memory limit", o.arm);
            }
        }
        assert_eq!(run.curve.final_value(), 0.0);
    }

    #[test]
    fn per_class_costs_scale_durations() {
        let (p, t) = problem_and_truth();
        // One class-1 device with a 3× cost multiplier and no memory
        // limit: every job's duration is 3·c(arm).
        let model = crate::problem::PerClassCost::from_problem(
            &p,
            vec![1.0, 3.0],
            vec![f64::INFINITY, f64::INFINITY],
        );
        let fleet = DeviceFleet::uniform(1).with_classes(vec![1]);
        let mut pol = MmGpEi::with_cost_model(&p, &model);
        let mut params = static_params(&p, &t, &fleet);
        params.cost_model = Some(&model);
        let mut clock = VirtualClock::new(1);
        let run = run(&params, PolicyHost::borrowed(&mut pol), &mut clock);
        assert_eq!(run.observations.len(), 6);
        for o in &run.observations {
            assert!((o.finish - o.start - 3.0 * p.cost[o.arm]).abs() < 1e-12);
        }
    }

    fn fault_params<'a>(
        p: &'a Problem,
        t: &'a Truth,
        fleet: &'a DeviceFleet,
        plan: &'a FaultPlan,
    ) -> EngineParams<'a> {
        let mut params = static_params(p, t, fleet);
        params.faults = Some(plan);
        params
    }

    fn run_with_faults(p: &Problem, t: &Truth, fleet: &DeviceFleet, plan: &FaultPlan) -> EngineRun {
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let mut clock = VirtualClock::new(fleet.n_devices());
        run(&fault_params(p, t, fleet, plan), PolicyHost::from_factory(&factory), &mut clock)
    }

    #[test]
    fn empty_fault_plan_is_byte_identical_to_no_plan() {
        let (p, t) = problem_and_truth();
        let fleet = DeviceFleet::uniform(2);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let mut clock_a = VirtualClock::new(2);
        let base = run(&static_params(&p, &t, &fleet), PolicyHost::from_factory(&factory), &mut clock_a);
        let empty = FaultPlan::empty();
        let faulted = run_with_faults(&p, &t, &fleet, &empty);
        let key = |r: &EngineRun| -> Vec<(usize, usize, u64, u64)> {
            r.observations
                .iter()
                .map(|o| (o.arm, o.device, o.start.to_bits(), o.finish.to_bits()))
                .collect()
        };
        assert_eq!(key(&base), key(&faulted));
        assert_eq!(base.cumulative_regret.to_bits(), faulted.cumulative_regret.to_bits());
        assert_eq!(base.curve, faulted.curve);
        assert_eq!(faulted.fault_stats, FaultStats::default());
    }

    #[test]
    fn crash_preempts_and_restart_resumes_service() {
        let (p, t) = problem_and_truth();
        let fleet = DeviceFleet::uniform(1);
        // Warm start dispatches a cost-1 arm at t = 0; the crash at 0.5
        // preempts it and the device is down until t = 2.
        let plan = FaultPlan::new(
            1,
            vec![
                FaultEvent { time: 0.5, device: 0, kind: FaultKind::DeviceCrash },
                FaultEvent { time: 2.0, device: 0, kind: FaultKind::DeviceRestart },
            ],
            RetryPolicy::default(),
        );
        let run = run_with_faults(&p, &t, &fleet, &plan);
        assert_eq!(run.fault_stats.n_crashes, 1);
        assert_eq!(run.fault_stats.n_restarts, 1);
        assert_eq!(run.n_preemptions, 1);
        assert_eq!(run.requeue_latency.len(), 1);
        assert!((run.requeue_latency[0] - 1.5).abs() < 1e-12, "preempted at 0.5, re-served at 2");
        // Every arm is still revealed exactly once, none during the
        // all-devices-down window (0.5, 2).
        let mut arms: Vec<_> = run.observations.iter().map(|o| o.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3, 4, 5]);
        for o in &run.observations {
            assert!(
                o.finish <= 0.5 + 1e-12 || o.finish >= 2.0 - 1e-12,
                "arm {} completed at {} while every device was down",
                o.arm,
                o.finish
            );
        }
        assert_eq!(run.curve.final_value(), 0.0, "service recovers fully after the restart");
    }

    #[test]
    fn job_failure_retries_with_backoff_and_reveals_once() {
        let (p, t) = problem_and_truth();
        let fleet = DeviceFleet::uniform(1);
        let retry = RetryPolicy { deadline_factor: 10.0, max_retries: 3, backoff_base: 0.5, backoff_cap: 4.0 };
        // Kill whatever runs at t = 0.5 (the first warm-start arm).
        let plan = FaultPlan::new(
            1,
            vec![FaultEvent { time: 0.5, device: 0, kind: FaultKind::JobFailure }],
            retry,
        );
        let run = run_with_faults(&p, &t, &fleet, &plan);
        assert_eq!(run.fault_stats.n_job_failures, 1);
        assert_eq!(run.fault_stats.n_retries, 1);
        assert_eq!(run.fault_stats.n_abandoned, 0);
        assert_eq!(run.fault_stats.recovery_latency.len(), 1);
        assert!(run.fault_stats.recovery_latency[0] >= 0.5, "backoff alone is 0.5");
        let mut arms: Vec<_> = run.observations.iter().map(|o| o.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3, 4, 5], "the failed arm is eventually re-served once");
    }

    #[test]
    fn repeated_failures_abandon_the_arm() {
        // One user, two arms: the cheap arm (the warm head, and the
        // best arm) is killed on both of its attempts and abandoned
        // under max_retries = 1; the run degrades gracefully to the
        // other arm's incumbent instead of spinning.
        let user_arms = vec![vec![0, 1]];
        let arm_users = Problem::compute_arm_users(2, &user_arms);
        let p = Problem {
            name: "abandon".into(),
            n_users: 1,
            cost: vec![1.0, 3.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 2],
            prior_cov: Mat::eye(2),
        };
        let t = Truth { z: vec![0.9, 0.5] };
        let fleet = DeviceFleet::uniform(1);
        let retry =
            RetryPolicy { deadline_factor: 10.0, max_retries: 1, backoff_base: 0.25, backoff_cap: 0.25 };
        // Timeline: arm 0 runs 0→1, killed at 0.5 (attempt 1, retried —
        // released at 0.75); arm 1 runs 0.5→3.5; arm 0 re-dispatched
        // from the requeue 3.5→4.5, killed again at 4.0 → abandoned.
        let plan = FaultPlan::new(
            1,
            vec![
                FaultEvent { time: 0.5, device: 0, kind: FaultKind::JobFailure },
                FaultEvent { time: 4.0, device: 0, kind: FaultKind::JobFailure },
            ],
            retry,
        );
        let run = run_with_faults(&p, &t, &fleet, &plan);
        assert_eq!(run.fault_stats.n_job_failures, 2);
        assert_eq!(run.fault_stats.n_retries, 1);
        assert_eq!(run.fault_stats.n_abandoned, 1);
        // Only the surviving arm is revealed; the abandoned arm's gap
        // stays open forever.
        let arms: Vec<_> = run.observations.iter().map(|o| o.arm).collect();
        assert_eq!(arms, vec![1], "only the un-killed arm completes");
        assert!(
            (run.curve.final_value() - 0.4).abs() < 1e-12,
            "graceful degradation: the user's gap settles at z* − z₁ = 0.9 − 0.5"
        );
    }

    #[test]
    fn deadline_kill_fires_on_straggling_job() {
        let (p, t) = problem_and_truth();
        let fleet = DeviceFleet::uniform(1);
        // Deadline factor 2 on unit-estimate costs: a job stretched past
        // 2× its estimate must be killed and retried.
        let retry = RetryPolicy { deadline_factor: 2.0, max_retries: 3, backoff_base: 0.25, backoff_cap: 1.0 };
        // 10× slowdown at t = 0.5: the in-flight cost-1 arm would now
        // finish at 0.5 + 0.5·10 = 5.5, but its deadline is 2.0.
        let plan = FaultPlan::new(
            1,
            vec![FaultEvent { time: 0.5, device: 0, kind: FaultKind::Straggler(10.0) }],
            retry,
        );
        let run = run_with_faults(&p, &t, &fleet, &plan);
        assert_eq!(run.fault_stats.n_stragglers, 1);
        assert_eq!(run.fault_stats.n_deadline_kills, 1);
        assert_eq!(run.fault_stats.n_retries, 1);
        let mut arms: Vec<_> = run.observations.iter().map(|o| o.arm).collect();
        arms.sort_unstable();
        assert_eq!(arms, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn straggler_that_meets_its_deadline_reports_original_start() {
        let (p, t) = problem_and_truth();
        let fleet = DeviceFleet::uniform(1);
        // Mild 1.5× slowdown, generous deadline: the job completes late
        // but alive, and its observation keeps the original dispatch
        // time (start = 0), not the re-dispatch instant.
        let retry = RetryPolicy { deadline_factor: 10.0, ..RetryPolicy::default() };
        let plan = FaultPlan::new(
            1,
            vec![FaultEvent { time: 0.5, device: 0, kind: FaultKind::Straggler(1.5) }],
            retry,
        );
        let run = run_with_faults(&p, &t, &fleet, &plan);
        assert_eq!(run.fault_stats.n_stragglers, 1);
        assert_eq!(run.fault_stats.n_deadline_kills, 0);
        let slowed = &run.observations[0];
        assert_eq!(slowed.start, 0.0, "straggler keeps its original dispatch time");
        assert!(
            (slowed.finish - (0.5 + 0.5 * 1.5)).abs() < 1e-12,
            "remaining cost is stretched: finish at 0.5 + 0.5×1.5, got {}",
            slowed.finish
        );
    }

    #[test]
    fn fast_devices_wake_first() {
        let (p, t) = problem_and_truth();
        // Two devices, device 1 faster: at t = 0 the warm-start arms
        // must go to device 1 first (speed desc, index asc).
        let fleet = DeviceFleet::new(vec![1.0, 2.0], vec![true, true], Vec::new());
        let mut pol = MmGpEi::new(&p);
        let mut clock = VirtualClock::new(2);
        let run = run(&static_params(&p, &t, &fleet), PolicyHost::borrowed(&mut pol), &mut clock);
        // Both devices start at t = 0; the warm queue head (arm 0) must
        // have gone to the faster device 1, the second warm arm to
        // device 0.
        let arm0 = run.observations.iter().find(|o| o.arm == 0).unwrap();
        assert_eq!(arm0.device, 1, "fastest device asks first");
        assert_eq!(arm0.start, 0.0);
    }
}
