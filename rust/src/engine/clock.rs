//! Time sources for the scheduling engine.
//!
//! The engine's event loop is written once and parameterized over a
//! [`Clock`]: the thing that runs dispatched jobs and hands back the
//! next event. Three implementations:
//!
//! * [`VirtualClock`] — deterministic discrete-event time: a min-heap of
//!   completions with the historical `(finish, device)` total order, so
//!   identical seeds replay identical schedules (the simulator's
//!   substrate);
//! * [`WallClock`] — real asynchrony: one worker thread per device that
//!   "trains" a model by waiting out its scaled cost on a condvar and
//!   reports back over a channel; timed-event deadlines are served by
//!   `recv_timeout` (the live coordinator's substrate);
//! * [`MockClock`] — the wall clock's deterministic stand-in: same
//!   adapter-facing semantics (deadline handling, start reconstruction)
//!   but virtual delivery, used by the cross-loop parity tests to drive
//!   the wall-clock adapters over an exactly replayable trace.
//!
//! Device preemption (elastic fleets, fault injection) keeps the
//! **revealed-on-completion contract**: every dispatch carries a job id,
//! and a cancelled job's completion is never delivered — a preempted arm
//! reveals nothing. [`VirtualClock`] filters stale heap entries lazily;
//! [`WallClock`] cancellation is **eager**: the worker's timed condvar
//! wait observes the bumped cancel generation and aborts the job
//! immediately, so the device accepts its next dispatch now instead of
//! sleeping out the cancelled cost (any already-sent completion is
//! dropped at delivery as a stale message).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::problem::ArmId;

/// One finished job delivered by a [`Clock`].
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    /// Completion time in clock units.
    pub finish: f64,
    /// Device that ran the job.
    pub device: usize,
    /// Arm that ran.
    pub arm: ArmId,
    /// Dispatch time in clock units.
    pub start: f64,
    /// Job id (engine-issued; cancellation matches on it — lazily
    /// filtered by [`VirtualClock`], eagerly aborted by [`WallClock`]).
    pub job: u64,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Completion {}
impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Completion {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        // `total_cmp` makes the order *total* (no NaN panic, no
        // platform-dependent partial_cmp escape hatch), and equal finish
        // times break deterministically by device index so identical
        // seeds replay identical schedules everywhere — the same-cost
        // warm-start burst at t = 0 would otherwise leave the completion
        // order to heap internals.
        other
            .finish
            .total_cmp(&self.finish)
            .then_with(|| other.device.cmp(&self.device))
    }
}

/// What [`Clock::next_event`] hands back.
#[derive(Debug)]
pub enum Step {
    /// A live (non-cancelled) job finished.
    Completed(Completion),
    /// The timed-event deadline fired before any completion; the payload
    /// is the clock's current time (the deadline itself in virtual time,
    /// the measured wake-up time on the wall clock).
    TimedDue(f64),
    /// No live jobs and no deadline — the run is over.
    Exhausted,
}

/// A job runner + time source the engine drives.
///
/// Times are in *clock units*: abstract cost units for the virtual and
/// mock clocks, wall seconds for [`WallClock`] (the engine pre-scales
/// durations and deadlines by its `time_scale`).
pub trait Clock {
    /// Current time.
    fn now(&self) -> f64;

    /// Start a job: `arm` on `device`, occupying `dur` clock units.
    fn dispatch(&mut self, device: usize, arm: ArmId, dur: f64, job: u64);

    /// Cancel the in-flight job `job` on `device` (fleet preemption).
    /// The job's completion will never be delivered.
    fn cancel(&mut self, device: usize, job: u64);

    /// Block until the next event: the earliest live completion, or —
    /// when `deadline` is `Some` and due no later — a timed-event tick.
    /// Ties go to the timed event, matching the historical churn loop.
    fn next_event(&mut self, deadline: Option<f64>) -> Step;
}

/// Deterministic virtual time: completions from a min-heap, `now` is the
/// time of the last delivered event.
pub struct VirtualClock {
    heap: BinaryHeap<Completion>,
    /// Live job id per device (`None` = idle/cancelled); lazily filters
    /// stale heap entries after a preemption.
    live: Vec<Option<u64>>,
    n_live: usize,
    now: f64,
}

impl VirtualClock {
    /// New virtual clock over `n_devices` device slots, at t = 0.
    pub fn new(n_devices: usize) -> Self {
        VirtualClock { heap: BinaryHeap::new(), live: vec![None; n_devices], n_live: 0, now: 0.0 }
    }

    /// Number of live (non-cancelled) in-flight jobs (tests/diagnostics).
    pub fn in_flight(&self) -> usize {
        self.n_live
    }

    /// Drop cancelled completions off the top of the heap.
    fn skim_stale(&mut self) {
        while let Some(c) = self.heap.peek() {
            if self.live[c.device] == Some(c.job) {
                break;
            }
            self.heap.pop();
        }
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.now
    }

    fn dispatch(&mut self, device: usize, arm: ArmId, dur: f64, job: u64) {
        debug_assert!(self.live[device].is_none(), "device {device} already busy");
        self.live[device] = Some(job);
        self.n_live += 1;
        self.heap.push(Completion { finish: self.now + dur, device, arm, start: self.now, job });
    }

    fn cancel(&mut self, device: usize, job: u64) {
        if self.live[device] == Some(job) {
            self.live[device] = None;
            self.n_live -= 1;
        }
    }

    fn next_event(&mut self, deadline: Option<f64>) -> Step {
        self.skim_stale();
        match (self.heap.peek().map(|c| c.finish), deadline) {
            (None, None) => Step::Exhausted,
            (None, Some(d)) => {
                self.now = d;
                Step::TimedDue(d)
            }
            (Some(_), None) => {
                // pallas-lint: allow(R5) — the match arm is only reachable when `peek` returned Some.
                let c = self.heap.pop().expect("peeked above");
                self.live[c.device] = None;
                self.n_live -= 1;
                self.now = c.finish;
                Step::Completed(c)
            }
            (Some(f), Some(d)) => {
                if d <= f {
                    self.now = d;
                    Step::TimedDue(d)
                } else {
                    // pallas-lint: allow(R5) — the match arm is only reachable when `peek` returned Some.
                    let c = self.heap.pop().expect("peeked above");
                    self.live[c.device] = None;
                    self.n_live -= 1;
                    self.now = c.finish;
                    Step::Completed(c)
                }
            }
        }
    }
}

/// The wall clock's deterministic stand-in for parity tests: delegates
/// to a [`VirtualClock`] so the *adapter* code path (per-tenant
/// accounting, report conversion, deadline handling) can be driven over
/// an exactly replayable trace and compared bit-for-bit against the
/// virtual-time adapter — see `rust/tests/engine_parity.rs`.
pub struct MockClock(VirtualClock);

impl MockClock {
    /// New mock clock over `n_devices` device slots.
    pub fn new(n_devices: usize) -> Self {
        MockClock(VirtualClock::new(n_devices))
    }

    /// Number of live in-flight jobs (tests/diagnostics).
    pub fn in_flight(&self) -> usize {
        self.0.in_flight()
    }
}

impl Clock for MockClock {
    fn now(&self) -> f64 {
        self.0.now()
    }
    fn dispatch(&mut self, device: usize, arm: ArmId, dur: f64, job: u64) {
        self.0.dispatch(device, arm, dur, job)
    }
    fn cancel(&mut self, device: usize, job: u64) {
        self.0.cancel(device, job)
    }
    fn next_event(&mut self, deadline: Option<f64>) -> Step {
        self.0.next_event(deadline)
    }
}

/// Job message to a device worker thread.
struct WallJob {
    arm: ArmId,
    job: u64,
    sleep: Duration,
}

/// Completion message back to the leader.
struct WallDone {
    device: usize,
    arm: ArmId,
    job: u64,
}

/// Leader↔worker mailbox for one device: the pending job hand-off plus
/// the cancellation generation counter. Guarded by the slot mutex; every
/// state change notifies the paired [`Condvar`] so a worker mid-wait
/// re-examines the world immediately.
struct Slot {
    /// Next job for the worker to run (leader sets, worker takes).
    pending: Option<WallJob>,
    /// Bumped by every `cancel`; a worker that started a job under an
    /// older generation aborts it at the next condvar wake-up.
    cancel_gen: u64,
    /// Set once by `Drop`: workers exit without finishing their waits.
    shutdown: bool,
}

type SharedSlot = Arc<(Mutex<Slot>, Condvar)>;

/// Real wall-clock time over a pool of device worker threads. Running a
/// model is simulated by waiting out its (speed- and scale-adjusted)
/// cost on a per-device condvar; the completion flows back over a shared
/// channel. Timed-event deadlines are served by `recv_timeout` — the
/// leader wakes for whichever comes first, exactly like the virtual loop
/// but under real asynchrony. `cancel` is **eager**: it bumps the slot's
/// cancel generation and notifies the condvar, so the worker abandons
/// the job immediately and the device is free for its next dispatch now
/// (no residual sleep) — the property the fleet/fault serving adapters
/// and their preemption-heavy schedules rely on.
pub struct WallClock {
    t0: Instant,
    slots: Vec<SharedSlot>,
    done_rx: mpsc::Receiver<WallDone>,
    workers: Vec<Option<JoinHandle<()>>>,
    live: Vec<Option<u64>>,
    /// Duration (seconds) of the job running on each device — used to
    /// reconstruct `Completion::start` from the measured finish, the
    /// historical `ServeReport` convention.
    dur: Vec<f64>,
    n_live: usize,
}

/// Body of one device worker thread: take the pending job under the slot
/// lock, wait out its cost on the condvar (re-checking the cancel
/// generation and the shutdown flag at every wake-up), and report the
/// completion only if the job survived uncancelled. Any poisoned-lock
/// error means the leader (or a sibling) panicked — exit quietly; the
/// leader side re-raises with context.
fn worker_loop(device: usize, slot: SharedSlot, done_tx: mpsc::Sender<WallDone>) {
    let (lock, cv) = &*slot;
    loop {
        // Phase 1: wait for a job (or shutdown).
        let (job, my_gen) = {
            let Ok(mut guard) = lock.lock() else { return };
            loop {
                if guard.shutdown {
                    return;
                }
                if let Some(job) = guard.pending.take() {
                    break (job, guard.cancel_gen);
                }
                let Ok(next) = cv.wait(guard) else { return };
                guard = next;
            }
        };
        // Phase 2: "train" the model — a timed condvar wait that a
        // cancel (generation bump) or shutdown interrupts immediately.
        let deadline = Instant::now() + job.sleep;
        let finished = {
            let Ok(mut guard) = lock.lock() else { return };
            loop {
                if guard.shutdown {
                    return;
                }
                if guard.cancel_gen != my_gen {
                    break false; // preempted — abort, reveal nothing
                }
                let now = Instant::now();
                if now >= deadline {
                    break true;
                }
                let Ok((next, _)) = cv.wait_timeout(guard, deadline - now) else { return };
                guard = next;
            }
        };
        if finished && done_tx.send(WallDone { device, arm: job.arm, job: job.job }).is_err() {
            return; // leader gone
        }
    }
}

impl WallClock {
    /// Spawn one worker thread per device slot (offline fleet devices
    /// simply never receive jobs) and start the clock.
    pub fn spawn(n_devices: usize) -> Self {
        let (done_tx, done_rx) = mpsc::channel::<WallDone>();
        let mut slots = Vec::with_capacity(n_devices);
        let mut workers = Vec::with_capacity(n_devices);
        for device in 0..n_devices {
            let slot: SharedSlot = Arc::new((
                Mutex::new(Slot { pending: None, cancel_gen: 0, shutdown: false }),
                Condvar::new(),
            ));
            let worker_slot = Arc::clone(&slot);
            let done_tx = done_tx.clone();
            slots.push(slot);
            workers.push(Some(thread::spawn(move || worker_loop(device, worker_slot, done_tx))));
        }
        WallClock {
            t0: Instant::now(),
            slots,
            done_rx,
            workers,
            live: vec![None; n_devices],
            dur: vec![0.0; n_devices],
            n_live: 0,
        }
    }

    /// Number of live (non-cancelled) in-flight jobs (tests/diagnostics).
    pub fn in_flight(&self) -> usize {
        self.n_live
    }

    /// The worker thread for `device` died: join it and re-raise its
    /// panic with a diagnosable message instead of the opaque poisoned
    /// lock / hung channel the leader observed.
    fn propagate_worker_panic(&mut self, device: usize) -> ! {
        let payload = self.workers[device].take().and_then(|w| w.join().err());
        let msg = match payload.as_ref() {
            Some(p) => p
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string()),
            None => "worker exited without a panic payload (slot lock poisoned)".to_string(),
        };
        // pallas-lint: allow(R5) — deliberate: a dead device worker cannot be recovered mid-run; re-raise with the worker's own payload so the failure is diagnosable.
        panic!("device {device} worker thread panicked: {msg}");
    }

    fn deliver(&mut self, m: WallDone) -> Option<Completion> {
        // Stale (preempted) jobs are dropped: nothing is revealed.
        if self.live[m.device] != Some(m.job) {
            return None;
        }
        self.live[m.device] = None;
        self.n_live -= 1;
        let finish = self.now();
        let start = (finish - self.dur[m.device]).max(0.0);
        Some(Completion { finish, device: m.device, arm: m.arm, start, job: m.job })
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn dispatch(&mut self, device: usize, arm: ArmId, dur: f64, job: u64) {
        debug_assert!(self.live[device].is_none(), "device {device} already busy");
        if self.workers[device].as_ref().is_none_or(|w| w.is_finished()) {
            self.propagate_worker_panic(device);
        }
        self.live[device] = Some(job);
        self.dur[device] = dur;
        self.n_live += 1;
        let (lock, cv) = &*self.slots[device];
        match lock.lock() {
            Ok(mut guard) => {
                debug_assert!(guard.pending.is_none(), "device {device} has an untaken job");
                guard.pending = Some(WallJob { arm, job, sleep: Duration::from_secs_f64(dur) });
            }
            Err(_) => self.propagate_worker_panic(device),
        }
        cv.notify_all();
    }

    /// Eager cancellation: bump the slot's cancel generation (and clear a
    /// not-yet-taken pending job) under the lock, then notify the worker.
    /// A worker mid-wait observes the new generation at the wake-up and
    /// abandons the job immediately — the device accepts its next
    /// dispatch now, with no residual sleep. A completion the worker
    /// already sent is dropped at delivery (stale job id), preserving the
    /// revealed-on-completion contract either way.
    fn cancel(&mut self, device: usize, job: u64) {
        if self.live[device] == Some(job) {
            self.live[device] = None;
            self.n_live -= 1;
            let (lock, cv) = &*self.slots[device];
            match lock.lock() {
                Ok(mut guard) => {
                    guard.pending = None;
                    guard.cancel_gen += 1;
                }
                Err(_) => self.propagate_worker_panic(device),
            }
            cv.notify_all();
        }
    }

    fn next_event(&mut self, deadline: Option<f64>) -> Step {
        loop {
            let msg = match deadline {
                Some(d) => {
                    let timeout =
                        Duration::from_secs_f64(d.max(0.0)).saturating_sub(self.t0.elapsed());
                    match self.done_rx.recv_timeout(timeout) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Timeout) => return Step::TimedDue(self.now()),
                        Err(RecvTimeoutError::Disconnected) => return Step::Exhausted,
                    }
                }
                None => {
                    if self.n_live == 0 {
                        return Step::Exhausted;
                    }
                    match self.done_rx.recv() {
                        Ok(m) => m,
                        Err(_) => return Step::Exhausted,
                    }
                }
            };
            if let Some(c) = self.deliver(msg) {
                return Step::Completed(c);
            }
            // Stale completion of a preempted job — keep waiting.
        }
    }
}

impl Drop for WallClock {
    fn drop(&mut self) {
        // Raise the shutdown flag and wake every worker: a worker mid-job
        // abandons its wait at the notify (no residual sleep), so the
        // joins below return promptly even with jobs in flight.
        for slot in &self.slots {
            let (lock, cv) = &**slot;
            // A poisoned slot means its worker already died — nothing to
            // wake; the join below just collects the corpse.
            if let Ok(mut guard) = lock.lock() {
                guard.shutdown = true;
            }
            cv.notify_all();
        }
        for w in self.workers.drain(..).flatten() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_delivers_in_time_then_device_order() {
        let mut c = VirtualClock::new(3);
        c.dispatch(1, 10, 2.0, 1);
        c.dispatch(0, 11, 2.0, 2);
        c.dispatch(2, 12, 1.0, 3);
        let mut order = Vec::new();
        while let Step::Completed(done) = c.next_event(None) {
            order.push((done.device, done.arm, done.finish));
        }
        assert_eq!(order, vec![(2, 12, 1.0), (0, 11, 2.0), (1, 10, 2.0)]);
        assert!(matches!(c.next_event(None), Step::Exhausted));
    }

    #[test]
    fn virtual_clock_timed_deadline_wins_ties() {
        let mut c = VirtualClock::new(1);
        c.dispatch(0, 5, 2.0, 1);
        match c.next_event(Some(2.0)) {
            Step::TimedDue(t) => assert_eq!(t, 2.0),
            other => panic!("expected TimedDue, got {other:?}"),
        }
        // The completion is still pending afterwards.
        assert!(matches!(c.next_event(None), Step::Completed(_)));
    }

    #[test]
    fn virtual_clock_cancellation_is_lazy_and_silent() {
        let mut c = VirtualClock::new(2);
        c.dispatch(0, 5, 1.0, 1);
        c.dispatch(1, 6, 2.0, 2);
        assert_eq!(c.in_flight(), 2);
        c.cancel(0, 1);
        assert_eq!(c.in_flight(), 1);
        match c.next_event(None) {
            Step::Completed(done) => assert_eq!((done.device, done.arm), (1, 6)),
            other => panic!("cancelled job must not deliver, got {other:?}"),
        }
        assert!(matches!(c.next_event(None), Step::Exhausted));
    }

    #[test]
    fn virtual_clock_timed_only_advances_time() {
        let mut c = VirtualClock::new(1);
        assert!(matches!(c.next_event(Some(4.0)), Step::TimedDue(_)));
        assert_eq!(c.now(), 4.0);
        c.dispatch(0, 3, 1.5, 1);
        match c.next_event(None) {
            Step::Completed(done) => {
                assert_eq!(done.start, 4.0);
                assert_eq!(done.finish, 5.5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wall_clock_runs_and_reports() {
        let mut c = WallClock::spawn(2);
        c.dispatch(0, 7, 0.002, 1);
        c.dispatch(1, 8, 0.001, 2);
        let mut arms = Vec::new();
        while let Step::Completed(done) = c.next_event(None) {
            assert!(done.finish >= done.start);
            arms.push(done.arm);
        }
        arms.sort_unstable();
        assert_eq!(arms, vec![7, 8]);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn wall_clock_deadline_fires_when_idle() {
        let mut c = WallClock::spawn(1);
        match c.next_event(Some(0.002)) {
            Step::TimedDue(t) => assert!(t >= 0.0),
            other => panic!("expected TimedDue, got {other:?}"),
        }
        assert!(matches!(c.next_event(None), Step::Exhausted));
    }

    #[test]
    fn wall_clock_drops_cancelled_completions() {
        let mut c = WallClock::spawn(1);
        c.dispatch(0, 9, 0.001, 1);
        c.cancel(0, 1);
        assert_eq!(c.in_flight(), 0);
        // The worker's Done message for the preempted job must be
        // discarded, not delivered.
        assert!(matches!(c.next_event(None), Step::Exhausted));
    }

    #[test]
    fn wall_clock_cancel_is_eager() {
        // Regression pin for the condvar rewrite: under the old
        // sleep-based workers a cancelled 30 s job was slept out in full
        // and the next dispatch queued behind the residual sleep. The
        // preempted device must accept its next job *immediately*.
        let t0 = Instant::now();
        let mut c = WallClock::spawn(1);
        c.dispatch(0, 1, 30.0, 1);
        c.cancel(0, 1);
        assert_eq!(c.in_flight(), 0);
        c.dispatch(0, 2, 0.001, 2);
        match c.next_event(None) {
            Step::Completed(done) => assert_eq!((done.arm, done.job), (2, 2)),
            other => panic!("expected the replacement job, got {other:?}"),
        }
        drop(c); // must not wait out the cancelled sleep either
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "eager cancel regressed: cancelled job's cost was slept out ({:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn wall_clock_cancel_then_redispatch_races_are_clean() {
        // Hammer the cancel → immediate re-dispatch edge: whatever the
        // worker was doing (not yet taken the job, mid-wait, or already
        // finished), only the *latest* live job may ever be delivered.
        let mut c = WallClock::spawn(2);
        let mut job = 0u64;
        for round in 0..50 {
            for d in 0..2 {
                job += 1;
                c.dispatch(d, round, 5.0, job);
                c.cancel(d, job);
                job += 1;
                c.dispatch(d, 1000 + round, 0.0005, job);
            }
            let mut seen = 0;
            while seen < 2 {
                match c.next_event(None) {
                    Step::Completed(done) => {
                        assert!(done.arm >= 1000, "cancelled job {} delivered", done.job);
                        seen += 1;
                    }
                    other => panic!("expected completion, got {other:?}"),
                }
            }
        }
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn wall_clock_propagates_worker_panic_with_context() {
        let mut c = WallClock::spawn(1);
        // Simulate a crashed device worker: retire the real worker
        // through the shutdown path, then install a panicked handle in
        // its place.
        {
            let (lock, cv) = &*c.slots[0];
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(real) = c.workers[0].take() {
            real.join().unwrap();
        }
        let crashed = thread::spawn(|| panic!("simulated worker crash"));
        while !crashed.is_finished() {
            thread::yield_now();
        }
        c.workers[0] = Some(crashed);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.dispatch(0, 3, 0.001, 1);
        }))
        .expect_err("dispatch to a dead worker must fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic".to_string());
        assert!(
            msg.contains("device 0 worker thread panicked") && msg.contains("simulated worker crash"),
            "panic message must name the device and carry the worker's payload, got: {msg}"
        );
        // The failed dispatch marked the device live; clear it so Drop's
        // bookkeeping (which only joins workers) stays consistent.
        c.live[0] = None;
        c.n_live = 0;
    }
}
