//! Pseudo-random number generation substrate.
//!
//! The offline build environment provides no `rand` crate, so the library
//! carries its own generator: [xoshiro256++], a small, fast, high-quality
//! PRNG with 256 bits of state, seeded through SplitMix64 so that any
//! `u64` seed produces a well-mixed initial state. On top of the raw
//! generator we provide uniform floats, Box–Muller Gaussians, and
//! multivariate-normal sampling via a Cholesky factor (used by the
//! synthetic Matérn workload of the paper's Figure 5).
//!
//! [xoshiro256++]: https://prng.di.unimi.it/

use crate::linalg::Mat;

/// xoshiro256++ generator with Box–Muller caching for normal deviates.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the most recent Box–Muller pair.
    gauss_cache: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 step — used only for seeding.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent child generator (for per-repeat seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mix = self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(mix)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps bias below 2^-64 — negligible for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        // Avoid u1 == 0 (log(0)).
        let mut u1 = self.uniform();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.uniform();
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_cache = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Sample a multivariate normal `N(mean, L Lᵀ)` given the lower
    /// Cholesky factor `L`. Used to draw correlated model performances
    /// from a GP prior (paper §6.3 synthetic experiment).
    pub fn mvn(&mut self, mean: &[f64], chol_lower: &Mat) -> Vec<f64> {
        let n = mean.len();
        assert_eq!(chol_lower.rows(), n);
        assert_eq!(chol_lower.cols(), n);
        let z: Vec<f64> = (0..n).map(|_| self.normal()).collect();
        let mut out = vec![0.0; n];
        for i in 0..n {
            let mut acc = mean[i];
            for j in 0..=i {
                acc += chol_lower[(i, j)] * z[j];
            }
            out[i] = acc;
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices out of `n` (partial shuffle).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "seeds 1 and 2 should produce different streams");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let skew = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.03, "skew={skew}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(17);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = r.below(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn choose_indices_distinct() {
        let mut r = Rng::new(19);
        for _ in 0..100 {
            let picked = r.choose_indices(22, 8);
            assert_eq!(picked.len(), 8);
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8, "indices must be distinct");
            assert!(picked.iter().all(|&i| i < 22));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn mvn_identity_cov_moments() {
        use crate::linalg::Mat;
        let mut r = Rng::new(29);
        let l = Mat::eye(3);
        let mean = [1.0, -2.0, 0.5];
        let n = 50_000;
        let mut acc = [0.0; 3];
        for _ in 0..n {
            let x = r.mvn(&mean, &l);
            for d in 0..3 {
                acc[d] += x[d];
            }
        }
        for d in 0..3 {
            assert!((acc[d] / n as f64 - mean[d]).abs() < 0.02);
        }
    }

    #[test]
    fn mvn_correlated_cov() {
        use crate::linalg::Mat;
        // Cov = [[1, .8], [.8, 1]]; L = chol.
        let cov = Mat::from_rows(&[&[1.0, 0.8], &[0.8, 1.0]]);
        let l = crate::linalg::cholesky(&cov).unwrap();
        let mut r = Rng::new(31);
        let n = 100_000;
        let (mut sxy, mut sx, mut sy, mut sxx, mut syy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let v = r.mvn(&[0.0, 0.0], &l);
            sx += v[0];
            sy += v[1];
            sxy += v[0] * v[1];
            sxx += v[0] * v[0];
            syy += v[1] * v[1];
        }
        let nf = n as f64;
        let cov_xy = sxy / nf - (sx / nf) * (sy / nf);
        assert!((cov_xy - 0.8).abs() < 0.02, "cov={cov_xy}");
        assert!((sxx / nf - 1.0).abs() < 0.02);
        assert!((syy / nf - 1.0).abs() < 0.02);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(99);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
