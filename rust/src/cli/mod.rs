//! Command-line interface: hand-rolled argument parsing (no `clap`
//! offline) plus the experiment driver shared by `main.rs` and the bench
//! binaries.

mod driver;

pub use driver::{
    aggregate_cell, aggregate_churn_cell, aggregate_faults_cell, aggregate_fleet_cell,
    make_instance, make_policy, make_sharded_policy, run_churn_experiment, run_experiment,
    run_faults_experiment, run_fleet_experiment, sharded_prior_for, CellResult, ChurnCell,
    ChurnExperimentResults, ExperimentResults, FaultsCell, FaultsExperimentResults, FleetCell,
    FleetExperimentResults,
};

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, and
/// `--flag` booleans.
#[derive(Debug, Default)]
pub struct Args {
    /// First positional token (the subcommand).
    pub command: Option<String>,
    /// Positional tokens after the subcommand (e.g. the two report paths
    /// of `compare a.json b.json`).
    pub positionals: Vec<String>,
    /// `--key value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    ///
    /// Grammar: the first non-dash token is the subcommand and later
    /// non-dash tokens are its positionals; `--key value` binds the next
    /// token unless it also starts with `--`; a trailing or value-less
    /// `--key` becomes a flag.
    pub fn parse(tokens: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let value = it.next().unwrap();
                        args.options.insert(key.to_string(), value);
                    }
                    _ => args.flags.push(key.to_string()),
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parsed option with default; errors mention the key.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|e| format!("--{key} {raw:?}: {e}")),
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|s| s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
    }

    /// Whether a bare flag was passed.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("simulate --dataset azure --devices 1,2,4 --seeds 10 --verbose");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get("dataset"), Some("azure"));
        assert_eq!(a.get_list("devices").unwrap(), vec!["1", "2", "4"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_parsed_or("seeds", 5u64).unwrap(), 10);
    }

    #[test]
    fn defaults_and_missing() {
        let a = parse("theory");
        assert_eq!(a.get_or("dataset", "azure"), "azure");
        assert_eq!(a.get_parsed_or("seeds", 7u64).unwrap(), 7);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("serve --verbose --dataset azure");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("dataset"), Some("azure"));
    }

    #[test]
    fn collects_extra_positionals() {
        let a = parse("compare baselines/BENCH_fig2.json reports/BENCH_fig2.json --rel-tol 0.05");
        assert_eq!(a.command.as_deref(), Some("compare"));
        assert_eq!(a.positionals, vec!["baselines/BENCH_fig2.json", "reports/BENCH_fig2.json"]);
        assert_eq!(a.get("rel-tol"), Some("0.05"));
        assert!(parse("theory").positionals.is_empty());
    }

    #[test]
    fn parse_error_mentions_key() {
        let a = parse("simulate --seeds nope");
        let err = a.get_parsed_or("seeds", 1u64).unwrap_err();
        assert!(err.contains("--seeds"), "{err}");
    }
}
