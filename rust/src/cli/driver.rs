//! Experiment driver: instantiate workloads per the paper's protocol,
//! construct policies by name, sweep (policy × devices × seeds), and
//! aggregate the metrics the figures plot. Shared by the CLI launcher
//! and the `cargo bench` figure harnesses.

use crate::config::{Backend, ExperimentConfig, GpStructure};
use crate::gp::KroneckerPrior;
use crate::kernels::{Kernel, Matern52};
use crate::metrics::{aggregate_curves, mean_std, p99, time_grid, StepCurve};
use crate::pool::WorkerPool;
use crate::prng::Rng;
use crate::problem::{CostModel, DeviceFleet, PerClassCost, Problem, Truth};
use crate::report::{Direction, RunReport, TimingEntry};
use crate::runtime::{default_artifact_dir, XlaBackend};
use crate::sched::{GpEiRandom, GpEiRoundRobin, MmGpEi, MmGpEiIndep, Oracle, Policy};
use crate::sim::{
    simulate, simulate_churn, simulate_faults, simulate_fleet_with_cost_model, ChurnResult,
    FaultResult, FleetResult, SimConfig, SimResult,
};
use crate::workload::{
    azure, churn_workload, deeplearning, fault_plan, fleet_schedule, round_robin_classes,
    synthetic_gp,
};

/// Instantiate a policy by CLI name.
///
/// Vocabulary: `mdmt` (Algorithm 1), `mdmt-device` (device-aware
/// scoring — `EI/(c(x, class_d)/s_d)` for the asking device),
/// `mdmt-nocost` (EI-only ablation), `mdmt-indep` (independent-GP
/// ablation), `round-robin`, `random`, `oracle`.
///
/// `cost_model` feeds `mdmt-device` its per-class estimated-cost table
/// (`--cost-model` / `[cost_model]`); pass `None` outside cost-model
/// runs — `mdmt-device` then scores against the problem's single cost
/// vector (speed-aware only). Class tables need the native backend, so
/// `mdmt-device` ignores `--backend xla`.
///
/// `policy_pool` is the worker pool handed to the per-user-GP policies'
/// internal shards; pass `WorkerPool::new(1)` when the caller already
/// parallelizes at a coarser level (e.g. the seed sweep) so thread
/// counts don't multiply.
pub fn make_policy(
    name: &str,
    problem: &Problem,
    truth: &Truth,
    seed: u64,
    backend: Backend,
    policy_pool: &WorkerPool,
    cost_model: Option<&dyn CostModel>,
) -> Result<Box<dyn Policy>, String> {
    Ok(match name {
        "mdmt" => match backend {
            Backend::Native => Box::new(MmGpEi::new(problem)),
            Backend::Xla => {
                let b = XlaBackend::new(problem, &default_artifact_dir())
                    .map_err(|e| format!("xla backend: {e:#}"))?;
                Box::new(MmGpEi::with_backend(problem, Box::new(b)))
            }
        },
        "mdmt-device" => match cost_model {
            Some(model) => Box::new(MmGpEi::with_cost_model(problem, model)),
            None => Box::new(MmGpEi::device_aware(problem)),
        },
        "mdmt-nocost" => Box::new(MmGpEi::cost_insensitive(problem)),
        "mdmt-indep" => Box::new(MmGpEiIndep::with_pool(problem, policy_pool.clone())),
        "mdmt-fantasy" => Box::new(crate::sched::MmGpEiFantasy::new(problem)),
        "ucb-mdmt" => Box::new(crate::sched::GpUcbMdmt::new(problem)),
        "ucb-round-robin" => Box::new(crate::sched::GpUcbRoundRobin::with_pool(problem, policy_pool.clone())),
        "round-robin" => Box::new(GpEiRoundRobin::with_pool(problem, policy_pool.clone())),
        "random" => Box::new(GpEiRandom::with_pool(problem, seed ^ 0x5EED, policy_pool.clone())),
        "oracle" => Box::new(Oracle::new(problem, truth)),
        other => return Err(format!("unknown policy {other:?}")),
    })
}

/// Borrow a per-seed owned cost model as the trait object that
/// [`make_policy`] and the engine take (`None` passes through).
fn as_cost_model(model: &Option<PerClassCost>) -> Option<&dyn CostModel> {
    model.as_ref().map(|m| m as &dyn CostModel)
}

/// Build the (problem, truth) instance for seed `seed` per the paper's
/// protocol (§6.1): real datasets get a random 8-user holdout split; the
/// synthetic workload is regenerated from the seed.
pub fn make_instance(cfg: &ExperimentConfig, seed: u64) -> Result<(Problem, Truth), String> {
    match cfg.dataset.as_str() {
        "azure" => {
            let data = azure();
            let mut rng = Rng::new(0xAE0 + seed);
            let split = data.protocol_split(&mut rng, cfg.holdout);
            Ok(data.make_problem(&split))
        }
        "deeplearning" => {
            let data = deeplearning();
            let mut rng = Rng::new(0xD1 + seed);
            let split = data.protocol_split(&mut rng, cfg.holdout);
            Ok(data.make_problem(&split))
        }
        "synthetic" => Ok(synthetic_gp(&cfg.synthetic, 0x517 + seed)),
        other => Err(format!("unknown dataset {other:?}")),
    }
}

/// Reconstruct the B(ρ) ⊗ C Kronecker factorization of the workload's
/// dense prior, for the sharded GP store (`[gp] structure = "sharded"`).
///
/// The synthetic and churn workloads *generate* their dense
/// `problem.prior_cov` from exactly this structure (shared Matérn-5/2
/// model gram `C` over the grid `m · 0.25`, exchangeable user factor
/// `B(ρ)` — ρ = 0 for synthetic, `churn.user_corr` under churn), so the
/// prior built here is bitwise the same covariance the dense oracle
/// factors; only the mean shift is instance-specific, hence the
/// per-seed `problem` argument. Real datasets have empirical dense
/// priors with no Kronecker factorization — config validation rejects
/// them before this runs, and the error here is the backstop.
pub fn sharded_prior_for(cfg: &ExperimentConfig, problem: &Problem) -> Result<KroneckerPrior, String> {
    let (n_users, n_models, variance, lengthscale, rho) = if cfg.churn {
        let c = &cfg.churn_cfg;
        (c.n_users, c.n_models, c.variance, c.lengthscale, c.user_corr)
    } else if cfg.dataset == "synthetic" {
        let s = &cfg.synthetic;
        (s.n_users, s.n_models, s.variance, s.lengthscale, 0.0)
    } else {
        return Err(format!(
            "sharded GP prior: dataset {:?} has an empirical dense prior (only the synthetic and \
             churn workloads are Kronecker-structured)",
            cfg.dataset
        ));
    };
    let pts: Vec<Vec<f64>> = (0..n_models).map(|m| vec![m as f64 * 0.25]).collect();
    let model_cov = Matern52 { variance, lengthscale }.gram(&pts);
    KroneckerPrior::new(n_users, model_cov, rho, problem.prior_mean.clone())
}

/// [`make_policy`] twin for `[gp] structure = "sharded"` sweeps.
///
/// `mdmt` gets the sharded native backend ([`MmGpEi::sharded`]); the
/// GP-free baselines (`round-robin`, `random`, `oracle`) delegate to
/// [`make_policy`] unchanged so cross-policy comparisons stay valid.
/// Config validation guarantees no other policy name reaches a sharded
/// sweep (they would silently score off a dense store), so the
/// delegation arm never constructs a second GP-EI variant in practice.
pub fn make_sharded_policy(
    name: &str,
    problem: &Problem,
    truth: &Truth,
    seed: u64,
    policy_pool: &WorkerPool,
    prior: &KroneckerPrior,
) -> Result<Box<dyn Policy>, String> {
    match name {
        "mdmt" => Ok(Box::new(MmGpEi::sharded(problem, prior.clone()))),
        _ => make_policy(name, problem, truth, seed, Backend::Native, policy_pool, None),
    }
}

/// Aggregated results for one (policy, device-count) cell of the sweep.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Policy name.
    pub policy: String,
    /// Device count.
    pub devices: usize,
    /// Per-seed raw results.
    pub runs: Vec<SimResult>,
    /// Mean ± std of cumulative regret.
    pub cumulative: (f64, f64),
    /// Mean ± std of time-to-cutoff (seeds that reached it).
    pub time_to_cutoff: Option<(f64, f64)>,
    /// Mean instantaneous-regret curve (simple per-seed average curve on
    /// a uniform grid; also carries the 1σ band).
    pub curve: Vec<(f64, f64, f64)>,
}

/// Full sweep output.
#[derive(Clone, Debug)]
pub struct ExperimentResults {
    /// Config used.
    pub config: ExperimentConfig,
    /// One cell per (policy, devices) pair, in sweep order.
    pub cells: Vec<CellResult>,
}

impl ExperimentResults {
    /// Find a cell.
    pub fn cell(&self, policy: &str, devices: usize) -> Option<&CellResult> {
        self.cells.iter().find(|c| c.policy == policy && c.devices == devices)
    }

    /// Fold this sweep into `report`: the config fingerprint, one KPI set
    /// per cell under `prefix` (e.g. `azure/`), and — outside smoke mode
    /// — the per-decision scheduler wall time as a timing entry.
    ///
    /// Per-cell KPIs (all virtual-time, hence seed-deterministic):
    /// `cumulative_regret`, `final_regret`, `makespan`, and `t_le_<cut>`
    /// for each cutoff that **every** seed reached (partially-reached
    /// cutoffs are omitted rather than averaged over a varying subset).
    pub fn push_kpis(&self, report: &mut RunReport, prefix: &str, cutoffs: &[f64]) {
        report.fold_config(&self.config.canonical_string());
        for cell in &self.cells {
            let key = |metric: &str| format!("{prefix}{}@M{}/{metric}", cell.policy, cell.devices);
            report.push_kpi(key("cumulative_regret"), cell.cumulative.0, Direction::LowerIsBetter);
            let finals: Vec<f64> = cell.runs.iter().map(|r| r.inst_regret.final_value()).collect();
            report.push_kpi(key("final_regret"), mean_std(&finals).0, Direction::LowerIsBetter);
            let makespans: Vec<f64> = cell.runs.iter().map(|r| r.makespan).collect();
            report.push_kpi(key("makespan"), mean_std(&makespans).0, Direction::LowerIsBetter);
            for &cut in cutoffs {
                let hits: Vec<f64> = cell.runs.iter().filter_map(|r| r.time_to(cut)).collect();
                if hits.len() == cell.runs.len() {
                    report.push_kpi(key(&format!("t_le_{cut}")), mean_std(&hits).0, Direction::LowerIsBetter);
                }
            }
            let decisions: u64 = cell.runs.iter().map(|r| r.n_decisions as u64).sum();
            if decisions > 0 {
                let total_ns: f64 = cell.runs.iter().map(|r| r.decision_wall_time.as_nanos() as f64).sum();
                report.push_timing(TimingEntry::flat(key("decision_wall"), decisions, total_ns / decisions as f64));
            }
        }
    }
}

/// Run the full sweep described by `cfg`.
///
/// Seeds within each (policy, devices) cell are independent simulations,
/// so they shard across the worker pool (`cfg.threads` /
/// `MMGPEI_THREADS`); each worker builds, runs, and drops its own policy
/// instance, and the per-seed results merge in seed order — the sweep's
/// KPIs are byte-identical at any thread count.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentResults, String> {
    cfg.validate()?;
    let pool = WorkerPool::new(cfg.effective_threads());
    // One level of parallelism: the sweep owns it, so every policy it
    // constructs gets a serial pool (thread counts must not multiply,
    // and an explicit `threads = 1` config means *serial*, full stop).
    // Policy-internal sharding is for the single-run serving paths —
    // `mmgpei serve`, the coordinator examples — which construct
    // policies against the env-resolved pool directly.
    let policy_pool = WorkerPool::new(1);
    let mut cells = Vec::new();
    for policy_name in &cfg.policies {
        for &devices in &cfg.devices {
            let seed_runs = pool.map_indexed(cfg.seeds as usize, |seed| {
                let seed = seed as u64;
                let (problem, truth) = make_instance(cfg, seed)?;
                let mut policy = if cfg.gp_structure == GpStructure::Sharded {
                    let prior = sharded_prior_for(cfg, &problem)?;
                    make_sharded_policy(policy_name, &problem, &truth, seed, &policy_pool, &prior)?
                } else {
                    make_policy(policy_name, &problem, &truth, seed, cfg.backend, &policy_pool, None)?
                };
                Ok::<SimResult, String>(simulate(
                    &problem,
                    &truth,
                    policy.as_mut(),
                    &SimConfig {
                        n_devices: devices,
                        warm_start_per_user: cfg.warm_start,
                        horizon: cfg.horizon,
                        stop_at_cutoff: None,
                    },
                ))
            });
            let mut runs = Vec::with_capacity(cfg.seeds as usize);
            for run in seed_runs {
                runs.push(run?);
            }
            cells.push(aggregate_cell(policy_name, devices, runs, cfg.cutoff));
        }
    }
    Ok(ExperimentResults { config: cfg.clone(), cells })
}

/// Aggregated results for one (policy, device-count) cell of a **churn**
/// sweep (`--churn` / a `[churn]` config section).
#[derive(Clone, Debug)]
pub struct ChurnCell {
    /// Policy name.
    pub policy: String,
    /// Device count.
    pub devices: usize,
    /// Per-seed raw churn runs.
    pub runs: Vec<ChurnResult>,
    /// Mean ± std of cumulative (all-tenant) regret over seeds.
    pub cumulative: (f64, f64),
    /// Mean per-tenant regret at exit, over every (seed, tenant) pair.
    pub mean_exit_regret: f64,
    /// p99 of the join-to-first-decision latency over every served
    /// (seed, tenant) pair (virtual time — deterministic).
    pub p99_join_latency: f64,
    /// Fraction of (seed, tenant) pairs that were ever served.
    pub served_fraction: f64,
    /// Total driver-side policy rebuilds across seeds (0 when the policy
    /// implements the churn hooks in place).
    pub n_rebuilds: usize,
}

/// Full churn-sweep output.
#[derive(Clone, Debug)]
pub struct ChurnExperimentResults {
    /// Config used.
    pub config: ExperimentConfig,
    /// One cell per (policy, devices) pair, in sweep order.
    pub cells: Vec<ChurnCell>,
}

impl ChurnExperimentResults {
    /// Find a cell.
    pub fn cell(&self, policy: &str, devices: usize) -> Option<&ChurnCell> {
        self.cells.iter().find(|c| c.policy == policy && c.devices == devices)
    }

    /// Fold this sweep into `report`: config fingerprint + per-cell churn
    /// KPIs (all virtual-time, hence seed-deterministic), and — outside
    /// smoke mode — per-decision scheduler wall time.
    pub fn push_kpis(&self, report: &mut RunReport, prefix: &str) {
        report.fold_config(&self.config.canonical_string());
        for cell in &self.cells {
            let key = |metric: &str| format!("{prefix}{}@M{}/{metric}", cell.policy, cell.devices);
            report.push_kpi(key("cumulative_regret"), cell.cumulative.0, Direction::LowerIsBetter);
            report.push_kpi(key("mean_exit_regret"), cell.mean_exit_regret, Direction::LowerIsBetter);
            report.push_kpi(key("p99_join_latency"), cell.p99_join_latency, Direction::LowerIsBetter);
            report.push_kpi(key("served_fraction"), cell.served_fraction, Direction::HigherIsBetter);
            report.push_kpi(key("rebuilds"), cell.n_rebuilds as f64, Direction::LowerIsBetter);
            let decisions: u64 = cell.runs.iter().map(|r| r.n_decisions as u64).sum();
            if decisions > 0 {
                let total_ns: f64 =
                    cell.runs.iter().map(|r| r.decision_wall_time.as_nanos() as f64).sum();
                report.push_timing(TimingEntry::flat(key("decision_wall"), decisions, total_ns / decisions as f64));
            }
        }
    }
}

/// Run the churn sweep described by `cfg` (requires `cfg.churn`): for
/// each (policy × devices × seed), generate the churn workload and
/// replay its arrival/departure timeline through the churn event loop.
/// Seeds shard across the worker pool exactly like [`run_experiment`].
pub fn run_churn_experiment(cfg: &ExperimentConfig) -> Result<ChurnExperimentResults, String> {
    cfg.validate()?;
    if !cfg.churn {
        return Err("run_churn_experiment requires churn to be enabled (--churn / [churn])".into());
    }
    let pool = WorkerPool::new(cfg.effective_threads());
    let policy_pool = WorkerPool::new(1);
    // Surface construction errors (unknown policy, missing XLA artifacts)
    // once, up front, instead of panicking inside the factory closure.
    {
        let (p0, t0, _) = churn_workload(&cfg.churn_cfg, 0x6C0);
        if cfg.gp_structure == GpStructure::Sharded {
            let prior = sharded_prior_for(cfg, &p0)?;
            for name in &cfg.policies {
                make_sharded_policy(name, &p0, &t0, 0, &policy_pool, &prior)?;
            }
        } else {
            for name in &cfg.policies {
                make_policy(name, &p0, &t0, 0, cfg.backend, &policy_pool, None)?;
            }
        }
    }
    let mut cells = Vec::new();
    for policy_name in &cfg.policies {
        for &devices in &cfg.devices {
            let seed_runs = pool.map_indexed(cfg.seeds as usize, |seed| {
                let seed = seed as u64;
                let (problem, truth, schedule) = churn_workload(&cfg.churn_cfg, 0x6C0 + seed);
                // Per-seed: the Kronecker prior carries the instance's
                // (seed-dependent) mean shift alongside the shared B ⊗ C.
                let sharded_prior = (cfg.gp_structure == GpStructure::Sharded).then(|| {
                    sharded_prior_for(cfg, &problem)
                        .expect("sharded prior construction validated above")
                });
                let factory = |p: &Problem| -> Box<dyn Policy> {
                    match &sharded_prior {
                        Some(prior) => make_sharded_policy(policy_name, p, &truth, seed, &policy_pool, prior)
                            .expect("policy construction validated above"),
                        None => make_policy(policy_name, p, &truth, seed, cfg.backend, &policy_pool, None)
                            .expect("policy construction validated above"),
                    }
                };
                simulate_churn(
                    &problem,
                    &truth,
                    &schedule,
                    &factory,
                    &SimConfig {
                        n_devices: devices,
                        warm_start_per_user: cfg.warm_start,
                        horizon: cfg.horizon,
                        stop_at_cutoff: None,
                    },
                )
            });
            cells.push(aggregate_churn_cell(policy_name, devices, seed_runs));
        }
    }
    Ok(ChurnExperimentResults { config: cfg.clone(), cells })
}

/// Aggregated results for one policy of an **elastic fleet** sweep
/// (`--fleet` / a `[fleet]` config section). The fleet is the sweep's
/// device dimension, so cells are keyed by policy only.
#[derive(Clone, Debug)]
pub struct FleetCell {
    /// Policy name.
    pub policy: String,
    /// Per-seed raw fleet runs.
    pub runs: Vec<FleetResult>,
    /// Mean ± std of cumulative regret over seeds.
    pub cumulative: (f64, f64),
    /// Total preempted jobs across seeds (workload-determined but
    /// deterministic — gated so the scenario itself cannot drift).
    pub n_preemptions: usize,
    /// p99 of the preemption → re-dispatch delay over every
    /// (seed, preemption) pair (NaN when nothing was requeued — dropped
    /// by `push_kpi`).
    pub p99_requeue_latency: f64,
    /// Total engine-side policy rebuilds across seeds (0 when the policy
    /// implements the device hooks in place).
    pub n_rebuilds: usize,
}

/// Full elastic-fleet sweep output.
#[derive(Clone, Debug)]
pub struct FleetExperimentResults {
    /// Config used.
    pub config: ExperimentConfig,
    /// One cell per policy, in sweep order.
    pub cells: Vec<FleetCell>,
}

impl FleetExperimentResults {
    /// Find a cell.
    pub fn cell(&self, policy: &str) -> Option<&FleetCell> {
        self.cells.iter().find(|c| c.policy == policy)
    }

    /// Fold this sweep into `report`: config fingerprint + per-policy
    /// fleet KPIs (all virtual-time, hence seed-deterministic), and —
    /// outside smoke mode — per-decision scheduler wall time.
    pub fn push_kpis(&self, report: &mut RunReport, prefix: &str) {
        report.fold_config(&self.config.canonical_string());
        let f = self.config.fleet_cfg.n_devices;
        for cell in &self.cells {
            let key = |metric: &str| format!("{prefix}{}@F{f}/{metric}", cell.policy);
            report.push_kpi(key("cumulative_regret"), cell.cumulative.0, Direction::LowerIsBetter);
            let finals: Vec<f64> =
                cell.runs.iter().map(|r| r.sim.inst_regret.final_value()).collect();
            report.push_kpi(key("final_regret"), mean_std(&finals).0, Direction::LowerIsBetter);
            let makespans: Vec<f64> = cell.runs.iter().map(|r| r.sim.makespan).collect();
            report.push_kpi(key("makespan"), mean_std(&makespans).0, Direction::LowerIsBetter);
            report.push_kpi(key("preemptions"), cell.n_preemptions as f64, Direction::LowerIsBetter);
            report.push_kpi(
                key("p99_requeue_latency"),
                cell.p99_requeue_latency,
                Direction::LowerIsBetter,
            );
            report.push_kpi(key("rebuilds"), cell.n_rebuilds as f64, Direction::LowerIsBetter);
            let decisions: u64 = cell.runs.iter().map(|r| r.sim.n_decisions as u64).sum();
            if decisions > 0 {
                let total_ns: f64 =
                    cell.runs.iter().map(|r| r.sim.decision_wall_time.as_nanos() as f64).sum();
                report.push_timing(TimingEntry::flat(
                    key("decision_wall"),
                    decisions,
                    total_ns / decisions as f64,
                ));
            }
        }
    }
}

/// Run the elastic-fleet sweep described by `cfg` (requires
/// `cfg.fleet`): for each (policy × seed), build the dataset instance
/// and the seeded heterogeneous fleet, then replay the availability
/// timeline through the unified engine. Seeds shard across the worker
/// pool exactly like [`run_experiment`]; `cfg.devices` is ignored — the
/// fleet is the device dimension.
///
/// With `cfg.cost_model` enabled, each seed builds the `[cost_model]`
/// per-class cost table against its instance, spreads device classes
/// round-robin over the fleet, and charges devices per-class durations;
/// `mdmt-device` additionally *scores* against the same table.
pub fn run_fleet_experiment(cfg: &ExperimentConfig) -> Result<FleetExperimentResults, String> {
    cfg.validate()?;
    if !cfg.fleet {
        return Err("run_fleet_experiment requires fleet to be enabled (--fleet / [fleet])".into());
    }
    let pool = WorkerPool::new(cfg.effective_threads());
    let policy_pool = WorkerPool::new(1);
    // Surface construction errors (unknown policy, missing XLA artifacts)
    // once, up front, instead of panicking inside the factory closure.
    {
        let (p0, t0) = make_instance(cfg, 0)?;
        let model0 = if cfg.cost_model { Some(cfg.cost_model_cfg.build(&p0)) } else { None };
        for name in &cfg.policies {
            make_policy(name, &p0, &t0, 0, cfg.backend, &policy_pool, as_cost_model(&model0))?;
        }
    }
    let mut cells = Vec::new();
    for policy_name in &cfg.policies {
        let seed_runs = pool.map_indexed(cfg.seeds as usize, |seed| {
            let seed = seed as u64;
            let (problem, truth) = make_instance(cfg, seed)?;
            let mut fleet = fleet_schedule(&cfg.fleet_cfg, 0xF1EE7 + seed);
            let model = if cfg.cost_model {
                fleet = fleet.with_classes(round_robin_classes(
                    fleet.n_devices(),
                    cfg.cost_model_cfg.n_classes(),
                ));
                Some(cfg.cost_model_cfg.build(&problem))
            } else {
                None
            };
            let factory = |p: &Problem| -> Box<dyn Policy> {
                make_policy(policy_name, p, &truth, seed, cfg.backend, &policy_pool, as_cost_model(&model))
                    .expect("policy construction validated above")
            };
            Ok::<FleetResult, String>(simulate_fleet_with_cost_model(
                &problem,
                &truth,
                &fleet,
                &factory,
                &SimConfig {
                    n_devices: fleet.n_devices(),
                    warm_start_per_user: cfg.warm_start,
                    horizon: cfg.horizon,
                    stop_at_cutoff: None,
                },
                as_cost_model(&model),
            ))
        });
        let mut runs = Vec::with_capacity(cfg.seeds as usize);
        for run in seed_runs {
            runs.push(run?);
        }
        cells.push(aggregate_fleet_cell(policy_name, runs));
    }
    Ok(FleetExperimentResults { config: cfg.clone(), cells })
}

/// Aggregated results for one policy of a **fault-injection** sweep
/// (`--faults` / a `[faults]` config section). Like the fleet sweep,
/// cells are keyed by policy only — the device set is fixed per config.
#[derive(Clone, Debug)]
pub struct FaultsCell {
    /// Policy name.
    pub policy: String,
    /// Per-seed raw fault runs.
    pub runs: Vec<FaultResult>,
    /// Mean ± std of cumulative regret over seeds.
    pub cumulative: (f64, f64),
    /// Mean served fraction over seeds (abandoned arms push it below 1).
    pub served_fraction: f64,
    /// Total crashes injected across seeds (plan-determined but gated so
    /// the scenario itself cannot drift).
    pub n_crashes: usize,
    /// Total lost jobs (injected kills + blown deadlines) across seeds.
    pub n_job_failures: usize,
    /// Total deadline kills across seeds (subset of `n_job_failures`).
    pub n_deadline_kills: usize,
    /// Total scheduled retries across seeds.
    pub n_retries: usize,
    /// Total abandoned arms across seeds.
    pub n_abandoned: usize,
    /// p99 of first-failure → successful-completion latency over every
    /// (seed, recovered arm) pair (NaN when nothing failed — dropped by
    /// `push_kpi`).
    pub p99_recovery_latency: f64,
}

/// Full fault-injection sweep output.
#[derive(Clone, Debug)]
pub struct FaultsExperimentResults {
    /// Config used.
    pub config: ExperimentConfig,
    /// One cell per policy, in sweep order.
    pub cells: Vec<FaultsCell>,
}

impl FaultsExperimentResults {
    /// Find a cell.
    pub fn cell(&self, policy: &str) -> Option<&FaultsCell> {
        self.cells.iter().find(|c| c.policy == policy)
    }

    /// Fold this sweep into `report`: config fingerprint + per-policy
    /// fault KPIs (all virtual-time, hence seed-deterministic), and —
    /// outside smoke mode — per-decision scheduler wall time.
    pub fn push_kpis(&self, report: &mut RunReport, prefix: &str) {
        report.fold_config(&self.config.canonical_string());
        let d = self.faults_device_count();
        for cell in &self.cells {
            let key = |metric: &str| format!("{prefix}{}@D{d}/{metric}", cell.policy);
            report.push_kpi(key("cumulative_regret"), cell.cumulative.0, Direction::LowerIsBetter);
            let finals: Vec<f64> =
                cell.runs.iter().map(|r| r.fleet.sim.inst_regret.final_value()).collect();
            report.push_kpi(key("final_regret"), mean_std(&finals).0, Direction::LowerIsBetter);
            let makespans: Vec<f64> = cell.runs.iter().map(|r| r.fleet.sim.makespan).collect();
            report.push_kpi(key("makespan"), mean_std(&makespans).0, Direction::LowerIsBetter);
            report.push_kpi(key("served_fraction"), cell.served_fraction, Direction::HigherIsBetter);
            report.push_kpi(key("crashes"), cell.n_crashes as f64, Direction::LowerIsBetter);
            report.push_kpi(key("job_failures"), cell.n_job_failures as f64, Direction::LowerIsBetter);
            report.push_kpi(
                key("deadline_kills"),
                cell.n_deadline_kills as f64,
                Direction::LowerIsBetter,
            );
            report.push_kpi(key("retries"), cell.n_retries as f64, Direction::LowerIsBetter);
            report.push_kpi(key("abandoned"), cell.n_abandoned as f64, Direction::LowerIsBetter);
            report.push_kpi(
                key("p99_recovery_latency"),
                cell.p99_recovery_latency,
                Direction::LowerIsBetter,
            );
            let decisions: u64 = cell.runs.iter().map(|r| r.fleet.sim.n_decisions as u64).sum();
            if decisions > 0 {
                let total_ns: f64 = cell
                    .runs
                    .iter()
                    .map(|r| r.fleet.sim.decision_wall_time.as_nanos() as f64)
                    .sum();
                report.push_timing(TimingEntry::flat(
                    key("decision_wall"),
                    decisions,
                    total_ns / decisions as f64,
                ));
            }
        }
    }

    /// The device-slot count the sweep ran over (for KPI labels).
    fn faults_device_count(&self) -> usize {
        if self.config.fleet {
            self.config.fleet_cfg.n_devices
        } else {
            self.config.devices.first().copied().unwrap_or(1)
        }
    }
}

/// Run the fault-injection sweep described by `cfg` (requires
/// `cfg.faults`): for each (policy × seed), build the dataset instance,
/// the device set (the seeded `[fleet]` when enabled, else a uniform
/// always-on fleet of `cfg.devices[0]` slots), and a seeded fault plan,
/// then replay everything through the engine's fault layer. Seeds shard
/// across the worker pool exactly like [`run_experiment`].
pub fn run_faults_experiment(cfg: &ExperimentConfig) -> Result<FaultsExperimentResults, String> {
    cfg.validate()?;
    if !cfg.faults {
        return Err(
            "run_faults_experiment requires faults to be enabled (--faults / [faults])".into()
        );
    }
    let pool = WorkerPool::new(cfg.effective_threads());
    let policy_pool = WorkerPool::new(1);
    // Surface construction errors (unknown policy, missing XLA artifacts)
    // once, up front, instead of panicking inside the factory closure.
    {
        let (p0, t0) = make_instance(cfg, 0)?;
        for name in &cfg.policies {
            make_policy(name, &p0, &t0, 0, cfg.backend, &policy_pool, None)?;
        }
    }
    let mut cells = Vec::new();
    for policy_name in &cfg.policies {
        let seed_runs = pool.map_indexed(cfg.seeds as usize, |seed| {
            let seed = seed as u64;
            let (problem, truth) = make_instance(cfg, seed)?;
            let fleet = if cfg.fleet {
                fleet_schedule(&cfg.fleet_cfg, 0xF1EE7 + seed)
            } else {
                DeviceFleet::uniform(cfg.devices.first().copied().unwrap_or(1))
            };
            let plan = fault_plan(&cfg.faults_cfg, fleet.n_devices(), 0xFA17 + seed);
            let factory = |p: &Problem| -> Box<dyn Policy> {
                make_policy(policy_name, p, &truth, seed, cfg.backend, &policy_pool, None)
                    .expect("policy construction validated above")
            };
            Ok::<FaultResult, String>(simulate_faults(
                &problem,
                &truth,
                &fleet,
                &plan,
                &factory,
                &SimConfig {
                    n_devices: fleet.n_devices(),
                    warm_start_per_user: cfg.warm_start,
                    horizon: cfg.horizon,
                    stop_at_cutoff: None,
                },
            ))
        });
        let mut runs = Vec::with_capacity(cfg.seeds as usize);
        for run in seed_runs {
            runs.push(run?);
        }
        cells.push(aggregate_faults_cell(policy_name, runs));
    }
    Ok(FaultsExperimentResults { config: cfg.clone(), cells })
}

/// Aggregate per-seed fault runs into a cell.
pub fn aggregate_faults_cell(policy: &str, runs: Vec<FaultResult>) -> FaultsCell {
    let cumulative =
        mean_std(&runs.iter().map(|r| r.fleet.sim.cumulative_regret).collect::<Vec<_>>());
    let served_fraction =
        mean_std(&runs.iter().map(|r| r.served_fraction).collect::<Vec<_>>()).0;
    let n_crashes = runs.iter().map(|r| r.fault_stats.n_crashes).sum();
    let n_job_failures = runs.iter().map(|r| r.fault_stats.n_job_failures).sum();
    let n_deadline_kills = runs.iter().map(|r| r.fault_stats.n_deadline_kills).sum();
    let n_retries = runs.iter().map(|r| r.fault_stats.n_retries).sum();
    let n_abandoned = runs.iter().map(|r| r.fault_stats.n_abandoned).sum();
    // NaN when nothing ever failed — dropped by push_kpi.
    let p99_recovery_latency =
        p99(runs.iter().flat_map(|r| r.fault_stats.recovery_latency.iter().copied()).collect());
    FaultsCell {
        policy: policy.to_string(),
        runs,
        cumulative,
        served_fraction,
        n_crashes,
        n_job_failures,
        n_deadline_kills,
        n_retries,
        n_abandoned,
        p99_recovery_latency,
    }
}

/// Aggregate per-seed fleet runs into a cell.
pub fn aggregate_fleet_cell(policy: &str, runs: Vec<FleetResult>) -> FleetCell {
    let cumulative =
        mean_std(&runs.iter().map(|r| r.sim.cumulative_regret).collect::<Vec<_>>());
    let n_preemptions = runs.iter().map(|r| r.n_preemptions).sum();
    // NaN when nothing was requeued — dropped by push_kpi.
    let p99_requeue_latency =
        p99(runs.iter().flat_map(|r| r.requeue_latency.iter().copied()).collect());
    let n_rebuilds = runs.iter().map(|r| r.n_rebuilds).sum();
    FleetCell {
        policy: policy.to_string(),
        runs,
        cumulative,
        n_preemptions,
        p99_requeue_latency,
        n_rebuilds,
    }
}

/// Aggregate per-seed churn runs into a cell.
pub fn aggregate_churn_cell(policy: &str, devices: usize, runs: Vec<ChurnResult>) -> ChurnCell {
    let cumulative = mean_std(&runs.iter().map(|r| r.cumulative_regret).collect::<Vec<_>>());
    let per_tenant: Vec<f64> =
        runs.iter().flat_map(|r| r.per_user_regret.iter().copied()).collect();
    let mean_exit_regret = if per_tenant.is_empty() { 0.0 } else { mean_std(&per_tenant).0 };
    let latencies: Vec<f64> =
        runs.iter().flat_map(|r| r.join_latency.iter().flatten().copied()).collect();
    let n_served = latencies.len();
    // NaN when nobody was served — dropped by push_kpi.
    let p99_join_latency = p99(latencies);
    let tenant_slots: usize = runs.iter().map(|r| r.join_latency.len()).sum();
    let served_fraction =
        if tenant_slots == 0 { 0.0 } else { n_served as f64 / tenant_slots as f64 };
    let n_rebuilds = runs.iter().map(|r| r.n_rebuilds).sum();
    ChurnCell {
        policy: policy.to_string(),
        devices,
        runs,
        cumulative,
        mean_exit_regret,
        p99_join_latency,
        served_fraction,
        n_rebuilds,
    }
}

/// Aggregate per-seed runs into a cell.
pub fn aggregate_cell(
    policy: &str,
    devices: usize,
    runs: Vec<SimResult>,
    cutoff: f64,
) -> CellResult {
    let cumulative = mean_std(&runs.iter().map(|r| r.cumulative_regret).collect::<Vec<_>>());
    let hit_times: Vec<f64> = runs.iter().filter_map(|r| r.time_to(cutoff)).collect();
    let time_to_cutoff =
        if hit_times.len() == runs.len() { Some(mean_std(&hit_times)) } else { None };
    let t_end = runs.iter().map(|r| r.makespan).fold(0.0f64, f64::max).max(1e-9);
    let curves: Vec<StepCurve> = runs.iter().map(|r| r.inst_regret.clone()).collect();
    let curve = aggregate_curves(&curves, &time_grid(t_end, 120));
    CellResult {
        policy: policy.to_string(),
        devices,
        runs,
        cumulative,
        time_to_cutoff,
        curve,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig {
            dataset: "azure".into(),
            policies: vec!["mdmt".into(), "round-robin".into()],
            devices: vec![1, 2],
            seeds: 2,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_produces_all_cells() {
        let res = run_experiment(&quick_cfg()).unwrap();
        assert_eq!(res.cells.len(), 4);
        assert!(res.cell("mdmt", 1).is_some());
        assert!(res.cell("round-robin", 2).is_some());
        assert!(res.cell("oracle", 1).is_none());
        for cell in &res.cells {
            assert_eq!(cell.runs.len(), 2);
            assert!(cell.cumulative.0 > 0.0);
            assert!(!cell.curve.is_empty());
        }
    }

    #[test]
    fn policy_vocabulary() {
        let cfg = quick_cfg();
        let (p, t) = make_instance(&cfg, 0).unwrap();
        for name in [
            "mdmt",
            "mdmt-device",
            "mdmt-nocost",
            "mdmt-indep",
            "mdmt-fantasy",
            "ucb-mdmt",
            "ucb-round-robin",
            "round-robin",
            "random",
            "oracle",
        ] {
            let pol =
                make_policy(name, &p, &t, 0, Backend::Native, &WorkerPool::new(1), None).unwrap();
            assert!(!pol.name().is_empty());
        }
        assert!(make_policy("ucb", &p, &t, 0, Backend::Native, &WorkerPool::new(1), None).is_err());
        // mdmt-device picks up a cost model when one is supplied.
        let model = PerClassCost::from_problem(&p, vec![1.0, 2.0], vec![f64::INFINITY, f64::INFINITY]);
        let pol = make_policy("mdmt-device", &p, &t, 0, Backend::Native, &WorkerPool::new(1), Some(&model))
            .unwrap();
        assert_eq!(pol.name(), "GP-EI-MDMT[device]");
    }

    #[test]
    fn instances_deterministic_per_seed() {
        let cfg = quick_cfg();
        let (p1, t1) = make_instance(&cfg, 3).unwrap();
        let (p2, t2) = make_instance(&cfg, 3).unwrap();
        assert_eq!(t1.z, t2.z);
        assert_eq!(p1.cost, p2.cost);
        let (_, t3) = make_instance(&cfg, 4).unwrap();
        assert_ne!(t1.z, t3.z);
    }

    #[test]
    fn push_kpis_covers_every_cell_and_respects_smoke() {
        let cfg = quick_cfg();
        let res = run_experiment(&cfg).unwrap();
        let mut smoke = RunReport::new("test", 0, true);
        res.push_kpis(&mut smoke, "azure/", &[1e9]);
        // 4 cells × (cumulative, final, makespan, t_le_1000000000 — the
        // huge cutoff is hit at t=0 by every run).
        assert_eq!(smoke.kpis.len(), 16);
        assert!(smoke.kpis.iter().all(|k| k.name.starts_with("azure/")));
        assert!(smoke.kpis.iter().any(|k| k.name == "azure/mdmt@M1/cumulative_regret"));
        assert!(smoke.timings.is_empty(), "smoke reports must exclude wall-clock timings");
        assert_ne!(smoke.provenance.config_hash, format!("{:016x}", crate::report::fnv1a64(b"")));
        let mut full = RunReport::new("test", 0, false);
        res.push_kpis(&mut full, "azure/", &[]);
        assert_eq!(full.timings.len(), 4, "one decision_wall timing per cell");
    }

    #[test]
    fn churn_sweep_produces_cells_and_kpis() {
        let mut cfg = quick_cfg();
        cfg.churn = true;
        cfg.churn_cfg = crate::workload::ChurnConfig {
            n_users: 6,
            n_models: 4,
            initial_users: 2,
            ..Default::default()
        };
        cfg.policies = vec!["mdmt".into(), "round-robin".into()];
        cfg.devices = vec![2];
        cfg.seeds = 2;
        let res = run_churn_experiment(&cfg).unwrap();
        assert_eq!(res.cells.len(), 2);
        let mdmt = res.cell("mdmt", 2).unwrap();
        assert_eq!(mdmt.runs.len(), 2);
        assert_eq!(mdmt.n_rebuilds, 0, "mdmt serves churn in place");
        let rr = res.cell("round-robin", 2).unwrap();
        assert!(rr.n_rebuilds > 0, "baselines churn through the rebuild path");
        assert!(mdmt.served_fraction > 0.0 && mdmt.served_fraction <= 1.0);
        let mut report = RunReport::new("churn-test", 0, true);
        res.push_kpis(&mut report, "churn/");
        assert!(report.kpis.iter().any(|k| k.name == "churn/mdmt@M2/mean_exit_regret"));
        assert!(report.kpis.iter().any(|k| k.name == "churn/round-robin@M2/p99_join_latency"));
        assert!(report.timings.is_empty(), "smoke reports exclude wall-clock timings");
        // Churn-disabled configs must refuse the churn driver.
        assert!(run_churn_experiment(&quick_cfg()).is_err());
    }

    #[test]
    fn sharded_structure_runs_synthetic_and_churn_sweeps() {
        // Synthetic sweep (ρ = 0): the sharded store is bitwise the dense
        // oracle, so the whole sweep's aggregates must match to the bit.
        let mut cfg = quick_cfg();
        cfg.dataset = "synthetic".into();
        cfg.synthetic.n_users = 4;
        cfg.synthetic.n_models = 3;
        cfg.policies = vec!["mdmt".into()];
        cfg.devices = vec![2];
        cfg.seeds = 2;
        let dense = run_experiment(&cfg).unwrap();
        cfg.gp_structure = GpStructure::Sharded;
        let sharded = run_experiment(&cfg).unwrap();
        let (d, s) = (dense.cell("mdmt", 2).unwrap(), sharded.cell("mdmt", 2).unwrap());
        assert_eq!(d.cumulative.0.to_bits(), s.cumulative.0.to_bits(), "ρ = 0 sharded ≠ dense");
        for (dr, sr) in d.runs.iter().zip(&s.runs) {
            assert_eq!(dr.n_decisions, sr.n_decisions);
            assert_eq!(dr.makespan.to_bits(), sr.makespan.to_bits());
        }
        // The sharded mdmt policy advertises its backend in its label.
        let (p, t) = make_instance(&cfg, 0).unwrap();
        let prior = sharded_prior_for(&cfg, &p).unwrap();
        let pol = make_sharded_policy("mdmt", &p, &t, 0, &WorkerPool::new(1), &prior).unwrap();
        assert_eq!(pol.name(), "GP-EI-MDMT[sharded]");
        let rr = make_sharded_policy("round-robin", &p, &t, 0, &WorkerPool::new(1), &prior).unwrap();
        assert!(!rr.name().is_empty(), "baselines delegate to the dense factory");
        // Churn sweep (ρ > 0): the sharded store serves arrivals and
        // departures in place — no driver-side rebuilds.
        let mut cfg = quick_cfg();
        cfg.churn = true;
        cfg.churn_cfg = crate::workload::ChurnConfig {
            n_users: 6,
            n_models: 4,
            initial_users: 2,
            ..Default::default()
        };
        cfg.policies = vec!["mdmt".into()];
        cfg.devices = vec![2];
        cfg.seeds = 1;
        cfg.gp_structure = GpStructure::Sharded;
        let res = run_churn_experiment(&cfg).unwrap();
        let mdmt = res.cell("mdmt", 2).unwrap();
        assert_eq!(mdmt.n_rebuilds, 0, "sharded mdmt serves churn in place");
        assert!(mdmt.served_fraction > 0.0);
        // Real datasets have no Kronecker factorization to shard.
        assert!(sharded_prior_for(&quick_cfg(), &p).is_err());
    }

    #[test]
    fn fleet_sweep_produces_cells_and_kpis() {
        let mut cfg = quick_cfg();
        cfg.fleet = true;
        cfg.fleet_cfg = crate::workload::FleetConfig {
            n_devices: 3,
            initial_online: 2,
            arrival_gap: 4.0,
            uptime: (8.0, 20.0),
            outage: (2.0, 6.0),
            horizon: 60.0,
            ..Default::default()
        };
        cfg.policies = vec!["mdmt".into(), "round-robin".into()];
        cfg.seeds = 2;
        let res = run_fleet_experiment(&cfg).unwrap();
        assert_eq!(res.cells.len(), 2);
        let mdmt = res.cell("mdmt").unwrap();
        assert_eq!(mdmt.runs.len(), 2);
        assert_eq!(mdmt.n_rebuilds, 0, "mdmt applies device churn in place");
        assert!(mdmt.cumulative.0 >= 0.0);
        let mut report = RunReport::new("fleet-test", 0, true);
        res.push_kpis(&mut report, "fleet/");
        assert!(report.kpis.iter().any(|k| k.name == "fleet/mdmt@F3/cumulative_regret"));
        assert!(report.kpis.iter().any(|k| k.name == "fleet/round-robin@F3/preemptions"));
        assert!(report.timings.is_empty(), "smoke reports exclude wall-clock timings");
        // Fleet-disabled configs must refuse the fleet driver.
        assert!(run_fleet_experiment(&quick_cfg()).is_err());
    }

    #[test]
    fn faults_sweep_produces_cells_and_kpis() {
        let mut cfg = quick_cfg();
        cfg.fleet = true;
        cfg.fleet_cfg = crate::workload::FleetConfig {
            n_devices: 3,
            initial_online: 3,
            arrival_gap: 4.0,
            uptime: (40.0, 80.0),
            outage: (2.0, 6.0),
            horizon: 100.0,
            ..Default::default()
        };
        cfg.faults = true;
        cfg.faults_cfg = crate::workload::FaultsConfig {
            mtbf: 15.0,
            mean_downtime: 3.0,
            job_failure_gap: 8.0,
            straggler_gap: 10.0,
            horizon: 100.0,
            ..Default::default()
        };
        cfg.policies = vec!["mdmt".into(), "round-robin".into()];
        cfg.seeds = 2;
        let res = run_faults_experiment(&cfg).unwrap();
        assert_eq!(res.cells.len(), 2);
        let mdmt = res.cell("mdmt").unwrap();
        assert_eq!(mdmt.runs.len(), 2);
        assert!(mdmt.cumulative.0 >= 0.0);
        assert!(mdmt.served_fraction > 0.0 && mdmt.served_fraction <= 1.0);
        assert!(
            mdmt.n_crashes + mdmt.n_job_failures > 0,
            "gaps well under the horizon must inject faults"
        );
        let mut report = RunReport::new("faults-test", 0, true);
        res.push_kpis(&mut report, "faults/");
        assert!(report.kpis.iter().any(|k| k.name == "faults/mdmt@D3/cumulative_regret"));
        assert!(report.kpis.iter().any(|k| k.name == "faults/round-robin@D3/served_fraction"));
        assert!(report.timings.is_empty(), "smoke reports exclude wall-clock timings");
        // Faults-disabled configs must refuse the faults driver.
        assert!(run_faults_experiment(&quick_cfg()).is_err());
    }

    #[test]
    fn cost_model_fleet_sweep_runs_device_aware_policy() {
        let mut cfg = quick_cfg();
        cfg.fleet = true;
        cfg.fleet_cfg = crate::workload::FleetConfig {
            n_devices: 3,
            initial_online: 3,
            arrival_gap: 4.0,
            uptime: (8.0, 20.0),
            outage: (2.0, 6.0),
            horizon: 60.0,
            ..Default::default()
        };
        cfg.cost_model = true;
        cfg.cost_model_cfg =
            crate::config::CostModelConfig { multipliers: vec![1.0, 2.0], mem_limit: Vec::new() };
        cfg.policies = vec!["mdmt-device".into(), "mdmt".into()];
        cfg.seeds = 2;
        let res = run_fleet_experiment(&cfg).unwrap();
        let dev = res.cell("mdmt-device").unwrap();
        assert_eq!(dev.runs.len(), 2);
        assert!(dev.cumulative.0 >= 0.0);
        assert_eq!(dev.n_rebuilds, 0, "mdmt-device applies device churn in place");
        // The device-blind cell runs on the very same classed fleet with
        // the same per-class true costs — only its *scores* are blind.
        assert!(res.cell("mdmt").is_some());
    }

    #[test]
    fn synthetic_instance_uses_config() {
        let mut cfg = quick_cfg();
        cfg.dataset = "synthetic".into();
        cfg.synthetic.n_users = 4;
        cfg.synthetic.n_models = 5;
        let (p, t) = make_instance(&cfg, 0).unwrap();
        assert_eq!(p.n_users, 4);
        assert_eq!(t.z.len(), 20);
    }
}
